"""Bit-plane decomposition invariants (Eq. 1 of the paper)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bitplane as bp
from compile.kernels import ref, spec as S


def rand_aw(rng, m=16, c=S.COLS, h=S.HMUS):
    a = rng.integers(0, 256, (m, c), dtype=np.int32)
    w = rng.integers(-128, 128, (h, c), dtype=np.int32)
    return a, w


def test_weight_plane_recompose_roundtrip():
    w = np.arange(-128, 128, dtype=np.int32).reshape(16, 16)
    planes = bp.weight_planes(jnp.asarray(w))
    back = bp.recompose_weights(planes)
    np.testing.assert_array_equal(np.asarray(back), w)


def test_act_plane_recompose_roundtrip():
    a = np.arange(0, 256, dtype=np.int32).reshape(16, 16)
    planes = bp.act_planes(jnp.asarray(a))
    back = bp.recompose_acts(planes)
    np.testing.assert_array_equal(np.asarray(back), a)


def test_planes_are_binary():
    rng = np.random.default_rng(0)
    a, w = rand_aw(rng)
    for p in bp.act_planes(jnp.asarray(a)) + bp.weight_planes(jnp.asarray(w)):
        arr = np.asarray(p)
        assert set(np.unique(arr)).issubset({0, 1})


def test_plane_sign():
    assert bp.plane_sign(7) == -1
    assert all(bp.plane_sign(i) == 1 for i in range(7))
    assert bp.plane_sign(3, w_bits=4) == -1


def test_eq1_decomposition_equals_exact_mac():
    """sum_{i,j} s_i 2^(i+j) D[i,j] == integer dot product (paper Eq. 1)."""
    rng = np.random.default_rng(1)
    a, w = rand_aw(rng)
    d = bp.order_partials(jnp.asarray(a), jnp.asarray(w))
    acc = np.zeros((a.shape[0], w.shape[0]), np.int64)
    for i in range(S.W_BITS):
        for j in range(S.A_BITS):
            acc += bp.plane_sign(i) * (np.asarray(d[i, j], np.int64) << (i + j))
    np.testing.assert_array_equal(acc, np.asarray(ref.exact_mac(a, w), np.int64))


def test_partial_range():
    rng = np.random.default_rng(2)
    a, w = rand_aw(rng)
    d = np.asarray(bp.order_partials(jnp.asarray(a), jnp.asarray(w)))
    assert d.min() >= 0 and d.max() <= S.COLS


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2**32 - 1), st.integers(1, 8), st.integers(1, 8))
def test_eq1_small_shapes_hypothesis(seed, m, c):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, (m, c), dtype=np.int32)
    w = rng.integers(-128, 128, (4, c), dtype=np.int32)
    d = bp.order_partials(jnp.asarray(a), jnp.asarray(w))
    acc = np.zeros((m, 4), np.int64)
    for i in range(S.W_BITS):
        for j in range(S.A_BITS):
            acc += bp.plane_sign(i) * (np.asarray(d[i, j], np.int64) << (i + j))
    np.testing.assert_array_equal(acc, np.asarray(ref.exact_mac(a, w), np.int64))
