"""Artifact integrity — runs only after `make artifacts` has produced them.

These close the L1/L2 loop: the HLO text artifacts the Rust runtime loads
must (a) exist, (b) parse as HLO text with the expected entry signature,
and (c) the spec.json constants must equal kernels/spec.py.
"""

import json
import os

import numpy as np
import pytest

from compile import rten
from compile.kernels import spec as S

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "spec.json")),
    reason="artifacts not built (run `make artifacts`)",
)


def _read(name):
    with open(os.path.join(ART, name)) as f:
        return f.read()


def test_spec_json_matches_module():
    doc = json.loads(_read("spec.json"))
    expect = S.as_dict()
    for k, v in expect.items():
        assert doc[k] == v, f"spec.json[{k}] = {doc[k]} != {v}"


def test_prng_golden_vectors_present():
    doc = json.loads(_read("spec.json"))
    gv = doc["prng_golden"]
    from compile.prng import SplitMix64
    g = SplitMix64(int(gv["seed_hex"], 16))
    assert [f"{g.next_u64():016x}" for _ in range(len(gv["u64_hex"]))] == gv["u64_hex"]


def test_hlo_artifacts_exist_and_look_like_hlo():
    for name, inputs in [
        ("model.hlo.txt", 1),
        ("se_tile.hlo.txt", 2),
        ("hybrid_tile.hlo.txt", 4),
        ("acim_tile.hlo.txt", 3),
    ]:
        text = _read(name)
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name


def test_dataset_rten():
    d = rten.read(os.path.join(ART, "dataset.rten"))
    assert d["train_x"].dtype == np.uint8
    assert d["train_x"].shape[1:] == (32, 32, 3)
    assert d["test_x"].shape[0] == d["test_y"].shape[0]


def test_weights_rten_and_graph():
    w = rten.read(os.path.join(ART, "weights.rten"))
    g = json.loads(_read("graph.json"))
    for c in g["convs"]:
        assert f"{c['name']}.w_q" in w
        assert w[f"{c['name']}.w_q"].shape == (c["cout"], c["kh"] * c["kw"] * c["cin"])
    assert "fc.w_q" in w


def test_golden_logits_sane():
    g = rten.read(os.path.join(ART, "golden.rten"))
    n = int(g["golden_n"][0])
    assert g["float_logits"].shape[1] == 10
    assert g["dcim_logits"].shape == (n, 10)
    labels = g["labels"]
    acc = (g["float_logits"].argmax(1) == labels).mean()
    assert acc == pytest.approx(float(g["float_acc"][0]), abs=1e-3)
    assert acc > 0.6, f"float model underfit: acc={acc}"
    # quantized DCIM should agree with float predictions on most images
    agree = (g["dcim_logits"].argmax(1) == g["float_logits"][:n].argmax(1)).mean()
    assert agree > 0.8, f"quantization broke the model: agree={agree}"


def test_quant_forward_matches_golden_dcim():
    """Recompute a few DCIM logits from weights.rten — pipeline closure."""
    import jax.numpy as jnp
    from compile import model as M, quantize
    g = rten.read(os.path.join(ART, "golden.rten"))
    d = rten.read(os.path.join(ART, "dataset.rten"))
    w = rten.read(os.path.join(ART, "weights.rten"))
    graph = json.loads(_read("graph.json"))
    qgraph = quantize.load_qgraph(w, graph)
    x = jnp.asarray(d["test_x"][:8], jnp.float32) / 255.0
    logits, _ = M.quant_forward(qgraph, x, M.MacroGemm("dcim"))
    np.testing.assert_allclose(np.asarray(logits), g["dcim_logits"][:8], rtol=1e-5)
