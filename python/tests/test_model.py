"""L2 model: shapes, BN folding, quantized forward, MacroGemm modes."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import dataset, model as M, quantize
from compile.kernels import spec as S


@pytest.fixture(scope="module")
def tiny_setup():
    """Untrained net + tiny data — enough for structural/numeric checks."""
    data = dataset.build(train_n=64, test_n=16, seed=11)
    params, state = M.init_params(seed=3)
    qgraph = quantize.quantize(params, state, data["train_x"][:32])
    x = jnp.asarray(data["test_x"][:8], jnp.float32) / 255.0
    return data, params, state, qgraph, x


def test_param_count_resnet_mini():
    params, _ = M.init_params()
    n = M.count_params(params)
    assert 150_000 < n < 400_000, n


def test_forward_shapes(tiny_setup):
    _, params, state, _, x = tiny_setup
    logits, new_state = M.forward(params, state, x, train=True)
    assert logits.shape == (8, M.NUM_CLASSES)
    logits_e = M.forward_eval(params, state, x)
    assert logits_e.shape == (8, M.NUM_CLASSES)


def test_bn_fold_matches_eval(tiny_setup):
    _, params, state, _, x = tiny_setup
    convs = M.fold_bn(params, state)
    l1 = M.forward_eval(params, state, x)
    l2 = M.folded_forward(convs, np.asarray(params["fc"]["w"]),
                          np.asarray(params["fc"]["b"]), x)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=2e-4)


def test_im2col_matches_conv(tiny_setup):
    _, params, state, _, x = tiny_setup
    w = np.asarray(params["stem"]["w"])  # [3,3,3,16]
    direct = M._conv2d(x, jnp.asarray(w), 1)
    patches = M.im2col(x, 3, 3, 1, 1)
    w_mat = w.transpose(3, 0, 1, 2).reshape(16, -1)
    via = jnp.einsum("nhwk,ck->nhwc", patches, jnp.asarray(w_mat))
    np.testing.assert_allclose(np.asarray(direct), np.asarray(via), atol=1e-4)


def test_im2col_stride2():
    x = jnp.arange(2 * 8 * 8 * 4, dtype=jnp.float32).reshape(2, 8, 8, 4)
    p = M.im2col(x, 3, 3, 2, 1)
    assert p.shape == (2, 4, 4, 36)


def test_act_quantize_clamps_and_relu():
    x = jnp.asarray([-1.0, 0.0, 0.5, 300.0])
    q = np.asarray(M.act_quantize(x, 1.0))
    np.testing.assert_array_equal(q, [0, 0, 1, 255])


def test_quant_round_half_up():
    x = jnp.asarray([-1.5, -0.5, 0.5, 1.5, 2.49])
    np.testing.assert_array_equal(np.asarray(M.quant_round(x)), [-1, 0, 1, 2, 2])


def test_dcim_forward_runs(tiny_setup):
    _, _, _, qgraph, x = tiny_setup
    gemm = M.MacroGemm("dcim")
    logits, _ = M.quant_forward(qgraph, x, gemm)
    assert logits.shape == (8, M.NUM_CLASSES)
    assert np.isfinite(np.asarray(logits)).all()
    assert gemm.stats["macro_ops"] > 0


def test_hcim_close_to_dcim_at_b5(tiny_setup):
    """B=5 keeps high orders digital: logits should track DCIM closely."""
    _, _, _, qgraph, x = tiny_setup
    l_d, _ = M.quant_forward(qgraph, x, M.MacroGemm("dcim"))
    l_h, _ = M.quant_forward(qgraph, x, M.MacroGemm("hcim", fixed_b=5))
    d, h = np.asarray(l_d), np.asarray(l_h)
    denom = np.abs(d).mean() + 1e-9
    assert np.abs(d - h).mean() / denom < 0.35


def test_hcim_error_grows_with_b(tiny_setup):
    _, _, _, qgraph, x = tiny_setup
    l_d = np.asarray(M.quant_forward(qgraph, x, M.MacroGemm("dcim"))[0])
    errs = []
    for b in (5, 8, 10):
        l_h = np.asarray(M.quant_forward(qgraph, x, M.MacroGemm("hcim", fixed_b=b))[0])
        errs.append(np.abs(l_d - l_h).mean())
    assert errs[0] < errs[-1]


def test_osa_forward_and_bda_maps(tiny_setup):
    _, _, _, qgraph, x = tiny_setup
    thresholds = [40, 80, 160, 320, 640]
    gemm = M.MacroGemm("osa", thresholds=thresholds)
    logits, maps = M.quant_forward(qgraph, x, gemm, collect_bda=True)
    assert logits.shape == (8, M.NUM_CLASSES)
    assert len(maps) == len(qgraph["convs"])
    name, m0 = maps[0]
    assert name == "stem" and m0.shape == (8, 32, 32)
    assert set(np.unique(m0)).issubset(set(S.B_CANDIDATES))
    assert gemm.stats["b_hist"].sum() > 0


def test_acim_forward_runs(tiny_setup):
    _, _, _, qgraph, x = tiny_setup
    logits, _ = M.quant_forward(qgraph, x, M.MacroGemm("acim"))
    assert np.isfinite(np.asarray(logits)).all()


def test_macrogemm_pads_arbitrary_k():
    rng = np.random.default_rng(0)
    a = rng.integers(0, 256, (16, 200), dtype=np.int32)  # K=200 -> 2 tiles
    w = rng.integers(-128, 128, (12, 200), dtype=np.int32)  # N=12 -> 2 tiles
    out = M.MacroGemm("dcim")(jnp.asarray(a), jnp.asarray(w), 0)
    expect = a.astype(np.int64) @ w.T.astype(np.int64)
    np.testing.assert_array_equal(np.asarray(out, np.int64), expect)


def test_macrogemm_hcim_zero_noise_matches_tiled_ref():
    from compile.kernels import ref
    sp0 = S.MacroSpec(sigma_code=0.0)
    rng = np.random.default_rng(1)
    a = rng.integers(0, 256, (8, S.COLS * 2), dtype=np.int32)
    w = rng.integers(-128, 128, (S.HMUS, S.COLS * 2), dtype=np.int32)
    gemm = M.MacroGemm("hcim", fixed_b=8, sp=sp0)
    out = np.asarray(gemm(jnp.asarray(a), jnp.asarray(w), 0))
    z = np.zeros((8, S.HMUS, S.W_BITS), np.float32)
    b = np.full(8, 8, np.int32)
    expect = np.zeros((8, S.HMUS), np.int32)
    for ki in range(2):
        expect += np.asarray(ref.hybrid_mac_ref(
            a[:, ki * S.COLS:(ki + 1) * S.COLS],
            w[:, ki * S.COLS:(ki + 1) * S.COLS], b, z, sp0))
    np.testing.assert_array_equal(out, expect)
