"""SplitMix64 / Box-Muller parity primitives (see rust util::prng tests)."""

import math

import numpy as np
import pytest

from compile.prng import (
    GOLDEN,
    MASK64,
    SplitMix64,
    golden_vectors,
    layer_noise_seed,
    unit_noise_seed,
)


def test_splitmix_reference_vector():
    # Reference outputs for seed 0 (cross-checked against the canonical
    # C implementation by Vigna).
    g = SplitMix64(0)
    assert g.next_u64() == 0xE220A8397B1DCDAF
    assert g.next_u64() == 0x6E789E6AA1B965F4
    assert g.next_u64() == 0x06C45D188009454F


def test_u64_range_and_determinism():
    g1, g2 = SplitMix64(123), SplitMix64(123)
    v1 = [g1.next_u64() for _ in range(100)]
    v2 = [g2.next_u64() for _ in range(100)]
    assert v1 == v2
    assert all(0 <= v <= MASK64 for v in v1)


def test_f64_in_unit_interval():
    g = SplitMix64(7)
    for _ in range(1000):
        u = g.next_f64()
        assert 0.0 <= u < 1.0


def test_normals_moments():
    g = SplitMix64(42)
    xs = np.asarray(g.normals(20000))
    assert abs(xs.mean()) < 0.03
    assert abs(xs.std() - 1.0) < 0.03


def test_normal_consumes_two_u64():
    g1 = SplitMix64(9)
    g1.next_normal()
    g2 = SplitMix64(9)
    g2.next_u64(); g2.next_u64()
    assert g1.state == g2.state


def test_layer_noise_seed_distinct():
    seeds = {layer_noise_seed(1, i) for i in range(32)}
    assert len(seeds) == 32
    assert layer_noise_seed(1, 0) == (1 ^ GOLDEN) & MASK64


def test_unit_noise_seed_golden_and_distinct():
    # golden vectors asserted on the Rust side too (util::prng tests):
    # the per-work-unit convention must agree bit-exactly cross-language
    assert unit_noise_seed(0, 0, 0, 0) == 0xA95E878202EA98D0
    assert unit_noise_seed(0xC1A02024, 3, 17, 2) == 0x219A57539A5E311A
    assert unit_noise_seed(1, 0, 1, 0) == 0x852EF111CD105E34
    assert unit_noise_seed(1, 0, 0, 1) == 0x3CB65FF36326AD46
    seeds = {
        unit_noise_seed(1, layer, row, tile)
        for layer in range(2)
        for row in range(32)
        for tile in range(4)
    }
    assert len(seeds) == 2 * 32 * 4


def test_golden_vectors_shape():
    gv = golden_vectors(n=16)
    assert len(gv["u64_hex"]) == 16 and len(gv["normal"]) == 16
    g = SplitMix64(int(gv["seed_hex"], 16))
    assert g.next_u64() == int(gv["u64_hex"][0], 16)


def test_normals_pairwise_consumption():
    """normals(n) consumes ceil(n/2)*2 u64s (both Box-Muller branches)."""
    g1 = SplitMix64(3)
    g1.normals(5)
    g2 = SplitMix64(3)
    for _ in range(6):
        g2.next_u64()
    assert g1.state == g2.state
    # first element of normals == next_normal (cos branch)
    ga, gb = SplitMix64(9), SplitMix64(9)
    assert ga.normals(1)[0] == gb.next_normal()
