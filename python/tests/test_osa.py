"""OSA scheme invariants: saliency normalization, boundary selection,
and the saliency/magnitude correlation that the whole paper rests on."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, spec as S


def test_normalize_saliency_identity_at_full_k():
    s = np.asarray([0, 10, 100], np.int64)
    out = S.normalize_saliency(s, S.COLS)
    np.testing.assert_array_equal(out, s)


def test_normalize_saliency_scales_small_k():
    # stem layer: K=27 -> scale by 144/27
    out = S.normalize_saliency(np.asarray([27]), 27)
    assert out[0] == 144
    # multi-tile layer: K=576 -> scale by 1/4
    out = S.normalize_saliency(np.asarray([400]), 576)
    assert out[0] == 100


def test_normalize_saliency_zero_k_safe():
    assert S.normalize_saliency(np.asarray([5]), 0)[0] == 5 * S.COLS  # max(k,1)


@settings(max_examples=50, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 2000))
def test_normalize_monotone_in_s(s, k):
    a = int(S.normalize_saliency(np.asarray([s]), k)[0])
    b = int(S.normalize_saliency(np.asarray([s + 1]), k)[0])
    assert b >= a


def test_boundary_count_matches_candidates():
    t = jnp.asarray([1, 2, 3, 4, 5])
    cand = jnp.asarray(S.B_CANDIDATES)
    s = jnp.arange(0, 8)
    out = np.asarray(ref.select_boundary(s, t, cand))
    # s=0 -> coarsest; s>=5 -> finest
    assert out[0] == S.B_CANDIDATES[0]
    assert out[-1] == S.B_CANDIDATES[-1]
    assert all(b in S.B_CANDIDATES for b in out)


def test_saliency_separates_object_from_background():
    """End-to-end premise: a bright-object tile must out-score a muted
    background tile through the SE-mode pipeline."""
    rng = np.random.default_rng(3)
    w = rng.integers(-128, 128, (S.HMUS, S.COLS), dtype=np.int32)
    obj = rng.integers(150, 256, (8, S.COLS), dtype=np.int32)
    bg = rng.integers(20, 120, (8, S.COLS), dtype=np.int32)
    s_obj = np.asarray(ref.saliency_ref(obj, w)).mean()
    s_bg = np.asarray(ref.saliency_ref(bg, w)).mean()
    assert s_obj > 2 * s_bg, (s_obj, s_bg)


def test_se_orders_cover_only_top_s():
    """SE mode uses exactly the s=2 highest orders (k in {13, 14})."""
    pairs = sorted(
        (i, j)
        for i in range(S.W_BITS)
        for j in range(S.A_BITS)
        if i + j >= S.SE_K_MIN
    )
    assert pairs == [(6, 7), (7, 6), (7, 7)]


def test_saliency_zero_for_low_activations():
    """Activations without high-order bits produce S == 0."""
    rng = np.random.default_rng(4)
    w = rng.integers(-128, 128, (S.HMUS, S.COLS), dtype=np.int32)
    a = rng.integers(0, 32, (4, S.COLS), dtype=np.int32)  # bits 0-4 only
    np.testing.assert_array_equal(np.asarray(ref.saliency_ref(a, w)), 0)
