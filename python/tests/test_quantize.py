"""Quantizer: scales, layouts, graph.json schema, rten round-trip."""

import json

import numpy as np
import pytest

from compile import dataset, model as M, quantize, rten


@pytest.fixture(scope="module")
def qsetup():
    data = dataset.build(train_n=64, test_n=8, seed=21)
    params, state = M.init_params(seed=5)
    qgraph = quantize.quantize(params, state, data["train_x"][:32])
    return params, state, qgraph


def test_weight_range_int8(qsetup):
    _, _, qgraph = qsetup
    for c in qgraph["convs"]:
        assert c["w_q"].min() >= -127 and c["w_q"].max() <= 127
    assert np.abs(qgraph["fc"]["w_q"]).max() <= 127


def test_scales_positive(qsetup):
    _, _, qgraph = qsetup
    for c in qgraph["convs"]:
        assert c["act_scale"] > 0 and c["w_scale"] > 0


def test_weight_dequant_close(qsetup):
    params, state, qgraph = qsetup
    convs = M.fold_bn(params, state)
    by_name = {n: w for n, w, _, _ in convs}
    for c in qgraph["convs"]:
        w = by_name[c["name"]]
        w_mat = w.transpose(3, 0, 1, 2).reshape(c["cout"], -1)
        deq = c["w_q"].astype(np.float32) * c["w_scale"]
        assert np.abs(deq - w_mat).max() <= c["w_scale"] * 0.5 + 1e-7


def test_conv_count_matches_arch(qsetup):
    _, _, qgraph = qsetup
    # stem + 6 blocks x 2 convs + 2 projection shortcuts = 15
    assert len(qgraph["convs"]) == 15


def test_graph_json_schema(qsetup):
    _, _, qgraph = qsetup
    g = json.loads(quantize.graph_json(qgraph))
    assert g["arch"] == "resnet-mini"
    assert g["num_classes"] == 10
    ops = [o["op"] for o in g["ops"]]
    assert ops[0] == "qconv" and ops[-2:] == ["gap", "qfc"]
    assert ops.count("residual_relu") == 6
    assert len(g["convs"]) == 15


def test_rten_roundtrip_and_reload(qsetup, tmp_path):
    _, _, qgraph = qsetup
    p = str(tmp_path / "w.rten")
    rten.write(p, quantize.qgraph_tensors(qgraph))
    tensors = rten.read(p)
    g = json.loads(quantize.graph_json(qgraph))
    qg2 = quantize.load_qgraph(tensors, g)
    for c1, c2 in zip(qgraph["convs"], qg2["convs"]):
        np.testing.assert_array_equal(c1["w_q"], c2["w_q"])
        np.testing.assert_array_equal(c1["bias_q"], c2["bias_q"])
        # scales are stored f32 in the container
        assert abs(c1["act_scale"] - c2["act_scale"]) < 1e-6 * c1["act_scale"]


def test_bias_q_in_accumulator_domain(qsetup):
    params, state, qgraph = qsetup
    convs = M.fold_bn(params, state)
    by_name = {n: b for n, _, b, _ in convs}
    c = qgraph["convs"][0]
    expect = np.floor(by_name[c["name"]] / (c["act_scale"] * c["w_scale"]) + 0.5)
    np.testing.assert_array_equal(c["bias_q"], expect.astype(np.int32))
