"""SynthCIFAR generator sanity: the saliency structure the paper needs."""

import numpy as np
import pytest

from compile import dataset


def test_deterministic_for_seed():
    x1, y1 = dataset.generate(64, 5)
    x2, y2 = dataset.generate(64, 5)
    np.testing.assert_array_equal(x1, x2)
    np.testing.assert_array_equal(y1, y2)


def test_different_seeds_differ():
    x1, _ = dataset.generate(16, 1)
    x2, _ = dataset.generate(16, 2)
    assert (x1 != x2).any()


def test_balanced_labels():
    _, y = dataset.generate(200, 3)
    counts = np.bincount(y, minlength=10)
    assert counts.min() == counts.max() == 20


def test_image_format():
    x, y = dataset.generate(20, 4)
    assert x.shape == (20, 32, 32, 3) and x.dtype == np.uint8
    assert y.shape == (20,) and y.dtype == np.int32
    assert y.min() >= 0 and y.max() < dataset.NUM_CLASSES


def test_object_brighter_than_background():
    """Objects are the salient, bright, class-carrying pixels."""
    rng = np.random.default_rng(0)
    for cls in range(dataset.NUM_CLASSES):
        mask = dataset._object_mask(cls, np.random.default_rng(cls))
        assert 8 < mask.sum() < 32 * 32 / 2, f"class {cls} mask degenerate"


def test_every_class_generable():
    rng = np.random.default_rng(0)
    for cls in range(dataset.NUM_CLASSES):
        img = dataset.make_image(cls, rng)
        assert img.shape == (32, 32, 3)
        assert img.std() > 5  # not a constant image


def test_build_splits():
    d = dataset.build(train_n=100, test_n=40, seed=9)
    assert d["train_x"].shape[0] == 100 and d["test_x"].shape[0] == 40
    # train/test drawn from different seeds -> disjoint with overwhelming prob.
    assert (d["train_x"][:40] != d["test_x"]).any()
