"""`.rten` container round-trips (the Rust reader is tested in io/rten.rs)."""

import numpy as np
import pytest

from compile import rten


def test_roundtrip_all_dtypes(tmp_path):
    p = str(tmp_path / "t.rten")
    tensors = {
        "f": np.linspace(-1, 1, 24, dtype=np.float32).reshape(2, 3, 4),
        "i": np.arange(-5, 7, dtype=np.int32).reshape(3, 4),
        "b": np.arange(-8, 8, dtype=np.int8).reshape(4, 4),
        "u": np.arange(0, 16, dtype=np.uint8).reshape(2, 8),
        "l": np.asarray([2**40, -3], dtype=np.int64),
    }
    rten.write(p, tensors)
    back = rten.read(p)
    assert set(back) == set(tensors)
    for k in tensors:
        np.testing.assert_array_equal(back[k], tensors[k])
        assert back[k].dtype == tensors[k].dtype


def test_scalar_and_empty_dims(tmp_path):
    p = str(tmp_path / "s.rten")
    rten.write(p, {"s": np.float32(3.5).reshape(()), "v": np.zeros((0,), np.int32)})
    back = rten.read(p)
    assert back["s"].shape == ()
    assert float(back["s"]) == 3.5
    assert back["v"].shape == (0,)


def test_bad_magic_rejected(tmp_path):
    p = tmp_path / "bad.rten"
    p.write_bytes(b"NOPE" + b"\x00" * 16)
    with pytest.raises(ValueError, match="bad magic"):
        rten.read(str(p))


def test_unsupported_dtype_rejected(tmp_path):
    with pytest.raises(TypeError):
        rten.write(str(tmp_path / "x.rten"), {"c": np.zeros(2, np.complex64)})


def test_name_unicode(tmp_path):
    p = str(tmp_path / "u.rten")
    rten.write(p, {"层.w_q": np.ones((2, 2), np.int8)})
    assert "层.w_q" in rten.read(p)
