"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

This is the CORE correctness signal: the AOT tile artifacts the Rust
runtime executes are lowered from exactly these Pallas kernels, so
pallas == ref (bit-exact) + rust-native == artifact (bit-exact, tested on
the Rust side) closes the loop.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hybrid_mac as hm
from compile.kernels import ref, spec as S


def gen(seed, m=128, sigma=0.3, bmax=16):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 256, (m, S.COLS), dtype=np.int32)
    w = rng.integers(-128, 128, (S.HMUS, S.COLS), dtype=np.int32)
    b = rng.integers(0, bmax, (m,), dtype=np.int32)
    noise = rng.normal(0, sigma, (m, S.HMUS, S.W_BITS)).astype(np.float32)
    return a, w, b, noise


def test_hybrid_pallas_matches_ref_bitexact():
    a, w, b, noise = gen(0)
    r = np.asarray(ref.hybrid_mac_ref(a, w, b, noise))
    p = np.asarray(hm.hybrid_tile(a, w, b, noise))
    np.testing.assert_array_equal(r, p)


def test_se_pallas_matches_ref_bitexact():
    a, w, _, _ = gen(1)
    np.testing.assert_array_equal(
        np.asarray(ref.saliency_ref(a, w)), np.asarray(hm.se_tile(a, w))
    )


def test_hybrid_b0_is_exact_dcim():
    """B_D/A = 0 puts every order in the digital domain -> loss-free."""
    a, w, _, noise = gen(2)
    b = np.zeros(a.shape[0], np.int32)
    out = np.asarray(ref.hybrid_mac_ref(a, w, b, noise))
    np.testing.assert_array_equal(out, np.asarray(ref.exact_mac(a, w)))


def test_hybrid_zero_noise_deterministic():
    a, w, b, _ = gen(3)
    z = np.zeros((a.shape[0], S.HMUS, S.W_BITS), np.float32)
    o1 = np.asarray(ref.hybrid_mac_ref(a, w, b, z))
    o2 = np.asarray(hm.hybrid_tile(a, w, b, z))
    np.testing.assert_array_equal(o1, o2)


def test_snr_monotonically_degrades_with_b():
    """Fig 5b: pushing the boundary up trades SNR for efficiency."""
    a, w, _, noise = gen(4, m=512)
    ex = np.asarray(ref.exact_mac(a, w), np.float64)
    prev = np.inf
    for bb in (0, 5, 6, 7, 8, 9, 10):
        out = np.asarray(
            ref.hybrid_mac_ref(a, w, np.full(a.shape[0], bb, np.int32), noise),
            np.float64,
        )
        err = ((out - ex) ** 2).mean()
        snr = np.inf if err == 0 else 10 * np.log10((ex ** 2).mean() / err)
        assert snr <= prev + 1e-9, f"SNR not monotone at B={bb}"
        prev = snr
    assert prev < 20, "B=10 should be clearly lossy"


def test_saliency_tracks_magnitude():
    """Large-|MAC| inputs must evaluate as more salient (the OSA premise)."""
    rng = np.random.default_rng(5)
    hi = rng.integers(160, 256, (64, S.COLS), dtype=np.int32)
    lo = rng.integers(0, 24, (64, S.COLS), dtype=np.int32)
    w = rng.integers(-128, 128, (S.HMUS, S.COLS), dtype=np.int32)
    s_hi = np.asarray(ref.saliency_ref(hi, w)).mean()
    s_lo = np.asarray(ref.saliency_ref(lo, w)).mean()
    assert s_hi > 4 * s_lo


def test_select_boundary_edges():
    t = jnp.asarray([10, 20, 30, 40, 50])
    cand = jnp.asarray(S.B_CANDIDATES)
    s = jnp.asarray([0, 9, 10, 25, 50, 1000])
    out = np.asarray(ref.select_boundary(s, t, cand))
    np.testing.assert_array_equal(out, [10, 10, 9, 8, 5, 5])


def test_acim_noisier_than_hybrid():
    a, w, _, _ = gen(6, m=256)
    rng = np.random.default_rng(7)
    ex = np.asarray(ref.exact_mac(a, w), np.float64)
    n_h = rng.normal(0, 0.3, (256, S.HMUS, S.W_BITS)).astype(np.float32)
    n_a = rng.normal(0, 0.3, (256, S.HMUS, S.W_BITS, 2)).astype(np.float32)
    hyb = np.asarray(ref.hybrid_mac_ref(a, w, np.full(256, 8, np.int32), n_h), np.float64)
    aci = np.asarray(ref.acim_mac_ref(a, w, n_a), np.float64)
    assert ((aci - ex) ** 2).mean() > ((hyb - ex) ** 2).mean()


def test_adc_transfer_clamps():
    amac = jnp.asarray([[0], [100000]], jnp.int32)
    nbits = jnp.asarray([[4], [4]], jnp.int32)
    noise = jnp.zeros((2, 1), jnp.float32)
    out = np.asarray(ref.adc_transfer(amac, nbits, noise))
    fs = S.COLS * 15 * S.ADC_FS_FRAC
    assert out[0, 0] == 0  # mid-tread: zero input -> zero (no bias)
    assert out[1, 0] == int(np.floor(7.0 / 8 * fs + 0.5))  # saturated at code 7


def test_adc_transfer_unbiased_on_uniform_input():
    """Mid-tread requirement: E[rec - amac] ≈ 0 over the linear range."""
    rng = np.random.default_rng(0)
    amac = rng.integers(0, int(S.COLS * 15 * S.ADC_FS_FRAC), (4096, 1)).astype(np.int32)
    nbits = jnp.full((4096, 1), 4, jnp.int32)
    noise = jnp.zeros((4096, 1), jnp.float32)
    rec = np.asarray(ref.adc_transfer(jnp.asarray(amac), nbits, noise))
    bias = (rec - amac).mean()
    step = S.COLS * 15 * S.ADC_FS_FRAC / 8
    assert abs(bias) < step * 0.15, f"ADC biased by {bias}"


@settings(max_examples=10, deadline=None)
@given(
    st.integers(0, 2**32 - 1),
    st.sampled_from([64, 128, 256]),
    st.floats(0.0, 1.0),
)
def test_hybrid_pallas_vs_ref_hypothesis(seed, m, sigma):
    """Hypothesis sweep of shapes/noise levels: pallas == ref always."""
    a, w, b, noise = gen(seed, m=m, sigma=sigma)
    r = np.asarray(ref.hybrid_mac_ref(a, w, b, noise))
    p = np.asarray(hm.hybrid_tile(a, w, b, noise))
    np.testing.assert_array_equal(r, p)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**32 - 1), st.sampled_from([64, 192]))
def test_se_pallas_vs_ref_hypothesis(seed, m):
    a, w, _, _ = gen(seed, m=m)
    np.testing.assert_array_equal(
        np.asarray(ref.saliency_ref(a, w)), np.asarray(hm.se_tile(a, w))
    )


def test_hybrid_counts_partition():
    """Fig 5a: digital+analog+discard == 64 for every boundary."""
    for b in range(0, 16):
        c = ref.hybrid_mac_counts(b)
        assert c["digital"] + c["analog"] + c["discard"] == 64
        assert 0 <= c["adc_groups"] <= 8
    assert ref.hybrid_mac_counts(0) == {
        "digital": 64, "analog": 0, "discard": 0, "adc_groups": 0
    }
