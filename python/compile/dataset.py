"""SynthCIFAR — synthetic 32x32x3 10-class dataset (CIFAR substitute).

The offline build has no CIFAR100 and no pretrained ResNet (DESIGN.md §1),
so we generate a dataset with exactly the property the paper's motivation
(Fig 1) relies on: every image is a *salient object* (class-determining
shape with distinctive texture) on a *non-salient background* (smooth
textured field irrelevant to the label).  Saliency-aware precision maps
(Fig 8a) should therefore light up on the object and stay coarse on the
background, and accuracy-vs-efficiency tradeoffs (Fig 9) reproduce in
shape.

Classes: 0 circle, 1 square, 2 triangle, 3 cross, 4 ring, 5 hbar,
6 vbar, 7 diamond, 8 checker, 9 corner-L.
"""

from __future__ import annotations

import numpy as np

NUM_CLASSES = 10
IMG = 32
CLASS_NAMES = (
    "circle", "square", "triangle", "cross", "ring",
    "hbar", "vbar", "diamond", "checker", "corner_l",
)


def _background(rng: np.random.Generator) -> np.ndarray:
    """Smooth low-frequency color field + speckle, like grass/sky texture."""
    base = rng.uniform(0.15, 0.55, (4, 4, 3))
    # bilinear upsample 4x4 -> 32x32
    xs = np.linspace(0, 3, IMG)
    x0 = np.clip(xs.astype(int), 0, 2)
    fx = xs - x0
    up = (
        base[x0][:, x0] * (1 - fx)[:, None, None] * (1 - fx)[None, :, None]
        + base[x0 + 1][:, x0] * fx[:, None, None] * (1 - fx)[None, :, None]
        + base[x0][:, x0 + 1] * (1 - fx)[:, None, None] * fx[None, :, None]
        + base[x0 + 1][:, x0 + 1] * fx[:, None, None] * fx[None, :, None]
    )
    up += rng.normal(0, 0.03, up.shape)
    return up


def _object_mask(cls: int, rng: np.random.Generator) -> np.ndarray:
    """Boolean [32,32] mask of the class shape at random position/scale."""
    cy = rng.uniform(10, 22)
    cx = rng.uniform(10, 22)
    r = rng.uniform(5.0, 9.0)
    yy, xx = np.mgrid[0:IMG, 0:IMG]
    dy, dx = yy - cy, xx - cx
    rad = np.hypot(dy, dx)
    if cls == 0:  # circle
        return rad <= r
    if cls == 1:  # square
        return (np.abs(dy) <= r * 0.8) & (np.abs(dx) <= r * 0.8)
    if cls == 2:  # triangle (upward)
        return (dy >= -r) & (dy <= r * 0.6) & (np.abs(dx) <= (dy + r) * 0.6)
    if cls == 3:  # cross
        w = r * 0.35
        return ((np.abs(dx) <= w) & (np.abs(dy) <= r)) | (
            (np.abs(dy) <= w) & (np.abs(dx) <= r)
        )
    if cls == 4:  # ring
        return (rad <= r) & (rad >= r * 0.55)
    if cls == 5:  # hbar
        return (np.abs(dy) <= r * 0.3) & (np.abs(dx) <= r)
    if cls == 6:  # vbar
        return (np.abs(dx) <= r * 0.3) & (np.abs(dy) <= r)
    if cls == 7:  # diamond
        return (np.abs(dy) + np.abs(dx)) <= r
    if cls == 8:  # checker patch
        inside = (np.abs(dy) <= r * 0.8) & (np.abs(dx) <= r * 0.8)
        return inside & (((yy // 3) + (xx // 3)) % 2 == 0)
    if cls == 9:  # corner L
        w = r * 0.4
        return ((np.abs(dx + r * 0.4) <= w) & (dy >= -r) & (dy <= r)) | (
            (np.abs(dy - r + w) <= w) & (dx >= -r * 0.4) & (dx <= r)
        )
    raise ValueError(cls)


def _blob_mask(rng: np.random.Generator) -> np.ndarray:
    """Soft irregular blob — label-free background structure."""
    cy, cx = rng.uniform(4, 28, 2)
    yy, xx = np.mgrid[0:IMG, 0:IMG]
    dy, dx = yy - cy, xx - cx
    # anisotropic ellipse with wavy radius (never matches a class shape)
    ang = np.arctan2(dy, dx)
    r0 = rng.uniform(2.5, 5.0)
    wob = 1.0 + 0.4 * np.sin(ang * rng.integers(5, 9) + rng.uniform(0, 6.28))
    sx, sy = rng.uniform(0.6, 1.8, 2)
    rad = np.hypot(dy / sy, dx / sx)
    return rad <= r0 * wob


def make_image(cls: int, rng: np.random.Generator) -> np.ndarray:
    img = _background(rng)
    # distractor texture in muted background-like colors: structure that
    # carries NO label information (soft blobs, not class shapes — the
    # paper's premise is that background pixels are truly non-salient;
    # class-shaped distractors would make background fidelity matter)
    for _ in range(rng.integers(1, 3)):
        dmask = _blob_mask(rng)
        dcol = rng.uniform(0.15, 0.45, 3)
        img = np.where(dmask[:, :, None], dcol[None, None, :], img)
    mask = _object_mask(cls, rng)
    color = rng.uniform(0.55, 0.95, 3)
    # dim one random channel so colors vary but stay bright vs background
    color[rng.integers(0, 3)] *= rng.uniform(0.2, 0.6)
    tex = rng.normal(0, 0.06, (IMG, IMG, 1))
    obj = np.clip(color[None, None, :] + tex, 0, 1)
    img = np.where(mask[:, :, None], obj, img)
    img += rng.normal(0, 0.04, img.shape)
    return (np.clip(img, 0, 1) * 255).astype(np.uint8)


def generate(n: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """n images, balanced classes, deterministic for a given seed."""
    rng = np.random.default_rng(seed)
    labels = np.arange(n) % NUM_CLASSES
    rng.shuffle(labels)
    imgs = np.stack([make_image(int(c), rng) for c in labels])
    return imgs, labels.astype(np.int32)


def build(train_n: int = 4096, test_n: int = 1024, seed: int = 2024):
    train_x, train_y = generate(train_n, seed)
    test_x, test_y = generate(test_n, seed + 1)
    return {
        "train_x": train_x,
        "train_y": train_y,
        "test_x": test_x,
        "test_y": test_y,
    }
