"""AOT artifact builder — the single build-time Python entrypoint.

``make artifacts`` runs ``python -m compile.aot --out-dir ../artifacts``:

1. generates SynthCIFAR            -> dataset.rten
2. trains ResNet-mini (cached)     -> weights_float.rten (+ history)
3. folds BN + quantizes            -> weights.rten, graph.json
4. evaluates goldens               -> golden.rten (float + DCIM logits)
5. lowers HLO text artifacts       -> model.hlo.txt, se_tile.hlo.txt,
                                      hybrid_tile.hlo.txt, acim_tile.hlo.txt
6. dumps the normative spec        -> spec.json (+ PRNG golden vectors)

HLO is exported as *text*, never ``.serialize()``: jax >= 0.5 emits protos
with 64-bit instruction ids that xla_extension 0.5.1 (the version the
published ``xla`` crate binds) rejects; the text parser reassigns ids.
See /opt/xla-example/README.md.

Python never runs at inference time — the Rust binary is self-contained
once this script has produced ``artifacts/``.
"""

from __future__ import annotations

import argparse
import json
import os
import pickle
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import dataset, model as M, prng, quantize, rten, train
from .kernels import hybrid_mac, ref, spec as S


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants: the folded model bakes its weights into the
    # HLO; the default printer elides them as `constant({...})`, which the
    # rust-side text parser could not reload.
    return comp.as_hlo_text(print_large_constants=True)


def export_tile_artifacts(out_dir: str) -> None:
    sp = S.DEFAULT_SPEC
    m = S.TILE_M
    a_spec = jax.ShapeDtypeStruct((m, sp.cols), jnp.int32)
    w_spec = jax.ShapeDtypeStruct((sp.hmus, sp.cols), jnp.int32)
    b_spec = jax.ShapeDtypeStruct((m,), jnp.int32)
    n_spec = jax.ShapeDtypeStruct((m, sp.hmus, sp.w_bits), jnp.float32)
    n_slices = (sp.a_bits + sp.analog_band - 1) // sp.analog_band
    an_spec = jax.ShapeDtypeStruct((m, sp.hmus, sp.w_bits, n_slices), jnp.float32)

    lowered = jax.jit(lambda a, w: (hybrid_mac.se_tile(a, w),)).lower(a_spec, w_spec)
    with open(os.path.join(out_dir, "se_tile.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    lowered = jax.jit(
        lambda a, w, b, n: (hybrid_mac.hybrid_tile(a, w, b, n),)
    ).lower(a_spec, w_spec, b_spec, n_spec)
    with open(os.path.join(out_dir, "hybrid_tile.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    lowered = jax.jit(lambda a, w, n: (ref.acim_mac_ref(a, w, n),)).lower(
        a_spec, w_spec, an_spec
    )
    with open(os.path.join(out_dir, "acim_tile.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))


def export_model_hlo(out_dir: str, convs, fc_w, fc_b, batch: int = 128) -> None:
    x_spec = jax.ShapeDtypeStruct((batch, 32, 32, 3), jnp.float32)
    fn = lambda x: (M.folded_forward(convs, fc_w, fc_b, x),)
    lowered = jax.jit(fn).lower(x_spec)
    with open(os.path.join(out_dir, "model.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))


def float_tensors(params, state) -> dict:
    """Raw float params for weights_float.rten (training cache)."""
    flat, treedef = jax.tree_util.tree_flatten((params, state))
    out = {f"leaf{i}": np.asarray(x) for i, x in enumerate(flat)}
    out["_count"] = np.asarray([len(flat)], np.int32)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--epochs", type=int, default=18)
    ap.add_argument("--train-n", type=int, default=4096)
    ap.add_argument("--test-n", type=int, default=1024)
    ap.add_argument("--seed", type=int, default=2024)
    ap.add_argument("--retrain", action="store_true")
    ap.add_argument("--golden-n", type=int, default=64,
                    help="test images for the bit-exact rust golden")
    args = ap.parse_args(argv)

    out = os.path.abspath(args.out_dir)
    os.makedirs(out, exist_ok=True)
    t0 = time.time()

    # 1. dataset ----------------------------------------------------------
    ds_path = os.path.join(out, "dataset.rten")
    if not os.path.exists(ds_path):
        print("[aot] generating SynthCIFAR ...", flush=True)
        data = dataset.build(args.train_n, args.test_n, args.seed)
        rten.write(ds_path, data)
    else:
        data = rten.read(ds_path)
        print("[aot] dataset.rten cached", flush=True)

    # 2. train (cached via pickle of the param pytree) ---------------------
    ckpt = os.path.join(out, "train_ckpt.pkl")
    if args.retrain or not os.path.exists(ckpt):
        print("[aot] training ResNet-mini ...", flush=True)
        params, state, history = train.train(data, epochs=args.epochs)
        with open(ckpt, "wb") as f:
            pickle.dump({"params": params, "state": state, "history": history}, f)
    else:
        with open(ckpt, "rb") as f:
            saved = pickle.load(f)
        params, state, history = saved["params"], saved["state"], saved["history"]
        print("[aot] train_ckpt.pkl cached", flush=True)
    float_acc = history[-1]["test_acc"]
    print(f"[aot] float test accuracy: {float_acc:.4f} "
          f"({M.count_params(params)} params)", flush=True)

    # 3. fold + quantize ----------------------------------------------------
    convs = M.fold_bn(params, state)
    fc_w = np.asarray(params["fc"]["w"])
    fc_b = np.asarray(params["fc"]["b"])
    qgraph = quantize.quantize(params, state, data["train_x"][:256])
    rten.write(os.path.join(out, "weights.rten"), quantize.qgraph_tensors(qgraph))
    with open(os.path.join(out, "graph.json"), "w") as f:
        f.write(quantize.graph_json(qgraph))

    # 4. goldens ------------------------------------------------------------
    print("[aot] computing goldens ...", flush=True)
    xs = jnp.asarray(data["test_x"], jnp.float32) / 255.0
    float_logits = []
    for s in range(0, xs.shape[0], 256):
        float_logits.append(np.asarray(M.folded_forward(convs, fc_w, fc_b, xs[s:s + 256])))
    float_logits = np.concatenate(float_logits)

    gemm = M.MacroGemm("dcim")
    dcim_logits, _ = M.quant_forward(qgraph, xs[:args.golden_n], gemm)
    rten.write(os.path.join(out, "golden.rten"), {
        "float_logits": float_logits.astype(np.float32),
        "dcim_logits": np.asarray(dcim_logits, np.float32),
        "labels": data["test_y"],
        "golden_n": np.asarray([args.golden_n], np.int32),
        "float_acc": np.asarray([float_acc], np.float32),
    })

    # 5. HLO artifacts --------------------------------------------------------
    print("[aot] lowering HLO artifacts ...", flush=True)
    export_model_hlo(out, convs, fc_w, fc_b)
    export_tile_artifacts(out)

    # 6. spec.json ------------------------------------------------------------
    spec_doc = S.as_dict()
    spec_doc["prng_golden"] = prng.golden_vectors()
    spec_doc["dataset"] = {
        "train_n": int(data["train_x"].shape[0]),
        "test_n": int(data["test_x"].shape[0]),
        "num_classes": dataset.NUM_CLASSES,
        "class_names": list(dataset.CLASS_NAMES),
        "float_test_acc": float(float_acc),
    }
    with open(os.path.join(out, "spec.json"), "w") as f:
        json.dump(spec_doc, f, indent=1)

    print(f"[aot] done in {time.time()-t0:.0f}s -> {out}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
