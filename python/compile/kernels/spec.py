"""Normative numeric specification for the OSA-HCIM reproduction.

Every constant here is mirrored by ``rust/src/spec.rs``; ``aot.py`` embeds
this module's values (plus PRNG golden vectors) into ``artifacts/spec.json``
and the Rust side validates its own constants against that file at startup
and in tests.  See DESIGN.md §3 for the semantics of each knob.

The macro modeled is the paper's 64b x 144b split-port 6T SRAM array:
8 Hybrid MAC Units (HMUs), each owning 144 Hybrid CIM Arrays (HCIMA) that
store one 8-bit weight apiece, a digital adder tree (DAT), an N/Q unit and
a 3-bit SAR ADC.
"""

from __future__ import annotations

import dataclasses

# ---------------------------------------------------------------- geometry
COLS = 144  #: columns per HMU == dot-product (K-tile) length
HMUS = 8  #: HMUs per macro == output channels produced per macro op
ROWS = 64  #: SRAM rows = HMUS * W_BITS (one 8-bit weight per HCIMA)

# ------------------------------------------------------------- bit layout
W_BITS = 8  #: weight bit-planes (int8 two's complement; plane 7 is -2^7)
A_BITS = 8  #: activation bit-planes (uint8, post-ReLU)
K_MAX = W_BITS + A_BITS - 2  #: highest output order k = i + j

# --------------------------------------------------------------- OSA knobs
ANALOG_BAND = 4  #: orders B-4 <= k < B go to ACIM (DAC supports 1..4 bits)
SE_ORDERS = 2  #: saliency is evaluated from the s=2 highest orders
SE_K_MIN = K_MAX - SE_ORDERS + 1  #: k in {13, 14} for 8b x 8b
NQ_SHIFT = 1  #: N/Q unit: NQ(d) = min(NQ_MAX, d >> NQ_SHIFT)
NQ_MAX = 7  #: 3-bit N/Q ceiling
B_CANDIDATES = (10, 9, 8, 7, 6, 5)  #: Fig 5b operating points, coarse->fine
B_DCIM = 0  #: boundary value that makes every order digital (DCIM baseline)

# --------------------------------------------------------------- ADC model
ADC_BITS = 3  #: SAR ADC resolution (paper: low precision is the point)
ADC_LEVELS = 1 << ADC_BITS
ADC_FS_FRAC = 0.25  #: charge-share rail sized for typical 25% bit density
SIGMA_CODE = 0.3  #: default input-referred noise, in ADC code units

# -------------------------------------------------------------- tile shapes
TILE_M = 256  #: samples per AOT hybrid/se tile artifact
PALLAS_BLOCK_M = 64  #: pallas grid block along the sample axis

SPEC_VERSION = 5


def normalize_saliency(s_raw, k_real: int, cols: int = COLS):
    """Normalize accumulated raw saliency by the layer's true K depth.

    The OSE compares S against *global* pre-trained thresholds; layers
    have different K (im2col depth), so the N/Q unit's normalization
    stage rescales by ``cols / k_real`` (a per-layer constant the
    controller programs).  Integer floor division -- mirrored by
    ``rust spec::normalize_saliency``.
    """
    import numpy as np

    return (np.asarray(s_raw, np.int64) * cols) // max(k_real, 1)


@dataclasses.dataclass(frozen=True)
class MacroSpec:
    """Bundled spec so code can carry/override knobs (tests use this)."""

    cols: int = COLS
    hmus: int = HMUS
    w_bits: int = W_BITS
    a_bits: int = A_BITS
    analog_band: int = ANALOG_BAND
    se_orders: int = SE_ORDERS
    nq_shift: int = NQ_SHIFT
    nq_max: int = NQ_MAX
    adc_bits: int = ADC_BITS
    adc_fs_frac: float = ADC_FS_FRAC
    sigma_code: float = SIGMA_CODE

    @property
    def k_max(self) -> int:
        return self.w_bits + self.a_bits - 2

    @property
    def se_k_min(self) -> int:
        return self.k_max - self.se_orders + 1

    @property
    def adc_levels(self) -> int:
        return 1 << self.adc_bits


DEFAULT_SPEC = MacroSpec()


def as_dict() -> dict:
    """Spec constants serialized into artifacts/spec.json."""
    return {
        "version": SPEC_VERSION,
        "cols": COLS,
        "hmus": HMUS,
        "rows": ROWS,
        "w_bits": W_BITS,
        "a_bits": A_BITS,
        "k_max": K_MAX,
        "analog_band": ANALOG_BAND,
        "se_orders": SE_ORDERS,
        "se_k_min": SE_K_MIN,
        "nq_shift": NQ_SHIFT,
        "nq_max": NQ_MAX,
        "b_candidates": list(B_CANDIDATES),
        "b_dcim": B_DCIM,
        "adc_bits": ADC_BITS,
        "adc_fs_frac": ADC_FS_FRAC,
        "sigma_code": SIGMA_CODE,
        "tile_m": TILE_M,
    }
