"""Pure-jnp oracle for the OSA-HCIM macro datapath.

This is the normative functional model: the Pallas kernel
(:mod:`hybrid_mac`), the AOT artifacts, and the Rust native simulator
(``rust/src/macrosim``) must all agree with it bit-exactly given the same
explicit noise buffer (DESIGN.md §3).  Every arithmetic step that involves
floating point (the ADC transfer function) is written as an exact sequence
of f32 ops that the Rust side mirrors literally.

Conventions
-----------
* ``a_q``  [M, C] int32 holding uint8 activations (0..2^a_bits-1)
* ``w_q``  [H, C] int32 holding int8 two's-complement weights
* ``b_da`` [M]    int32 per-sample digital/analog boundary B_D/A
* ``noise``[M, H, w_bits] f32 input-referred ADC noise, code units
* return  [M, H] int32 hybrid MAC result
"""

from __future__ import annotations

import jax.numpy as jnp

from . import spec as S
from .bitplane import order_partials, plane_sign


def exact_mac(a_q: jnp.ndarray, w_q: jnp.ndarray) -> jnp.ndarray:
    """Loss-free integer MAC — the DCIM ground truth. [M,C]x[H,C] -> [M,H]."""
    return jnp.matmul(
        a_q.astype(jnp.int32), w_q.astype(jnp.int32).T, preferred_element_type=jnp.int32
    )


def nq(d: jnp.ndarray, sp: S.MacroSpec = S.DEFAULT_SPEC) -> jnp.ndarray:
    """Normalization-and-Quantization unit: 3-bit compression of a DMAC."""
    return jnp.minimum(d >> sp.nq_shift, sp.nq_max)


def adc_transfer(
    amac: jnp.ndarray, nbits: jnp.ndarray, noise: jnp.ndarray, sp: S.MacroSpec = S.DEFAULT_SPEC
) -> jnp.ndarray:
    """3-bit SAR ADC: charge-share voltage -> code -> integer reconstruction.

    ``amac``  int32 >= 0 (sum over columns of w_bit * analog slice value)
    ``nbits`` int32 in [1, ANALOG_BAND]: DAC precision of the slice
    ``noise`` f32 input-referred noise in code units

    Mirrored exactly by ``rust/src/analog/adc.rs`` — keep the op order.
    """
    levels = jnp.float32(sp.adc_levels)
    span = (jnp.int32(1) << nbits) - 1  # 2^nbits - 1
    fs = jnp.float32(sp.cols) * span.astype(jnp.float32) * jnp.float32(sp.adc_fs_frac)
    scale = levels / fs
    v = amac.astype(jnp.float32) * scale
    # mid-tread (unbiased) quantizer: code = round(v), rec = code * step.
    # A mid-riser reconstruction would add +step/2 to every conversion,
    # which (scaled by 2^(i+j_lo), accumulated over 8 groups) shifts every
    # MAC and collapses the quantized network (~50% acc at B=8).
    code = jnp.clip(jnp.floor(v + jnp.float32(0.5) + noise), 0.0, levels - 1.0)
    rec = jnp.floor(code * (fs / levels) + jnp.float32(0.5))
    return rec.astype(jnp.int32)


def analog_group_bounds(i: int, b_da: jnp.ndarray, sp: S.MacroSpec = S.DEFAULT_SPEC):
    """Per-sample analog activation-plane range for weight plane ``i``.

    Orders ``B-band <= k < B`` with ``k = i + j`` give
    ``j in [max(0, B-band-i), min(a_bits-1, B-1-i)]``; the group exists
    when that range is non-empty.
    """
    j_lo = jnp.maximum(0, b_da - sp.analog_band - i)
    j_hi = jnp.minimum(sp.a_bits - 1, b_da - 1 - i)
    exists = j_hi >= j_lo
    return j_lo, j_hi, exists


def hybrid_mac_ref(
    a_q: jnp.ndarray,
    w_q: jnp.ndarray,
    b_da: jnp.ndarray,
    noise: jnp.ndarray,
    sp: S.MacroSpec = S.DEFAULT_SPEC,
) -> jnp.ndarray:
    """OSA-HCIM computing-mode MAC with a per-sample boundary ``b_da``.

    digital: orders k >= B (exact, bit-serial DCIM);
    analog:  orders B-band <= k < B (per weight plane, DAC slice + ADC);
    discard: orders k < B-band.
    """
    d = order_partials(a_q, w_q, sp)  # [w, a, M, H]
    b = b_da.astype(jnp.int32)[:, None]  # [M, 1]
    acc = jnp.zeros((a_q.shape[0], w_q.shape[0]), dtype=jnp.int32)

    # --- digital domain -------------------------------------------------
    for i in range(sp.w_bits):
        for j in range(sp.a_bits):
            dig = (i + j) >= b  # [M, 1]
            term = jnp.where(dig, d[i, j], 0)
            acc = acc + plane_sign(i, sp.w_bits) * (term << (i + j))

    # --- analog domain --------------------------------------------------
    for i in range(sp.w_bits):
        j_lo, j_hi, exists = analog_group_bounds(i, b[:, 0], sp)  # [M]
        amac = jnp.zeros_like(acc)
        for j in range(sp.a_bits):
            in_grp = (j >= j_lo) & (j <= j_hi)  # [M]
            shift = jnp.clip(j - j_lo, 0, sp.analog_band - 1)
            amac = amac + jnp.where(in_grp[:, None], d[i, j] << shift[:, None], 0)
        nbits = jnp.clip(j_hi - j_lo + 1, 1, sp.analog_band)
        rec = adc_transfer(amac, nbits[:, None], noise[:, :, i], sp)
        shift_out = jnp.clip(i + j_lo, 0, sp.k_max)
        contrib = jnp.where(exists[:, None], rec << shift_out[:, None], 0)
        acc = acc + plane_sign(i, sp.w_bits) * contrib

    return acc


def saliency_ref(
    a_q: jnp.ndarray, w_q: jnp.ndarray, sp: S.MacroSpec = S.DEFAULT_SPEC
) -> jnp.ndarray:
    """Saliency-evaluation mode: S[m] from the s highest-order 1-bit MACs.

    The DMACs of orders k >= SE_K_MIN are N/Q-compressed to 3 bits and
    summed across the 8 HMU channels (the OSE then accumulates across
    K-tiles, i.e. "cycles", outside this function).
    """
    d = order_partials(a_q, w_q, sp)
    s = jnp.zeros((a_q.shape[0],), dtype=jnp.int32)
    for i in range(sp.w_bits):
        for j in range(sp.a_bits):
            if i + j >= sp.se_k_min:
                s = s + jnp.sum(nq(d[i, j], sp), axis=1)
    return s


def select_boundary(
    s: jnp.ndarray,
    thresholds: jnp.ndarray,
    candidates: jnp.ndarray,
) -> jnp.ndarray:
    """OSE boundary select: B = candidates[#{T_i <= S}].

    ``thresholds`` ascending [b-1]; ``candidates`` coarse-to-fine [b]
    (e.g. [10,9,8,7,6,5]): low saliency -> candidates[0] (most analog),
    high saliency -> candidates[-1] (most digital).
    """
    idx = jnp.sum(s[:, None] >= thresholds[None, :].astype(jnp.int32), axis=1)
    return candidates.astype(jnp.int32)[idx]


def acim_mac_ref(
    a_q: jnp.ndarray,
    w_q: jnp.ndarray,
    noise: jnp.ndarray,
    sp: S.MacroSpec = S.DEFAULT_SPEC,
) -> jnp.ndarray:
    """Full-analog baseline (conventional ACIM).

    Every weight plane is multiplied against bit-parallel activation
    slices of ANALOG_BAND bits (two 4-bit slices for 8-bit activations),
    each slice going through its own charge-share + 3-bit ADC conversion.
    ``noise``: [M, H, w_bits, n_slices] f32.
    """
    d = order_partials(a_q, w_q, sp)
    n_slices = (sp.a_bits + sp.analog_band - 1) // sp.analog_band
    acc = jnp.zeros((a_q.shape[0], w_q.shape[0]), dtype=jnp.int32)
    for i in range(sp.w_bits):
        for sl in range(n_slices):
            j_lo = sl * sp.analog_band
            j_hi = min(j_lo + sp.analog_band - 1, sp.a_bits - 1)
            amac = jnp.zeros_like(acc)
            for j in range(j_lo, j_hi + 1):
                amac = amac + (d[i, j] << (j - j_lo))
            nbits = jnp.int32(j_hi - j_lo + 1)
            rec = adc_transfer(amac, nbits, noise[:, :, i, sl], sp)
            acc = acc + plane_sign(i, sp.w_bits) * (rec << (i + j_lo))
    return acc


def hybrid_mac_counts(b: int, sp: S.MacroSpec = S.DEFAULT_SPEC) -> dict:
    """Static workload allocation for one boundary value (Fig 5a).

    Returns the number of 1-bit MAC (i,j) pairs computed digitally /
    in analog / discarded, and the number of ADC conversions (analog
    groups, one per weight plane with a non-empty slice).
    """
    dig = ana = disc = 0
    groups = 0
    for i in range(sp.w_bits):
        lo = max(0, b - sp.analog_band - i)
        hi = min(sp.a_bits - 1, b - 1 - i)
        if hi >= lo:
            groups += 1
        for j in range(sp.a_bits):
            k = i + j
            if k >= b:
                dig += 1
            elif k >= b - sp.analog_band:
                ana += 1
            else:
                disc += 1
    return {"digital": dig, "analog": ana, "discard": disc, "adc_groups": groups}
