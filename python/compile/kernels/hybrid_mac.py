"""Pallas implementation of the OSA-HCIM hybrid macro op (L1 hot-spot).

Two kernels, mirroring the macro's two operating modes:

* ``se_tile``      — Saliency-Evaluation mode: the s=2 highest-order 1-bit
                     MACs are computed digitally, N/Q-compressed and summed
                     into a per-sample saliency contribution S.
* ``hybrid_tile``  — Computing mode: given the per-sample boundary B_D/A,
                     compute digital orders exactly, analog orders through
                     the DAC-slice/charge-share/3-bit-SAR model, and drop
                     the rest.

Both are lowered with ``interpret=True`` — the CPU PJRT plugin cannot run
Mosaic custom calls, and interpret mode lowers to plain HLO the Rust
runtime executes directly (see /opt/xla-example/README.md).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the macro's spatial
144-column x 8-HMU array becomes a tiled reduction.  The grid walks the
sample axis in PALLAS_BLOCK_M blocks; each grid step keeps the full
(8 x 144) weight bit-planes resident in VMEM (they are tiny and reused by
all 64 (i,j) bit-plane products — the analogue of weights staying in the
SRAM array) while activation bit-planes stream per block (the analogue of
the GBL/GBLB input drive).  On a real TPU each D[i,j] product is an
int8-friendly [block_m,144] @ [144,8] matmul that maps onto the MXU, and
the boundary masks are VPU elementwise selects.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import spec as S
from .bitplane import plane_sign


def _act_planes_block(a, a_bits):
    return [(a >> j) & 1 for j in range(a_bits)]


def _weight_planes_block(w, w_bits):
    wm = w & ((1 << w_bits) - 1)
    return [(wm >> i) & 1 for i in range(w_bits)]


def _partial(ap_j, wp_i):
    """D[i,j] for one block: [bm, C] @ [C, H] -> [bm, H], int32."""
    return jax.lax.dot_general(
        ap_j,
        wp_i.T,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _se_kernel(a_ref, w_ref, s_ref, *, sp: S.MacroSpec):
    a = a_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    ap = _act_planes_block(a, sp.a_bits)
    wp = _weight_planes_block(w, sp.w_bits)
    s = jnp.zeros((a.shape[0],), dtype=jnp.int32)
    for i in range(sp.w_bits):
        for j in range(sp.a_bits):
            if i + j >= sp.se_k_min:
                d = _partial(ap[j], wp[i])
                s = s + jnp.sum(jnp.minimum(d >> sp.nq_shift, sp.nq_max), axis=1)
    s_ref[...] = s


def _hybrid_kernel(a_ref, w_ref, b_ref, n_ref, o_ref, *, sp: S.MacroSpec):
    a = a_ref[...].astype(jnp.int32)
    w = w_ref[...].astype(jnp.int32)
    b = b_ref[...].astype(jnp.int32)[:, None]  # [bm, 1]
    noise = n_ref[...]  # [bm, H, w_bits] f32
    ap = _act_planes_block(a, sp.a_bits)
    wp = _weight_planes_block(w, sp.w_bits)

    # Reused 1-bit MAC partial sums (the hardware computes SE-mode orders
    # once and reuses them in computing mode; numerically identical).
    d = [[_partial(ap[j], wp[i]) for j in range(sp.a_bits)] for i in range(sp.w_bits)]

    acc = jnp.zeros((a.shape[0], w.shape[0]), dtype=jnp.int32)

    # Digital domain: bit-serial DAT accumulation of orders k >= B.
    for i in range(sp.w_bits):
        for j in range(sp.a_bits):
            term = jnp.where((i + j) >= b, d[i][j], 0)
            acc = acc + plane_sign(i, sp.w_bits) * (term << (i + j))

    # Analog domain: one DAC slice + ADC conversion per weight plane.
    levels = jnp.float32(sp.adc_levels)
    for i in range(sp.w_bits):
        j_lo = jnp.maximum(0, b[:, 0] - sp.analog_band - i)  # [bm]
        j_hi = jnp.minimum(sp.a_bits - 1, b[:, 0] - 1 - i)
        exists = j_hi >= j_lo
        amac = jnp.zeros_like(acc)
        for j in range(sp.a_bits):
            in_grp = (j >= j_lo) & (j <= j_hi)
            shift = jnp.clip(j - j_lo, 0, sp.analog_band - 1)
            amac = amac + jnp.where(in_grp[:, None], d[i][j] << shift[:, None], 0)
        nbits = jnp.clip(j_hi - j_lo + 1, 1, sp.analog_band)[:, None]
        span = (jnp.int32(1) << nbits) - 1
        fs = jnp.float32(sp.cols) * span.astype(jnp.float32) * jnp.float32(sp.adc_fs_frac)
        scale = levels / fs
        v = amac.astype(jnp.float32) * scale
        # mid-tread unbiased quantizer — must mirror ref.adc_transfer
        code = jnp.clip(jnp.floor(v + jnp.float32(0.5) + noise[:, :, i]), 0.0, levels - 1.0)
        rec = jnp.floor(code * (fs / levels) + jnp.float32(0.5))
        rec = rec.astype(jnp.int32)
        shift_out = jnp.clip(i + j_lo, 0, sp.k_max)[:, None]
        contrib = jnp.where(exists[:, None], rec << shift_out, 0)
        acc = acc + plane_sign(i, sp.w_bits) * contrib

    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("block_m",))
def se_tile(a_q, w_q, *, block_m: int = S.PALLAS_BLOCK_M):
    """Saliency-evaluation pass over one K-tile. [M,C],[H,C] -> S[M] i32."""
    sp = S.DEFAULT_SPEC
    m, c = a_q.shape
    h = w_q.shape[0]
    assert m % block_m == 0, (m, block_m)
    return pl.pallas_call(
        functools.partial(_se_kernel, sp=sp),
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, c), lambda g: (g, 0)),
            pl.BlockSpec((h, c), lambda g: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m,), lambda g: (g,)),
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=True,
    )(a_q.astype(jnp.int32), w_q.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("block_m",))
def hybrid_tile(a_q, w_q, b_da, noise, *, block_m: int = S.PALLAS_BLOCK_M):
    """Computing-mode hybrid MAC over one K-tile.

    [M,C],[H,C],[M],[M,H,w_bits] -> [M,H] i32.
    """
    sp = S.DEFAULT_SPEC
    m, c = a_q.shape
    h = w_q.shape[0]
    assert m % block_m == 0, (m, block_m)
    assert noise.shape == (m, h, sp.w_bits), noise.shape
    return pl.pallas_call(
        functools.partial(_hybrid_kernel, sp=sp),
        grid=(m // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, c), lambda g: (g, 0)),
            pl.BlockSpec((h, c), lambda g: (0, 0)),
            pl.BlockSpec((block_m,), lambda g: (g,)),
            pl.BlockSpec((block_m, h, sp.w_bits), lambda g: (g, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, h), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((m, h), jnp.int32),
        interpret=True,
    )(
        a_q.astype(jnp.int32),
        w_q.astype(jnp.int32),
        b_da.astype(jnp.int32),
        noise.astype(jnp.float32),
    )
