"""Bit-plane decomposition helpers (jnp, int32 domain).

The macro decomposes a multi-bit MAC into 1-bit MACs (paper Eq. 1):

    MAC(A, W) = sum_{i,j} s_i * 2^(i+j) * MAC(A[j], W[i])

with s_i = -1 for the weight sign plane (two's complement MSB) and +1
otherwise.  Everything here operates on int32 tensors holding uint8
activations (0..255) and int8 weights (-128..127).
"""

from __future__ import annotations

import jax.numpy as jnp

from . import spec as S


def act_planes(a_q: jnp.ndarray, a_bits: int = S.A_BITS) -> list[jnp.ndarray]:
    """Unsigned activation bit planes, LSB first: list of 0/1 int32 arrays."""
    a = a_q.astype(jnp.int32)
    return [(a >> j) & 1 for j in range(a_bits)]


def weight_planes(w_q: jnp.ndarray, w_bits: int = S.W_BITS) -> list[jnp.ndarray]:
    """Two's-complement weight bit planes, LSB first (MSB is the sign plane).

    Planes are the raw bits of the two's complement encoding, so
    ``w == -2^(w_bits-1)*p[w_bits-1] + sum_{i<w_bits-1} 2^i * p[i]``.
    """
    w = w_q.astype(jnp.int32) & ((1 << w_bits) - 1)
    return [(w >> i) & 1 for i in range(w_bits)]


def plane_sign(i: int, w_bits: int = S.W_BITS) -> int:
    """Sign s_i of weight plane i under two's complement."""
    return -1 if i == w_bits - 1 else 1


def recompose_weights(planes: list[jnp.ndarray], w_bits: int = S.W_BITS) -> jnp.ndarray:
    """Inverse of :func:`weight_planes` (used by tests)."""
    acc = jnp.zeros_like(planes[0])
    for i, p in enumerate(planes):
        acc = acc + plane_sign(i, w_bits) * (p << i)
    return acc


def recompose_acts(planes: list[jnp.ndarray]) -> jnp.ndarray:
    """Inverse of :func:`act_planes` (used by tests)."""
    acc = jnp.zeros_like(planes[0])
    for j, p in enumerate(planes):
        acc = acc + (p << j)
    return acc


def order_partials(a_q: jnp.ndarray, w_q: jnp.ndarray, sp: S.MacroSpec = S.DEFAULT_SPEC) -> jnp.ndarray:
    """All 1-bit MAC partial sums D[i, j, m, h].

    a_q: [M, C] activations, w_q: [H, C] weights ->
    D[i, j] = a_plane_j @ w_plane_i^T, each in [0, C].
    """
    ap = act_planes(a_q, sp.a_bits)
    wp = weight_planes(w_q, sp.w_bits)
    rows = []
    for i in range(sp.w_bits):
        row = [
            jnp.matmul(ap[j], wp[i].T, preferred_element_type=jnp.int32)
            for j in range(sp.a_bits)
        ]
        rows.append(jnp.stack(row))
    return jnp.stack(rows)  # [w_bits, a_bits, M, H]
