"""Post-training quantization of the folded ResNet-mini (DESIGN.md §3).

* weights: per-layer symmetric int8, scale = max|w| / 127
* activations: per-layer uint8; scale calibrated so that the 99.9th
  percentile of the layer's float input maps to 255 (ReLU makes inputs
  non-negative; the input image is already [0,1])
* bias: int32 in the accumulator domain, bias_q = round(b / (s_a * s_w))

The quantized graph (``qgraph``) is the single source of truth consumed by
``model.quant_forward`` (Python oracle), ``aot.py`` (artifact export) and,
via weights.rten + graph.json, by ``rust/src/nn``.
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


def _collect_conv_inputs(convs, fc_w, fc_b, x):
    """Run the folded float graph, recording every conv/fc input tensor."""
    by_name = {name: (w, b, s) for name, w, b, s in convs}
    records = {}

    def conv(name, t):
        records[name] = np.asarray(t)
        w, b, s = by_name[name]
        return M._conv2d(t, jnp.asarray(w), s) + jnp.asarray(b)

    h = jax.nn.relu(conv("stem", x))
    n_blocks = len(M.STAGES) * M.BLOCKS_PER_STAGE
    for li in range(n_blocks):
        t = jax.nn.relu(conv(f"b{li}.conv1", h))
        t = conv(f"b{li}.conv2", t)
        sc = conv(f"b{li}.shortcut", h) if f"b{li}.shortcut" in by_name else h
        h = jax.nn.relu(t + sc)
    h = jnp.mean(h, axis=(1, 2))
    records["fc"] = np.asarray(h)
    return records


def _act_scale(t: np.ndarray, pct: float = 99.9) -> float:
    hi = float(np.percentile(t, pct))
    return max(hi, 1e-6) / M.ACT_QMAX


def quantize(params, state, calib_x: np.ndarray) -> dict:
    """Build the quantized graph from trained params + calibration images.

    calib_x: uint8 [N,32,32,3]; ~256 images suffice.
    """
    convs = M.fold_bn(params, state)
    fc_w = np.asarray(params["fc"]["w"]).T  # [10, 64]
    fc_b = np.asarray(params["fc"]["b"])
    x = jnp.asarray(calib_x, jnp.float32) / 255.0
    records = _collect_conv_inputs(convs, fc_w.T, fc_b, x)

    qconvs = []
    for name, w, b, stride in convs:
        kh, kw, cin, cout = w.shape
        a_scale = _act_scale(records[name])
        w_scale = max(float(np.abs(w).max()), 1e-8) / M.W_QMAX
        # im2col layout [cout, kh*kw*cin] with (dy, dx, c) order, c fastest —
        # matches model.im2col / rust sched::im2col.
        w_mat = w.transpose(3, 0, 1, 2).reshape(cout, kh * kw * cin)
        w_q = np.clip(np.floor(w_mat / w_scale + 0.5), -127, 127).astype(np.int8)
        bias_q = np.floor(b / (a_scale * w_scale) + 0.5).astype(np.int32)
        qconvs.append({
            "name": name, "kh": kh, "kw": kw, "cin": cin, "cout": cout,
            "stride": stride, "act_scale": a_scale, "w_scale": w_scale,
            "w_q": w_q.astype(np.int32), "bias_q": bias_q,
        })

    fc_scale = _act_scale(records["fc"])
    fc_wscale = max(float(np.abs(fc_w).max()), 1e-8) / M.W_QMAX
    fc_wq = np.clip(np.floor(fc_w / fc_wscale + 0.5), -127, 127).astype(np.int8)
    fc_bq = np.floor(fc_b / (fc_scale * fc_wscale) + 0.5).astype(np.int32)
    return {
        "convs": qconvs,
        "fc": {
            "name": "fc", "act_scale": fc_scale, "w_scale": fc_wscale,
            "w_q": fc_wq.astype(np.int32), "bias_q": fc_bq,
        },
    }


def qgraph_tensors(qgraph) -> dict:
    """Flatten the qgraph into named tensors for weights.rten."""
    out = {}
    for c in qgraph["convs"]:
        out[f"{c['name']}.w_q"] = c["w_q"].astype(np.int8)
        out[f"{c['name']}.bias_q"] = c["bias_q"]
        out[f"{c['name']}.scales"] = np.asarray(
            [c["act_scale"], c["w_scale"]], np.float32
        )
    fc = qgraph["fc"]
    out["fc.w_q"] = fc["w_q"].astype(np.int8)
    out["fc.bias_q"] = fc["bias_q"]
    out["fc.scales"] = np.asarray([fc["act_scale"], fc["w_scale"]], np.float32)
    return out


def graph_json(qgraph) -> str:
    """Topology description consumed by rust/src/nn/graph.rs."""
    n_blocks = len(M.STAGES) * M.BLOCKS_PER_STAGE
    convs = {c["name"]: c for c in qgraph["convs"]}
    ops = [{"op": "qconv", "name": "stem", "relu": True}]
    for bi in range(n_blocks):
        ops.append({"op": "qconv", "name": f"b{bi}.conv1", "relu": True})
        ops.append({"op": "qconv", "name": f"b{bi}.conv2", "relu": False})
        if f"b{bi}.shortcut" in convs:
            ops.append({"op": "qconv_shortcut", "name": f"b{bi}.shortcut", "relu": False})
        ops.append({"op": "residual_relu"})
    ops.append({"op": "gap"})
    ops.append({"op": "qfc", "name": "fc"})
    meta = {
        "arch": "resnet-mini",
        "stages": list(M.STAGES),
        "blocks_per_stage": M.BLOCKS_PER_STAGE,
        "num_classes": M.NUM_CLASSES,
        "ops": ops,
        "convs": [
            {k: c[k] for k in ("name", "kh", "kw", "cin", "cout", "stride",
                               "act_scale", "w_scale")}
            for c in qgraph["convs"]
        ],
        "fc": {"act_scale": qgraph["fc"]["act_scale"],
               "w_scale": qgraph["fc"]["w_scale"],
               "cin": int(qgraph["fc"]["w_q"].shape[1]),
               "cout": int(qgraph["fc"]["w_q"].shape[0])},
    }
    return json.dumps(meta, indent=1)


def load_qgraph(tensors: dict, graph: dict) -> dict:
    """Rebuild a qgraph from weights.rten tensors + graph.json (tests)."""
    qconvs = []
    for c in graph["convs"]:
        name = c["name"]
        qconvs.append({
            **c,
            "act_scale": float(tensors[f"{name}.scales"][0]),
            "w_scale": float(tensors[f"{name}.scales"][1]),
            "w_q": tensors[f"{name}.w_q"].astype(np.int32),
            "bias_q": tensors[f"{name}.bias_q"],
        })
    fc = graph["fc"]
    return {
        "convs": qconvs,
        "fc": {
            "name": "fc",
            "act_scale": float(tensors["fc.scales"][0]),
            "w_scale": float(tensors["fc.scales"][1]),
            "w_q": tensors["fc.w_q"].astype(np.int32),
            "bias_q": tensors["fc.bias_q"],
        },
    }
