"""`.rten` tensor container — the Rust<->Python data interchange format.

Little-endian layout (DESIGN.md §7):

    magic   b"RTEN"
    u32     version (1)
    u32     ntensors
    per tensor:
        u32     name length, then utf-8 name bytes
        u8      dtype: 0=f32, 1=i32, 2=i8, 3=u8, 4=i64
        u32     ndim, then u32 * ndim dims (row-major)
        raw     data bytes

Kept deliberately trivial so the Rust reader (rust/src/io/rten.rs) needs no
external dependencies; numpy `.npy`/`.npz` would have dragged zip + a
header DSL across the boundary.
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = b"RTEN"
VERSION = 1

_DTYPES = {
    np.dtype(np.float32): 0,
    np.dtype(np.int32): 1,
    np.dtype(np.int8): 2,
    np.dtype(np.uint8): 3,
    np.dtype(np.int64): 4,
}
_RDTYPES = {v: k for k, v in _DTYPES.items()}


def write(path: str, tensors: dict[str, np.ndarray]) -> None:
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<II", VERSION, len(tensors)))
        for name, arr in tensors.items():
            # NB: ascontiguousarray promotes 0-d to 1-d; preserve scalars.
            arr = np.asarray(arr)
            if arr.ndim > 0:
                arr = np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<B", _DTYPES[arr.dtype]))
            f.write(struct.pack("<I", arr.ndim))
            for d in arr.shape:
                f.write(struct.pack("<I", d))
            f.write(arr.tobytes())


def read(path: str) -> dict[str, np.ndarray]:
    out: dict[str, np.ndarray] = {}
    with open(path, "rb") as f:
        if f.read(4) != MAGIC:
            raise ValueError(f"{path}: bad magic")
        version, n = struct.unpack("<II", f.read(8))
        if version != VERSION:
            raise ValueError(f"{path}: unsupported version {version}")
        for _ in range(n):
            (nlen,) = struct.unpack("<I", f.read(4))
            name = f.read(nlen).decode("utf-8")
            (dt,) = struct.unpack("<B", f.read(1))
            (ndim,) = struct.unpack("<I", f.read(4))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim)) if ndim else ()
            dtype = _RDTYPES[dt]
            count = int(np.prod(dims)) if dims else 1
            data = np.frombuffer(f.read(count * dtype.itemsize), dtype=dtype)
            out[name] = data.reshape(dims).copy()
    return out
