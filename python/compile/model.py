"""L2: ResNet-mini in JAX — float training graph + quantized CIM graph.

Three forward paths, all sharing one parameter set:

1. ``forward`` (train/eval) — float, with BatchNorm; used by ``train.py``
   and, with BN folded, as the accuracy golden (AOT-exported to
   ``artifacts/model.hlo.txt``).
2. ``quant_forward(..., MacroGemm("dcim"))`` — integer exact (loss-free
   DCIM baseline).  Bit-exact with ``rust/src/nn`` in DCIM mode.
3. ``quant_forward(..., MacroGemm("osa"|"hcim"|"acim"))`` — the CIM
   datapath: im2col GEMMs tiled onto 64x144 macros through the L1 kernel
   oracle (:mod:`kernels.ref`; the Pallas kernels lower the same math
   into the AOT tile artifacts executed by Rust).

Architecture (ResNet20-style for 32x32, ~272k params):
    stem conv3x3(3->16) — 3 stages x 2 basic blocks (16/32/64, stride 2
    between stages, 1x1 projection shortcuts) — GAP — FC(64->10).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import ref
from .kernels import spec as S
from .prng import SplitMix64, unit_noise_seed

NUM_CLASSES = 10
STAGES = (16, 32, 64)
BLOCKS_PER_STAGE = 2
BN_EPS = 1e-5
BN_MOM = 0.9
ACT_QMAX = 255  # uint8 activations
W_QMAX = 127  # int8 weights


# --------------------------------------------------------------------------
# parameter construction
# --------------------------------------------------------------------------

def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    std = np.sqrt(2.0 / fan_in)
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * std


def _bn_init(c):
    return {
        "gamma": jnp.ones((c,), jnp.float32),
        "beta": jnp.zeros((c,), jnp.float32),
    }


def _bn_state(c):
    return {
        "mean": jnp.zeros((c,), jnp.float32),
        "var": jnp.ones((c,), jnp.float32),
    }


def init_params(seed: int = 0):
    """Returns (params, bn_state) pytrees."""
    key = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(key, 64))
    params = {"stem": {"w": _conv_init(next(keys), 3, 3, 3, STAGES[0]), "bn": _bn_init(STAGES[0])}}
    state = {"stem": _bn_state(STAGES[0])}
    blocks = []
    bstate = []
    cin = STAGES[0]
    for si, cout in enumerate(STAGES):
        for bi in range(BLOCKS_PER_STAGE):
            stride = 2 if (si > 0 and bi == 0) else 1
            blk = {
                "conv1": {"w": _conv_init(next(keys), 3, 3, cin, cout), "bn": _bn_init(cout)},
                "conv2": {"w": _conv_init(next(keys), 3, 3, cout, cout), "bn": _bn_init(cout)},
            }
            st = {"conv1": _bn_state(cout), "conv2": _bn_state(cout)}
            if stride != 1 or cin != cout:
                blk["shortcut"] = {"w": _conv_init(next(keys), 1, 1, cin, cout), "bn": _bn_init(cout)}
                st["shortcut"] = _bn_state(cout)
            blocks.append(blk)
            bstate.append(st)
            cin = cout
    params["blocks"] = blocks
    state["blocks"] = bstate
    params["fc"] = {
        "w": jax.random.normal(next(keys), (STAGES[-1], NUM_CLASSES), jnp.float32)
        * np.sqrt(1.0 / STAGES[-1]),
        "b": jnp.zeros((NUM_CLASSES,), jnp.float32),
    }
    return params, state


def block_strides():
    out = []
    for si in range(len(STAGES)):
        for bi in range(BLOCKS_PER_STAGE):
            out.append(2 if (si > 0 and bi == 0) else 1)
    return out


def count_params(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))


# --------------------------------------------------------------------------
# float forward (training / golden)
# --------------------------------------------------------------------------

def _conv2d(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )


def _bn_apply(x, bn, mean, var):
    inv = bn["gamma"] * jax.lax.rsqrt(var + BN_EPS)
    return x * inv + (bn["beta"] - mean * inv)


def _bn_train(x, bn, st):
    mean = jnp.mean(x, axis=(0, 1, 2))
    var = jnp.var(x, axis=(0, 1, 2))
    y = _bn_apply(x, bn, mean, var)
    new_st = {
        "mean": BN_MOM * st["mean"] + (1 - BN_MOM) * mean,
        "var": BN_MOM * st["var"] + (1 - BN_MOM) * var,
    }
    return y, new_st


def forward(params, state, x, train: bool):
    """x: [N,32,32,3] float in [0,1]. Returns (logits, new_state)."""
    new_state = {"stem": dict(state["stem"]), "blocks": []}

    def bn(t, p, st):
        if train:
            return _bn_train(t, p, st)
        return _bn_apply(t, p, st["mean"], st["var"]), st

    h = _conv2d(x, params["stem"]["w"])
    h, new_state["stem"] = bn(h, params["stem"]["bn"], state["stem"])
    h = jax.nn.relu(h)
    strides = block_strides()
    for blk, st, stride in zip(params["blocks"], state["blocks"], strides):
        nst = {}
        t = _conv2d(h, blk["conv1"]["w"], stride)
        t, nst["conv1"] = bn(t, blk["conv1"]["bn"], st["conv1"])
        t = jax.nn.relu(t)
        t = _conv2d(t, blk["conv2"]["w"])
        t, nst["conv2"] = bn(t, blk["conv2"]["bn"], st["conv2"])
        if "shortcut" in blk:
            sc = _conv2d(h, blk["shortcut"]["w"], stride)
            sc, nst["shortcut"] = bn(sc, blk["shortcut"]["bn"], st["shortcut"])
        else:
            sc = h
        h = jax.nn.relu(t + sc)
        new_state["blocks"].append(nst)
    h = jnp.mean(h, axis=(1, 2))
    logits = h @ params["fc"]["w"] + params["fc"]["b"]
    return logits, new_state


def forward_eval(params, state, x):
    return forward(params, state, x, train=False)[0]


# --------------------------------------------------------------------------
# BN folding -> inference conv list
# --------------------------------------------------------------------------

def fold_bn(params, state):
    """Folds BN into conv weights/biases.

    Returns an ordered list of (name, w[kh,kw,cin,cout] float, bias, stride)
    — the inference graph shared with Rust via graph.json + weights.rten.
    """

    def fold(conv, st):
        w, bn = conv["w"], conv["bn"]
        inv = np.asarray(bn["gamma"]) / np.sqrt(np.asarray(st["var"]) + BN_EPS)
        wf = np.asarray(w) * inv[None, None, None, :]
        bf = np.asarray(bn["beta"]) - np.asarray(st["mean"]) * inv
        return wf, bf

    convs = []
    wf, bf = fold(params["stem"], state["stem"])
    convs.append(("stem", wf, bf, 1))
    strides = block_strides()
    for li, (blk, st, stride) in enumerate(zip(params["blocks"], state["blocks"], strides)):
        w1, b1 = fold(blk["conv1"], st["conv1"])
        convs.append((f"b{li}.conv1", w1, b1, stride))
        w2, b2 = fold(blk["conv2"], st["conv2"])
        convs.append((f"b{li}.conv2", w2, b2, 1))
        if "shortcut" in blk:
            ws, bs = fold(blk["shortcut"], st["shortcut"])
            convs.append((f"b{li}.shortcut", ws, bs, stride))
    return convs


def folded_forward(convs, fc_w, fc_b, x):
    """Float forward through the folded graph — must match forward_eval."""
    by_name = {name: (w, b, s) for name, w, b, s in convs}

    def conv(name, t):
        w, b, s = by_name[name]
        return _conv2d(t, jnp.asarray(w), s) + jnp.asarray(b)

    h = jax.nn.relu(conv("stem", x))
    n_blocks = len(STAGES) * BLOCKS_PER_STAGE
    for li in range(n_blocks):
        t = jax.nn.relu(conv(f"b{li}.conv1", h))
        t = conv(f"b{li}.conv2", t)
        sc = conv(f"b{li}.shortcut", h) if f"b{li}.shortcut" in by_name else h
        h = jax.nn.relu(t + sc)
    h = jnp.mean(h, axis=(1, 2))
    return h @ jnp.asarray(fc_w) + jnp.asarray(fc_b)


# --------------------------------------------------------------------------
# quantized CIM forward (oracle for rust/src/nn + sched)
# --------------------------------------------------------------------------

def quant_round(x):
    """round-half-up, matching Rust's `(x + 0.5).floor()`."""
    return jnp.floor(x + 0.5)


def act_quantize(x, scale):
    """uint8 activation quantization; clamp at 0 doubles as ReLU."""
    return jnp.clip(quant_round(x / scale), 0, ACT_QMAX).astype(jnp.int32)


def im2col(x, kh, kw, stride, pad):
    """[N,H,W,C] -> patches [N, Ho, Wo, kh*kw*C] (zero padded).

    Patch layout is (dy, dx, c) fastest-to-slowest = c fastest — the same
    layout rust/src/sched/im2col.rs produces and weights.rten stores.
    """
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    cols = []
    for dy in range(kh):
        for dx in range(kw):
            cols.append(
                jax.lax.slice(
                    xp,
                    (0, dy, dx, 0),
                    (0 + n, dy + (ho - 1) * stride + 1, dx + (wo - 1) * stride + 1, c),
                    (1, stride, stride, 1),
                )
            )
    return jnp.concatenate(cols, axis=-1).reshape(n, ho, wo, kh * kw * c)


def pad_to(x, axis, mult):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, rem)
    return jnp.pad(x, pads)


class MacroGemm:
    """Tiled integer GEMM through the macro datapath (oracle for sched/).

    A_q [M, K] uint8-as-i32, W_q [N, K] int8-as-i32 -> [M, N] i32.
    K tiled by COLS (144), N tiled by HMUS (8); per-(sample, N-tile)
    saliency accumulated over K-tiles selects B_D/A (OSA); or a fixed
    boundary (HCIM); or B=0 all-digital (DCIM); or full-analog (ACIM).
    """

    def __init__(self, mode: str, thresholds=None, fixed_b: int = 8,
                 noise_seed: int = 0, sp: S.MacroSpec = S.DEFAULT_SPEC):
        assert mode in ("dcim", "hcim", "osa", "acim")
        self.mode = mode
        self.sp = sp
        self.fixed_b = fixed_b
        self.thresholds = None if thresholds is None else np.asarray(thresholds, np.int32)
        self.noise_seed = noise_seed
        self.stats = {"macro_ops": 0, "b_hist": np.zeros(16, np.int64)}
        self.last_bda = None

    def _noise(self, shape, streams):
        """One K-tile's noise: row ``s`` draws ``prod(shape[1:])`` normals
        from its own per-unit stream (Rust convention, DESIGN.md §6)."""
        if self.sp.sigma_code == 0.0:
            return jnp.zeros(shape, jnp.float32)
        m = shape[0]
        per_row = int(np.prod(shape[1:]))
        vals = np.empty((m, per_row), np.float64)
        for s in range(m):
            vals[s] = np.asarray(streams[s].normals(per_row), np.float64)
        vals *= self.sp.sigma_code
        return jnp.asarray(vals.astype(np.float32).reshape(shape))

    def __call__(self, a_q, w_q, layer_idx: int):
        sp = self.sp
        m, k = a_q.shape
        n = w_q.shape[0]
        a_p = pad_to(a_q, 1, sp.cols)
        w_p = pad_to(pad_to(w_q, 1, sp.cols), 0, sp.hmus)
        kt = a_p.shape[1] // sp.cols
        nt = w_p.shape[0] // sp.hmus

        if self.mode == "dcim":
            self.stats["macro_ops"] += m * kt * nt
            self.stats["b_hist"][0] += m * kt * nt
            self.last_bda = np.zeros((m, nt), np.int32)
            return ref.exact_mac(a_p, w_p)[:, :n]

        bda_all = np.zeros((m, nt), np.int32)
        out = jnp.zeros((m, w_p.shape[0]), jnp.int32)
        for ni in range(nt):
            w_rows = w_p[ni * sp.hmus:(ni + 1) * sp.hmus]
            if self.mode == "osa":
                s_acc = jnp.zeros((m,), jnp.int32)
                for ki in range(kt):
                    a_t = a_p[:, ki * sp.cols:(ki + 1) * sp.cols]
                    w_t = w_rows[:, ki * sp.cols:(ki + 1) * sp.cols]
                    s_acc = s_acc + ref.saliency_ref(a_t, w_t, sp)
                # N/Q normalization by the layer's true (unpadded) K
                s_norm = jnp.asarray(
                    S.normalize_saliency(np.asarray(s_acc), k, sp.cols), jnp.int32
                )
                b_da = ref.select_boundary(
                    s_norm, jnp.asarray(self.thresholds), jnp.asarray(S.B_CANDIDATES)
                )
            elif self.mode == "hcim":
                b_da = jnp.full((m,), self.fixed_b, jnp.int32)
            else:  # acim
                b_da = None

            # per-unit noise streams (Rust convention): row s of N-tile ni
            # draws from its own stream, advanced K-tile-major
            streams = [
                SplitMix64(unit_noise_seed(self.noise_seed, layer_idx, s, ni))
                for s in range(m)
            ]
            acc = jnp.zeros((m, sp.hmus), jnp.int32)
            for ki in range(kt):
                a_t = a_p[:, ki * sp.cols:(ki + 1) * sp.cols]
                w_t = w_rows[:, ki * sp.cols:(ki + 1) * sp.cols]
                if self.mode == "acim":
                    n_slices = (sp.a_bits + sp.analog_band - 1) // sp.analog_band
                    noise = self._noise((m, sp.hmus, sp.w_bits, n_slices), streams)
                    acc = acc + ref.acim_mac_ref(a_t, w_t, noise, sp)
                else:
                    noise = self._noise((m, sp.hmus, sp.w_bits), streams)
                    acc = acc + ref.hybrid_mac_ref(a_t, w_t, b_da, noise, sp)
            out = out.at[:, ni * sp.hmus:(ni + 1) * sp.hmus].set(acc)
            self.stats["macro_ops"] += m * kt
            if b_da is not None:
                bda_np = np.asarray(b_da)
                bda_all[:, ni] = bda_np
                self.stats["b_hist"] += np.bincount(bda_np, minlength=16)[:16] * kt
        self.last_bda = bda_all
        return out[:, :n]


def quant_forward(qgraph, x, gemm: MacroGemm, collect_bda: bool = False):
    """Quantized inference through the graph produced by quantize.py.

    x float NHWC in [0,1].  Returns (logits [N,10] float, bda_maps) where
    bda_maps is a list of (layer_name, [N,Ho,Wo] most-precise-B map) when
    ``collect_bda`` and the gemm runs OSA mode.
    """
    h = x
    bda_maps = []
    n_blocks = len(STAGES) * BLOCKS_PER_STAGE
    by_name = {c["name"]: c for c in qgraph["convs"]}

    def qconv(name, xf, layer_idx):
        c = by_name[name]
        a_scale, w_scale = c["act_scale"], c["w_scale"]
        kh, kw, stride = c["kh"], c["kw"], c["stride"]
        pad = (kh - 1) // 2
        a_q = act_quantize(xf, a_scale)
        patches = im2col(a_q, kh, kw, stride, pad)
        nb, ho, wo, kdim = patches.shape
        a_mat = patches.reshape(nb * ho * wo, kdim)
        acc = gemm(a_mat, jnp.asarray(c["w_q"], jnp.int32), layer_idx)
        if collect_bda and gemm.mode == "osa" and gemm.last_bda is not None:
            # min over N-tiles = the most precise boundary chosen at this
            # output position (Fig 8a visualization convention)
            bmap = gemm.last_bda.min(axis=1).reshape(nb, ho, wo)
            bda_maps.append((name, bmap))
        acc = acc + jnp.asarray(c["bias_q"], jnp.int32)[None, :]
        outf = acc.astype(jnp.float32) * jnp.float32(a_scale * w_scale)
        return outf.reshape(nb, ho, wo, -1)

    li = 0
    h = qconv("stem", h, li); li += 1
    h = jax.nn.relu(h)
    for bi in range(n_blocks):
        t = jax.nn.relu(qconv(f"b{bi}.conv1", h, li)); li += 1
        t = qconv(f"b{bi}.conv2", t, li); li += 1
        if f"b{bi}.shortcut" in by_name:
            sc = qconv(f"b{bi}.shortcut", h, li); li += 1
        else:
            sc = h
        h = jax.nn.relu(t + sc)
    h = jnp.mean(h, axis=(1, 2))
    fc = qgraph["fc"]
    h_q = act_quantize(h, fc["act_scale"])
    logits = (
        (jnp.matmul(h_q, jnp.asarray(fc["w_q"], jnp.int32).T,
                    preferred_element_type=jnp.int32)
         + jnp.asarray(fc["bias_q"], jnp.int32)[None, :]).astype(jnp.float32)
        * jnp.float32(fc["act_scale"] * fc["w_scale"])
    )
    return logits, bda_maps
