"""SplitMix64 PRNG + Box-Muller normals, bit-identical to rust/src/util/prng.rs.

The hot path never samples noise inside a kernel: the caller (Rust L3, or
the Python model for build-time evaluation) draws noise buffers from this
generator and passes them in as explicit inputs, so the native Rust
simulator and the PJRT artifact can be compared bit-exactly on the same
buffer.  Cross-language parity of the *generator itself* is asserted
against golden vectors embedded in artifacts/spec.json (f64 values may
differ across libm implementations by ~1 ulp in ln/cos, so the parity test
uses a 1e-12 relative tolerance; integer u64 output is exact).
"""

from __future__ import annotations

import math

MASK64 = (1 << 64) - 1
GOLDEN = 0x9E3779B97F4A7C15


class SplitMix64:
    """Sebastiano Vigna's splitmix64; the sole seeding primitive."""

    def __init__(self, seed: int):
        self.state = seed & MASK64

    def next_u64(self) -> int:
        self.state = (self.state + GOLDEN) & MASK64
        z = self.state
        z = ((z ^ (z >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
        z = ((z ^ (z >> 27)) * 0x94D049BB133111EB) & MASK64
        return (z ^ (z >> 31)) & MASK64

    def next_f64(self) -> float:
        """Uniform in [0, 1) with 53 random bits."""
        return (self.next_u64() >> 11) * (2.0 ** -53)

    def next_normal(self) -> float:
        """One standard normal via Box-Muller (cosine branch only).

        Consumes exactly two u64s per call so the stream position is
        easy to reason about on both sides of the FFI boundary.
        """
        u1 = self.next_f64()
        u2 = self.next_f64()
        if u1 <= 0.0:
            u1 = 2.0 ** -53
        return math.sqrt(-2.0 * math.log(u1)) * math.cos(2.0 * math.pi * u2)

    def normals(self, n: int) -> list[float]:
        """n standard normals, using BOTH Box-Muller branches per pair of
        u64 draws — bit-identical with rust ``SplitMix64::normals_f32``."""
        out: list[float] = []
        while len(out) < n:
            u1 = self.next_f64()
            u2 = self.next_f64()
            if u1 <= 0.0:
                u1 = 2.0 ** -53
            r = math.sqrt(-2.0 * math.log(u1))
            t = 2.0 * math.pi * u2
            out.append(r * math.cos(t))
            if len(out) < n:
                out.append(r * math.sin(t))
        return out


def layer_noise_seed(base_seed: int, layer_idx: int) -> int:
    """Convention shared with Rust: per-layer noise stream seed."""
    return (base_seed ^ ((layer_idx + 1) * GOLDEN)) & MASK64


def unit_noise_seed(base_seed: int, layer_idx: int, row: int, tile_idx: int) -> int:
    """Per-work-unit noise stream seed, shared with Rust
    ``prng::unit_noise_seed``: one independent stream per
    ``(layer, row, N-tile)`` work unit, advanced K-tile-major inside the
    unit.  Depends only on the unit's coordinates, so the execution
    schedule (thread count, unit order) can never shift the noise."""
    h = layer_noise_seed(base_seed, layer_idx)
    h = (h + (row + 1) * 0xBF58476D1CE4E5B9) & MASK64
    h = (h + (tile_idx + 1) * 0x94D049BB133111EB) & MASK64
    return SplitMix64(h).next_u64()


def golden_vectors(seed: int = 0xC1A0_05A1_1CE5_2024, n: int = 64) -> dict:
    """Golden parity vectors embedded in spec.json and checked by Rust.

    u64 values are hex strings — JSON numbers are f64 and would lose the
    top bits of a 64-bit integer in any standards-compliant parser.
    """
    g_u = SplitMix64(seed)
    g_n = SplitMix64(seed)
    return {
        "seed_hex": f"{seed:016x}",
        "u64_hex": [f"{g_u.next_u64():016x}" for _ in range(n)],
        "normal": g_n.normals(n),
    }
