"""Build-time training of ResNet-mini on SynthCIFAR (float, CPU JAX).

Runs once from ``aot.py`` (skipped when artifacts/weights.rten exists).
A hand-rolled Adam is used — optax is not in the offline image.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params), "t": jnp.zeros(())}


def adam_update(params, grads, opt, lr, b1=0.9, b2=0.999, eps=1e-8):
    t = opt["t"] + 1.0
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    mhat_scale = 1.0 / (1.0 - b1 ** t)
    vhat_scale = 1.0 / (1.0 - b2 ** t)
    new_params = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps),
        params, m, v,
    )
    return new_params, {"m": m, "v": v, "t": t}


def cross_entropy(logits, labels):
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))


def augment(key, x):
    """Random horizontal flip + up-to-3px shift (pad & crop)."""
    n = x.shape[0]
    kf, ks = jax.random.split(key)
    flip = jax.random.bernoulli(kf, 0.5, (n,))
    x = jnp.where(flip[:, None, None, None], x[:, :, ::-1, :], x)
    shifts = jax.random.randint(ks, (n, 2), -3, 4)
    xp = jnp.pad(x, ((0, 0), (3, 3), (3, 3), (0, 0)))

    def crop(img, dy, dx):
        return jax.lax.dynamic_slice(img, (dy + 3, dx + 3, 0), (32, 32, 3))

    return jax.vmap(crop)(xp, shifts[:, 0], shifts[:, 1])


def train(
    data: dict,
    epochs: int = 18,
    batch: int = 128,
    lr: float = 2e-3,
    seed: int = 0,
    verbose: bool = True,
):
    """Returns (params, bn_state, history)."""
    params, state = M.init_params(seed)
    opt = adam_init(params)
    x_all = jnp.asarray(data["train_x"], jnp.float32) / 255.0
    y_all = jnp.asarray(data["train_y"], jnp.int32)
    n = x_all.shape[0]
    steps = n // batch

    @jax.jit
    def step(params, state, opt, key, xb, yb, lr_now):
        xb = augment(key, xb)

        def loss_fn(p):
            logits, new_state = M.forward(p, state, xb, train=True)
            return cross_entropy(logits, yb), new_state

        (loss, new_state), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt = adam_update(params, grads, opt, lr_now)
        return params, new_state, opt, loss

    @jax.jit
    def eval_batch(params, state, xb):
        return jnp.argmax(M.forward_eval(params, state, xb), axis=1)

    def accuracy(params, state, x, y, bs=256):
        correct = 0
        for s in range(0, len(x), bs):
            pred = eval_batch(params, state, jnp.asarray(x[s:s + bs], jnp.float32) / 255.0)
            correct += int(jnp.sum(pred == jnp.asarray(y[s:s + bs])))
        return correct / len(x)

    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed + 1)
    history = []
    t0 = time.time()
    for ep in range(epochs):
        perm = rng.permutation(n)
        lr_now = lr * 0.5 * (1 + np.cos(np.pi * ep / epochs))
        losses = []
        for s in range(steps):
            idx = perm[s * batch:(s + 1) * batch]
            key, sub = jax.random.split(key)
            params, state, opt, loss = step(
                params, state, opt, sub, x_all[idx], y_all[idx], lr_now
            )
            losses.append(float(loss))
        test_acc = accuracy(params, state, data["test_x"], data["test_y"])
        history.append({"epoch": ep, "loss": float(np.mean(losses)), "test_acc": test_acc})
        if verbose:
            print(
                f"[train] epoch {ep:2d} loss {np.mean(losses):.4f} "
                f"test_acc {test_acc:.4f} lr {lr_now:.2e} ({time.time()-t0:.0f}s)",
                flush=True,
            )
    return params, state, history
