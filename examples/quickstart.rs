//! Quickstart: load the AOT artifacts, run a few SynthCIFAR images
//! through OSA-HCIM, and print accuracy + modeled efficiency.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::figures::FigCtx;

fn main() -> anyhow::Result<()> {
    osa_hcim::util::logging::init();
    let cfg = SystemConfig::default();
    let ctx = FigCtx::load(cfg)?;

    println!("OSA-HCIM quickstart — {} test images available\n", ctx.ds.test_n());
    for (mode, fixed_b) in [
        (CimMode::Dcim, 0),
        (CimMode::Hcim, 8),
        (CimMode::Osa, 8),
    ] {
        let ev = ctx.eval_mode(mode, fixed_b, &ctx.cfg.thresholds, 32)?;
        println!(
            "{:<5}  acc {:>6.2}%  {:>5.2} TOPS/W  {:>8.1} nJ/image",
            mode.name(),
            ev.acc * 100.0,
            ev.tops_w,
            ev.energy_nj_per_img
        );
    }
    println!("\n(the OSA row uses the default thresholds; run the");
    println!(" calibrate_thresholds example to fit them to a loss profile)");
    Ok(())
}
