//! End-to-end driver proving all three layers compose (EXPERIMENTS.md §E2E):
//!
//! 1. validates `artifacts/` (spec parity, dataset, weights, goldens);
//! 2. runs the **PJRT float golden** (L2 JAX model, AOT-lowered, loaded by
//!    the L3 Rust runtime) over the test set;
//! 3. cross-checks the **native DCIM** path bit-for-bit against the
//!    Python-quantized golden logits;
//! 4. cross-checks the **PJRT hybrid tile** (L1 Pallas kernel, lowered to
//!    HLO) against the native cycle-level simulator on identical noise;
//! 5. serves the test set through the threaded coordinator in OSA mode
//!    and reports the headline numbers: accuracy vs DCIM, TOPS/W ratio,
//!    latency percentiles.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_inference
//! ```

use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::coordinator::Server;
use osa_hcim::engine::{Backend, Engine};
use osa_hcim::figures::FigCtx;
use osa_hcim::nn::{accuracy, Executor};
use osa_hcim::runtime::Runtime;
use osa_hcim::spec::TILE_M;
use osa_hcim::util::prng::SplitMix64;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    osa_hcim::util::logging::init();
    let cfg = SystemConfig::default();
    println!("=== OSA-HCIM end-to-end driver ===\n");

    // ---- 1. artifacts -----------------------------------------------------
    cfg.spec.validate_against_artifacts(&cfg.artifacts_dir)?;
    let ctx = FigCtx::load(cfg.clone())?;
    println!(
        "[1] artifacts OK: {} train / {} test images, {} conv layers, float acc {:.2}%",
        ctx.ds.train_n(),
        ctx.ds.test_n(),
        ctx.graph().convs.len(),
        ctx.golden.float_acc * 100.0
    );

    // ---- 2. PJRT float golden over the full test set ----------------------
    let rt = Runtime::load(&cfg.artifacts_dir, true)?;
    let n_all = ctx.ds.test_n();
    let t0 = std::time::Instant::now();
    let float_logits = rt.model_forward_all(&ctx.ds.test_x, n_all, ctx.golden.classes)?;
    let float_acc = accuracy(&float_logits, &ctx.ds.test_y, ctx.golden.classes);
    println!(
        "[2] PJRT float model: acc {:.2}% over {n_all} images ({:.2}s, platform {})",
        float_acc * 100.0,
        t0.elapsed().as_secs_f64(),
        rt.platform()
    );

    // ---- 3. native DCIM vs python golden ----------------------------------
    let n_golden = ctx.golden.golden_n;
    let (imgs, labels) = ctx.ds.test_batch(0, n_golden);
    let mut exec = Executor::new(ctx.graph(), ctx.backend(CimMode::Dcim)?);
    let (logits, _) = exec.forward(imgs, labels.len())?;
    let mut max_rel = 0.0f32;
    for (a, b) in logits.iter().zip(&ctx.golden.dcim_logits) {
        max_rel = max_rel.max((a - b).abs() / b.abs().max(1.0));
    }
    anyhow::ensure!(max_rel < 1.5e-2, "native DCIM diverged: {max_rel}");
    println!("[3] native DCIM == python golden (max rel err {max_rel:.2e} on {n_golden} images)");

    // ---- 4. PJRT hybrid tile vs native simulator, identical noise ---------
    let sp = cfg.spec;
    let mut rng = SplitMix64::new(42);
    let a: Vec<i32> = (0..TILE_M * sp.cols).map(|_| rng.next_range_i32(0, 256)).collect();
    let w: Vec<i32> = (0..sp.hmus * sp.cols).map(|_| rng.next_range_i32(-128, 128)).collect();
    let b: Vec<i32> = (0..TILE_M).map(|_| rng.next_range_i32(0, 12)).collect();
    let noise = rng.normals_f32(TILE_M * sp.hmus * sp.w_bits, sp.sigma_code);
    let pjrt_out = rt.hybrid_tile(&a, &w, &b, &noise)?;
    let unit = osa_hcim::macrosim::MacroUnit::new(&w, sp)?;
    let mut mism = 0usize;
    for s in 0..TILE_M {
        let packed = unit.pack_acts(&a[s * sp.cols..(s + 1) * sp.cols]);
        let nslice = &noise[s * sp.hmus * sp.w_bits..(s + 1) * sp.hmus * sp.w_bits];
        let native = unit.compute_hybrid(&packed, b[s], nslice);
        if native != pjrt_out[s * sp.hmus..(s + 1) * sp.hmus] {
            mism += 1;
        }
    }
    anyhow::ensure!(mism == 0, "{mism}/{TILE_M} rows mismatch between PJRT and native");
    println!("[4] PJRT hybrid tile (Pallas L1) == native simulator, bit-exact on {TILE_M} rows");

    // sanity: the registry's pjrt backend drives a whole GEMM through the
    // artifact runtime (its own Runtime instance, selected by name)
    let mut pjrt_cfg = cfg.clone();
    pjrt_cfg.mode = CimMode::Hcim;
    pjrt_cfg.backend = "pjrt".to_string();
    match Engine::builder().config(pjrt_cfg).graph(ctx.engine.graph().clone()).build() {
        Ok(pjrt_engine) => {
            let mut pjrt_gemm = pjrt_engine.backend()?;
            let r = pjrt_gemm.gemm(&a[..4 * sp.cols], 4, sp.cols, &w, sp.hmus, 0)?;
            println!(
                "    pjrt backend OK ({} macro ops accounted)",
                r.account.macro_ops
            );
        }
        Err(e) => println!("    pjrt backend skipped ({e:#})"),
    }

    // ---- 5. serve the test set through the coordinator (OSA) --------------
    // DCIM reference efficiency for the ratio
    let dcim = ctx.eval_mode(CimMode::Dcim, 0, &[], 64)?;
    let serve_n = 256.min(n_all);
    let graph = ctx.engine.graph().clone();
    // the closed-loop burst below submits everything up front: size the
    // admission bound so it exercises batching, not backpressure
    let mut serve_cfg = cfg.clone();
    serve_cfg.queue_cap = serve_cfg.queue_cap.max(serve_n);
    let engine = Engine::builder().config(serve_cfg).graph(graph).build()?;
    let server = Server::with_engine(Arc::new(engine))?;
    let mut pending = Vec::with_capacity(serve_n);
    for i in 0..serve_n {
        let (img, _) = ctx.ds.test_batch(i, 1);
        pending.push((i, server.submit(img.to_vec())?));
    }
    let mut correct = 0usize;
    for (i, rx) in pending {
        let resp = rx.recv()?;
        anyhow::ensure!(resp.error.is_none(), "request {i} failed: {:?}", resp.error);
        if resp.pred as i32 == ctx.ds.test_y[i] {
            correct += 1;
        }
    }
    let metrics = server.shutdown();
    let osa_acc = correct as f64 / serve_n as f64;

    let osa_tw = metrics.tops_per_watt(&cfg.spec);
    println!(
        "[5] coordinator served {serve_n} requests in OSA mode:\n\
         \n    headline: OSA-HCIM acc {:.2}% (drop {:.2}% vs DCIM {:.2}%)\n\
         \n    OSA  {:.2} TOPS/W vs DCIM {:.2} TOPS/W -> {:.2}x efficiency (paper: 1.95x)\n\
         \n    {}",
        osa_acc * 100.0,
        (dcim.acc - osa_acc) * 100.0,
        dcim.acc * 100.0,
        osa_tw,
        dcim.tops_w,
        osa_tw / dcim.tops_w,
        metrics.report(&cfg.spec)
    );
    println!("\n=== end-to-end complete: all layers compose ===");
    Ok(())
}
