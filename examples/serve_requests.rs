//! Serving demo: drive the threaded coordinator (router + dynamic
//! batcher + worker pool) with a bursty open-loop workload and report
//! latency percentiles, batching behaviour, throughput and modeled
//! macro efficiency.
//!
//! ```bash
//! cargo run --release --example serve_requests -- \
//!     [--requests N] [--workers N] [--max-batch N] [--rps N]
//! ```

#![allow(clippy::field_reassign_with_default)] // repo config idiom

use osa_hcim::config::SystemConfig;
use osa_hcim::coordinator::Server;
use osa_hcim::figures::FigCtx;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    osa_hcim::util::logging::init();
    let mut cfg = SystemConfig::default();
    cfg.workers = arg("--workers").and_then(|s| s.parse().ok()).unwrap_or(4);
    cfg.max_batch = arg("--max-batch").and_then(|s| s.parse().ok()).unwrap_or(32);
    let n: usize = arg("--requests").and_then(|s| s.parse().ok()).unwrap_or(512);
    let rps: f64 = arg("--rps").and_then(|s| s.parse().ok()).unwrap_or(400.0);

    let ctx = FigCtx::load(cfg.clone())?;
    let n = n.min(ctx.ds.test_n());
    // open-loop demo: admit the whole run even if the pool lags
    cfg.queue_cap = cfg.queue_cap.max(n);
    let graph = ctx.engine.graph().clone();
    let engine = osa_hcim::engine::Engine::builder().config(cfg.clone()).graph(graph).build()?;
    let server = Server::with_engine(Arc::new(engine))?;
    println!(
        "serving {n} requests at ~{rps:.0} req/s (workers={}, max_batch={}, mode={})",
        cfg.workers,
        cfg.max_batch,
        cfg.mode.name()
    );

    // open-loop arrival: deterministic jittered inter-arrival times,
    // cycling through the QoS tiers (gold / silver / batch)
    let tiers = osa_hcim::serve::Tier::ALL;
    let mut rng = osa_hcim::util::prng::SplitMix64::new(7);
    let mut pending = Vec::with_capacity(n);
    let t0 = Instant::now();
    for i in 0..n {
        let (img, _) = ctx.ds.test_batch(i, 1);
        pending.push((i, server.submit_tier(img.to_vec(), tiers[i % tiers.len()])?));
        let jitter = 0.5 + rng.next_f64(); // 0.5..1.5x the base gap
        std::thread::sleep(Duration::from_secs_f64(jitter / rps));
    }
    let mut correct = 0usize;
    for (i, rx) in pending {
        let resp = rx.recv()?;
        if let Some(err) = &resp.error {
            anyhow::bail!("request {i} failed in the worker: {err}");
        }
        if resp.pred as i32 == ctx.ds.test_y[i] {
            correct += 1;
        }
    }
    let wall = t0.elapsed();
    let metrics = server.shutdown();
    println!("\nresults:");
    println!("  accuracy      {:.2}%", correct as f64 / n as f64 * 100.0);
    println!("  wall time     {:.2}s ({:.1} req/s effective)", wall.as_secs_f64(),
             n as f64 / wall.as_secs_f64());
    println!("  p50 latency   {:.1} ms", metrics.p50_latency_us() / 1e3);
    println!("  p95 latency   {:.1} ms", metrics.p95_latency_us() / 1e3);
    println!("  mean batch    {:.1}", metrics.mean_batch());
    println!("  batches       {}", metrics.batches);
    println!("  macro model   {:.2} TOPS/W", metrics.tops_per_watt(&cfg.spec));
    for tier in tiers {
        let t = metrics.tier(tier);
        println!(
            "  tier {:<6}   {} reqs  p50 {:.1} ms  p99 {:.1} ms  mean_B {:.2}",
            tier.name(),
            t.requests,
            t.p50_latency_us() / 1e3,
            t.p99_latency_us() / 1e3,
            t.mean_boundary()
        );
    }
    Ok(())
}
