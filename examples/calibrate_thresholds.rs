//! Fig 4b threshold calibration across the loss-constraint profiles,
//! with an `--nq-shift` ablation knob on the OSE's N/Q compression.
//!
//! The N/Q shift controls how much of the high-order 1-bit-MAC dynamic
//! range survives into the saliency score S: too coarse a shift maps
//! most DMACs to 0 and the OSE loses its ability to separate salient
//! from non-salient pixels (DESIGN.md §3).
//!
//! ```bash
//! cargo run --release --example calibrate_thresholds -- \
//!     [--nq-shift N] [--calib-images N] [--profile name]
//! ```

use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::figures::{calibrate_osa, FigCtx};
use osa_hcim::osa::{loss_profile, PROFILES};

fn arg(name: &str) -> Option<String> {
    let args: Vec<String> = std::env::args().collect();
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    osa_hcim::util::logging::init();
    let cfg = SystemConfig::default();
    let calib_n: usize = arg("--calib-images").and_then(|s| s.parse().ok()).unwrap_or(32);
    let eval_n: usize = arg("--images").and_then(|s| s.parse().ok()).unwrap_or(96);
    let only: Option<String> = arg("--profile");
    // override AFTER load: spec.json validation pins the default value,
    // the ablation intentionally departs from it
    let mut ctx = FigCtx::load(cfg)?;
    if let Some(shift) = arg("--nq-shift").and_then(|s| s.parse::<i32>().ok()) {
        ctx.cfg.spec.nq_shift = shift;
        println!("[ablation] NQ shift override: {shift}");
    }

    let dcim = ctx.eval_mode(CimMode::Dcim, 0, &[], eval_n)?;
    println!(
        "DCIM baseline: acc {:.2}%  ce {:.4}  {:.2} TOPS/W\n",
        dcim.acc * 100.0,
        dcim.ce,
        dcim.tops_w
    );

    for profile in PROFILES {
        if let Some(ref p) = only {
            if p != profile {
                continue;
            }
        }
        let constraints = loss_profile(profile).unwrap();
        let t0 = std::time::Instant::now();
        let cal = calibrate_osa(&ctx, &constraints, calib_n)?;
        let ev = ctx.eval_mode(CimMode::Osa, ctx.cfg.fixed_b, &cal.thresholds, eval_n)?;
        println!(
            "profile {:<8} thresholds {:?}  ({} evals, {:.0}s)",
            profile,
            cal.thresholds,
            cal.evals,
            t0.elapsed().as_secs_f64()
        );
        println!(
            "  -> test acc {:.2}% (drop {:.2}%)  {:.2} TOPS/W  ({:.2}x vs DCIM)  B-hist {:?}",
            ev.acc * 100.0,
            (dcim.acc - ev.acc) * 100.0,
            ev.tops_w,
            dcim.energy_nj_per_img / ev.energy_nj_per_img,
            &ev.b_hist[5..11]
        );
    }
    Ok(())
}
