//! Fig 8a demo: render the per-pixel B_D/A maps the OSE assigns across
//! hidden layers for one test image — the object should get precise
//! (digital-heavy) boundaries, the background coarse (analog/discard).
//!
//! ```bash
//! cargo run --release --example saliency_map -- [image_idx]
//! ```

use osa_hcim::config::SystemConfig;
use osa_hcim::figures::{self, FigCtx};

fn main() -> anyhow::Result<()> {
    osa_hcim::util::logging::init();
    let idx: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0);
    let ctx = FigCtx::load(SystemConfig::default())?;

    // render the input image itself as ASCII luminance for comparison
    let (img, label) = ctx.ds.test_batch(idx, 1);
    println!("input image {idx} (label {}):", label[0]);
    let ramp = [' ', '.', ':', '=', '+', '*', '#', '@'];
    for y in 0..32 {
        print!("    |");
        for x in 0..32 {
            let o = (y * 32 + x) * 3;
            let lum = (img[o] as u32 + img[o + 1] as u32 + img[o + 2] as u32) / 3;
            print!("{}", ramp[(lum as usize * ramp.len()) / 256]);
        }
        println!("|");
    }
    println!();
    let text = figures::fig8a(&ctx, idx, &["stem", "b2.conv1", "b4.conv1"])?;
    println!("{text}");
    Ok(())
}
