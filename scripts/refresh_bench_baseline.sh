#!/usr/bin/env bash
# Re-anchor the CI bench-gate floors on this machine: run the pipeline
# bench 3x, take the median, and overwrite BENCH_baseline/*.json.
# Review the diff before committing — the floors gate every future PR.
set -euo pipefail
cd "$(dirname "$0")/.."

runs=${1:-3}
tmp=$(mktemp -d)
trap 'rm -rf "$tmp"' EXIT

for i in $(seq 1 "$runs"); do
    echo "== bench run $i/$runs =="
    (cd rust && BENCH_OUT="$tmp/BENCH_pipeline.run$i.json" \
        BENCH_SERVE_OUT="$tmp/BENCH_serve.run$i.json" \
        cargo bench --bench pipeline)
done

python3 scripts/bench_gate.py \
    --baseline BENCH_baseline/BENCH_pipeline.json \
    --runs "$tmp"/BENCH_pipeline.run*.json \
    --write-median BENCH_baseline/BENCH_pipeline.json || true
python3 scripts/bench_gate.py \
    --baseline BENCH_baseline/BENCH_serve.json \
    --runs "$tmp"/BENCH_serve.run*.json \
    --write-median BENCH_baseline/BENCH_serve.json || true

echo "refreshed BENCH_baseline/ — review with: git diff BENCH_baseline/"
