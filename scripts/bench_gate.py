#!/usr/bin/env python3
"""CI bench-regression gate (stdlib only).

Diffs fresh bench emissions (``BENCH_pipeline.json`` / ``BENCH_serve.json``)
against the committed floors in ``BENCH_baseline/`` and fails on a
throughput regression beyond the tolerance.  Noise-tolerant by design:
the gate takes the **median of N runs** (CI passes 3) per metric, so a
single noisy run cannot fail — or pass — the gate.

Two kinds of checks:

* ``--metric KEY`` (repeatable): higher-is-better throughput metrics.
  FAIL when ``median(runs) < baseline * (1 - tolerance)``.
* ``--warn-metric KEY`` (repeatable): same floor math, but a miss is
  reported WARN without failing the gate — for metrics that shared
  runners can sink with no code change (connection-reuse rate under
  noisy-neighbor accept latency, NDJSON batch throughput).
* ``--max-metric KEY=CEILING`` (repeatable): lower-is-better metrics
  gated against an absolute ceiling rather than the committed baseline
  (e.g. ``obs_overhead_pct=5``: tracing must cost < 5% of keep-alive
  throughput).  FAIL when ``median(runs) > CEILING``.
* ``--check-speedup KEY --speedup-floor X``: a machine-relative check
  (e.g. the engine thread-scaling curve, ``gemm_speedup_4t``), enforced
  only when the runner reports at least ``--min-cores`` cores in the
  bench doc — a 2-core runner cannot show a 4-thread speedup.
* ``--warn-speedup KEY=FLOOR`` (repeatable): a speedup checked against
  an absolute floor, WARN-only — for *modeled* scaling curves (e.g.
  ``fleet_speedup_2=1.3``) that should hold on any runner but must never
  gate a merge.  A missing key is still a hard failure (code bug).

``--write-median PATH`` additionally writes the median document (the
baseline refresh artifact: copy it into ``BENCH_baseline/`` to re-anchor
the floors on new hardware).

Exit status: 0 = pass, 1 = regression, 2 = bad invocation/inputs.
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys
from pathlib import Path


def load(path: str) -> dict:
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"bench-gate: cannot read {path}: {e}", file=sys.stderr)
        sys.exit(2)


def median_of(runs: list[dict], key: str) -> float | None:
    vals = [r[key] for r in runs if isinstance(r.get(key), (int, float))]
    if not vals:
        return None
    return statistics.median(vals)


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--baseline", required=True, help="committed baseline JSON")
    p.add_argument("--runs", nargs="+", required=True, help="fresh bench JSONs (>=1)")
    p.add_argument(
        "--metric",
        action="append",
        default=[],
        help="higher-is-better metric key to gate (repeatable)",
    )
    p.add_argument(
        "--warn-metric",
        action="append",
        default=[],
        help="higher-is-better metric key to report without failing (repeatable)",
    )
    p.add_argument(
        "--max-metric",
        action="append",
        default=[],
        metavar="KEY=CEILING",
        help="lower-is-better metric gated against an absolute ceiling (repeatable)",
    )
    p.add_argument(
        "--tolerance",
        type=float,
        default=0.25,
        help="allowed fractional regression vs baseline (default 0.25)",
    )
    p.add_argument("--check-speedup", help="machine-relative speedup key to enforce")
    p.add_argument("--speedup-floor", type=float, default=1.5)
    p.add_argument(
        "--speedup-warn-only",
        action="store_true",
        help="report a speedup miss without failing the gate (for shared CI "
        "runners where noisy-neighbor contention can eat the scaling headroom)",
    )
    p.add_argument(
        "--warn-speedup",
        action="append",
        default=[],
        metavar="KEY=FLOOR",
        help="speedup key checked against an absolute floor, WARN-only "
        "(modeled scaling curves that should hold on any runner but must "
        "never gate a merge, e.g. fleet_speedup_2=1.3)",
    )
    p.add_argument(
        "--min-cores",
        type=int,
        default=4,
        help="skip the speedup check below this engine_cores reading",
    )
    p.add_argument("--write-median", help="write the median document here")
    args = p.parse_args()

    baseline = load(args.baseline)
    runs = [load(r) for r in args.runs]
    failures: list[str] = []

    print(f"bench-gate: {len(runs)} run(s) vs {args.baseline} (tolerance {args.tolerance:.0%})")
    for key, warn_only in [(k, False) for k in args.metric] + [
        (k, True) for k in args.warn_metric
    ]:
        med = median_of(runs, key)
        base = baseline.get(key)
        if med is None:
            # a warn-only metric that is absent is still a hard failure:
            # the bench stopped emitting it, which is a code bug, not
            # runner noise
            failures.append(f"{key}: missing from every run")
            continue
        if not isinstance(base, (int, float)):
            failures.append(f"{key}: missing from baseline {args.baseline}")
            continue
        floor = base * (1.0 - args.tolerance)
        below = med < floor
        verdict = "OK" if not below else ("WARN" if warn_only else "REGRESSION")
        print(f"  {key}: median {med:.2f} vs baseline {base:.2f} (floor {floor:.2f}) {verdict}")
        if below and not warn_only:
            failures.append(f"{key}: median {med:.2f} < floor {floor:.2f} (baseline {base:.2f})")

    for spec in args.max_metric:
        key, sep, raw_ceiling = spec.partition("=")
        if not sep:
            print(f"bench-gate: --max-metric needs KEY=CEILING, got {spec!r}", file=sys.stderr)
            return 2
        try:
            ceiling = float(raw_ceiling)
        except ValueError:
            print(f"bench-gate: bad ceiling in {spec!r}", file=sys.stderr)
            return 2
        med = median_of(runs, key)
        if med is None:
            failures.append(f"{key}: missing from every run")
            continue
        above = med > ceiling
        verdict = "REGRESSION" if above else "OK"
        print(f"  {key}: median {med:.2f} vs ceiling {ceiling:.2f} {verdict}")
        if above:
            failures.append(f"{key}: median {med:.2f} > ceiling {ceiling:.2f}")

    if args.check_speedup:
        cores = median_of(runs, "engine_cores") or 0
        med = median_of(runs, args.check_speedup)
        if cores < args.min_cores:
            print(
                f"  {args.check_speedup}: skipped (runner has {cores:.0f} cores"
                f" < {args.min_cores})"
            )
        elif med is None:
            failures.append(f"{args.check_speedup}: missing from every run")
        else:
            below = med < args.speedup_floor
            verdict = "OK" if not below else ("WARN" if args.speedup_warn_only else "REGRESSION")
            print(
                f"  {args.check_speedup}: median {med:.2f}x"
                f" (floor {args.speedup_floor:.2f}x, {cores:.0f} cores) {verdict}"
            )
            if below and not args.speedup_warn_only:
                failures.append(
                    f"{args.check_speedup}: median {med:.2f}x < {args.speedup_floor:.2f}x"
                )

    for spec in args.warn_speedup:
        key, sep, raw_floor = spec.partition("=")
        if not sep:
            print(f"bench-gate: --warn-speedup needs KEY=FLOOR, got {spec!r}", file=sys.stderr)
            return 2
        try:
            floor = float(raw_floor)
        except ValueError:
            print(f"bench-gate: bad floor in {spec!r}", file=sys.stderr)
            return 2
        med = median_of(runs, key)
        if med is None:
            # a warn-only speedup that is absent is still a hard failure:
            # the bench stopped emitting it, which is a code bug
            failures.append(f"{key}: missing from every run")
            continue
        verdict = "OK" if med >= floor else "WARN"
        print(f"  {key}: median {med:.2f}x vs floor {floor:.2f}x {verdict}")

    if args.write_median:
        med_doc = dict(runs[0])
        for key, val in runs[0].items():
            if isinstance(val, (int, float)) and not isinstance(val, bool):
                m = median_of(runs, key)
                if m is not None:
                    med_doc[key] = m
        Path(args.write_median).write_text(json.dumps(med_doc, sort_keys=True) + "\n")
        print(f"  wrote median doc -> {args.write_median}")

    if failures:
        print("bench-gate: FAIL", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("bench-gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
