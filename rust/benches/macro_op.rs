//! Macro-op microbenchmarks (cargo bench --bench macro_op).
//!
//! Covers the native hot path at every operating point of Fig 5a/5b:
//! exact (DCIM), hybrid per boundary, ACIM, saliency evaluation, bit
//! packing and noise generation.  Rows feed EXPERIMENTS.md §Perf.

use osa_hcim::benchkit::Bench;
use osa_hcim::macrosim::MacroUnit;
use osa_hcim::sched::plan::LayerPlan;
use osa_hcim::spec::MacroSpec;
use osa_hcim::util::prng::SplitMix64;
use std::time::Duration;

fn main() {
    let sp = MacroSpec::default();
    let mut rng = SplitMix64::new(1);
    let w: Vec<i32> = (0..sp.hmus * sp.cols).map(|_| rng.next_range_i32(-128, 128)).collect();
    let unit = MacroUnit::new(&w, sp).unwrap();
    let a: Vec<i32> = (0..sp.cols).map(|_| rng.next_range_i32(0, 256)).collect();
    let packed = unit.pack_acts(&a);
    let noise: Vec<f32> = rng.normals_f32(sp.hmus * sp.w_bits, sp.sigma_code);
    let macs = (sp.hmus * sp.cols) as f64;

    println!("# macro_op — single 64x144 macro operation (8 HMUs x 144 cols)");
    Bench::new("pack_acts").target(Duration::from_secs(1)).items(macs).run(|| unit.pack_acts(&a));
    Bench::new("exact(DCIM ground truth)")
        .target(Duration::from_secs(1))
        .items(macs)
        .run(|| unit.exact(&a));
    Bench::new("saliency_eval(SE mode)")
        .target(Duration::from_secs(1))
        .items(macs)
        .run(|| unit.saliency(&packed));
    for b in [0, 5, 6, 7, 8, 9, 10] {
        Bench::new(&format!("compute_hybrid(B={b})"))
            .target(Duration::from_secs(1))
            .items(macs)
            .run(|| unit.compute_hybrid(&packed, b, &noise));
    }
    let n_slices = sp.a_bits.div_ceil(sp.analog_band as usize);
    let acim_noise: Vec<f32> = {
        let mut g = SplitMix64::new(2);
        g.normals_f32(sp.hmus * sp.w_bits * n_slices, sp.sigma_code)
    };
    Bench::new("compute_acim(full analog)")
        .target(Duration::from_secs(1))
        .items(macs)
        .run(|| unit.compute_acim(&packed, &acim_noise));
    let mut g = SplitMix64::new(3);
    Bench::new("noise_gen(64 normals)")
        .target(Duration::from_secs(1))
        .items(64.0)
        .run(|| g.normals_f32(64, 0.3));

    // plan build: the one-time weight-packing cost the PlanCache
    // amortizes across every call (stage-2 layer shape)
    let (kk, nn) = (288usize, 32usize);
    let mut pg = SplitMix64::new(4);
    let wl: Vec<i32> = (0..nn * kk).map(|_| pg.next_range_i32(-128, 128)).collect();
    Bench::new("layer_plan_build(K=288,N=32)")
        .target(Duration::from_secs(1))
        .items((nn * kk) as f64)
        .run(|| LayerPlan::build(&wl, nn, kk, 0, sp).unwrap());
}
