//! Connection-scaling smoke bench (`cargo bench --bench conn_scale`).
//!
//! The CI shape of the event-loop acceptance claim: park 1000 idle
//! keep-alive connections on one gateway, then assert
//!
//! * memory stays flat — RSS growth under ~40 KB per idle connection
//!   (pooled buffers, no thread per connection);
//! * requests still flow at full speed with the herd attached;
//! * shutdown drains: an in-flight request is answered, the idle herd
//!   is closed, and the whole teardown completes promptly.
//!
//! Runs on `QGraph::synthetic()` — no artifacts needed.  Emits
//! `BENCH_conn_scale.json` (override the path with
//! `BENCH_CONN_SCALE_OUT`) for `scripts/bench_gate.py`.

#![allow(clippy::field_reassign_with_default)] // repo config idiom

fn main() {
    osa_hcim::util::logging::init();
    #[cfg(unix)]
    run();
    #[cfg(not(unix))]
    println!("conn_scale: the readiness-driven gateway needs unix — skipping");
}

#[cfg(unix)]
fn run() {
    use osa_hcim::benchkit::{raise_nofile, vm_rss_mb};
    use osa_hcim::config::SystemConfig;
    use osa_hcim::io::json::{num, obj, parse, s, JsonValue};
    use osa_hcim::nn::QGraph;
    use osa_hcim::serve::{http, Gateway};
    use osa_hcim::util::prng::SplitMix64;
    use std::io::Read;
    use std::net::TcpStream;
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let mut cfg = SystemConfig::default();
    cfg.workers = 2;
    cfg.max_batch = 8;
    // a lone batch-tier request coalesces for its full 100ms window —
    // room for shutdown to start while it is demonstrably in flight
    cfg.batch_timeout_us = 100_000;
    cfg.queue_cap = 1024;
    cfg.max_conns = 4096;
    cfg.read_timeout_ms = 120_000; // the idle herd must not be shed mid-bench

    let nofile = raise_nofile(8192);
    let budget = (nofile as usize).saturating_sub(256) / 2;
    let target = 1000usize.min(budget);

    let gw = Gateway::start(&cfg, Arc::new(QGraph::synthetic()), "127.0.0.1:0").unwrap();
    let addr = gw.addr().to_string();

    // warm the serving path so pooled buffers and lazy allocations are
    // part of the RSS base, not attributed to the herd
    let mut probe = http::Client::connect(&addr).expect("probe connect");
    for _ in 0..50 {
        let (status, _) = probe.request("GET", "/healthz", None).unwrap();
        assert_eq!(status, 200);
    }
    let rss_before = vm_rss_mb();

    // --- the idle herd ---------------------------------------------------
    let mut herd: Vec<TcpStream> = Vec::new();
    while herd.len() < target {
        herd.push(TcpStream::connect(&addr).expect("herd connect"));
    }
    // accepts are asynchronous: wait for the gauge to agree
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let (status, body) = http::request(&addr, "GET", "/metrics", None).unwrap();
        assert_eq!(status, 200);
        let open = parse(&body)
            .ok()
            .and_then(|doc| {
                doc.get("event_loop")
                    .and_then(|ev| ev.get("open_connections"))
                    .and_then(JsonValue::as_f64)
            })
            .expect("event_loop gauges in /metrics");
        if open >= herd.len() as f64 {
            break;
        }
        assert!(Instant::now() < deadline, "gateway never accepted the herd ({open} open)");
        std::thread::sleep(Duration::from_millis(20));
    }

    // --- flat memory -----------------------------------------------------
    let rss_after = vm_rss_mb();
    let delta_mb = (rss_after - rss_before).max(0.0);
    let kb_per_conn = delta_mb * 1024.0 / herd.len().max(1) as f64;
    println!(
        "conn_scale: {} idle conns, rss {rss_before:.1} -> {rss_after:.1} MB \
         ({kb_per_conn:.1} KB/conn)",
        herd.len()
    );
    if rss_after > 0.0 {
        assert!(
            kb_per_conn < 40.0,
            "idle connections are not flat-memory: {kb_per_conn:.1} KB/conn"
        );
    }

    // --- throughput with the herd attached -------------------------------
    let probe_reqs = 500usize;
    let t0 = Instant::now();
    for _ in 0..probe_reqs {
        let (status, _) = probe.request("GET", "/healthz", None).unwrap();
        assert_eq!(status, 200);
    }
    let rps = probe_reqs as f64 / t0.elapsed().as_secs_f64();
    println!("conn_scale: probe {rps:.0} req/s through {} idle conns", herd.len());

    // --- drain on shutdown -----------------------------------------------
    // submit a slow-coalescing request, prove it was read, then shut
    // down with the herd still parked: the request must be answered and
    // the teardown must not wait out any idle timeout
    let img: Vec<u8> = {
        let mut g = SplitMix64::new(17);
        (0..32 * 32 * 3).map(|_| g.next_below(256) as u8).collect()
    };
    let http_requests = |addr: &str| -> i64 {
        let (status, body) = http::request(addr, "GET", "/metrics", None).unwrap();
        assert_eq!(status, 200);
        parse(&body)
            .unwrap()
            .get("connections")
            .and_then(|c| c.get("http_requests"))
            .and_then(JsonValue::as_i64)
            .unwrap()
    };
    // the baseline includes the snapshot request itself; afterwards
    // each poll adds exactly one more, so the counter strictly
    // exceeding baseline + polls proves the POST has been read and
    // will therefore be drained, not dropped
    let baseline = http_requests(&addr);
    let in_flight = {
        let addr = addr.clone();
        let body = http::infer_body("batch", &img);
        std::thread::spawn(move || {
            let mut c = http::Client::connect(&addr).unwrap();
            c.request("POST", "/v1/infer", Some(&body)).unwrap()
        })
    };
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut polls = 0i64;
    loop {
        polls += 1;
        if http_requests(&addr) > baseline + polls {
            break;
        }
        assert!(Instant::now() < deadline, "the POST was never read by the gateway");
        std::thread::sleep(Duration::from_millis(5));
    }
    let t0 = Instant::now();
    let metrics = gw.shutdown();
    let drain_ms = t0.elapsed().as_secs_f64() * 1e3;
    let (status, body) = in_flight.join().unwrap();
    assert_eq!(status, 200, "in-flight request dropped by shutdown: {body}");
    assert!(
        drain_ms < 10_000.0,
        "shutdown waited out idle connections instead of draining: {drain_ms:.0} ms"
    );
    assert_eq!(metrics.errors, 0);
    // the herd was actively closed, not abandoned: sockets read EOF
    for sock in herd.iter_mut().take(8) {
        sock.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let mut buf = [0u8; 64];
        assert_eq!(sock.read(&mut buf).unwrap(), 0, "idle conn not closed by drain");
    }
    println!(
        "conn_scale: drained in {drain_ms:.0} ms with {} idle conns parked",
        herd.len()
    );

    let doc = obj(vec![
        ("bench", s("conn_scale")),
        ("conn_scale_conns", num(herd.len() as f64)),
        ("conn_scale_rps", num(rps)),
        ("conn_scale_rss_mb_delta", num(delta_mb)),
        ("conn_scale_rss_kb_per_conn", num(kb_per_conn)),
        ("conn_scale_drain_ms", num(drain_ms)),
    ]);
    let out = std::env::var("BENCH_CONN_SCALE_OUT")
        .unwrap_or_else(|_| "BENCH_conn_scale.json".into());
    std::fs::write(&out, doc.to_string_compact()).unwrap();
    println!("wrote {out}");
}
