//! End-to-end pipeline benchmarks (cargo bench --bench pipeline).
//!
//! One row per paper experiment surface: the tiled GEMM per mode
//! (Fig 5 workloads), the plan/execute split (cold packing vs warm
//! cached execution), full-network inference over a persistent executor
//! (Fig 9 workloads) and the coordinator serve loop (throughput /
//! latency claims).  Falls back to `QGraph::synthetic()` when the AOT
//! artifacts are absent so the perf trajectory is tracked everywhere.
//!
//! Emits `BENCH_pipeline.json` (override the path with `BENCH_OUT`):
//! serve requests/s, latency percentiles and the plan-cache hit rate —
//! and `BENCH_serve.json` (override with `BENCH_SERVE_OUT`): per-QoS-
//! tier latency through the HTTP gateway over a real socket.

#![allow(clippy::field_reassign_with_default)] // repo config idiom

use osa_hcim::benchkit::Bench;
#[cfg(unix)]
use osa_hcim::benchkit::{raise_nofile, vm_rss_mb};
use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::coordinator::Server;
use osa_hcim::device::sweep::{self, EvalSet, SweepGrid};
use osa_hcim::engine::{Backend, Engine};
use osa_hcim::obs::SweepProgress;
use osa_hcim::io::json::{arr, num, obj, s, JsonValue};
use osa_hcim::nn::data::Dataset;
use osa_hcim::nn::{Executor, QGraph};
use osa_hcim::sched::exec::auto_threads;
use osa_hcim::serve::{http, Gateway, Tier};
use osa_hcim::util::prng::SplitMix64;
use std::sync::Arc;
use std::time::{Duration, Instant};

use osa_hcim::serve::http::infer_body;

fn main() {
    osa_hcim::util::logging::init();
    let mut cfg = SystemConfig::default();
    // the closed-loop burst submits everything up front — keep it under
    // the admission bound so the bench measures batching, not 429s
    cfg.queue_cap = 1024;
    let have_artifacts = cfg.spec.validate_against_artifacts(&cfg.artifacts_dir).is_ok();
    let (graph, img) = if have_artifacts {
        let ds = Dataset::load(&cfg.artifacts_dir).unwrap();
        let graph = QGraph::load(&cfg.artifacts_dir).unwrap();
        let (img, _) = ds.test_batch(0, 1);
        (graph, img.to_vec())
    } else {
        eprintln!("artifacts not built — benchmarking over the synthetic graph");
        let mut g = SplitMix64::new(11);
        let img: Vec<u8> = (0..32 * 32 * 3).map(|_| g.next_below(256) as u8).collect();
        (QGraph::synthetic(), img)
    };
    let graph = Arc::new(graph);

    // One engine per bench section; every backend below comes out of a
    // registry via the builder — no hand-wired MacroGemm anywhere.
    let engine =
        Engine::builder().config(cfg.clone()).graph(graph.clone()).build().unwrap();

    // --- tiled GEMM per mode (stage-2 layer shape: K=288, N=32) ---------
    let (m, k, n) = (256usize, 288usize, 32usize);
    let mut rng = SplitMix64::new(5);
    let a: Vec<i32> = (0..m * k).map(|_| rng.next_range_i32(0, 256)).collect();
    let w: Vec<i32> = (0..n * k).map(|_| rng.next_range_i32(-128, 128)).collect();
    println!("# pipeline — tiled GEMM [{m}x{k}] x [{n}x{k}] through the macro datapath");
    for mode in [CimMode::Dcim, CimMode::Hcim, CimMode::Osa, CimMode::Acim] {
        let mut gemm = engine.backend_for_mode(mode).unwrap();
        Bench::new(&format!("gemm/{}", mode.name()))
            .target(Duration::from_secs(3))
            .items((m * n * k) as f64)
            .run(|| gemm.gemm(&a, m, k, &w, n, 0).unwrap());
    }
    for mode in [CimMode::Pg, CimMode::Drq] {
        let mut gemm = engine.backend_for_mode(mode).unwrap();
        Bench::new(&format!("gemm/{}", mode.name()))
            .target(Duration::from_secs(1))
            .items((m * n * k) as f64)
            .run(|| gemm.gemm(&a, m, k, &w, n, 0).unwrap());
    }

    // --- engine thread scaling: the same warm OSA GEMM on explicit pools -
    // The acceptance curve for the parallel tile engine: single-request
    // speedup vs a 1-thread pool (near-linear on multicore runners).
    println!("\n# pipeline — engine thread scaling (OSA GEMM, explicit pool sizes)");
    let cores = auto_threads();
    let mut scale_threads: Vec<usize> = vec![1, 2, 4];
    if cores > 4 {
        scale_threads.push(cores);
    }
    let mut scale_rates: Vec<f64> = Vec::new();
    for &t in &scale_threads {
        let sized = Engine::builder()
            .config(cfg.clone())
            .graph(graph.clone())
            .threads(t)
            .build()
            .unwrap();
        let mut gemm = sized.backend_for_mode(CimMode::Osa).unwrap();
        gemm.gemm(&a, m, k, &w, n, 0).unwrap(); // build the plan once
        let stats = Bench::new(&format!("gemm/osa_threads_{t}"))
            .target(Duration::from_secs(2))
            .items((m * n * k) as f64)
            .run(|| gemm.gemm(&a, m, k, &w, n, 0).unwrap());
        scale_rates.push(stats.throughput().unwrap_or(0.0));
    }
    let rate_at = |t: usize| -> f64 {
        scale_threads
            .iter()
            .position(|&tt| tt == t)
            .map(|i| scale_rates[i])
            .unwrap_or(0.0)
    };
    let speedup_2t = rate_at(2) / rate_at(1).max(1e-9);
    let speedup_4t = rate_at(4) / rate_at(1).max(1e-9);
    println!(
        "gemm thread scaling on {cores}-core runner: 2t = {speedup_2t:.2}x, 4t = {speedup_4t:.2}x"
    );

    // --- plan/execute split: cold packing vs warm cached execution -------
    println!("\n# pipeline — plan/execute split (same GEMM, fresh cache vs cached plan)");
    let plan_engine =
        Engine::builder().config(cfg.clone()).graph(graph.clone()).build().unwrap();
    Bench::new("plan/cold_build_and_execute")
        .target(Duration::from_secs(3))
        .items((m * n * k) as f64)
        .run(|| plan_engine.backend_cold().unwrap().gemm(&a, m, k, &w, n, 0).unwrap());
    let mut warm = plan_engine.backend_for_mode(CimMode::Osa).unwrap();
    warm.gemm(&a, m, k, &w, n, 0).unwrap();
    Bench::new("plan/warm_execute")
        .target(Duration::from_secs(3))
        .items((m * n * k) as f64)
        .run(|| warm.gemm(&a, m, k, &w, n, 0).unwrap());
    let ws = plan_engine.plan_stats();
    println!(
        "plan cache after warm run: hits={} misses={} hit_rate={:.4}",
        ws.hits,
        ws.misses,
        ws.hit_rate()
    );

    // --- macro-fleet scaling: modeled K-macro wall-clock, same GEMM ------
    // The fleet acceptance curve: modeled GEMM/s (1 / fleet_seconds, the
    // busiest macro's critical path) at K = 1/2/4 with residency pinned
    // to one tile so the K=288 contraction must shard, plus the share of
    // energy the inter-macro partial-sum transfers cost.
    println!("\n# pipeline — macro-fleet scaling (K = 1/2/4, residency-forced sharding)");
    let mut fleet_points: Vec<(usize, f64, f64)> = Vec::new();
    for kf in [1usize, 2, 4] {
        let mut fcfg = cfg.clone();
        fcfg.backend = "macro-fleet".to_string();
        fcfg.fleet_macros = kf;
        fcfg.fleet_residency_tiles = 1;
        let fleet_engine =
            Engine::builder().config(fcfg).graph(graph.clone()).build().unwrap();
        let mut gemm = fleet_engine.backend().unwrap();
        gemm.gemm(&a, m, k, &w, n, 0).unwrap(); // warm the plan + placement
        let r = gemm.gemm(&a, m, k, &w, n, 0).unwrap();
        let rate = 1.0 / r.account.fleet_seconds().max(1e-12);
        let pct = r.account.transfer_fraction() * 100.0;
        println!("fleet/k{kf}: modeled {rate:.1} gemm/s, transfer {pct:.2}% of energy");
        fleet_points.push((kf, rate, pct));
    }
    let fleet_rate = |kf: usize| {
        fleet_points.iter().find(|p| p.0 == kf).map(|p| p.1).unwrap_or(0.0)
    };
    let fleet_speedup_2 = fleet_rate(2) / fleet_rate(1).max(1e-9);
    let fleet_speedup_4 = fleet_rate(4) / fleet_rate(1).max(1e-9);
    let fleet_transfer_pct =
        fleet_points.iter().find(|p| p.0 == 4).map(|p| p.2).unwrap_or(0.0);
    println!(
        "fleet scaling: 2 macros = {fleet_speedup_2:.2}x, 4 macros = {fleet_speedup_4:.2}x, \
         transfer {fleet_transfer_pct:.2}% of energy at K=4"
    );

    // --- full-network inference over a persistent executor ---------------
    println!("\n# pipeline — single-image inference (32x32x3), persistent executor");
    for mode in [CimMode::Dcim, CimMode::Hcim, CimMode::Osa] {
        let gemm = engine.backend_for_mode(mode).unwrap();
        let mut exec = Executor::new(&graph, gemm);
        exec.preplan().unwrap();
        Bench::new(&format!("infer/{}", mode.name()))
            .target(Duration::from_secs(5))
            .max_iters(200)
            .items(1.0)
            .run(|| exec.forward(&img, 1).unwrap());
    }

    // --- cost model overhead: compact vs hierarchy pricing ---------------
    // The PR-9 acceptance curve: the hierarchy model's dataflow pricing
    // is a per-layer post-pass on the merged account, so full-network
    // inference must stay within a few percent of the compact model.
    // Also records the modeled energy per inference (millijoules) under
    // the hierarchy stack — the joule figure the governor budgets.
    println!("\n# pipeline — cost model overhead (compact vs hierarchy movement pricing)");
    let run_model = |model: &str| -> (f64, f64) {
        let mut mcfg = cfg.clone();
        mcfg.hardware_model = model.to_string();
        let model_engine =
            Engine::builder().config(mcfg).graph(graph.clone()).build().unwrap();
        let gemm = model_engine.backend().unwrap();
        let mut exec = Executor::new(&graph, gemm);
        exec.preplan().unwrap();
        let (_, stats) = exec.forward(&img, 1).unwrap();
        let energy_mj = stats.account.total_energy_j() * 1e3;
        let bstats = Bench::new(&format!("infer/costmodel_{model}"))
            .target(Duration::from_secs(3))
            .max_iters(200)
            .items(1.0)
            .run(|| exec.forward(&img, 1).unwrap());
        (bstats.throughput().unwrap_or(0.0), energy_mj)
    };
    let (costmodel_rate_compact, _) = run_model("compact");
    let (costmodel_rate_hier, energy_per_inference_mj) = run_model("hierarchy");
    let costmodel_delta = (costmodel_rate_compact - costmodel_rate_hier).max(0.0);
    let costmodel_overhead_pct = costmodel_delta / costmodel_rate_compact.max(1e-9) * 100.0;
    println!(
        "costmodel: compact {costmodel_rate_compact:.1} inf/s vs hierarchy \
         {costmodel_rate_hier:.1} inf/s -> overhead {costmodel_overhead_pct:.2}%, \
         {energy_per_inference_mj:.4} mJ/inference modeled"
    );

    // --- device sweep driver: Monte Carlo grid points per second ---------
    // The PR-10 acceptance curve: `osa-hcim sweep` cell-evaluation rate.
    // One point = one (boundary, sigma, seed) engine run over the eval
    // batch (plus the governor-ladder corner cells), all fanned onto the
    // shared pool — the figure that sizes real design-space sweeps.
    println!("\n# pipeline — device sweep driver (boundary x sigma x seeds grid)");
    let sweep_points_per_s = {
        let mut wcfg = cfg.clone();
        wcfg.gov_max_level = 1;
        let eval = EvalSet::synthetic(&wcfg, &graph, 4).unwrap();
        let grid = SweepGrid {
            boundaries: vec![10, 6],
            sigmas: vec![0.0, 0.3],
            mc_seeds: 2,
            images: eval.len(),
            corner_sigma: 0.45,
        };
        let progress = SweepProgress::new();
        let t0 = Instant::now();
        let report = sweep::run(&wcfg, &graph, &eval, &grid, &progress).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        let (done, total, _) = progress.snapshot();
        assert_eq!(done, total, "sweep left cells unevaluated");
        let rate = done as f64 / wall.max(1e-9);
        println!(
            "sweep/grid: {done} cells ({} surface) in {wall:.3}s -> {rate:.2} points/s",
            report.surface.len()
        );
        rate
    };

    // --- coordinator serve loop ------------------------------------------
    println!("\n# pipeline — coordinator round trip (submit -> batch -> respond)");
    let serve_engine =
        Engine::builder().config(cfg.clone()).graph(graph.clone()).build().unwrap();
    let server = Server::with_engine(Arc::new(serve_engine)).unwrap();
    Bench::new("serve/round_trip")
        .target(Duration::from_secs(5))
        .max_iters(500)
        .items(1.0)
        .run(|| {
            let rx = server.submit(img.clone()).unwrap();
            rx.recv().unwrap()
        });

    // --- serve throughput: closed burst of single-image requests ---------
    let burst = 256usize;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..burst).map(|_| server.submit(img.clone()).unwrap()).collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "serve burst hit an error: {:?}", resp.error);
    }
    let wall = t0.elapsed().as_secs_f64();
    let rps = burst as f64 / wall;
    let pstats = server.plan_stats();
    let metrics = server.shutdown();
    println!(
        "serve/burst: {burst} requests in {wall:.3}s -> {rps:.1} req/s  \
         plan_cache: hits={} misses={} hit_rate={:.4}",
        pstats.hits,
        pstats.misses,
        pstats.hit_rate()
    );
    println!("{}", metrics.report(&cfg.spec));

    // --- BENCH_pipeline.json ---------------------------------------------
    let doc = obj(vec![
        ("bench", s("pipeline")),
        ("synthetic_graph", JsonValue::Bool(!have_artifacts)),
        ("engine_cores", num(cores as f64)),
        ("gemm_scale_threads", arr(scale_threads.iter().map(|&t| num(t as f64)))),
        ("gemm_scale_items_per_s", arr(scale_rates.iter().map(|&r| num(r)))),
        ("gemm_speedup_2t", num(speedup_2t)),
        ("gemm_speedup_4t", num(speedup_4t)),
        ("serve_burst", num(burst as f64)),
        ("serve_requests_per_s", num(rps)),
        ("serve_p50_latency_us", num(metrics.p50_latency_us())),
        ("serve_p95_latency_us", num(metrics.p95_latency_us())),
        ("serve_mean_batch", num(metrics.mean_batch())),
        ("plan_cache_hits", num(pstats.hits as f64)),
        ("plan_cache_misses", num(pstats.misses as f64)),
        ("plan_cache_hit_rate", num(pstats.hit_rate())),
    ]);
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_pipeline.json".into());
    std::fs::write(&out, doc.to_string_compact()).unwrap();
    println!("wrote {out}");

    // --- HTTP gateway: per-QoS-tier latency over a real socket -----------
    println!("\n# pipeline — HTTP gateway (POST /v1/infer per tier, real socket)");
    let mut gcfg = SystemConfig::default();
    gcfg.workers = 4;
    gcfg.max_batch = 16;
    gcfg.batch_timeout_us = 2_000;
    gcfg.queue_cap = 1024;
    let gateway_engine =
        Engine::builder().config(gcfg.clone()).graph(graph.clone()).build().unwrap();
    let gateway = Gateway::with_engine(Arc::new(gateway_engine), "127.0.0.1:0").unwrap();
    let addr = gateway.addr().to_string();
    // sequential closed loop per tier: isolates the tier's coalescing
    // window + dispatch priority in the round-trip latency
    let seq_per_tier = 40usize;
    for tier in Tier::ALL {
        let body = infer_body(tier.name(), &img);
        let addr = addr.clone();
        Bench::new(&format!("serve_http/{}", tier.name()))
            .warmup(Duration::from_millis(100))
            .target(Duration::from_secs(2))
            .max_iters(seq_per_tier)
            .items(1.0)
            .run(|| {
                let (status, _) =
                    http::request(&addr, "POST", "/v1/infer", Some(&body)).unwrap();
                assert_eq!(status, 200);
            });
    }
    // mixed-tier burst from parallel clients: throughput + backpressure
    let clients = 8usize;
    let per_client = 16usize;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        let img = img.clone();
        handles.push(std::thread::spawn(move || {
            let mut served = 0u64;
            let mut busy = 0u64;
            for i in 0..per_client {
                let tier = Tier::ALL[(c + i) % Tier::ALL.len()];
                let body = infer_body(tier.name(), &img);
                match http::request(&addr, "POST", "/v1/infer", Some(&body)) {
                    Ok((200, _)) => served += 1,
                    Ok((429, _)) => busy += 1,
                    Ok((status, b)) => panic!("unexpected status {status}: {b}"),
                    Err(e) => panic!("gateway request failed: {e:#}"),
                }
            }
            (served, busy)
        }));
    }
    let mut served = 0u64;
    let mut busy = 0u64;
    for h in handles {
        let (s_n, b_n) = h.join().unwrap();
        served += s_n;
        busy += b_n;
    }
    let wall = t0.elapsed().as_secs_f64();
    let http_rps = served as f64 / wall;
    println!(
        "serve_http/burst (close): {served} served + {busy} busy in {wall:.3}s \
         -> {http_rps:.1} req/s"
    );

    // --- keep-alive burst: same load, one persistent conn per client ----
    // The tentpole acceptance curve: requests/s with connection reuse vs
    // the Connection: close baseline above, on the same machine.
    let conn_stats = gateway.conn_stats();
    let ka_conns_before = conn_stats.accepted.load(std::sync::atomic::Ordering::Relaxed);
    let ka_reqs_before = conn_stats.requests.load(std::sync::atomic::Ordering::Relaxed);
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let addr = addr.clone();
        let img = img.clone();
        handles.push(std::thread::spawn(move || {
            let mut conn = http::Client::connect(&addr).expect("keep-alive connect");
            let mut served = 0u64;
            let mut busy = 0u64;
            for i in 0..per_client {
                let tier = Tier::ALL[(c + i) % Tier::ALL.len()];
                let body = infer_body(tier.name(), &img);
                match conn.request("POST", "/v1/infer", Some(&body)) {
                    Ok((200, _)) => served += 1,
                    Ok((429, _)) => busy += 1,
                    Ok((status, b)) => panic!("unexpected status {status}: {b}"),
                    Err(e) => panic!("keep-alive request failed: {e:#}"),
                }
            }
            (served, busy)
        }));
    }
    let mut ka_served = 0u64;
    let mut ka_busy = 0u64;
    for h in handles {
        let (s_n, b_n) = h.join().unwrap();
        ka_served += s_n;
        ka_busy += b_n;
    }
    let ka_wall = t0.elapsed().as_secs_f64();
    let ka_rps = ka_served as f64 / ka_wall;
    let ka_conns = conn_stats.accepted.load(std::sync::atomic::Ordering::Relaxed) - ka_conns_before;
    let ka_reqs = conn_stats.requests.load(std::sync::atomic::Ordering::Relaxed) - ka_reqs_before;
    let conn_reuse_rate =
        if ka_reqs == 0 { 0.0 } else { 1.0 - ka_conns.min(ka_reqs) as f64 / ka_reqs as f64 };
    let keepalive_speedup = ka_rps / http_rps.max(1e-9);
    println!(
        "serve_http/burst (keep-alive): {ka_served} served + {ka_busy} busy in {ka_wall:.3}s \
         -> {ka_rps:.1} req/s ({keepalive_speedup:.2}x vs close, reuse {conn_reuse_rate:.3} \
         over {ka_conns} conns)"
    );

    // --- tracing overhead: paired keep-alive bursts, spans on vs off ----
    // The obs acceptance curve: per-request span tracing must stay
    // under a few percent of keep-alive throughput.  Run the identical
    // burst twice back-to-back — ring enabled, then disabled — so both
    // sides see the same warm server; the wait-free histograms stay on
    // in both runs (they are the always-on telemetry path).
    let ka_burst = |label: &str| -> f64 {
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..clients {
            let addr = addr.clone();
            let img = img.clone();
            let label = label.to_string();
            handles.push(std::thread::spawn(move || {
                let mut conn = http::Client::connect(&addr).expect("burst connect");
                let mut served = 0u64;
                for i in 0..per_client {
                    let tier = Tier::ALL[(c + i) % Tier::ALL.len()];
                    let body = infer_body(tier.name(), &img);
                    match conn.request("POST", "/v1/infer", Some(&body)) {
                        Ok((200, _)) => served += 1,
                        Ok((429, _)) => {}
                        Ok((status, b)) => panic!("{label}: unexpected status {status}: {b}"),
                        Err(e) => panic!("{label}: request failed: {e:#}"),
                    }
                }
                served
            }));
        }
        let served: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        served as f64 / t0.elapsed().as_secs_f64()
    };
    let telem = gateway.obs();
    telem.set_trace_enabled(true);
    let rps_traced = ka_burst("traced");
    telem.set_trace_enabled(false);
    let rps_untraced = ka_burst("untraced");
    telem.set_trace_enabled(true);
    let obs_delta = (rps_untraced - rps_traced).max(0.0);
    let obs_overhead_pct = obs_delta / rps_untraced.max(1e-9) * 100.0;
    println!(
        "serve_http/obs_overhead: traced {rps_traced:.1} req/s vs untraced {rps_untraced:.1} \
         req/s -> overhead {obs_overhead_pct:.2}%"
    );

    // --- NDJSON batch endpoint: many images per request ------------------
    let batch_lines = 64usize;
    let batch_posts = 4usize;
    let ndjson = {
        let mut lines = String::new();
        for _ in 0..batch_lines {
            lines.push_str(&infer_body("batch", &img));
            lines.push('\n');
        }
        lines
    };
    let t0 = Instant::now();
    let mut conn = http::Client::connect(&addr).expect("batch connect");
    let mut batch_images = 0u64;
    for _ in 0..batch_posts {
        let (status, body) = conn
            .request_typed("POST", "/v1/infer_batch", "application/x-ndjson", Some(&ndjson))
            .expect("infer_batch request");
        assert_eq!(status, 200, "{body}");
        batch_images += body.lines().filter(|l| !l.contains("\"error\"")).count() as u64;
    }
    let batch_wall = t0.elapsed().as_secs_f64();
    let batch_ips = batch_images as f64 / batch_wall;
    println!(
        "serve_http/infer_batch: {batch_images} images over {batch_posts} NDJSON posts in \
         {batch_wall:.3}s -> {batch_ips:.1} images/s"
    );

    let m = gateway.shutdown();
    println!(
        "serve_http totals: gold p99 {:.1}us, batch p99 {:.1}us",
        m.tier(Tier::Gold).p99_latency_us(),
        m.tier(Tier::Batch).p99_latency_us()
    );

    // --- connection scaling: idle keep-alive herds, RSS + throughput -----
    // The event-loop acceptance curve: 64 / 1k / 10k idle keep-alive
    // connections parked on one gateway while a probe client measures
    // round-trip throughput; RSS is sampled at each point (the
    // flat-memory claim).  Conn counts clamp to the fd budget — client
    // and server sockets both live in this one process.
    #[allow(unused_mut)]
    let mut scale_points: Vec<(&str, f64, f64, f64)> = Vec::new();
    #[allow(unused_mut)]
    let mut conns_max = 0.0f64;
    #[cfg(unix)]
    {
        println!("\n# pipeline — connection scaling (idle keep-alive herds, event loop)");
        let nofile = raise_nofile(65_536);
        let budget = (nofile as usize).saturating_sub(256) / 2;
        let mut scfg = SystemConfig::default();
        scfg.workers = 2;
        scfg.queue_cap = 1024;
        scfg.max_conns = 16_384;
        scfg.read_timeout_ms = 120_000; // the idle herd must not be shed mid-bench
        let scale_engine =
            Engine::builder().config(scfg.clone()).graph(graph.clone()).build().unwrap();
        let scale_gw = Gateway::with_engine(Arc::new(scale_engine), "127.0.0.1:0").unwrap();
        let saddr = scale_gw.addr().to_string();
        let mut herd: Vec<std::net::TcpStream> = Vec::new();
        for (label, target) in [("64", 64usize), ("1k", 1_000), ("10k", 10_000)] {
            let want = target.min(budget);
            while herd.len() < want {
                match std::net::TcpStream::connect(&saddr) {
                    Ok(s) => herd.push(s),
                    Err(e) => {
                        println!("conn_scale/{label}: connect stalled at {}: {e}", herd.len());
                        break;
                    }
                }
            }
            wait_for_open_conns(&saddr, herd.len());
            let rss_mb = vm_rss_mb();
            let mut probe = http::Client::connect(&saddr).expect("probe connect");
            let probe_reqs = 300usize;
            let t0 = Instant::now();
            for _ in 0..probe_reqs {
                let (status, _) = probe.request("GET", "/healthz", None).unwrap();
                assert_eq!(status, 200);
            }
            let rps = probe_reqs as f64 / t0.elapsed().as_secs_f64();
            println!(
                "conn_scale/{label}: {} idle conns, probe {rps:.0} req/s, rss {rss_mb:.1} MB",
                herd.len()
            );
            conns_max = conns_max.max(herd.len() as f64);
            scale_points.push((label, herd.len() as f64, rps, rss_mb));
        }
        drop(herd);
        scale_gw.shutdown();
    }
    let point = |label: &str| {
        scale_points
            .iter()
            .find(|p| p.0 == label)
            .map(|&(_, c, r, mb)| (c, r, mb))
            .unwrap_or((0.0, 0.0, 0.0))
    };
    let (c64, r64, m64) = point("64");
    let (c1k, r1k, m1k) = point("1k");
    let (c10k, r10k, m10k) = point("10k");
    let serve_doc = obj(vec![
        ("bench", s("serve")),
        ("synthetic_graph", JsonValue::Bool(!have_artifacts)),
        ("http_served", num(served as f64)),
        ("http_busy", num(busy as f64)),
        ("http_requests_per_s", num(http_rps)),
        ("http_keepalive_served", num(ka_served as f64)),
        ("http_keepalive_requests_per_s", num(ka_rps)),
        ("keepalive_speedup", num(keepalive_speedup)),
        ("conn_reuse_rate", num(conn_reuse_rate)),
        ("obs_overhead_pct", num(obs_overhead_pct)),
        ("obs_rps_traced", num(rps_traced)),
        ("obs_rps_untraced", num(rps_untraced)),
        ("infer_batch_images", num(batch_images as f64)),
        ("infer_batch_images_per_s", num(batch_ips)),
        ("rejected", num(m.rejected as f64)),
        ("gold_p50_latency_us", num(m.tier(Tier::Gold).p50_latency_us())),
        ("gold_p99_latency_us", num(m.tier(Tier::Gold).p99_latency_us())),
        ("silver_p50_latency_us", num(m.tier(Tier::Silver).p50_latency_us())),
        ("silver_p99_latency_us", num(m.tier(Tier::Silver).p99_latency_us())),
        ("batch_p50_latency_us", num(m.tier(Tier::Batch).p50_latency_us())),
        ("batch_p99_latency_us", num(m.tier(Tier::Batch).p99_latency_us())),
        ("mean_batch", num(m.mean_batch())),
        ("tops_per_watt", num(m.tops_per_watt(&gcfg.spec))),
        ("conn_scale_64_conns", num(c64)),
        ("conn_scale_64_rps", num(r64)),
        ("conn_scale_64_rss_mb", num(m64)),
        ("conn_scale_1k_conns", num(c1k)),
        ("conn_scale_1k_rps", num(r1k)),
        ("conn_scale_1k_rss_mb", num(m1k)),
        ("conn_scale_10k_conns", num(c10k)),
        ("conn_scale_10k_rps", num(r10k)),
        ("conn_scale_10k_rss_mb", num(m10k)),
        ("conn_scale_conns_max", num(conns_max)),
        ("fleet_rps_1", num(fleet_rate(1))),
        ("fleet_rps_2", num(fleet_rate(2))),
        ("fleet_rps_4", num(fleet_rate(4))),
        ("fleet_speedup_2", num(fleet_speedup_2)),
        ("fleet_speedup_4", num(fleet_speedup_4)),
        ("fleet_transfer_energy_pct", num(fleet_transfer_pct)),
        ("sweep_points_per_s", num(sweep_points_per_s)),
        ("energy_per_inference_mj", num(energy_per_inference_mj)),
        ("costmodel_overhead_pct", num(costmodel_overhead_pct)),
        ("costmodel_infer_per_s_compact", num(costmodel_rate_compact)),
        ("costmodel_infer_per_s_hierarchy", num(costmodel_rate_hier)),
    ]);
    let serve_out =
        std::env::var("BENCH_SERVE_OUT").unwrap_or_else(|_| "BENCH_serve.json".into());
    std::fs::write(&serve_out, serve_doc.to_string_compact()).unwrap();
    println!("wrote {serve_out}");
}

/// Block until the gateway reports at least `want` open connections in
/// its `/metrics` event-loop gauges (accepts are asynchronous), or a
/// 20s deadline passes.  The threaded fallback has no gauge block —
/// treat that as ready so the bench still runs.
#[cfg(unix)]
fn wait_for_open_conns(addr: &str, want: usize) {
    let deadline = Instant::now() + Duration::from_secs(20);
    loop {
        let (status, body) = http::request(addr, "GET", "/metrics", None).unwrap();
        assert_eq!(status, 200);
        let open = osa_hcim::io::json::parse(&body)
            .ok()
            .and_then(|doc| {
                doc.get("event_loop")
                    .and_then(|ev| ev.get("open_connections"))
                    .and_then(JsonValue::as_f64)
            })
            .unwrap_or(want as f64);
        if open >= want as f64 || Instant::now() >= deadline {
            return;
        }
        std::thread::sleep(Duration::from_millis(20));
    }
}
