//! End-to-end pipeline benchmarks (cargo bench --bench pipeline).
//!
//! One row per paper experiment surface: the tiled GEMM per mode
//! (Fig 5 workloads), the plan/execute split (cold packing vs warm
//! cached execution), full-network inference over a persistent executor
//! (Fig 9 workloads) and the coordinator serve loop (throughput /
//! latency claims).  Falls back to `QGraph::synthetic()` when the AOT
//! artifacts are absent so the perf trajectory is tracked everywhere.
//!
//! Emits `BENCH_pipeline.json` (override the path with `BENCH_OUT`):
//! serve requests/s, latency percentiles and the plan-cache hit rate.

use osa_hcim::benchkit::Bench;
use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::coordinator::Server;
use osa_hcim::io::json::{num, obj, s, JsonValue};
use osa_hcim::nn::data::Dataset;
use osa_hcim::nn::{Executor, QGraph};
use osa_hcim::sched::{GemmEngine, MacroGemm};
use osa_hcim::util::prng::SplitMix64;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn main() {
    osa_hcim::util::logging::init();
    let cfg = SystemConfig::default();
    let have_artifacts = cfg.spec.validate_against_artifacts(&cfg.artifacts_dir).is_ok();
    let (graph, img) = if have_artifacts {
        let ds = Dataset::load(&cfg.artifacts_dir).unwrap();
        let graph = QGraph::load(&cfg.artifacts_dir).unwrap();
        let (img, _) = ds.test_batch(0, 1);
        (graph, img.to_vec())
    } else {
        eprintln!("artifacts not built — benchmarking over the synthetic graph");
        let mut g = SplitMix64::new(11);
        let img: Vec<u8> = (0..32 * 32 * 3).map(|_| g.next_below(256) as u8).collect();
        (QGraph::synthetic(), img)
    };

    // --- tiled GEMM per mode (stage-2 layer shape: K=288, N=32) ---------
    let (m, k, n) = (256usize, 288usize, 32usize);
    let mut rng = SplitMix64::new(5);
    let a: Vec<i32> = (0..m * k).map(|_| rng.next_range_i32(0, 256)).collect();
    let w: Vec<i32> = (0..n * k).map(|_| rng.next_range_i32(-128, 128)).collect();
    println!("# pipeline — tiled GEMM [{m}x{k}] x [{n}x{k}] through the macro datapath");
    for mode in [CimMode::Dcim, CimMode::Hcim, CimMode::Osa, CimMode::Acim] {
        let mut gemm = MacroGemm::with_mode(mode);
        Bench::new(&format!("gemm/{}", mode.name()))
            .target(Duration::from_secs(3))
            .items((m * n * k) as f64)
            .run(|| gemm.gemm(&a, m, k, &w, n, 0).unwrap());
    }
    for mode in [CimMode::Pg, CimMode::Drq] {
        let mut gemm = MacroGemm::with_mode(mode);
        Bench::new(&format!("gemm/{}", mode.name()))
            .target(Duration::from_secs(1))
            .items((m * n * k) as f64)
            .run(|| gemm.gemm(&a, m, k, &w, n, 0).unwrap());
    }

    // --- plan/execute split: cold packing vs warm cached execution -------
    println!("\n# pipeline — plan/execute split (same GEMM, fresh cache vs cached plan)");
    Bench::new("plan/cold_build_and_execute")
        .target(Duration::from_secs(3))
        .items((m * n * k) as f64)
        .run(|| MacroGemm::with_mode(CimMode::Osa).gemm(&a, m, k, &w, n, 0).unwrap());
    let mut warm = MacroGemm::with_mode(CimMode::Osa);
    warm.gemm(&a, m, k, &w, n, 0).unwrap();
    Bench::new("plan/warm_execute")
        .target(Duration::from_secs(3))
        .items((m * n * k) as f64)
        .run(|| warm.gemm(&a, m, k, &w, n, 0).unwrap());
    let ws = warm.plan_stats();
    println!(
        "plan cache after warm run: hits={} misses={} hit_rate={:.4}",
        ws.hits,
        ws.misses,
        ws.hit_rate()
    );

    // --- full-network inference over a persistent executor ---------------
    println!("\n# pipeline — single-image inference (32x32x3), persistent executor");
    for mode in [CimMode::Dcim, CimMode::Hcim, CimMode::Osa] {
        let gemm = MacroGemm::with_mode(mode);
        let mut exec = Executor::new(&graph, gemm);
        exec.preplan().unwrap();
        Bench::new(&format!("infer/{}", mode.name()))
            .target(Duration::from_secs(5))
            .max_iters(200)
            .items(1.0)
            .run(|| exec.forward(&img, 1).unwrap());
    }

    // --- coordinator serve loop ------------------------------------------
    println!("\n# pipeline — coordinator round trip (submit -> batch -> respond)");
    let graph = Arc::new(graph);
    let server = Server::start(&cfg, graph).unwrap();
    Bench::new("serve/round_trip")
        .target(Duration::from_secs(5))
        .max_iters(500)
        .items(1.0)
        .run(|| {
            let rx = server.submit(img.clone()).unwrap();
            rx.recv().unwrap()
        });

    // --- serve throughput: closed burst of single-image requests ---------
    let burst = 256usize;
    let t0 = Instant::now();
    let rxs: Vec<_> = (0..burst).map(|_| server.submit(img.clone()).unwrap()).collect();
    for rx in rxs {
        let resp = rx.recv().unwrap();
        assert!(resp.error.is_none(), "serve burst hit an error: {:?}", resp.error);
    }
    let wall = t0.elapsed().as_secs_f64();
    let rps = burst as f64 / wall;
    let pstats = server.plan_stats();
    let metrics = server.shutdown();
    println!(
        "serve/burst: {burst} requests in {wall:.3}s -> {rps:.1} req/s  \
         plan_cache: hits={} misses={} hit_rate={:.4}",
        pstats.hits,
        pstats.misses,
        pstats.hit_rate()
    );
    println!("{}", metrics.report(&cfg.spec));

    // --- BENCH_pipeline.json ---------------------------------------------
    let doc = obj(vec![
        ("bench", s("pipeline")),
        ("synthetic_graph", JsonValue::Bool(!have_artifacts)),
        ("serve_burst", num(burst as f64)),
        ("serve_requests_per_s", num(rps)),
        ("serve_p50_latency_us", num(metrics.p50_latency_us())),
        ("serve_p95_latency_us", num(metrics.p95_latency_us())),
        ("serve_mean_batch", num(metrics.mean_batch())),
        ("plan_cache_hits", num(pstats.hits as f64)),
        ("plan_cache_misses", num(pstats.misses as f64)),
        ("plan_cache_hit_rate", num(pstats.hit_rate())),
    ]);
    let out = std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_pipeline.json".into());
    std::fs::write(&out, doc.to_string_compact()).unwrap();
    println!("wrote {out}");
}
