//! End-to-end pipeline benchmarks (cargo bench --bench pipeline).
//!
//! One row per paper experiment surface: the tiled GEMM per mode
//! (Fig 5 workloads), full-network single-image inference per mode
//! (Fig 9 workloads) and the coordinator serve loop (throughput /
//! latency claims).  Requires `make artifacts`.

use osa_hcim::benchkit::Bench;
use osa_hcim::config::{CimMode, SystemConfig};
use osa_hcim::coordinator::Server;
use osa_hcim::nn::data::Dataset;
use osa_hcim::nn::{Executor, QGraph};
use osa_hcim::sched::{GemmEngine, MacroGemm};
use osa_hcim::util::prng::SplitMix64;
use std::sync::Arc;
use std::time::Duration;

fn main() {
    osa_hcim::util::logging::init();
    let cfg = SystemConfig::default();
    if cfg.spec.validate_against_artifacts(&cfg.artifacts_dir).is_err() {
        eprintln!("pipeline bench needs artifacts — run `make artifacts` first");
        return;
    }
    let ds = Dataset::load(&cfg.artifacts_dir).unwrap();
    let graph = QGraph::load(&cfg.artifacts_dir).unwrap();

    // --- tiled GEMM per mode (stage-2 layer shape: K=288, N=32) ---------
    let (m, k, n) = (256usize, 288usize, 32usize);
    let mut rng = SplitMix64::new(5);
    let a: Vec<i32> = (0..m * k).map(|_| rng.next_range_i32(0, 256)).collect();
    let w: Vec<i32> = (0..n * k).map(|_| rng.next_range_i32(-128, 128)).collect();
    println!("# pipeline — tiled GEMM [{m}x{k}] x [{n}x{k}] through the macro datapath");
    for mode in [CimMode::Dcim, CimMode::Hcim, CimMode::Osa, CimMode::Acim] {
        let mut gemm = MacroGemm::with_mode(mode);
        Bench::new(&format!("gemm/{}", mode.name()))
            .target(Duration::from_secs(3))
            .items((m * n * k) as f64)
            .run(|| gemm.gemm(&a, m, k, &w, n, 0).unwrap());
    }

    // --- full-network inference per mode --------------------------------
    println!("\n# pipeline — ResNet-mini single-image inference (32x32x3)");
    let (img, _) = ds.test_batch(0, 1);
    for mode in [CimMode::Dcim, CimMode::Hcim, CimMode::Osa] {
        let gemm = MacroGemm::with_mode(mode);
        Bench::new(&format!("infer/{}", mode.name()))
            .target(Duration::from_secs(5))
            .max_iters(200)
            .items(1.0)
            .run(|| {
                let mut exec = Executor::new(&graph, gemm.clone());
                exec.forward(img, 1).unwrap()
            });
    }

    // --- coordinator serve loop ------------------------------------------
    println!("\n# pipeline — coordinator round trip (submit -> batch -> respond)");
    let graph = Arc::new(graph);
    let server = Server::start(&cfg, graph).unwrap();
    let (img, _) = ds.test_batch(0, 1);
    let img = img.to_vec();
    Bench::new("serve/round_trip")
        .target(Duration::from_secs(5))
        .max_iters(500)
        .items(1.0)
        .run(|| {
            let rx = server.submit(img.clone()).unwrap();
            rx.recv().unwrap()
        });
    let metrics = server.shutdown();
    println!("{}", metrics.report(&cfg.spec));
}
