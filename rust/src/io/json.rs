//! Minimal JSON parser + writer (serde is not in the offline mirror).
//!
//! Supports the full JSON grammar we produce (`graph.json`, `spec.json`,
//! coordinator metrics): objects, arrays, strings with escapes, numbers,
//! booleans, null.  Not streaming; documents here are at most a few MB.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<JsonValue>),
    Object(BTreeMap<String, JsonValue>),
}

impl JsonValue {
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Object(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&JsonValue> {
        match self {
            JsonValue::Array(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Number(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x as i64)
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Array(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, JsonValue>> {
        match self {
            JsonValue::Object(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize back to compact JSON text.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Null => out.push_str("null"),
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Number(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            JsonValue::String(s) => write_escaped(out, s),
            JsonValue::Array(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            JsonValue::Object(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<JsonValue> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        bail!("trailing garbage at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> Result<JsonValue> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(JsonValue::String(self.string()?)),
            Some(b't') => self.lit("true", JsonValue::Bool(true)),
            Some(b'f') => self.lit("false", JsonValue::Bool(false)),
            Some(b'n') => self.lit("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|b| b as char), self.pos),
        }
    }

    fn lit(&mut self, text: &str, v: JsonValue) -> Result<JsonValue> {
        if self.bytes[self.pos..].starts_with(text.as_bytes()) {
            self.pos += text.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Object(map));
                }
                other => bail!("expected ',' or '}}', found {:?}", other.map(|b| b as char)),
            }
        }
    }

    fn array(&mut self) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Array(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Array(out));
                }
                other => bail!("expected ',' or ']', found {:?}", other.map(|b| b as char)),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy a run of plain utf-8 bytes
                    let start = self.pos;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.pos += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(JsonValue::Number(text.parse::<f64>()?))
    }
}

/// Convenience builder for writing small JSON documents.
pub fn obj(pairs: Vec<(&str, JsonValue)>) -> JsonValue {
    JsonValue::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> JsonValue {
    JsonValue::Number(x)
}

pub fn s(x: &str) -> JsonValue {
    JsonValue::String(x.to_string())
}

pub fn arr<I: IntoIterator<Item = JsonValue>>(xs: I) -> JsonValue {
    JsonValue::Array(xs.into_iter().collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), JsonValue::Null);
        assert_eq!(parse("true").unwrap(), JsonValue::Bool(true));
        assert_eq!(parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(parse("\"a\\nb\"").unwrap().as_str(), Some("a\nb"));
    }

    #[test]
    fn parse_nested() {
        let doc = parse(r#"{"a": [1, 2, {"b": "x", "c": false}], "d": {}}"#).unwrap();
        assert_eq!(doc.get("a").unwrap().idx(0).unwrap().as_i64(), Some(1));
        assert_eq!(
            doc.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("x")
        );
        assert!(doc.get("d").unwrap().as_object().unwrap().is_empty());
    }

    #[test]
    fn parse_unicode_escape() {
        assert_eq!(parse(r#""é""#).unwrap().as_str(), Some("é"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("'single'").is_err());
    }

    #[test]
    fn roundtrip_compact() {
        let doc = parse(r#"{"k": [1, 2.5, "s", null, true]}"#).unwrap();
        let text = doc.to_string_compact();
        assert_eq!(parse(&text).unwrap(), doc);
    }

    #[test]
    fn builder_helpers() {
        let d = obj(vec![("x", num(1.0)), ("y", arr([s("a"), s("b")]))]);
        assert_eq!(d.get("x").unwrap().as_i64(), Some(1));
        assert_eq!(d.get("y").unwrap().idx(1).unwrap().as_str(), Some("b"));
    }
}
