//! I/O substrates: the `.rten` tensor container and a minimal JSON
//! parser/writer (built in-repo; serde is not in the offline mirror).

pub mod json;
pub mod rten;
