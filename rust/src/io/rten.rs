//! `.rten` tensor container reader/writer — mirror of
//! `python/compile/rten.py` (DESIGN.md §7).

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"RTEN";
const VERSION: u32 = 1;

/// Element type tag.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32 = 0,
    I32 = 1,
    I8 = 2,
    U8 = 3,
    I64 = 4,
}

impl DType {
    fn from_u8(x: u8) -> Result<Self> {
        Ok(match x {
            0 => DType::F32,
            1 => DType::I32,
            2 => DType::I8,
            3 => DType::U8,
            4 => DType::I64,
            other => bail!("unknown dtype tag {other}"),
        })
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 | DType::U8 => 1,
            DType::I64 => 8,
        }
    }
}

/// Typed tensor storage.
#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
    I8(Vec<i8>),
    U8(Vec<u8>),
    I64(Vec<i64>),
}

impl Data {
    pub fn len(&self) -> usize {
        match self {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
            Data::I8(v) => v.len(),
            Data::U8(v) => v.len(),
            Data::I64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> DType {
        match self {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
            Data::I8(_) => DType::I8,
            Data::U8(_) => DType::U8,
            Data::I64(_) => DType::I64,
        }
    }
}

/// A named, shaped tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

impl Tensor {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        Self { shape, data: Data::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        Self { shape, data: Data::I32(data) }
    }

    pub fn i8(shape: Vec<usize>, data: Vec<i8>) -> Self {
        Self { shape, data: Data::I8(data) }
    }

    pub fn u8(shape: Vec<usize>, data: Vec<u8>) -> Self {
        Self { shape, data: Data::U8(data) }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            Data::F32(v) => Ok(v),
            other => bail!("expected f32 tensor, found {:?}", other.dtype()),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            Data::I32(v) => Ok(v),
            other => bail!("expected i32 tensor, found {:?}", other.dtype()),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match &self.data {
            Data::I8(v) => Ok(v),
            other => bail!("expected i8 tensor, found {:?}", other.dtype()),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match &self.data {
            Data::U8(v) => Ok(v),
            other => bail!("expected u8 tensor, found {:?}", other.dtype()),
        }
    }
}

/// An ordered collection of named tensors.
pub type TensorMap = BTreeMap<String, Tensor>;

/// Read a container from disk.
pub fn read(path: &Path) -> Result<TensorMap> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    read_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
}

/// Read a container from a byte slice.
pub fn read_bytes(bytes: &[u8]) -> Result<TensorMap> {
    let mut cur = std::io::Cursor::new(bytes);
    let mut magic = [0u8; 4];
    cur.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad magic {magic:?}");
    }
    let version = read_u32(&mut cur)?;
    if version != VERSION {
        bail!("unsupported version {version}");
    }
    let count = read_u32(&mut cur)? as usize;
    let mut out = TensorMap::new();
    for _ in 0..count {
        let name_len = read_u32(&mut cur)? as usize;
        let mut name_bytes = vec![0u8; name_len];
        cur.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes)?;
        let mut tag = [0u8; 1];
        cur.read_exact(&mut tag)?;
        let dtype = DType::from_u8(tag[0])?;
        let ndim = read_u32(&mut cur)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut cur)? as usize);
        }
        let numel: usize = shape.iter().product();
        let mut raw = vec![0u8; numel * dtype.size()];
        cur.read_exact(&mut raw)?;
        let data = match dtype {
            DType::F32 => Data::F32(
                raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            DType::I32 => Data::I32(
                raw.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
            DType::I8 => Data::I8(raw.iter().map(|&b| b as i8).collect()),
            DType::U8 => Data::U8(raw),
            DType::I64 => Data::I64(
                raw.chunks_exact(8).map(|c| i64::from_le_bytes(c.try_into().unwrap())).collect(),
            ),
        };
        out.insert(name, Tensor { shape, data });
    }
    Ok(out)
}

/// Write a container to disk (used by tests and result dumps).
pub fn write(path: &Path, tensors: &TensorMap) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        if t.numel() != t.data.len() {
            bail!("{name}: shape/data mismatch");
        }
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&[t.data.dtype() as u8])?;
        f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            f.write_all(&(d as u32).to_le_bytes())?;
        }
        match &t.data {
            Data::F32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            Data::I32(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            Data::I8(v) => {
                let bytes: Vec<u8> = v.iter().map(|&x| x as u8).collect();
                f.write_all(&bytes)?;
            }
            Data::U8(v) => f.write_all(v)?,
            Data::I64(v) => {
                for x in v {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

fn read_u32(cur: &mut std::io::Cursor<&[u8]>) -> Result<u32> {
    let mut b = [0u8; 4];
    cur.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(map: &TensorMap) -> TensorMap {
        let dir = std::env::temp_dir().join(format!("rten_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.rten");
        write(&path, map).unwrap();
        let back = read(&path).unwrap();
        std::fs::remove_file(&path).ok();
        back
    }

    #[test]
    fn roundtrip_all_dtypes() {
        let mut m = TensorMap::new();
        m.insert("f".into(), Tensor::f32(vec![2, 3], vec![0.5, -1.0, 2.0, 3.5, 4.0, -0.25]));
        m.insert("i".into(), Tensor::i32(vec![4], vec![-5, 0, 7, i32::MAX]));
        m.insert("b".into(), Tensor::i8(vec![3], vec![-128, 0, 127]));
        m.insert("u".into(), Tensor::u8(vec![3], vec![0, 128, 255]));
        m.insert(
            "l".into(),
            Tensor { shape: vec![2], data: Data::I64(vec![1 << 40, -3]) },
        );
        assert_eq!(roundtrip(&m), m);
    }

    #[test]
    fn scalar_tensor() {
        let mut m = TensorMap::new();
        m.insert("s".into(), Tensor::f32(vec![], vec![3.5]));
        let back = roundtrip(&m);
        assert_eq!(back["s"].shape, Vec::<usize>::new());
        assert_eq!(back["s"].as_f32().unwrap(), &[3.5]);
    }

    #[test]
    fn bad_magic_rejected() {
        assert!(read_bytes(b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn truncated_rejected() {
        let mut m = TensorMap::new();
        m.insert("x".into(), Tensor::i32(vec![8], (0..8).collect()));
        let dir = std::env::temp_dir();
        let path = dir.join(format!("rten_trunc_{}.rten", std::process::id()));
        write(&path, &m).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.truncate(bytes.len() - 5);
        assert!(read_bytes(&bytes).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn shape_data_mismatch_rejected() {
        let mut m = TensorMap::new();
        m.insert("x".into(), Tensor { shape: vec![3], data: Data::I32(vec![1, 2]) });
        let path = std::env::temp_dir().join(format!("rten_bad_{}.rten", std::process::id()));
        assert!(write(&path, &m).is_err());
    }

    #[test]
    fn typed_accessors() {
        let t = Tensor::i32(vec![2], vec![1, 2]);
        assert!(t.as_i32().is_ok());
        assert!(t.as_f32().is_err());
    }
}
