//! Cycle-level behavioral model of the 64b x 144b OSA-HCIM macro.
//!
//! One [`MacroUnit`] holds the weights of 8 HMUs (one 8-bit weight per
//! HCIMA column) and executes the two operating modes of the paper:
//!
//! * **Saliency-Evaluation mode** ([`MacroUnit::saliency`]): the s=2
//!   highest-order 1-bit MACs are computed by the DAT, N/Q-compressed to
//!   3 bits and summed across HMU channels — the per-K-tile contribution
//!   the OSE accumulates "across cycles".
//! * **Computing mode** ([`MacroUnit::compute_hybrid`]): orders
//!   `k >= B_D/A` exactly via the split-port digital path, orders
//!   `B-4 <= k < B` through the DAC-slice / charge-share / 3-bit SAR ADC
//!   analog path, lower orders discarded.
//!
//! Numerics are bit-exact with `kernels/ref.py` (same f32 ADC transfer,
//! same integer paths) given the same noise buffer; cross-checked against
//! the PJRT artifacts in `rust/tests/artifact_parity.rs`.
//!
//! The cycle model (DESIGN.md §4): the DAT retires one 1-bit MAC per
//! digital clock across all 144 columns; the digital clock runs at 2x the
//! analog clock ("DAT has twice lower latency than the ADC").  The SAR
//! ADC needs 3 analog cycles per conversion and is pipelined II=1 across
//! the per-weight-plane groups.  Digital and analog pipelines run
//! concurrently (split-port readout), so computing-mode latency is their
//! max.

pub mod ose;

use crate::analog::{adc_transfer, adc_transfer_dev, analog_group_bounds};
use crate::quant::{and_popcount_words, plane_sign, PackedBits};
use crate::spec::MacroSpec;
use anyhow::{ensure, Result};

/// Device context for the variation-aware compute paths (DESIGN.md §16):
/// per-column static gains (None = unity), the operation-unit group size
/// `s_ou` (0 = one full-width conversion per analog group), and the ADC
/// offset/gain error forwarded to [`adc_transfer_dev`].
#[derive(Debug, Clone, Copy)]
pub struct DevCtx<'a> {
    pub col_gains: Option<&'a [f32]>,
    pub s_ou: usize,
    pub adc_offset: f32,
    pub adc_gain: f32,
}

impl DevCtx<'_> {
    /// Sub-conversions per analog group for this macro geometry.
    pub fn n_sub(&self, cols: usize) -> usize {
        if self.s_ou == 0 {
            1
        } else {
            cols.div_ceil(self.s_ou)
        }
    }
}

/// Gain-weighted AND of a weight plane and an activation plane over the
/// column range `[c_lo, c_hi)`.  With `gains == None` this is the plain
/// popcount (as f32); otherwise each set column contributes its static
/// gain.  Sums of <= 144 unit-scale f32 terms stay exact for the unity
/// case, which is what keeps the trivial device bit-equal to the
/// popcount path.
#[inline]
fn gain_weighted_and(
    wrow: &[u64],
    aw: &[u64],
    gains: Option<&[f32]>,
    c_lo: usize,
    c_hi: usize,
) -> f32 {
    let mut sum = 0.0f32;
    let w_lo = c_lo / 64;
    let w_hi = (c_hi - 1) / 64;
    for wi in w_lo..=w_hi {
        let mut word = wrow[wi] & aw[wi];
        if wi == w_lo {
            word &= !0u64 << (c_lo % 64);
        }
        if wi == w_hi && c_hi % 64 != 0 {
            word &= (1u64 << (c_hi % 64)) - 1;
        }
        if word == 0 {
            continue;
        }
        match gains {
            None => sum += word.count_ones() as f32,
            Some(g) => {
                while word != 0 {
                    let bit = word.trailing_zeros() as usize;
                    sum += g[wi * 64 + bit];
                    word &= word - 1;
                }
            }
        }
    }
    sum
}

/// Resolve the activation planes once per call: `None` for an all-zero
/// plane (its 1-bit MACs are 0 — the sparsity fast path), else the
/// plane's packed words.  Hoists both the `plane_empty` test and the
/// plane-slice lookup out of the per-HMU/per-weight-plane walk, leaving
/// a word-blocked AND+POPCNT as the only work in the inner loop.
#[inline]
fn resolve_planes(a_packed: &PackedBits) -> Vec<Option<&[u64]>> {
    (0..a_packed.n_planes)
        .map(|j| (!a_packed.plane_empty(j)).then(|| a_packed.plane(j)))
        .collect()
}

/// Workload/latency accounting of one macro op (all 8 HMUs).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct OpCounts {
    /// 1-bit MAC (i,j) pairs retired digitally (includes reused SE pairs).
    pub digital_pairs: u32,
    /// 1-bit MAC (i,j) pairs covered by analog slices.
    pub analog_pairs: u32,
    /// 1-bit MAC (i,j) pairs discarded.
    pub discard_pairs: u32,
    /// Analog slice groups (ADC conversions *per HMU*).
    pub adc_groups: u32,
    /// SE-mode pairs computed up front (always digital, reused later).
    pub se_pairs: u32,
    /// Computing-mode latency, analog-clock cycles.
    pub compute_cycles: u32,
    /// SE-mode latency, analog-clock cycles (0 for non-OSA modes).
    pub se_cycles: u32,
}

impl OpCounts {
    pub fn total_cycles(&self) -> u32 {
        self.compute_cycles + self.se_cycles
    }
}

/// Number of (i,j) pairs with i+j = k for the given bit widths.
fn pairs_at_order(k: i32, sp: &MacroSpec) -> u32 {
    let w = sp.w_bits as i32;
    let a = sp.a_bits as i32;
    if k < 0 || k > w + a - 2 {
        return 0;
    }
    let lo = (k - (a - 1)).max(0);
    let hi = k.min(w - 1);
    (hi - lo + 1).max(0) as u32
}

/// Static workload allocation for a boundary (paper Fig. 5a), including
/// the cycle model.  `with_se` adds the saliency-evaluation overhead
/// (OSA mode only).
pub fn counts_for_boundary(b: i32, with_se: bool, sp: &MacroSpec) -> OpCounts {
    let mut c = OpCounts::default();
    let k_max = sp.k_max();
    for k in 0..=k_max {
        let n = pairs_at_order(k, sp);
        if k >= b {
            c.digital_pairs += n;
        } else if k >= b - sp.analog_band {
            c.analog_pairs += n;
        } else {
            c.discard_pairs += n;
        }
    }
    for i in 0..sp.w_bits as i32 {
        if analog_group_bounds(i, b, sp).is_some() {
            c.adc_groups += 1;
        }
    }
    if with_se {
        for k in sp.se_k_min()..=k_max {
            c.se_pairs += pairs_at_order(k, sp);
        }
        // SE pairs run at the 2x digital clock, +1 cycle for the OSE
        // threshold compare.
        c.se_cycles = c.se_pairs.div_ceil(2) + 1;
    }
    // digital pairs already computed during SE mode are reused
    let dig_remaining = c.digital_pairs - if with_se { c.se_pairs.min(c.digital_pairs) } else { 0 };
    let dig_cycles = dig_remaining.div_ceil(2);
    let ana_cycles = if c.adc_groups > 0 { c.adc_groups + 2 } else { 0 };
    c.compute_cycles = dig_cycles.max(ana_cycles);
    c
}

/// The macro: 8 HMUs x 144 HCIMA columns with loaded weights.
#[derive(Debug, Clone)]
pub struct MacroUnit {
    sp: MacroSpec,
    /// Raw weights per HMU row, length `hmus * cols` (row-major).
    weights: Vec<i32>,
    /// Packed weight bit planes per HMU.
    packed: Vec<PackedBits>,
}

impl MacroUnit {
    /// Load weights: `w_q` is `[hmus, cols]` row-major int8-as-i32.
    pub fn new(w_q: &[i32], sp: MacroSpec) -> Result<Self> {
        ensure!(
            w_q.len() == sp.hmus * sp.cols,
            "weights must be hmus*cols = {}, got {}",
            sp.hmus * sp.cols,
            w_q.len()
        );
        ensure!(
            w_q.iter().all(|&w| (-128..=127).contains(&w)),
            "weights out of int8 range"
        );
        let packed = (0..sp.hmus)
            .map(|h| PackedBits::pack(&w_q[h * sp.cols..(h + 1) * sp.cols], sp.w_bits, true))
            .collect();
        Ok(Self { sp, weights: w_q.to_vec(), packed })
    }

    pub fn spec(&self) -> &MacroSpec {
        &self.sp
    }

    /// Pack one activation vector (length `cols`) for reuse across modes.
    pub fn pack_acts(&self, a: &[i32]) -> PackedBits {
        debug_assert_eq!(a.len(), self.sp.cols);
        PackedBits::pack(a, self.sp.a_bits, false)
    }

    /// Raw `[hmus, cols]` weights as loaded — the tile layout consumed by
    /// the PJRT artifact dispatch and the plan-parity tests.
    pub fn weights(&self) -> &[i32] {
        &self.weights
    }

    /// Loss-free integer MAC per HMU (conventional RW + digital compute —
    /// the DCIM ground truth).
    pub fn exact(&self, a: &[i32]) -> Vec<i32> {
        (0..self.sp.hmus)
            .map(|h| {
                let w = &self.weights[h * self.sp.cols..(h + 1) * self.sp.cols];
                a.iter().zip(w).map(|(&x, &y)| x * y).sum()
            })
            .collect()
    }

    /// Exact MAC per HMU over masked activation bits (`a & mask`) — the
    /// high-nibble pass of the dual-precision PG/DRQ baselines
    /// (`mask = !0xF` keeps bits 4..8).
    pub fn exact_masked(&self, a: &[i32], mask: i32) -> Vec<i32> {
        (0..self.sp.hmus)
            .map(|h| {
                let w = &self.weights[h * self.sp.cols..(h + 1) * self.sp.cols];
                a.iter().zip(w).map(|(&x, &y)| (x & mask) * y).sum()
            })
            .collect()
    }

    /// Saliency-Evaluation mode: S contribution of this K-tile
    /// (3-bit N/Q per high-order DMAC, summed over HMU channels).
    pub fn saliency(&self, a_packed: &PackedBits) -> i32 {
        let sp = &self.sp;
        let a_planes = resolve_planes(a_packed);
        let mut s = 0i32;
        for h in 0..sp.hmus {
            let wp = &self.packed[h];
            for i in 0..sp.w_bits {
                let j_start = ((sp.se_k_min() - i as i32).max(0) as usize).min(sp.a_bits);
                let wrow = wp.plane(i);
                for aw in a_planes[j_start..].iter().flatten() {
                    let d = and_popcount_words(wrow, aw);
                    s += (d >> sp.nq_shift).min(sp.nq_max);
                }
            }
        }
        s
    }

    /// Computing mode with boundary `b`.  `noise` is `[hmus, w_bits]`
    /// row-major, code units (ignored for planes without an analog group).
    pub fn compute_hybrid(&self, a_packed: &PackedBits, b: i32, noise: &[f32]) -> Vec<i32> {
        let sp = &self.sp;
        debug_assert_eq!(noise.len(), sp.hmus * sp.w_bits);
        let a_planes = resolve_planes(a_packed);
        let mut out = vec![0i32; sp.hmus];
        for h in 0..sp.hmus {
            let wp = &self.packed[h];
            let mut acc = 0i32;
            for i in 0..sp.w_bits {
                let sign = plane_sign(i, sp.w_bits);
                let wrow = wp.plane(i);
                // digital domain: orders k >= b (loop starts at the
                // boundary; empty activation planes contribute nothing)
                let j_start = ((b - i as i32).max(0) as usize).min(sp.a_bits);
                for (j, aw) in a_planes.iter().enumerate().skip(j_start) {
                    if let Some(aw) = aw {
                        let d = and_popcount_words(wrow, aw);
                        acc += sign * (d << (i + j));
                    }
                }
                // analog domain: one DAC slice + ADC conversion per plane
                if let Some((j_lo, j_hi)) = analog_group_bounds(i as i32, b, sp) {
                    let mut amac = 0i32;
                    for j in j_lo..=j_hi {
                        if let Some(aw) = a_planes[j as usize] {
                            let d = and_popcount_words(wrow, aw);
                            amac += d << (j - j_lo);
                        }
                    }
                    let nbits = j_hi - j_lo + 1;
                    let rec = adc_transfer(amac, nbits, noise[h * sp.w_bits + i], sp);
                    acc += sign * (rec << (i as i32 + j_lo));
                }
            }
            out[h] = acc;
        }
        out
    }

    /// Computing mode with boundary `b` through a device model: static
    /// per-column gains, `s_ou` operation-unit grouping (each sub-sum
    /// converts separately) and ADC offset/gain error.  `noise` is
    /// `[hmus, w_bits, n_sub]` row-major, one sample per sub-conversion;
    /// the draw count is independent of `b` so the unit noise stream
    /// stays aligned whatever boundary the OSE picks.  With a trivial
    /// `ctx` (unity gains, `s_ou == 0`, no ADC error) this reproduces
    /// [`MacroUnit::compute_hybrid`] bit-exactly on the same noise.
    pub fn compute_hybrid_dev(
        &self,
        a_packed: &PackedBits,
        b: i32,
        noise: &[f32],
        ctx: &DevCtx,
    ) -> Vec<i32> {
        let sp = &self.sp;
        let n_sub = ctx.n_sub(sp.cols);
        let group = if ctx.s_ou == 0 { sp.cols } else { ctx.s_ou };
        debug_assert_eq!(noise.len(), sp.hmus * sp.w_bits * n_sub);
        let a_planes = resolve_planes(a_packed);
        let mut out = vec![0i32; sp.hmus];
        for h in 0..sp.hmus {
            let wp = &self.packed[h];
            let mut acc = 0i32;
            for i in 0..sp.w_bits {
                let sign = plane_sign(i, sp.w_bits);
                let wrow = wp.plane(i);
                // digital domain is unchanged: exact split-port readout
                let j_start = ((b - i as i32).max(0) as usize).min(sp.a_bits);
                for (j, aw) in a_planes.iter().enumerate().skip(j_start) {
                    if let Some(aw) = aw {
                        let d = and_popcount_words(wrow, aw);
                        acc += sign * (d << (i + j));
                    }
                }
                // analog domain: s_ou-column sub-sums, one ADC conversion
                // each, summed post-reconstruction
                if let Some((j_lo, j_hi)) = analog_group_bounds(i as i32, b, sp) {
                    let nbits = j_hi - j_lo + 1;
                    for sub in 0..n_sub {
                        let c_lo = sub * group;
                        let c_hi = ((sub + 1) * group).min(sp.cols);
                        let mut amac = 0.0f32;
                        for j in j_lo..=j_hi {
                            if let Some(aw) = a_planes[j as usize] {
                                let d = gain_weighted_and(wrow, aw, ctx.col_gains, c_lo, c_hi);
                                amac += d * (1i32 << (j - j_lo)) as f32;
                            }
                        }
                        let idx = (h * sp.w_bits + i) * n_sub + sub;
                        let rec = adc_transfer_dev(
                            amac,
                            nbits,
                            noise[idx],
                            ctx.adc_offset,
                            ctx.adc_gain,
                            sp,
                        );
                        acc += sign * (rec << (i as i32 + j_lo));
                    }
                }
            }
            out[h] = acc;
        }
        out
    }

    /// Full-analog baseline through a device model; `noise` is
    /// `[hmus, w_bits, n_slices, n_sub]` row-major.  Trivial `ctx` ==
    /// [`MacroUnit::compute_acim`] bit-exactly on the same noise.
    pub fn compute_acim_dev(&self, a_packed: &PackedBits, noise: &[f32], ctx: &DevCtx) -> Vec<i32> {
        let sp = &self.sp;
        let n_slices = sp.a_bits.div_ceil(sp.analog_band as usize);
        let n_sub = ctx.n_sub(sp.cols);
        let group = if ctx.s_ou == 0 { sp.cols } else { ctx.s_ou };
        debug_assert_eq!(noise.len(), sp.hmus * sp.w_bits * n_slices * n_sub);
        let a_planes = resolve_planes(a_packed);
        let mut out = vec![0i32; sp.hmus];
        for h in 0..sp.hmus {
            let wp = &self.packed[h];
            let mut acc = 0i32;
            for i in 0..sp.w_bits {
                let sign = plane_sign(i, sp.w_bits);
                let wrow = wp.plane(i);
                for sl in 0..n_slices {
                    let j_lo = (sl * sp.analog_band as usize) as i32;
                    let j_hi = (j_lo + sp.analog_band - 1).min(sp.a_bits as i32 - 1);
                    let nbits = j_hi - j_lo + 1;
                    for sub in 0..n_sub {
                        let c_lo = sub * group;
                        let c_hi = ((sub + 1) * group).min(sp.cols);
                        let mut amac = 0.0f32;
                        for j in j_lo..=j_hi {
                            if let Some(aw) = a_planes[j as usize] {
                                let d = gain_weighted_and(wrow, aw, ctx.col_gains, c_lo, c_hi);
                                amac += d * (1i32 << (j - j_lo)) as f32;
                            }
                        }
                        let idx = ((h * sp.w_bits + i) * n_slices + sl) * n_sub + sub;
                        let rec = adc_transfer_dev(
                            amac,
                            nbits,
                            noise[idx],
                            ctx.adc_offset,
                            ctx.adc_gain,
                            sp,
                        );
                        acc += sign * (rec << (i as i32 + j_lo));
                    }
                }
            }
            out[h] = acc;
        }
        out
    }

    /// Full-analog baseline (conventional ACIM): every weight plane times
    /// bit-parallel activation slices of ANALOG_BAND bits.
    /// `noise` is `[hmus, w_bits, n_slices]` row-major.
    pub fn compute_acim(&self, a_packed: &PackedBits, noise: &[f32]) -> Vec<i32> {
        let sp = &self.sp;
        let n_slices = sp.a_bits.div_ceil(sp.analog_band as usize);
        debug_assert_eq!(noise.len(), sp.hmus * sp.w_bits * n_slices);
        let a_planes = resolve_planes(a_packed);
        let mut out = vec![0i32; sp.hmus];
        for h in 0..sp.hmus {
            let wp = &self.packed[h];
            let mut acc = 0i32;
            for i in 0..sp.w_bits {
                let sign = plane_sign(i, sp.w_bits);
                let wrow = wp.plane(i);
                for sl in 0..n_slices {
                    let j_lo = (sl * sp.analog_band as usize) as i32;
                    let j_hi = (j_lo + sp.analog_band - 1).min(sp.a_bits as i32 - 1);
                    let mut amac = 0i32;
                    for j in j_lo..=j_hi {
                        if let Some(aw) = a_planes[j as usize] {
                            let d = and_popcount_words(wrow, aw);
                            amac += d << (j - j_lo);
                        }
                    }
                    let nbits = j_hi - j_lo + 1;
                    let idx = (h * sp.w_bits + i) * n_slices + sl;
                    let rec = adc_transfer(amac, nbits, noise[idx], sp);
                    acc += sign * (rec << (i as i32 + j_lo));
                }
            }
            out[h] = acc;
        }
        out
    }

    /// Workload counts for running this macro at boundary `b`.
    pub fn counts(&self, b: i32, with_se: bool) -> OpCounts {
        counts_for_boundary(b, with_se, &self.sp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest::check;
    use crate::util::prng::SplitMix64;

    fn unit(seed: u64) -> (MacroUnit, SplitMix64) {
        let sp = MacroSpec::default();
        let mut g = SplitMix64::new(seed);
        let w: Vec<i32> = (0..sp.hmus * sp.cols).map(|_| g.next_range_i32(-128, 128)).collect();
        (MacroUnit::new(&w, sp).unwrap(), g)
    }

    fn acts(g: &mut SplitMix64, n: usize) -> Vec<i32> {
        (0..n).map(|_| g.next_range_i32(0, 256)).collect()
    }

    #[test]
    fn b0_is_exact() {
        let (u, mut g) = unit(1);
        let sp = *u.spec();
        for _ in 0..10 {
            let a = acts(&mut g, sp.cols);
            let p = u.pack_acts(&a);
            let noise = vec![0.5f32; sp.hmus * sp.w_bits];
            assert_eq!(u.compute_hybrid(&p, 0, &noise), u.exact(&a));
        }
    }

    #[test]
    fn error_grows_with_boundary() {
        let (u, mut g) = unit(2);
        let sp = *u.spec();
        let mut prev = 0.0f64;
        let samples: Vec<Vec<i32>> = (0..64).map(|_| acts(&mut g, sp.cols)).collect();
        let mut noise_g = SplitMix64::new(99);
        for b in [0, 5, 7, 9, 10] {
            let mut mse = 0.0;
            for a in &samples {
                let p = u.pack_acts(a);
                let noise = noise_g.normals_f32(sp.hmus * sp.w_bits, 0.3);
                let exact = u.exact(a);
                let hyb = u.compute_hybrid(&p, b, &noise);
                for (e, h) in exact.iter().zip(&hyb) {
                    mse += ((e - h) as f64).powi(2);
                }
            }
            assert!(mse >= prev, "MSE not monotone at B={b}: {mse} < {prev}");
            prev = mse;
        }
        assert!(prev > 0.0);
    }

    #[test]
    fn exact_masked_splits_into_nibbles() {
        let (u, mut g) = unit(11);
        let sp = *u.spec();
        let a = acts(&mut g, sp.cols);
        let hi = u.exact_masked(&a, !0xF);
        let lo = u.exact_masked(&a, 0xF);
        let full = u.exact(&a);
        for h in 0..sp.hmus {
            assert_eq!(hi[h] + lo[h], full[h], "hmu {h}");
        }
        assert_eq!(u.weights().len(), sp.hmus * sp.cols);
    }

    #[test]
    fn saliency_zero_for_zero_acts() {
        let (u, _) = unit(3);
        let p = u.pack_acts(&vec![0; u.spec().cols]);
        assert_eq!(u.saliency(&p), 0);
    }

    #[test]
    fn saliency_monotone_in_magnitude() {
        let (u, _) = unit(4);
        let sp = *u.spec();
        let lo = u.saliency(&u.pack_acts(&vec![3; sp.cols]));
        let hi = u.saliency(&u.pack_acts(&vec![255; sp.cols]));
        assert!(hi > lo);
    }

    #[test]
    fn counts_partition_complete() {
        let sp = MacroSpec::default();
        for b in 0..16 {
            let c = counts_for_boundary(b, false, &sp);
            assert_eq!(
                c.digital_pairs + c.analog_pairs + c.discard_pairs,
                64,
                "B={b}"
            );
        }
        // paper Fig 5a anchors
        let c8 = counts_for_boundary(8, false, &sp);
        assert_eq!((c8.digital_pairs, c8.analog_pairs, c8.discard_pairs), (28, 26, 10));
        assert_eq!(c8.adc_groups, 8);
        let c0 = counts_for_boundary(0, false, &sp);
        assert_eq!(c0.digital_pairs, 64);
        assert_eq!(c0.adc_groups, 0);
    }

    #[test]
    fn cycle_model_speeds_up_with_b() {
        let sp = MacroSpec::default();
        let mut prev = u32::MAX;
        for b in [5, 6, 7, 8, 9, 10] {
            let c = counts_for_boundary(b, true, &sp);
            assert!(
                c.total_cycles() <= prev,
                "cycles not monotone at B={b}: {} > {prev}",
                c.total_cycles()
            );
            prev = c.total_cycles();
        }
        // DCIM (no SE): 64 pairs at 2x clock
        assert_eq!(counts_for_boundary(0, false, &sp).compute_cycles, 32);
    }

    #[test]
    fn acim_runs_and_is_noisy() {
        let (u, mut g) = unit(5);
        let sp = *u.spec();
        let a = acts(&mut g, sp.cols);
        let p = u.pack_acts(&a);
        let n_slices = sp.a_bits.div_ceil(sp.analog_band as usize);
        let noise = vec![0.0f32; sp.hmus * sp.w_bits * n_slices];
        let out = u.compute_acim(&p, &noise);
        let exact = u.exact(&a);
        assert_ne!(out, exact, "3-bit ADC must lose information");
        // but should correlate strongly
        let corr: f64 = out
            .iter()
            .zip(&exact)
            .map(|(&o, &e)| (o as f64) * (e as f64))
            .sum::<f64>();
        assert!(corr > 0.0);
    }

    #[test]
    fn weight_validation() {
        let sp = MacroSpec::default();
        assert!(MacroUnit::new(&[0; 10], sp).is_err());
        let mut w = vec![0i32; sp.hmus * sp.cols];
        w[0] = 200;
        assert!(MacroUnit::new(&w, sp).is_err());
    }

    #[test]
    fn hybrid_matches_manual_order_sum_property() {
        // property: with zero noise and b <= 0 the hybrid equals exact for
        // arbitrary col counts packed into the fixed geometry via padding
        let sp = MacroSpec::default();
        check("hybrid b<=0 exact", 20, |g| {
            let mut rng = SplitMix64::new(g.u64());
            let w: Vec<i32> =
                (0..sp.hmus * sp.cols).map(|_| rng.next_range_i32(-128, 128)).collect();
            let u = MacroUnit::new(&w, sp).unwrap();
            let a: Vec<i32> = (0..sp.cols).map(|_| rng.next_range_i32(0, 256)).collect();
            let p = u.pack_acts(&a);
            let noise = vec![0.0f32; sp.hmus * sp.w_bits];
            assert_eq!(u.compute_hybrid(&p, 0, &noise), u.exact(&a));
        });
    }

    fn trivial_ctx() -> DevCtx<'static> {
        DevCtx { col_gains: None, s_ou: 0, adc_offset: 0.0, adc_gain: 1.0 }
    }

    #[test]
    fn dev_path_trivial_ctx_is_bit_equal() {
        // the device-aware path with a trivial context must reproduce
        // the legacy popcount path exactly, on the same noise buffer
        let (u, mut g) = unit(21);
        let sp = *u.spec();
        let ctx = trivial_ctx();
        let n_slices = sp.a_bits.div_ceil(sp.analog_band as usize);
        let mut ng = SplitMix64::new(77);
        for b in [0, 5, 7, 8, 10] {
            let a = acts(&mut g, sp.cols);
            let p = u.pack_acts(&a);
            let noise = ng.normals_f32(sp.hmus * sp.w_bits, sp.sigma_code);
            assert_eq!(u.compute_hybrid_dev(&p, b, &noise, &ctx), u.compute_hybrid(&p, b, &noise));
            let noise = ng.normals_f32(sp.hmus * sp.w_bits * n_slices, sp.sigma_code);
            assert_eq!(u.compute_acim_dev(&p, &noise, &ctx), u.compute_acim(&p, &noise));
        }
    }

    #[test]
    fn dev_path_unity_gain_vector_is_bit_equal() {
        // explicit all-ones gains walk the per-bit path yet must agree
        // bit-for-bit with the popcount path (sums of <= 144 ones are
        // exact in f32)
        let (u, mut g) = unit(22);
        let sp = *u.spec();
        let ones = vec![1.0f32; sp.cols];
        let ctx = DevCtx { col_gains: Some(&ones), ..trivial_ctx() };
        let mut ng = SplitMix64::new(78);
        for b in [5, 8, 10] {
            let a = acts(&mut g, sp.cols);
            let p = u.pack_acts(&a);
            let noise = ng.normals_f32(sp.hmus * sp.w_bits, sp.sigma_code);
            assert_eq!(u.compute_hybrid_dev(&p, b, &noise, &ctx), u.compute_hybrid(&p, b, &noise));
        }
    }

    #[test]
    fn dev_grouping_changes_quantization_not_digital() {
        let (u, mut g) = unit(23);
        let sp = *u.spec();
        let ctx = DevCtx { s_ou: 16, ..trivial_ctx() };
        let n_sub = ctx.n_sub(sp.cols);
        assert_eq!(n_sub, 9);
        let a = acts(&mut g, sp.cols);
        let p = u.pack_acts(&a);
        // b = 0: no analog groups, so grouping is irrelevant and exact
        let noise = vec![0.0f32; sp.hmus * sp.w_bits * n_sub];
        assert_eq!(u.compute_hybrid_dev(&p, 0, &noise, &ctx), u.exact(&a));
        // b = 8: sub-converted groups quantize differently from one
        // full-width conversion, but stay correlated with exact
        let b = 8;
        let grouped = u.compute_hybrid_dev(&p, b, &noise, &ctx);
        let full = u.compute_hybrid(&p, b, &vec![0.0f32; sp.hmus * sp.w_bits]);
        assert_ne!(grouped, full, "s_ou grouping must alter quantization");
        let exact = u.exact(&a);
        let corr: f64 =
            grouped.iter().zip(&exact).map(|(&o, &e)| o as f64 * e as f64).sum::<f64>();
        assert!(corr > 0.0);
    }

    #[test]
    fn dev_column_gains_perturb_analog_only() {
        let (u, mut g) = unit(24);
        let sp = *u.spec();
        let mut gg = SplitMix64::new(9);
        let gains: Vec<f32> = gg.normals_f32(sp.cols, 0.05).iter().map(|z| 1.0 + z).collect();
        let ctx = DevCtx { col_gains: Some(&gains), ..trivial_ctx() };
        let a = acts(&mut g, sp.cols);
        let p = u.pack_acts(&a);
        let noise = vec![0.0f32; sp.hmus * sp.w_bits];
        // b = 0 is all-digital: gains cannot touch it
        assert_eq!(u.compute_hybrid_dev(&p, 0, &noise, &ctx), u.exact(&a));
        // a large boundary routes low orders through the gained columns
        let perturbed = u.compute_hybrid_dev(&p, 10, &noise, &ctx);
        let clean = u.compute_hybrid(&p, 10, &noise);
        assert_ne!(perturbed, clean, "5% column mismatch must move codes");
    }

    #[test]
    fn gain_weighted_and_masks_column_ranges() {
        // one set bit per word boundary region to exercise the masks
        let wrow = [!0u64, !0u64, !0u64];
        let aw = [1u64 | (1 << 63), 1u64, 1u64 << 15];
        // full range counts all 4 set columns
        assert_eq!(gain_weighted_and(&wrow, &aw, None, 0, 144), 4.0);
        // [1, 64) drops column 0, keeps 63
        assert_eq!(gain_weighted_and(&wrow, &aw, None, 1, 64), 1.0);
        // [64, 128) sees only column 64
        assert_eq!(gain_weighted_and(&wrow, &aw, None, 64, 128), 1.0);
        // weighted: column 143 carries gain 2.5
        let mut gains = vec![1.0f32; 144];
        gains[143] = 2.5;
        let aw2 = [0u64, 0u64, 1u64 << 15];
        assert_eq!(gain_weighted_and(&wrow, &aw2, Some(&gains), 128, 144), 2.5);
    }

    #[test]
    fn pairs_at_order_counts() {
        let sp = MacroSpec::default();
        assert_eq!(pairs_at_order(0, &sp), 1);
        assert_eq!(pairs_at_order(7, &sp), 8);
        assert_eq!(pairs_at_order(14, &sp), 1);
        assert_eq!(pairs_at_order(15, &sp), 0);
        let total: u32 = (0..=14).map(|k| pairs_at_order(k, &sp)).sum();
        assert_eq!(total, 64);
    }
}

#[cfg(test)]
mod tests_4bit {
    //! The paper's Table I lists 4/8b input and weight precision; the
    //! datapath is fully parameterized, so exercise the 4b x 4b mode
    //! (each HCIMA then stores two 4-bit weights — same cell count).
    use super::*;
    use crate::util::prng::SplitMix64;

    fn spec4() -> MacroSpec {
        MacroSpec { w_bits: 4, a_bits: 4, ..MacroSpec::default() }
    }

    #[test]
    fn four_bit_b0_is_exact() {
        let sp = spec4();
        let mut g = SplitMix64::new(40);
        let w: Vec<i32> = (0..sp.hmus * sp.cols).map(|_| g.next_range_i32(-8, 8)).collect();
        let u = MacroUnit::new(&w, sp).unwrap();
        for _ in 0..5 {
            let a: Vec<i32> = (0..sp.cols).map(|_| g.next_range_i32(0, 16)).collect();
            let p = PackedBits::pack(&a, sp.a_bits, false);
            let noise = vec![0.0f32; sp.hmus * sp.w_bits];
            assert_eq!(u.compute_hybrid(&p, 0, &noise), u.exact(&a));
        }
    }

    #[test]
    fn four_bit_counts_partition() {
        let sp = spec4();
        // 4x4 -> 16 1-bit MACs, k_max = 6
        for b in 0..8 {
            let c = counts_for_boundary(b, false, &sp);
            assert_eq!(c.digital_pairs + c.analog_pairs + c.discard_pairs, 16, "B={b}");
        }
        // B=4: digital k>=4 (pairs (1,3),(2,2),(3,1),(2,3),(3,2),(3,3),(3,... )
        let c4 = counts_for_boundary(4, false, &sp);
        assert_eq!(c4.digital_pairs, 6); // k=4:3, k=5:2, k=6:1
        assert_eq!(c4.discard_pairs, 0); // band covers k in [0,4)
    }

    #[test]
    fn four_bit_se_orders() {
        let sp = spec4();
        assert_eq!(sp.k_max(), 6);
        assert_eq!(sp.se_k_min(), 5);
        let mut g = SplitMix64::new(41);
        let w: Vec<i32> = (0..sp.hmus * sp.cols).map(|_| g.next_range_i32(-8, 8)).collect();
        let u = MacroUnit::new(&w, sp).unwrap();
        let hi = u.saliency(&PackedBits::pack(&vec![15; sp.cols], sp.a_bits, false));
        let lo = u.saliency(&PackedBits::pack(&vec![1; sp.cols], sp.a_bits, false));
        assert!(hi > lo);
        assert_eq!(lo, 0, "activation bit 0 has no order >= 5 with 4b weights");
    }

    #[test]
    fn four_bit_error_monotone_in_boundary() {
        let sp = spec4();
        let mut g = SplitMix64::new(42);
        let w: Vec<i32> = (0..sp.hmus * sp.cols).map(|_| g.next_range_i32(-8, 8)).collect();
        let u = MacroUnit::new(&w, sp).unwrap();
        let samples: Vec<Vec<i32>> =
            (0..32).map(|_| (0..sp.cols).map(|_| g.next_range_i32(0, 16)).collect()).collect();
        let mut prev = 0.0;
        for b in [0, 3, 5, 7] {
            let mut mse = 0.0;
            let mut ng = SplitMix64::new(43);
            for a in &samples {
                let p = PackedBits::pack(a, sp.a_bits, false);
                let noise = ng.normals_f32(sp.hmus * sp.w_bits, sp.sigma_code);
                let exact = u.exact(a);
                for (e, h) in exact.iter().zip(u.compute_hybrid(&p, b, &noise)) {
                    mse += ((e - h) as f64).powi(2);
                }
            }
            assert!(mse >= prev, "B={b}: {mse} < {prev}");
            prev = mse;
        }
    }
}
