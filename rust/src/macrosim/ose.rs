//! On-the-fly Saliency Evaluator (OSE) — near-memory unit that
//! accumulates N/Q-compressed high-order DMACs across K-tiles ("cycles")
//! and resolves the digital/analog boundary by comparing the saliency S
//! against pre-trained thresholds (paper Fig. 4a).

use crate::spec::B_CANDIDATES;
use anyhow::{ensure, Result};

/// The OSE's programmable threshold register file.
#[derive(Debug, Clone)]
pub struct Ose {
    /// Ascending thresholds T[0..b-1].
    thresholds: Vec<i32>,
    /// Boundary candidates, coarse (most analog) to fine (most digital).
    candidates: Vec<i32>,
}

impl Ose {
    pub fn new(thresholds: Vec<i32>, candidates: Vec<i32>) -> Result<Self> {
        ensure!(
            thresholds.len() + 1 == candidates.len(),
            "need {} thresholds for {} candidates, got {}",
            candidates.len() - 1,
            candidates.len(),
            thresholds.len()
        );
        ensure!(
            thresholds.windows(2).all(|w| w[0] <= w[1]),
            "thresholds must be ascending: {thresholds:?}"
        );
        Ok(Self { thresholds, candidates })
    }

    /// OSE with the paper's Fig 5b candidate set [10..5].
    pub fn with_default_candidates(thresholds: Vec<i32>) -> Result<Self> {
        Self::new(thresholds, B_CANDIDATES.to_vec())
    }

    pub fn thresholds(&self) -> &[i32] {
        &self.thresholds
    }

    pub fn candidates(&self) -> &[i32] {
        &self.candidates
    }

    /// Boundary select: B = candidates[#{T_i <= S}].
    /// Matches `kernels/ref.py::select_boundary`.
    pub fn select(&self, s: i32) -> i32 {
        let idx = self.thresholds.iter().filter(|&&t| s >= t).count();
        self.candidates[idx]
    }

    /// Batched select.
    pub fn select_batch(&self, s: &[i32]) -> Vec<i32> {
        s.iter().map(|&x| self.select(x)).collect()
    }
}

/// Streaming saliency accumulator — one per in-flight (sample, HMU-group)
/// macro operation; the hardware keeps this register in the OSE.
#[derive(Debug, Clone, Copy, Default)]
pub struct SaliencyAccumulator {
    s: i32,
    tiles: u32,
}

impl SaliencyAccumulator {
    /// Add one K-tile's SE-mode contribution.
    pub fn add(&mut self, tile_s: i32) {
        self.s = self.s.saturating_add(tile_s);
        self.tiles += 1;
    }

    pub fn value(&self) -> i32 {
        self.s
    }

    pub fn tiles(&self) -> u32 {
        self.tiles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ose() -> Ose {
        Ose::with_default_candidates(vec![10, 20, 30, 40, 50]).unwrap()
    }

    #[test]
    fn select_matches_python_semantics() {
        let o = ose();
        // python test_kernel.py::test_select_boundary_edges
        let expect = [(0, 10), (9, 10), (10, 9), (25, 8), (50, 5), (1000, 5)];
        for (s, b) in expect {
            assert_eq!(o.select(s), b, "S={s}");
        }
    }

    #[test]
    fn select_batch() {
        let o = ose();
        // S=35 passes thresholds {10,20,30} -> candidates[3] = 7
        assert_eq!(o.select_batch(&[0, 35, 100]), vec![10, 7, 5]);
    }

    #[test]
    fn monotone_more_salient_more_digital() {
        let o = ose();
        let mut prev = i32::MAX;
        for s in 0..100 {
            let b = o.select(s);
            assert!(b <= prev);
            prev = b;
        }
    }

    #[test]
    fn rejects_bad_thresholds() {
        assert!(Ose::with_default_candidates(vec![1, 2]).is_err()); // wrong count
        assert!(Ose::with_default_candidates(vec![5, 4, 3, 2, 1]).is_err()); // descending
        assert!(Ose::new(vec![], vec![8]).is_ok()); // single candidate, no thresholds
    }

    #[test]
    fn accumulator_sums_tiles() {
        let mut acc = SaliencyAccumulator::default();
        acc.add(5);
        acc.add(7);
        assert_eq!(acc.value(), 12);
        assert_eq!(acc.tiles(), 2);
    }

    #[test]
    fn accumulator_saturates() {
        let mut acc = SaliencyAccumulator::default();
        acc.add(i32::MAX);
        acc.add(100);
        assert_eq!(acc.value(), i32::MAX);
    }
}
