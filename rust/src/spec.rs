//! Normative numeric specification — mirror of
//! `python/compile/kernels/spec.py` (DESIGN.md §3).
//!
//! [`MacroSpec::validate_against_artifacts`] cross-checks these constants
//! against `artifacts/spec.json` at startup so the two languages can
//! never silently drift.

use crate::io::json::JsonValue;
use anyhow::{bail, Context};
use std::path::Path;

/// Columns per HMU == dot-product (K-tile) length.
pub const COLS: usize = 144;
/// HMUs per macro == output channels produced per macro op.
pub const HMUS: usize = 8;
/// SRAM rows = HMUS * W_BITS (one 8-bit weight per HCIMA).
pub const ROWS: usize = 64;
/// Weight bit-planes (int8 two's complement; plane 7 weighs -2^7).
pub const W_BITS: usize = 8;
/// Activation bit-planes (uint8, post-ReLU).
pub const A_BITS: usize = 8;
/// Highest output order k = i + j.
pub const K_MAX: usize = W_BITS + A_BITS - 2;
/// Orders B-4 <= k < B go to ACIM (the DAC supports 1..4-bit slices).
pub const ANALOG_BAND: i32 = 4;
/// Saliency is evaluated from the s=2 highest orders.
pub const SE_ORDERS: usize = 2;
/// k threshold for saliency-evaluation mode (k in {13, 14} for 8b x 8b).
pub const SE_K_MIN: i32 = (K_MAX - SE_ORDERS + 1) as i32;
/// N/Q unit: NQ(d) = min(NQ_MAX, d >> NQ_SHIFT).
pub const NQ_SHIFT: i32 = 1;
/// 3-bit N/Q ceiling.
pub const NQ_MAX: i32 = 7;
/// Fig 5b operating points, coarse -> fine.
pub const B_CANDIDATES: [i32; 6] = [10, 9, 8, 7, 6, 5];
/// Boundary value that makes every order digital (the DCIM baseline).
pub const B_DCIM: i32 = 0;
/// SAR ADC resolution.
pub const ADC_BITS: u32 = 3;
/// 2^ADC_BITS quantization levels.
pub const ADC_LEVELS: i32 = 1 << ADC_BITS;
/// Charge-share rail sized for typical 25% bit density (DESIGN.md §3).
pub const ADC_FS_FRAC: f32 = 0.25;
/// Default input-referred ADC noise, in code units.
pub const SIGMA_CODE: f64 = 0.3;
/// Samples per AOT hybrid/se tile artifact.
pub const TILE_M: usize = 256;
/// Spec version — bump together with spec.py.
pub const SPEC_VERSION: i64 = 5;

/// Runtime-carried spec so tests can override knobs (e.g. sigma = 0).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MacroSpec {
    pub cols: usize,
    pub hmus: usize,
    pub w_bits: usize,
    pub a_bits: usize,
    pub analog_band: i32,
    pub se_orders: usize,
    pub nq_shift: i32,
    pub nq_max: i32,
    pub adc_bits: u32,
    pub adc_fs_frac: f32,
    pub sigma_code: f64,
}

impl Default for MacroSpec {
    fn default() -> Self {
        Self {
            cols: COLS,
            hmus: HMUS,
            w_bits: W_BITS,
            a_bits: A_BITS,
            analog_band: ANALOG_BAND,
            se_orders: SE_ORDERS,
            nq_shift: NQ_SHIFT,
            nq_max: NQ_MAX,
            adc_bits: ADC_BITS,
            adc_fs_frac: ADC_FS_FRAC,
            sigma_code: SIGMA_CODE,
        }
    }
}

impl MacroSpec {
    /// Highest output order k = i + j.
    pub fn k_max(&self) -> i32 {
        (self.w_bits + self.a_bits - 2) as i32
    }

    /// Lowest order included in saliency evaluation.
    pub fn se_k_min(&self) -> i32 {
        self.k_max() - self.se_orders as i32 + 1
    }

    /// ADC quantization level count.
    pub fn adc_levels(&self) -> i32 {
        1 << self.adc_bits
    }

    /// A spec with noise disabled — the deterministic cross-language mode.
    pub fn noiseless(mut self) -> Self {
        self.sigma_code = 0.0;
        self
    }

    /// Validate these constants against `artifacts/spec.json`.
    pub fn validate_against_artifacts(&self, artifacts_dir: &Path) -> anyhow::Result<()> {
        let path = artifacts_dir.join("spec.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = crate::io::json::parse(&text)?;
        let geti = |k: &str| -> anyhow::Result<i64> {
            doc.get(k)
                .and_then(JsonValue::as_i64)
                .with_context(|| format!("spec.json missing int field {k}"))
        };
        let getf = |k: &str| -> anyhow::Result<f64> {
            doc.get(k)
                .and_then(JsonValue::as_f64)
                .with_context(|| format!("spec.json missing float field {k}"))
        };
        if geti("version")? != SPEC_VERSION {
            bail!("spec.json version {} != crate {}", geti("version")?, SPEC_VERSION);
        }
        let checks: [(&str, i64); 9] = [
            ("cols", self.cols as i64),
            ("hmus", self.hmus as i64),
            ("w_bits", self.w_bits as i64),
            ("a_bits", self.a_bits as i64),
            ("analog_band", self.analog_band as i64),
            ("se_orders", self.se_orders as i64),
            ("nq_shift", self.nq_shift as i64),
            ("nq_max", self.nq_max as i64),
            ("adc_bits", self.adc_bits as i64),
        ];
        for (k, v) in checks {
            let got = geti(k)?;
            if got != v {
                bail!("spec mismatch for {k}: artifacts={got} crate={v}");
            }
        }
        if (getf("adc_fs_frac")? - self.adc_fs_frac as f64).abs() > 1e-9 {
            bail!("spec mismatch for adc_fs_frac");
        }
        let cands = doc
            .get("b_candidates")
            .and_then(JsonValue::as_array)
            .context("spec.json missing b_candidates")?;
        let cands: Vec<i64> = cands.iter().filter_map(JsonValue::as_i64).collect();
        if cands != B_CANDIDATES.map(|x| x as i64) {
            bail!("b_candidates mismatch: {cands:?}");
        }
        Ok(())
    }
}

/// Normalize a raw accumulated saliency to the macro's column budget so
/// OSE thresholds are comparable across layers with different K depths
/// (the "normalization" half of the N/Q unit; a per-layer constant the
/// controller programs).  `k_real` is the layer's unpadded K dimension.
/// Mirrored by `spec.py::normalize_saliency`.
pub fn normalize_saliency(s_raw: i64, k_real: usize, cols: usize) -> i32 {
    if k_real == 0 {
        return 0;
    }
    ((s_raw * cols as i64) / k_real as i64).min(i32::MAX as i64) as i32
}

/// Default artifacts directory (overridable with `--artifacts` / config).
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var("OSA_HCIM_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| std::path::PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_constants() {
        let sp = MacroSpec::default();
        assert_eq!(sp.k_max(), 14);
        assert_eq!(sp.se_k_min(), 13);
        assert_eq!(sp.adc_levels(), 8);
        assert_eq!(ROWS, HMUS * W_BITS);
    }

    #[test]
    fn noiseless_override() {
        let sp = MacroSpec::default().noiseless();
        assert_eq!(sp.sigma_code, 0.0);
        assert_eq!(sp.cols, COLS);
    }

    #[test]
    fn candidates_are_coarse_to_fine() {
        for w in B_CANDIDATES.windows(2) {
            assert!(w[0] > w[1]);
        }
    }
}
