//! Benchmark harness substrate (criterion is not in the offline mirror).
//!
//! Warms up, runs timed iterations until a target time or iteration cap,
//! reports mean / p50 / p95 / stddev, and can emit the rows in a stable
//! machine-greppable format used by `rust/benches/*` and EXPERIMENTS.md.

use crate::util::{mean, percentile, stddev};
use std::time::{Duration, Instant};

/// One benchmark's collected statistics (nanoseconds).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub stddev_ns: f64,
    /// Optional throughput denominator (items per iteration).
    pub items_per_iter: Option<f64>,
}

impl BenchStats {
    /// items/second (if a denominator was registered).
    pub fn throughput(&self) -> Option<f64> {
        self.items_per_iter.map(|n| n / (self.mean_ns * 1e-9))
    }

    pub fn report(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:>8.2} Mitems/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>8.2} Kitems/s", t / 1e3),
            Some(t) => format!("  {t:>8.2} items/s"),
            None => String::new(),
        };
        format!(
            "bench {:<40} {:>10} iters  mean {:>12}  p50 {:>12}  p95 {:>12}  sd {:>10}{}",
            self.name,
            self.iters,
            fmt_ns(self.mean_ns),
            fmt_ns(self.p50_ns),
            fmt_ns(self.p95_ns),
            fmt_ns(self.stddev_ns),
            tp
        )
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Builder-style bench runner.
pub struct Bench {
    name: String,
    warmup: Duration,
    target: Duration,
    max_iters: usize,
    items_per_iter: Option<f64>,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Self {
            name: name.to_string(),
            warmup: Duration::from_millis(200),
            target: Duration::from_secs(2),
            max_iters: 1_000_000,
            items_per_iter: None,
        }
    }

    pub fn warmup(mut self, d: Duration) -> Self {
        self.warmup = d;
        self
    }

    pub fn target(mut self, d: Duration) -> Self {
        self.target = d;
        self
    }

    pub fn max_iters(mut self, n: usize) -> Self {
        self.max_iters = n;
        self
    }

    /// Register a throughput denominator (e.g. MACs per iteration).
    pub fn items(mut self, n: f64) -> Self {
        self.items_per_iter = Some(n);
        self
    }

    /// Run the closure repeatedly and collect statistics.  The closure's
    /// return value is black-boxed to keep the optimizer honest.
    pub fn run<T, F: FnMut() -> T>(self, mut f: F) -> BenchStats {
        // warmup
        let wstart = Instant::now();
        while wstart.elapsed() < self.warmup {
            std::hint::black_box(f());
        }
        // timed
        let mut samples_ns: Vec<f64> = Vec::new();
        let start = Instant::now();
        while start.elapsed() < self.target && samples_ns.len() < self.max_iters {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
        let stats = BenchStats {
            name: self.name,
            iters: samples_ns.len(),
            mean_ns: mean(&samples_ns),
            p50_ns: percentile(&samples_ns, 50.0),
            p95_ns: percentile(&samples_ns, 95.0),
            stddev_ns: stddev(&samples_ns),
            items_per_iter: self.items_per_iter,
        };
        println!("{}", stats.report());
        stats
    }
}

/// Raise the open-file soft limit toward `want` (connection-scaling
/// benches park thousands of sockets, client and server ends in one
/// process).  Returns the effective soft limit after the attempt.
#[cfg(target_os = "linux")]
pub fn raise_nofile(want: u64) -> u64 {
    #[repr(C)]
    struct Rlimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut r = Rlimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
            return 1024;
        }
        if r.cur < want {
            let bumped = Rlimit { cur: want.min(r.max), max: r.max };
            if setrlimit(RLIMIT_NOFILE, &bumped) == 0 {
                return bumped.cur;
            }
        }
        r.cur
    }
}

/// Conservative fallback where rlimits are unavailable: callers clamp
/// their fd appetite to the returned budget.
#[cfg(not(target_os = "linux"))]
pub fn raise_nofile(_want: u64) -> u64 {
    1024
}

/// Resident set size in MiB (`VmRSS` from /proc); 0.0 where /proc is
/// unavailable — scaling benches still report throughput there.
#[cfg(target_os = "linux")]
pub fn vm_rss_mb() -> f64 {
    std::fs::read_to_string("/proc/self/status")
        .ok()
        .and_then(|st| {
            st.lines()
                .find(|l| l.starts_with("VmRSS:"))
                .and_then(|l| l.split_whitespace().nth(1).and_then(|kb| kb.parse::<f64>().ok()))
        })
        .map(|kb| kb / 1024.0)
        .unwrap_or(0.0)
}

/// See the linux variant; no portable RSS source without /proc.
#[cfg(not(target_os = "linux"))]
pub fn vm_rss_mb() -> f64 {
    0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let stats = Bench::new("noop")
            .warmup(Duration::from_millis(1))
            .target(Duration::from_millis(20))
            .items(1.0)
            .run(|| 1 + 1);
        assert!(stats.iters > 10);
        assert!(stats.mean_ns >= 0.0);
        assert!(stats.throughput().unwrap() > 0.0);
    }

    #[test]
    fn format_ns_ranges() {
        assert!(fmt_ns(5.0).ends_with("ns"));
        assert!(fmt_ns(5.0e3).ends_with("us"));
        assert!(fmt_ns(5.0e6).ends_with("ms"));
        assert!(fmt_ns(5.0e9).ends_with(" s"));
    }

    #[test]
    fn respects_max_iters() {
        let stats = Bench::new("capped")
            .warmup(Duration::from_millis(1))
            .target(Duration::from_secs(10))
            .max_iters(5)
            .run(|| ());
        assert_eq!(stats.iters, 5);
    }
}
