//! Small shared utilities: PRNG, logging, timing.

pub mod logging;
pub mod prng;

/// Monotonic wall-clock helper used by benches and the coordinator.
pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }
}
