//! Small shared utilities: PRNG, logging, timing.

pub mod logging;
pub mod prng;

/// Monotonic wall-clock helper used by benches and the coordinator.
pub fn now() -> std::time::Instant {
    std::time::Instant::now()
}

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// p-th percentile (0..=100) by nearest-rank on a sorted copy.
///
/// NaN-safe by construction: `total_cmp` is a total order (NaN sorts
/// above +inf), so one non-finite sample in a metrics ring can never
/// panic the metrics path the way `partial_cmp().unwrap()` did — the
/// gateway additionally scrubs non-finite results before they reach
/// the `/metrics` payload.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let idx = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[idx.min(v.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_stddev() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
        assert!((stddev(&[2.0, 4.0]) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
    }

    #[test]
    fn percentile_survives_nan_sample() {
        // regression: a single NaN latency sample in a ring used to
        // panic `sort_by(partial_cmp().unwrap())`; with total_cmp the
        // NaN sorts above +inf and the low/mid percentiles stay sane
        let xs = [5.0, 1.0, f64::NAN, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 4.0);
        assert!(percentile(&xs, 100.0).is_nan());
        // all-NaN never panics either
        assert!(percentile(&[f64::NAN, f64::NAN], 50.0).is_nan());
    }
}
