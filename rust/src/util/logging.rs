//! Tiny `log`-facade backend (env_logger is not in the offline mirror).
//!
//! Level comes from `OSA_HCIM_LOG` (off|error|warn|info|debug|trace),
//! defaulting to `info`.  An unrecognized value still defaults to
//! `info`, but says so once on stderr instead of silently swallowing
//! the typo.
//!
//! Serve-path log lines carry structured `key=value` fields
//! (`rid=req-… tier=…`) appended by the call sites; this backend keeps
//! the line format stable (`[LEVEL] target: message`) so those fields
//! stay grep-able.

use log::{Level, LevelFilter, Metadata, Record};

struct StderrLogger;

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &Metadata) -> bool {
        metadata.level() <= log::max_level()
    }

    fn log(&self, record: &Record) {
        if self.enabled(record.metadata()) {
            let tag = match record.level() {
                Level::Error => "ERROR",
                Level::Warn => "WARN ",
                Level::Info => "INFO ",
                Level::Debug => "DEBUG",
                Level::Trace => "TRACE",
            };
            eprintln!("[{tag}] {}: {}", record.target(), record.args());
        }
    }

    fn flush(&self) {}
}

static LOGGER: StderrLogger = StderrLogger;

/// Map an `OSA_HCIM_LOG` value to a level filter.  `Err` carries the
/// fallback (`info`) for an unrecognized, non-empty value — the caller
/// warns once.
fn parse_level(text: &str) -> Result<LevelFilter, LevelFilter> {
    match text {
        "off" | "none" => Ok(LevelFilter::Off),
        "error" => Ok(LevelFilter::Error),
        "warn" => Ok(LevelFilter::Warn),
        "info" | "" => Ok(LevelFilter::Info),
        "debug" => Ok(LevelFilter::Debug),
        "trace" => Ok(LevelFilter::Trace),
        _ => Err(LevelFilter::Info),
    }
}

/// Install the logger (idempotent).
pub fn init() {
    let level = match std::env::var("OSA_HCIM_LOG") {
        Err(_) => LevelFilter::Info,
        Ok(raw) => match parse_level(raw.trim()) {
            Ok(level) => level,
            Err(fallback) => {
                // logger may not be installed yet — warn directly, and
                // only from the install that wins the race below
                if log::set_logger(&LOGGER).is_ok() {
                    log::set_max_level(fallback);
                    eprintln!(
                        "[WARN ] osa_hcim::util::logging: unrecognized OSA_HCIM_LOG={raw:?} \
                         (expected off|error|warn|info|debug|trace) — defaulting to info"
                    );
                }
                return;
            }
        },
    };
    if log::set_logger(&LOGGER).is_ok() {
        log::set_max_level(level);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn init_is_idempotent() {
        super::init();
        super::init();
        log::info!("logging smoke");
    }

    #[test]
    fn level_strings_parse() {
        assert_eq!(parse_level("off"), Ok(LevelFilter::Off));
        assert_eq!(parse_level("none"), Ok(LevelFilter::Off));
        assert_eq!(parse_level("error"), Ok(LevelFilter::Error));
        assert_eq!(parse_level("warn"), Ok(LevelFilter::Warn));
        assert_eq!(parse_level("info"), Ok(LevelFilter::Info));
        assert_eq!(parse_level(""), Ok(LevelFilter::Info));
        assert_eq!(parse_level("debug"), Ok(LevelFilter::Debug));
        assert_eq!(parse_level("trace"), Ok(LevelFilter::Trace));
        // typos fall back to info, reported (not silently swallowed)
        assert_eq!(parse_level("verbose"), Err(LevelFilter::Info));
        assert_eq!(parse_level("INFO"), Err(LevelFilter::Info));
    }
}
