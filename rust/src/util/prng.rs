//! SplitMix64 PRNG + Box-Muller normals — bit-identical with
//! `python/compile/prng.py` (see the parity test against the golden
//! vectors embedded in `artifacts/spec.json`).
//!
//! The CIM noise model never samples inside a kernel: Rust draws explicit
//! noise buffers from this generator and hands the *same* buffer to both
//! the native simulator and the PJRT artifact, making the two paths
//! comparable bit-exactly.

/// The splitmix64 increment (also used for seed derivation conventions).
pub const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// Sebastiano Vigna's splitmix64.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Current internal state (used by stream-position tests).
    pub fn state(&self) -> u64 {
        self.state
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(GOLDEN);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1) with 53 random bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * 2.0_f64.powi(-53)
    }

    /// One standard normal via Box-Muller (cosine branch only); consumes
    /// exactly two u64s, matching the Python stream position.
    pub fn next_normal(&mut self) -> f64 {
        let mut u1 = self.next_f64();
        let u2 = self.next_f64();
        if u1 <= 0.0 {
            u1 = 2.0_f64.powi(-53);
        }
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// `n` standard normals as f32 (the ADC noise dtype), scaled by sigma.
    /// Uses both Box-Muller branches (cos and sin) per pair of u64 draws —
    /// half the transcendental cost of calling [`Self::next_normal`] n
    /// times.  Bit-identical with `python prng.SplitMix64.normals`.
    pub fn normals_f32(&mut self, n: usize, sigma: f64) -> Vec<f32> {
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            let mut u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 <= 0.0 {
                u1 = 2.0_f64.powi(-53);
            }
            let r = (-2.0 * u1.ln()).sqrt();
            let t = 2.0 * std::f64::consts::PI * u2;
            out.push((r * t.cos() * sigma) as f32);
            if out.len() < n {
                out.push((r * t.sin() * sigma) as f32);
            }
        }
        out
    }

    /// Uniform usize in [0, bound) by rejection-free multiply-shift
    /// (small bias acceptable for test-data generation only).
    pub fn next_below(&mut self, bound: usize) -> usize {
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform i32 in [lo, hi).
    pub fn next_range_i32(&mut self, lo: i32, hi: i32) -> i32 {
        lo + self.next_below((hi - lo) as usize) as i32
    }
}

/// Per-layer noise stream seed — the convention shared with Python
/// (`prng.layer_noise_seed`): `base ^ ((layer+1) * GOLDEN)`.
pub fn layer_noise_seed(base_seed: u64, layer_idx: u64) -> u64 {
    base_seed ^ (layer_idx + 1).wrapping_mul(GOLDEN)
}

/// Per-work-unit noise stream seed — the parallel-engine convention
/// (DESIGN.md §6, shared with `prng.unit_noise_seed`): one independent
/// SplitMix64 stream per `(layer, row, N-tile)` work unit, advanced
/// K-tile-major inside the unit.  Because the seed depends only on the
/// unit's coordinates, the noise a unit sees is invariant under the
/// execution schedule — any thread count, any unit order — which is
/// what makes `sched::exec` bit-deterministic.
pub fn unit_noise_seed(base_seed: u64, layer_idx: u64, row: u64, tile_idx: u64) -> u64 {
    let h = layer_noise_seed(base_seed, layer_idx)
        .wrapping_add((row.wrapping_add(1)).wrapping_mul(0xBF58_476D_1CE4_E5B9))
        .wrapping_add((tile_idx.wrapping_add(1)).wrapping_mul(0x94D0_49BB_1331_11EB));
    SplitMix64::new(h).next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_vector_seed0() {
        // Canonical outputs (Vigna's C implementation / python test_prng.py).
        let mut g = SplitMix64::new(0);
        assert_eq!(g.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(g.next_u64(), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(g.next_u64(), 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn f64_unit_interval() {
        let mut g = SplitMix64::new(7);
        for _ in 0..1000 {
            let u = g.next_f64();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut g = SplitMix64::new(42);
        let xs: Vec<f64> = (0..20000).map(|_| g.next_normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var.sqrt() - 1.0).abs() < 0.03, "std {}", var.sqrt());
    }

    #[test]
    fn normal_consumes_two_u64() {
        let mut g1 = SplitMix64::new(9);
        g1.next_normal();
        let mut g2 = SplitMix64::new(9);
        g2.next_u64();
        g2.next_u64();
        assert_eq!(g1.state(), g2.state());
    }

    #[test]
    fn layer_seed_convention() {
        assert_eq!(layer_noise_seed(1, 0), 1 ^ GOLDEN);
        let seeds: std::collections::HashSet<u64> =
            (0..32).map(|i| layer_noise_seed(1, i)).collect();
        assert_eq!(seeds.len(), 32);
    }

    #[test]
    fn unit_seed_matches_python_golden() {
        // golden vectors from python `prng.unit_noise_seed` — the two
        // implementations must agree bit-exactly
        assert_eq!(unit_noise_seed(0, 0, 0, 0), 0xA95E_8782_02EA_98D0);
        assert_eq!(unit_noise_seed(0xC1A0_2024, 3, 17, 2), 0x219A_5753_9A5E_311A);
        assert_eq!(unit_noise_seed(1, 0, 1, 0), 0x852E_F111_CD10_5E34);
        assert_eq!(unit_noise_seed(1, 0, 0, 1), 0x3CB6_5FF3_6326_AD46);
    }

    #[test]
    fn unit_seed_axes_are_independent() {
        // swapping row/tile or shifting the layer must change the seed;
        // a realistic grid must be collision-free
        let mut seen = std::collections::HashSet::new();
        for layer in 0..4u64 {
            for row in 0..64u64 {
                for tile in 0..8u64 {
                    seen.insert(unit_noise_seed(0xC1A0_2024, layer, row, tile));
                }
            }
        }
        assert_eq!(seen.len(), 4 * 64 * 8);
        assert_ne!(unit_noise_seed(1, 0, 1, 0), unit_noise_seed(1, 0, 0, 1));
    }

    #[test]
    fn next_below_bounds() {
        let mut g = SplitMix64::new(3);
        for _ in 0..1000 {
            assert!(g.next_below(10) < 10);
        }
        for _ in 0..1000 {
            let v = g.next_range_i32(-5, 5);
            assert!((-5..5).contains(&v));
        }
    }
}
