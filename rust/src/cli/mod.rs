//! Argument-parsing substrate (clap is not in the offline mirror).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! subcommands, with generated `--help` text.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// Declarative description of one option.
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

impl Opt {
    pub fn flag(name: &'static str, help: &'static str) -> Self {
        Self { name, help, takes_value: false, default: None }
    }

    pub fn value(name: &'static str, help: &'static str, default: Option<&'static str>) -> Self {
        Self { name, help, takes_value: true, default }
    }
}

/// Parsed argument bag.
#[derive(Debug, Default, Clone)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_i32(&self, name: &str, default: i32) -> Result<i32> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => Ok(v.parse()?),
        }
    }
}

/// One subcommand: name, blurb, options.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
}

/// Top-level parser over a set of subcommands.
pub struct Cli {
    pub bin: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl Cli {
    /// Parse argv (without the binary name). Returns (subcommand, args)
    /// or prints help and returns None.
    pub fn parse(&self, argv: &[String]) -> Result<Option<(String, Args)>> {
        if argv.is_empty() || argv[0] == "--help" || argv[0] == "-h" || argv[0] == "help" {
            println!("{}", self.help());
            return Ok(None);
        }
        let sub = &argv[0];
        let cmd = match self.commands.iter().find(|c| c.name == sub) {
            Some(c) => c,
            None => bail!("unknown subcommand '{sub}' (try --help)"),
        };
        if argv.iter().any(|a| a == "--help" || a == "-h") {
            println!("{}", self.command_help(cmd));
            return Ok(None);
        }
        let args = parse_args(&argv[1..], &cmd.opts)?;
        Ok(Some((sub.clone(), args)))
    }

    pub fn help(&self) -> String {
        let mut out = format!("{}\n\nUSAGE: {} <COMMAND> [OPTIONS]\n\nCOMMANDS:\n", self.about, self.bin);
        for c in &self.commands {
            out.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        out.push_str("\nRun a command with --help for its options.");
        out
    }

    fn command_help(&self, cmd: &Command) -> String {
        let mut out = format!("{} {} — {}\n\nOPTIONS:\n", self.bin, cmd.name, cmd.about);
        for o in &cmd.opts {
            let head = if o.takes_value {
                format!("--{} <v>", o.name)
            } else {
                format!("--{}", o.name)
            };
            let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            out.push_str(&format!("  {:<22} {}{}\n", head, o.help, def));
        }
        out
    }
}

/// Parse a flat option list against a declaration set.
pub fn parse_args(argv: &[String], opts: &[Opt]) -> Result<Args> {
    let mut args = Args::default();
    for o in opts {
        if let Some(d) = o.default {
            args.values.insert(o.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(body) = a.strip_prefix("--") {
            let (name, inline) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (body, None),
            };
            let decl = opts
                .iter()
                .find(|o| o.name == name)
                .ok_or_else(|| anyhow::anyhow!("unknown option --{name}"))?;
            if decl.takes_value {
                let value = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        argv.get(i)
                            .ok_or_else(|| anyhow::anyhow!("--{name} requires a value"))?
                            .clone()
                    }
                };
                args.values.insert(name.to_string(), value);
            } else {
                if inline.is_some() {
                    bail!("--{name} does not take a value");
                }
                args.flags.push(name.to_string());
            }
        } else {
            args.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(args)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn decls() -> Vec<Opt> {
        vec![
            Opt::flag("verbose", "more output"),
            Opt::value("batch", "batch size", Some("64")),
            Opt::value("mode", "run mode", None),
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = parse_args(&sv(&[]), &decls()).unwrap();
        assert_eq!(a.get("batch"), Some("64"));
        let a = parse_args(&sv(&["--batch", "128"]), &decls()).unwrap();
        assert_eq!(a.get_usize("batch", 0).unwrap(), 128);
    }

    #[test]
    fn equals_syntax_and_flags() {
        let a = parse_args(&sv(&["--batch=32", "--verbose", "pos1"]), &decls()).unwrap();
        assert_eq!(a.get("batch"), Some("32"));
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(parse_args(&sv(&["--nope"]), &decls()).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(parse_args(&sv(&["--mode"]), &decls()).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(parse_args(&sv(&["--verbose=1"]), &decls()).is_err());
    }

    #[test]
    fn subcommand_dispatch() {
        let cli = Cli {
            bin: "osa-hcim",
            about: "test",
            commands: vec![Command { name: "run", about: "run it", opts: decls() }],
        };
        let parsed = cli.parse(&sv(&["run", "--batch", "16"])).unwrap().unwrap();
        assert_eq!(parsed.0, "run");
        assert_eq!(parsed.1.get("batch"), Some("16"));
        assert!(cli.parse(&sv(&["bogus"])).is_err());
        assert!(cli.parse(&sv(&["--help"])).unwrap().is_none());
    }

    #[test]
    fn numeric_parsers() {
        let a = parse_args(&sv(&["--batch", "7"]), &decls()).unwrap();
        assert_eq!(a.get_i32("batch", 0).unwrap(), 7);
        assert_eq!(a.get_f64("batch", 0.0).unwrap(), 7.0);
        assert_eq!(a.get_u64("missing-but-defaulted", 9).unwrap(), 9);
    }
}
