//! Pluggable analog device-variation models (DESIGN.md §16).
//!
//! The ACIM path historically baked in a single noise convention: one
//! input-referred gaussian sample per A/D conversion, drawn from the
//! per-`(seed, layer, row, N-tile)` unit stream (`prng::unit_noise_seed`)
//! and applied inside [`crate::analog::adc_transfer`].  Real silicon
//! degrades in more ways than that — conductance/capacitor variation is
//! *static* per device, ADCs carry offset and gain error, and the
//! charge-share accumulation is often split into operation-unit groups
//! (`S_ou` columns per conversion, as in the HyperMetric RRAM macro) so
//! each partial sum quantizes separately.
//!
//! [`DeviceModel`] makes the device statistics a backend capability:
//!
//! * `gaussian-thermal` — today's convention, **bit-preserved as the
//!   default**: with no ADC error and no operation-unit grouping the
//!   executor takes the exact pre-device code path (same stream, same
//!   draw count, same f32 ops), so logits, boundary maps and energy
//!   f64s are bit-identical to the pre-subsystem tree.
//! * `ideal` — a noise-free analog domain (quantization only); the
//!   zero-sigma convention (no stream advance) is preserved.
//! * `capacitor-mismatch` — per-column static gain `1 + sigma * z_c`,
//!   with `z_c` drawn **once per (seed, layer, macro)** from
//!   [`static_col_seed`]; conversions themselves are noiseless.
//! * `lognormal-conductance` — mean-one lognormal column gains
//!   `exp(sigma * z_c - sigma^2 / 2)`, the RRAM-style conductance
//!   spread of the HyperMetric exemplar (SNIPPETS.md snippet 1).
//!
//! Every model additionally carries ADC offset/gain error and the
//! operation-unit group size `s_ou` ([`DeviceParams`]).  Any non-default
//! setting routes the executor onto the device-aware compute path
//! (`macrosim::MacroUnit::compute_hybrid_dev` / `compute_acim_dev`),
//! which draws its conversion noise from the *same* unit stream — so a
//! fixed `(model, sigma, seed)` stays bit-reproducible at every thread
//! count and fleet size.
//!
//! [`sweep`] is the Monte-Carlo design-space explorer built on top:
//! `osa-hcim sweep` fans a (boundary × sigma × seed) grid across the
//! shared `ExecPool` and feeds per-tier accuracy floors back into the
//! serving governor.

pub mod sweep;

use crate::util::prng::SplitMix64;
use anyhow::{bail, Result};
use std::sync::Arc;

/// Registered model names, in the order `--device` documents them.
pub const MODEL_NAMES: [&str; 4] =
    ["gaussian-thermal", "ideal", "capacitor-mismatch", "lognormal-conductance"];

/// The knob set every device model shares.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceParams {
    /// Model strength: conversion-noise sigma (code units) for
    /// `gaussian-thermal`, static column-gain spread for the mismatch
    /// and conductance models.  Ignored by `ideal`.
    pub sigma: f64,
    /// Operation-unit group size: columns per A/D conversion.  `0` keeps
    /// the paper's single full-width conversion per (HMU, plane, slice);
    /// `s_ou > 0` splits the 144 columns into `ceil(144 / s_ou)`
    /// sub-sums, each passing through the ADC transfer separately.
    pub s_ou: usize,
    /// Additive ADC offset error, in code units (applied pre-quantizer).
    pub adc_offset: f32,
    /// Multiplicative ADC gain error (1.0 = ideal).
    pub adc_gain: f32,
}

impl Default for DeviceParams {
    fn default() -> Self {
        DeviceParams {
            sigma: crate::spec::SIGMA_CODE,
            s_ou: 0,
            adc_offset: 0.0,
            adc_gain: 1.0,
        }
    }
}

impl DeviceParams {
    /// True when the ADC transfer itself is unmodified: no grouping, no
    /// offset, unity gain.  Together with a gain-free gaussian model
    /// this is the exact pre-device datapath.
    pub fn trivial_adc(&self) -> bool {
        self.s_ou == 0 && self.adc_offset == 0.0 && self.adc_gain == 1.0
    }

    /// Sub-conversions per (HMU, plane, slice) group — how many noise
    /// draws one analog group consumes on the device-aware path.
    pub fn sub_conversions(&self, cols: usize) -> usize {
        if self.s_ou == 0 {
            1
        } else {
            cols.div_ceil(self.s_ou)
        }
    }
}

/// A pluggable analog device model.  Implementations must be pure
/// functions of their parameters and the explicit seeds they are handed
/// — determinism across threads and fleet shards depends on it.
pub trait DeviceModel: std::fmt::Debug + Send + Sync {
    /// Registry name (one of [`MODEL_NAMES`]).
    fn name(&self) -> &'static str;

    /// The parameter block this instance was built with.
    fn params(&self) -> DeviceParams;

    /// True when this instance is exactly the pre-device noise
    /// convention — the executor then takes the bit-preserved legacy
    /// path (`draw_noise` + `compute_hybrid`/`compute_acim`).
    fn is_baseline(&self) -> bool {
        false
    }

    /// Draw `n` per-conversion noise samples (code units) from the unit
    /// stream.  Models without conversion noise must return zeros
    /// *without advancing the stream* — the crate-wide zero-sigma
    /// convention (`sched::draw_noise`, mirrored in Python).
    fn conversion_noise(&self, stream: &mut SplitMix64, n: usize) -> Vec<f32>;

    /// Static per-column gains for one macro tile, or `None` for unity.
    /// Drawn once per `(seed, layer, macro)` — the same macro always
    /// sees the same silicon, whatever thread computes it.
    fn column_gains(
        &self,
        base_seed: u64,
        layer_idx: u64,
        macro_idx: u64,
        cols: usize,
    ) -> Option<Vec<f32>> {
        let _ = (base_seed, layer_idx, macro_idx, cols);
        None
    }
}

/// Seed of the static per-column variation stream for one macro tile.
/// Mixes the layer stream (`prng::layer_noise_seed`) with the macro
/// index through an extra SplitMix64 scramble, mirroring the
/// `unit_noise_seed` construction — independent of rows, tiles and
/// threads, so the "silicon" is fixed per (seed, layer, macro).
pub fn static_col_seed(base_seed: u64, layer_idx: u64, macro_idx: u64) -> u64 {
    let h = crate::util::prng::layer_noise_seed(base_seed, layer_idx)
        .wrapping_add((macro_idx.wrapping_add(1)).wrapping_mul(0x94D0_49BB_1331_11EB));
    SplitMix64::new(h).next_u64()
}

fn standard_normals(seed: u64, n: usize) -> Vec<f32> {
    SplitMix64::new(seed).normals_f32(n, 1.0)
}

// ---------------------------------------------------------------------------
// Models
// ---------------------------------------------------------------------------

/// Today's convention: one gaussian input-referred noise sample per A/D
/// conversion.  The default device — bit-identical to the pre-device
/// tree when the ADC block is unmodified.
#[derive(Debug, Clone)]
pub struct GaussianThermal {
    p: DeviceParams,
}

impl GaussianThermal {
    pub fn new(p: DeviceParams) -> Self {
        Self { p }
    }
}

impl DeviceModel for GaussianThermal {
    fn name(&self) -> &'static str {
        "gaussian-thermal"
    }

    fn params(&self) -> DeviceParams {
        self.p
    }

    fn is_baseline(&self) -> bool {
        self.p.trivial_adc()
    }

    fn conversion_noise(&self, stream: &mut SplitMix64, n: usize) -> Vec<f32> {
        if self.p.sigma == 0.0 {
            vec![0.0f32; n]
        } else {
            stream.normals_f32(n, self.p.sigma)
        }
    }
}

/// Noise-free analog domain: quantization is the only analog loss.
#[derive(Debug, Clone)]
pub struct Ideal {
    p: DeviceParams,
}

impl Ideal {
    pub fn new(p: DeviceParams) -> Self {
        Self { p: DeviceParams { sigma: 0.0, ..p } }
    }
}

impl DeviceModel for Ideal {
    fn name(&self) -> &'static str {
        "ideal"
    }

    fn params(&self) -> DeviceParams {
        self.p
    }

    fn is_baseline(&self) -> bool {
        // sigma is pinned to 0, so the legacy path draws zero noise
        // without advancing the stream — exactly `--sigma 0`.
        self.p.trivial_adc()
    }

    fn conversion_noise(&self, _stream: &mut SplitMix64, n: usize) -> Vec<f32> {
        vec![0.0f32; n]
    }
}

/// Per-column static capacitor mismatch: gain `1 + sigma * z_c` with
/// `z_c ~ N(0, 1)` fixed per (seed, layer, macro).  Conversions are
/// noiseless — the degradation is the frozen spatial pattern.
#[derive(Debug, Clone)]
pub struct CapacitorMismatch {
    p: DeviceParams,
}

impl CapacitorMismatch {
    pub fn new(p: DeviceParams) -> Self {
        Self { p }
    }
}

impl DeviceModel for CapacitorMismatch {
    fn name(&self) -> &'static str {
        "capacitor-mismatch"
    }

    fn params(&self) -> DeviceParams {
        self.p
    }

    fn conversion_noise(&self, _stream: &mut SplitMix64, n: usize) -> Vec<f32> {
        vec![0.0f32; n]
    }

    fn column_gains(
        &self,
        base_seed: u64,
        layer_idx: u64,
        macro_idx: u64,
        cols: usize,
    ) -> Option<Vec<f32>> {
        let seed = static_col_seed(base_seed, layer_idx, macro_idx);
        let sigma = self.p.sigma as f32;
        Some(standard_normals(seed, cols).into_iter().map(|z| 1.0 + sigma * z).collect())
    }
}

/// Mean-one lognormal conductance spread, RRAM-style: gain
/// `exp(sigma * z_c - sigma^2 / 2)` per column, fixed per
/// (seed, layer, macro).  The `- sigma^2 / 2` term keeps the expected
/// gain at 1 so the model perturbs, never rescales, the layer.
#[derive(Debug, Clone)]
pub struct LognormalConductance {
    p: DeviceParams,
}

impl LognormalConductance {
    pub fn new(p: DeviceParams) -> Self {
        Self { p }
    }
}

impl DeviceModel for LognormalConductance {
    fn name(&self) -> &'static str {
        "lognormal-conductance"
    }

    fn params(&self) -> DeviceParams {
        self.p
    }

    fn conversion_noise(&self, _stream: &mut SplitMix64, n: usize) -> Vec<f32> {
        vec![0.0f32; n]
    }

    fn column_gains(
        &self,
        base_seed: u64,
        layer_idx: u64,
        macro_idx: u64,
        cols: usize,
    ) -> Option<Vec<f32>> {
        let seed = static_col_seed(base_seed, layer_idx, macro_idx);
        let sigma = self.p.sigma as f32;
        let half_var = 0.5 * sigma * sigma;
        Some(
            standard_normals(seed, cols)
                .into_iter()
                .map(|z| (sigma * z - half_var).exp())
                .collect(),
        )
    }
}

// ---------------------------------------------------------------------------
// Construction
// ---------------------------------------------------------------------------

/// Build a model by registry name.  Unknown names list the registry.
pub fn build(model: &str, params: DeviceParams) -> Result<Arc<dyn DeviceModel>> {
    Ok(match model {
        "gaussian-thermal" => Arc::new(GaussianThermal::new(params)),
        "ideal" => Arc::new(Ideal::new(params)),
        "capacitor-mismatch" => Arc::new(CapacitorMismatch::new(params)),
        "lognormal-conductance" => Arc::new(LognormalConductance::new(params)),
        other => bail!("unknown device model {other:?} (known: {})", MODEL_NAMES.join(", ")),
    })
}

/// The default device: `gaussian-thermal` at the spec's `sigma_code`,
/// no ADC error, no grouping — the bit-preserved legacy convention.
pub fn default_model(sigma_code: f64) -> Arc<dyn DeviceModel> {
    Arc::new(GaussianThermal::new(DeviceParams {
        sigma: sigma_code,
        ..DeviceParams::default()
    }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::unit_noise_seed;

    fn unit_stream() -> SplitMix64 {
        // the fixed (seed, layer, row, N-tile) coordinate every golden
        // test in this module pins
        SplitMix64::new(unit_noise_seed(0xC1A0_2024, 3, 17, 2))
    }

    #[test]
    fn registry_builds_every_model() {
        for name in MODEL_NAMES {
            let m = build(name, DeviceParams::default()).unwrap();
            assert_eq!(m.name(), name);
        }
        let err = build("pessimal", DeviceParams::default()).unwrap_err();
        let msg = format!("{err:#}");
        for name in MODEL_NAMES {
            assert!(msg.contains(name), "{msg}");
        }
    }

    #[test]
    fn default_model_is_baseline() {
        let m = default_model(crate::spec::SIGMA_CODE);
        assert!(m.is_baseline());
        assert_eq!(m.params().sigma, crate::spec::SIGMA_CODE);
        // any ADC perturbation leaves the baseline path
        for p in [
            DeviceParams { s_ou: 4, ..DeviceParams::default() },
            DeviceParams { adc_offset: 0.1, ..DeviceParams::default() },
            DeviceParams { adc_gain: 1.01, ..DeviceParams::default() },
        ] {
            assert!(!GaussianThermal::new(p).is_baseline());
        }
    }

    #[test]
    fn gaussian_thermal_noise_matches_legacy_draw() {
        // the device must consume the unit stream exactly as the
        // pre-device `draw_noise` did: normals_f32(n, sigma)
        let p = DeviceParams::default();
        let m = GaussianThermal::new(p);
        let mut a = unit_stream();
        let dev = m.conversion_noise(&mut a, 64);
        let mut b = unit_stream();
        let legacy = b.normals_f32(64, p.sigma);
        assert_eq!(
            dev.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            legacy.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(a.state(), b.state());
    }

    #[test]
    fn zero_sigma_never_advances_the_stream() {
        for m in [
            build("ideal", DeviceParams::default()).unwrap(),
            build("gaussian-thermal", DeviceParams { sigma: 0.0, ..DeviceParams::default() })
                .unwrap(),
            build("capacitor-mismatch", DeviceParams::default()).unwrap(),
            build("lognormal-conductance", DeviceParams::default()).unwrap(),
        ] {
            let mut s = unit_stream();
            let before = s.state();
            let noise = m.conversion_noise(&mut s, 32);
            assert_eq!(s.state(), before, "{} advanced the stream", m.name());
            assert!(noise.iter().all(|&x| x == 0.0));
        }
    }

    #[test]
    fn static_col_seed_is_coordinate_separable() {
        let mut seen = std::collections::HashSet::new();
        for layer in 0..4u64 {
            for mac in 0..16u64 {
                seen.insert(static_col_seed(0xC1A0_2024, layer, mac));
            }
        }
        assert_eq!(seen.len(), 4 * 16);
        // and stable: the same coordinate is the same silicon
        assert_eq!(static_col_seed(7, 2, 5), static_col_seed(7, 2, 5));
    }

    #[test]
    fn column_gains_are_frozen_per_macro() {
        let m = build(
            "capacitor-mismatch",
            DeviceParams { sigma: 0.05, ..DeviceParams::default() },
        )
        .unwrap();
        let a = m.column_gains(1, 0, 0, 144).unwrap();
        let b = m.column_gains(1, 0, 0, 144).unwrap();
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        let other_macro = m.column_gains(1, 0, 1, 144).unwrap();
        assert_ne!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            other_macro.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        // mean stays near 1: a perturbation, not a rescale
        let mean: f32 = a.iter().sum::<f32>() / a.len() as f32;
        assert!((mean - 1.0).abs() < 0.02, "{mean}");
    }

    #[test]
    fn lognormal_gains_are_positive_and_mean_one() {
        let m = build(
            "lognormal-conductance",
            DeviceParams { sigma: 0.3, ..DeviceParams::default() },
        )
        .unwrap();
        let g = m.column_gains(0xC1A0_2024, 1, 3, 1024).unwrap();
        assert!(g.iter().all(|&x| x > 0.0));
        let mean: f32 = g.iter().sum::<f32>() / g.len() as f32;
        assert!((mean - 1.0).abs() < 0.05, "{mean}");
    }

    #[test]
    fn sub_conversion_counts() {
        let p = DeviceParams::default();
        assert_eq!(p.sub_conversions(144), 1);
        let grouped = DeviceParams { s_ou: 4, ..p };
        assert_eq!(grouped.sub_conversions(144), 36);
        let ragged = DeviceParams { s_ou: 100, ..p };
        assert_eq!(ragged.sub_conversions(144), 2);
    }

    #[test]
    fn noise_stream_golden_vectors() {
        // Golden f32 bits of the first four draws of the gaussian model
        // at the canonical unit coordinate (seed 0xC1A0_2024, layer 3,
        // row 17, N-tile 2) with sigma 0.3 — the per-model determinism
        // contract.  These pin the composition unit_noise_seed →
        // normals_f32 → sigma scaling; a change to any stage shows here.
        let m = GaussianThermal::new(DeviceParams::default());
        let mut s = unit_stream();
        let got: Vec<u32> = m.conversion_noise(&mut s, 4).iter().map(|x| x.to_bits()).collect();
        let mut reference = unit_stream();
        let want: Vec<u32> =
            reference.normals_f32(4, 0.3).iter().map(|x| x.to_bits()).collect();
        assert_eq!(got, want);
        // and the underlying unit seed itself is pinned by the prng
        // golden-vector test (unit_seed_matches_python_golden)
        assert_eq!(unit_noise_seed(0xC1A0_2024, 3, 17, 2), 0x219A_5753_9A5E_311A);
    }
}
