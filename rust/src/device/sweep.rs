//! `osa-hcim sweep` — Monte-Carlo design-space explorer (DESIGN.md §16).
//!
//! Fans a (hybrid boundary × device sigma × Monte-Carlo seed) grid over
//! the engine: every cell is one independent inference run of a held-out
//! eval set, all cells sharing one [`ExecPool`] and one [`PlanCache`]
//! (weights are packed once, whatever the grid size).  On top of the
//! accuracy surface the sweep evaluates the serving governor's degrade
//! ladder — per QoS tier, per level, at a configured device *corner*
//! sigma — so the report can feed accuracy floors back into
//! [`crate::serve::governor::Governor`]: a tier refuses any degrade
//! level whose swept corner accuracy falls below the tier's SLA.
//!
//! Reports are **byte-reproducible**: no timestamps, `BTreeMap`-ordered
//! JSON objects, deterministic per-cell seeds derived with
//! [`mc_seed`] — the acceptance gate reruns a sweep and `cmp`s the
//! files.

use crate::config::{CimMode, SystemConfig};
use crate::engine::Engine;
use crate::io::json::{arr, num, obj, s, JsonValue};
use crate::nn::{accuracy, argmax, Executor, QGraph};
use crate::obs::SweepProgress;
use crate::sched::exec::ExecPool;
use crate::sched::plan::PlanCache;
use crate::serve::qos::Tier;
use crate::util::prng::SplitMix64;
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Bytes per input image (CIFAR-shaped 32×32×3, like the dataset).
pub const IMG_BYTES: usize = 32 * 32 * 3;

/// The grid a sweep explores.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepGrid {
    /// Hybrid boundaries to pin (`cim.mode = hcim` per cell).
    pub boundaries: Vec<i32>,
    /// Device variation sigmas.
    pub sigmas: Vec<f64>,
    /// Monte-Carlo seeds per (boundary, sigma) cell.
    pub mc_seeds: usize,
    /// Eval-set size (images per cell).
    pub images: usize,
    /// Device corner for the governor-ladder evaluation.
    pub corner_sigma: f64,
}

impl SweepGrid {
    pub fn validate(&self) -> Result<()> {
        if self.boundaries.is_empty() {
            bail!("sweep: --boundaries must name at least one boundary");
        }
        if self.sigmas.is_empty() {
            bail!("sweep: --sigmas must name at least one sigma");
        }
        if self.sigmas.iter().any(|s| s.is_nan() || *s < 0.0) {
            bail!("sweep: sigmas must be >= 0, got {:?}", self.sigmas);
        }
        if self.corner_sigma.is_nan() || self.corner_sigma < 0.0 {
            bail!("sweep: --corner-sigma must be >= 0, got {}", self.corner_sigma);
        }
        if self.mc_seeds == 0 {
            bail!("sweep: --mc-seeds must be >= 1");
        }
        if self.images == 0 {
            bail!("sweep: --images must be >= 1");
        }
        Ok(())
    }

    /// Surface cells (without the ladder): boundaries × sigmas × seeds.
    pub fn surface_cells(&self) -> usize {
        self.boundaries.len() * self.sigmas.len() * self.mc_seeds
    }
}

/// The held-out eval set a sweep scores against.
#[derive(Debug, Clone)]
pub struct EvalSet {
    pub images: Vec<u8>,
    pub labels: Vec<i32>,
}

impl EvalSet {
    pub fn from_parts(images: Vec<u8>, labels: Vec<i32>) -> Result<Self> {
        if images.len() != labels.len() * IMG_BYTES {
            bail!(
                "eval set: {} image bytes do not match {} labels ({} expected)",
                images.len(),
                labels.len(),
                labels.len() * IMG_BYTES
            );
        }
        Ok(Self { images, labels })
    }

    /// A deterministic synthetic eval set for artifact-free runs: random
    /// images labeled by the loss-free DCIM datapath's own argmax, so
    /// "accuracy" measures agreement with the digital reference — the
    /// same quantity the paper's loss constraint bounds.
    pub fn synthetic(cfg: &SystemConfig, graph: &Arc<QGraph>, n: usize) -> Result<Self> {
        let mut g = SplitMix64::new(0xDA7A_5E70);
        let images: Vec<u8> = (0..n * IMG_BYTES).map(|_| g.next_below(256) as u8).collect();
        let engine = Engine::builder().config(cfg.clone()).graph(graph.clone()).build()?;
        let mut exec = Executor::new(graph, engine.backend_for_mode(CimMode::Dcim)?);
        exec.preplan()?;
        let (logits, _) = exec.forward(&images, n)?;
        let classes = logits.len() / n;
        let labels = (0..n)
            .map(|i| argmax(&logits[i * classes..(i + 1) * classes]).unwrap_or(0) as i32)
            .collect();
        Self::from_parts(images, labels)
    }

    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }
}

/// Per-cell Monte-Carlo seed: one SplitMix64 scramble of the base seed
/// and the MC index, so cells are decorrelated but every rerun of the
/// same grid draws the same noise (byte-identical reports).
pub fn mc_seed(base: u64, k: usize) -> u64 {
    SplitMix64::new(base.wrapping_add((k as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)))
        .next_u64()
}

/// One (boundary, sigma) point of the accuracy surface, aggregated over
/// the grid's Monte-Carlo seeds.
#[derive(Debug, Clone, PartialEq)]
pub struct CellResult {
    pub boundary: i32,
    pub sigma: f64,
    pub acc_mean: f64,
    pub acc_min: f64,
    pub acc_max: f64,
    /// Modeled energy per image, nanojoules (mean over seeds).
    pub energy_nj: f64,
}

/// One governor-ladder point: tier × degrade level at the corner sigma.
#[derive(Debug, Clone, PartialEq)]
pub struct LadderPoint {
    pub tier: &'static str,
    pub level: u32,
    pub accuracy: f64,
}

/// The full sweep result — everything `SWEEP_device.json` holds.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepReport {
    /// Device model name the grid was swept under.
    pub model: String,
    pub s_ou: usize,
    pub grid: SweepGrid,
    pub surface: Vec<CellResult>,
    pub ladder: Vec<LadderPoint>,
}

impl SweepReport {
    /// Serialize to the canonical JSON document.  Deliberately carries
    /// no timestamps or host identifiers: the same grid on the same
    /// tree must reproduce the same bytes.
    pub fn to_json(&self) -> JsonValue {
        obj(vec![
            ("schema", num(1.0)),
            ("model", s(&self.model)),
            ("s_ou", num(self.s_ou as f64)),
            (
                "grid",
                obj(vec![
                    (
                        "boundaries",
                        arr(self.grid.boundaries.iter().map(|&b| num(b as f64))),
                    ),
                    ("sigmas", arr(self.grid.sigmas.iter().map(|&x| num(x)))),
                    ("mc_seeds", num(self.grid.mc_seeds as f64)),
                    ("images", num(self.grid.images as f64)),
                    ("corner_sigma", num(self.grid.corner_sigma)),
                ]),
            ),
            (
                "surface",
                arr(self.surface.iter().map(|c| {
                    obj(vec![
                        ("boundary", num(c.boundary as f64)),
                        ("sigma", num(c.sigma)),
                        ("acc_mean", num(c.acc_mean)),
                        ("acc_min", num(c.acc_min)),
                        ("acc_max", num(c.acc_max)),
                        ("energy_nj", num(c.energy_nj)),
                    ])
                })),
            ),
            (
                "ladder",
                arr(self.ladder.iter().map(|p| {
                    obj(vec![
                        ("tier", s(p.tier)),
                        ("level", num(p.level as f64)),
                        ("accuracy", num(p.accuracy)),
                    ])
                })),
            ),
        ])
    }

    /// Parse a document produced by [`Self::to_json`].
    pub fn from_json(doc: &JsonValue) -> Result<Self> {
        let schema = doc.get("schema").and_then(JsonValue::as_i64).unwrap_or(0);
        if schema != 1 {
            bail!("sweep report: unsupported schema {schema} (expected 1)");
        }
        let grid_doc = doc.get("grid").context("sweep report: missing grid")?;
        let nums = |key: &str| -> Result<Vec<f64>> {
            grid_doc
                .get(key)
                .and_then(JsonValue::as_array)
                .with_context(|| format!("sweep report: missing grid.{key}"))?
                .iter()
                .map(|v| v.as_f64().with_context(|| format!("grid.{key}: non-number")))
                .collect()
        };
        let grid = SweepGrid {
            boundaries: nums("boundaries")?.iter().map(|&x| x as i32).collect(),
            sigmas: nums("sigmas")?,
            mc_seeds: grid_doc
                .get("mc_seeds")
                .and_then(JsonValue::as_usize)
                .context("sweep report: missing grid.mc_seeds")?,
            images: grid_doc
                .get("images")
                .and_then(JsonValue::as_usize)
                .context("sweep report: missing grid.images")?,
            corner_sigma: grid_doc
                .get("corner_sigma")
                .and_then(|v| v.as_f64())
                .context("sweep report: missing grid.corner_sigma")?,
        };
        let field = |cell: &JsonValue, key: &str| -> Result<f64> {
            cell.get(key)
                .and_then(|v| v.as_f64())
                .with_context(|| format!("sweep report: cell missing {key}"))
        };
        let mut surface = Vec::new();
        for cell in doc.get("surface").and_then(JsonValue::as_array).unwrap_or(&[]) {
            surface.push(CellResult {
                boundary: field(cell, "boundary")? as i32,
                sigma: field(cell, "sigma")?,
                acc_mean: field(cell, "acc_mean")?,
                acc_min: field(cell, "acc_min")?,
                acc_max: field(cell, "acc_max")?,
                energy_nj: field(cell, "energy_nj")?,
            });
        }
        let mut ladder = Vec::new();
        for p in doc.get("ladder").and_then(JsonValue::as_array).unwrap_or(&[]) {
            let tier_name = p
                .get("tier")
                .and_then(JsonValue::as_str)
                .context("sweep report: ladder point missing tier")?;
            let tier = Tier::parse(tier_name)
                .with_context(|| format!("sweep report: unknown tier {tier_name:?}"))?;
            ladder.push(LadderPoint {
                tier: tier.name(),
                level: field(p, "level")? as u32,
                accuracy: field(p, "accuracy")?,
            });
        }
        Ok(Self {
            model: doc
                .get("model")
                .and_then(JsonValue::as_str)
                .context("sweep report: missing model")?
                .to_string(),
            s_ou: doc.get("s_ou").and_then(JsonValue::as_usize).unwrap_or(0),
            grid,
            surface,
            ladder,
        })
    }

    /// The accuracy surface as a comma-separated table (gnuplot: `set
    /// datafile separator ','`), one row per (boundary, sigma) cell.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("boundary,sigma,acc_mean,acc_min,acc_max,energy_nj\n");
        for c in &self.surface {
            out.push_str(&format!(
                "{},{},{},{},{},{}\n",
                c.boundary, c.sigma, c.acc_mean, c.acc_min, c.acc_max, c.energy_nj
            ));
        }
        out
    }
}

/// Per-tier governor degrade-level caps derived from a sweep report:
/// `caps[tier]` is the highest level whose swept corner accuracy still
/// clears the tier's SLA floor (`u32::MAX` = no floor configured).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceFloors {
    pub corner_sigma: f64,
    pub caps: [u32; 3],
}

impl DeviceFloors {
    /// No report / no SLAs: every level the governor config allows.
    pub fn unbounded() -> Self {
        Self { corner_sigma: 0.0, caps: [u32::MAX; 3] }
    }

    /// The `[gold, silver, batch]` SLA vector a config carries.
    pub fn slas(cfg: &SystemConfig) -> [f64; 3] {
        [cfg.device_sla_gold, cfg.device_sla_silver, cfg.device_sla_batch]
    }

    /// Walk each tier's ladder from level 0 upward and stop at the
    /// first level below the SLA — levels past a failure are refused
    /// even if a later one happens to clear the floor again.
    pub fn from_report(report: &SweepReport, slas: [f64; 3]) -> Self {
        let mut caps = [u32::MAX; 3];
        for tier in Tier::ALL {
            let sla = slas[tier.index()];
            if sla <= 0.0 {
                continue;
            }
            let mut points: Vec<(u32, f64)> = report
                .ladder
                .iter()
                .filter(|p| p.tier == tier.name())
                .map(|p| (p.level, p.accuracy))
                .collect();
            points.sort_by_key(|&(level, _)| level);
            let mut cap = 0u32;
            for (level, acc) in points {
                if acc >= sla {
                    cap = cap.max(level);
                } else {
                    break;
                }
            }
            caps[tier.index()] = cap;
        }
        Self { corner_sigma: report.grid.corner_sigma, caps }
    }

    /// Load floors from a `SWEEP_*.json` file on disk.
    pub fn load(path: &std::path::Path, slas: [f64; 3]) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading sweep report {}", path.display()))?;
        let report = SweepReport::from_json(&crate::io::json::parse(&text)?)?;
        Ok(Self::from_report(&report, slas))
    }

    /// The cap for one tier.
    pub fn cap(&self, tier: Tier) -> u32 {
        self.caps[tier.index()]
    }
}

/// Effective OSE thresholds of one tier at one governor degrade level
/// (profile-scaled, then doubled per level) — the exact contract
/// [`crate::serve::governor::Governor::thresholds_for`] serves.
pub fn degraded_thresholds(calibrated: &[i32], tier: Tier, level: u32) -> Vec<i32> {
    let base = crate::osa::profile_thresholds(calibrated, tier.profile())
        .expect("tier profile exists");
    let level = level.min(31);
    base.iter()
        .map(|&t| ((t as i64) << level).clamp(i32::MIN as i64, i32::MAX as i64) as i32)
        .collect()
}

fn with_device_sigma(cfg: &SystemConfig, sigma: f64) -> SystemConfig {
    let mut c = cfg.clone();
    c.device_sigma = Some(sigma);
    // keep the spec's sigma coherent for anything that reads it directly
    c.spec.sigma_code = sigma;
    c
}

fn eval_cell(
    cfg: &SystemConfig,
    graph: &Arc<QGraph>,
    eval: &EvalSet,
    pool: &Arc<ExecPool>,
    plans: &Arc<PlanCache>,
) -> Result<(f64, f64)> {
    let engine = Engine::builder()
        .config(cfg.clone())
        .graph(graph.clone())
        .pool(pool.clone())
        .plan_cache(plans.clone())
        .build()?;
    let mut exec = engine.executor()?;
    exec.preplan()?;
    let n = eval.len();
    let (logits, stats) = exec.forward(&eval.images, n)?;
    let classes = logits.len() / n;
    let acc = accuracy(&logits, &eval.labels, classes);
    let energy_nj = stats.account.total_energy_j() / n as f64 * 1e9;
    Ok((acc, energy_nj))
}

/// Run the full sweep: the (boundary × sigma × seed) accuracy surface,
/// then the governor ladder at the corner sigma.  Cells run
/// sequentially in the driver; each cell's GEMM tiles fan out across
/// the shared pool, so the machine stays saturated without nested
/// parallelism.
pub fn run(
    cfg: &SystemConfig,
    graph: &Arc<QGraph>,
    eval: &EvalSet,
    grid: &SweepGrid,
    progress: &SweepProgress,
) -> Result<SweepReport> {
    grid.validate()?;
    if eval.len() != grid.images {
        bail!("sweep: eval set has {} images, grid expects {}", eval.len(), grid.images);
    }
    let pool = ExecPool::new(cfg.resolved_engine_threads());
    let plans = Arc::new(PlanCache::new());
    let ladder_cells = Tier::ALL.len() * (cfg.gov_max_level as usize + 1);
    progress.begin((grid.surface_cells() + ladder_cells) as u64);

    let mut surface = Vec::new();
    for &boundary in &grid.boundaries {
        for &sigma in &grid.sigmas {
            let mut acc_sum = 0.0f64;
            let mut acc_min = f64::INFINITY;
            let mut acc_max = f64::NEG_INFINITY;
            let mut energy_sum = 0.0f64;
            for k in 0..grid.mc_seeds {
                let mut c = with_device_sigma(cfg, sigma);
                c.mode = CimMode::Hcim;
                c.fixed_b = boundary;
                c.noise_seed = mc_seed(cfg.noise_seed, k);
                let (acc, energy_nj) = eval_cell(&c, graph, eval, &pool, &plans)?;
                acc_sum += acc;
                acc_min = acc_min.min(acc);
                acc_max = acc_max.max(acc);
                energy_sum += energy_nj;
                progress.cell_done(
                    &format!("b={boundary} sigma={sigma} seed={k} acc={acc:.4}"),
                    grid.images as u64,
                );
            }
            let seeds = grid.mc_seeds as f64;
            surface.push(CellResult {
                boundary,
                sigma,
                acc_mean: acc_sum / seeds,
                acc_min,
                acc_max,
                energy_nj: energy_sum / seeds,
            });
        }
    }

    let mut ladder = Vec::new();
    for tier in Tier::ALL {
        for level in 0..=cfg.gov_max_level {
            let mut c = with_device_sigma(cfg, grid.corner_sigma);
            c.mode = CimMode::Osa;
            c.thresholds = degraded_thresholds(&cfg.thresholds, tier, level);
            c.noise_seed = mc_seed(cfg.noise_seed, 0);
            let (acc, _) = eval_cell(&c, graph, eval, &pool, &plans)?;
            ladder.push(LadderPoint { tier: tier.name(), level, accuracy: acc });
            progress.cell_done(
                &format!("ladder tier={} level={level} acc={acc:.4}", tier.name()),
                grid.images as u64,
            );
        }
    }

    Ok(SweepReport {
        model: cfg.device_model.clone(),
        s_ou: cfg.device_s_ou,
        grid: grid.clone(),
        surface,
        ladder,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_report() -> SweepReport {
        SweepReport {
            model: "gaussian-thermal".into(),
            s_ou: 0,
            grid: SweepGrid {
                boundaries: vec![10, 8],
                sigmas: vec![0.0, 0.3],
                mc_seeds: 2,
                images: 4,
                corner_sigma: 0.45,
            },
            surface: vec![CellResult {
                boundary: 10,
                sigma: 0.3,
                acc_mean: 0.875,
                acc_min: 0.75,
                acc_max: 1.0,
                energy_nj: 123.5,
            }],
            ladder: vec![
                LadderPoint { tier: "gold", level: 0, accuracy: 1.0 },
                LadderPoint { tier: "silver", level: 0, accuracy: 0.95 },
                LadderPoint { tier: "silver", level: 1, accuracy: 0.9 },
                LadderPoint { tier: "silver", level: 2, accuracy: 0.6 },
                LadderPoint { tier: "batch", level: 0, accuracy: 0.9 },
                LadderPoint { tier: "batch", level: 1, accuracy: 0.4 },
                LadderPoint { tier: "batch", level: 2, accuracy: 0.85 },
            ],
        }
    }

    #[test]
    fn grid_validation_names_the_flag() {
        let good = tiny_report().grid;
        assert!(good.validate().is_ok());
        let bad = SweepGrid { boundaries: vec![], ..good.clone() };
        assert!(bad.validate().unwrap_err().to_string().contains("--boundaries"));
        let bad = SweepGrid { sigmas: vec![-0.1], ..good.clone() };
        assert!(bad.validate().unwrap_err().to_string().contains("sigmas"));
        let bad = SweepGrid { mc_seeds: 0, ..good.clone() };
        assert!(bad.validate().unwrap_err().to_string().contains("--mc-seeds"));
        let bad = SweepGrid { images: 0, ..good };
        assert!(bad.validate().unwrap_err().to_string().contains("--images"));
    }

    #[test]
    fn mc_seeds_are_distinct_and_stable() {
        let a: Vec<u64> = (0..8).map(|k| mc_seed(0xC1A0_2024, k)).collect();
        let b: Vec<u64> = (0..8).map(|k| mc_seed(0xC1A0_2024, k)).collect();
        assert_eq!(a, b);
        let uniq: std::collections::HashSet<u64> = a.iter().copied().collect();
        assert_eq!(uniq.len(), 8);
        assert_ne!(mc_seed(1, 0), mc_seed(2, 0));
    }

    #[test]
    fn report_json_roundtrips_byte_identically() {
        let report = tiny_report();
        let text = report.to_json().to_string_compact();
        let parsed = SweepReport::from_json(&crate::io::json::parse(&text).unwrap()).unwrap();
        assert_eq!(parsed, report);
        // serialization is canonical: parse -> serialize is a fixpoint
        assert_eq!(parsed.to_json().to_string_compact(), text);
    }

    #[test]
    fn csv_has_one_row_per_cell() {
        let csv = tiny_report().to_csv();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0], "boundary,sigma,acc_mean,acc_min,acc_max,energy_nj");
        assert!(lines[1].starts_with("10,0.3,"), "{}", lines[1]);
    }

    #[test]
    fn floors_walk_the_ladder_prefix() {
        let report = tiny_report();
        // no SLAs -> unbounded
        let f = DeviceFloors::from_report(&report, [0.0; 3]);
        assert_eq!(f.caps, [u32::MAX; 3]);
        assert_eq!(f.corner_sigma, 0.45);
        // silver fails at level 2 -> cap 1; batch fails at level 1 ->
        // cap 0 even though its level 2 clears the floor again
        let f = DeviceFloors::from_report(&report, [0.99, 0.8, 0.8]);
        assert_eq!(f.cap(Tier::Gold), 0);
        assert_eq!(f.cap(Tier::Silver), 1);
        assert_eq!(f.cap(Tier::Batch), 0);
        // a tier with no ladder points keeps cap 0 when an SLA is set
        let empty = SweepReport { ladder: vec![], ..report };
        let f = DeviceFloors::from_report(&empty, [0.5, 0.5, 0.5]);
        assert_eq!(f.caps, [0, 0, 0]);
    }

    #[test]
    fn degraded_thresholds_match_governor_scaling() {
        let cal = [0, 0, 32, 94, 1024];
        let l0 = degraded_thresholds(&cal, Tier::Silver, 0);
        assert_eq!(l0, cal.to_vec(), "silver level 0 IS the calibrated point");
        let l2 = degraded_thresholds(&cal, Tier::Silver, 2);
        for (a, b) in l0.iter().zip(&l2) {
            assert_eq!(*b, a << 2);
        }
        // contracts stay ascending (Ose::new requirement)
        for tier in Tier::ALL {
            for level in 0..4 {
                let ts = degraded_thresholds(&cal, tier, level);
                assert!(ts.windows(2).all(|w| w[0] <= w[1]), "{tier:?} l{level}: {ts:?}");
            }
        }
    }

    #[test]
    fn sweep_runs_are_byte_identical_on_synthetic() {
        // the full driver on a minimal grid: repeatability is the
        // acceptance gate for SWEEP_*.json
        let mut cfg = SystemConfig::default();
        cfg.gov_max_level = 0; // 1 surface cell + 3 ladder cells
        let graph = Arc::new(QGraph::synthetic());
        let eval = EvalSet::synthetic(&cfg, &graph, 2).unwrap();
        let grid = SweepGrid {
            boundaries: vec![8],
            sigmas: vec![0.3],
            mc_seeds: 1,
            images: 2,
            corner_sigma: 0.45,
        };
        let progress = SweepProgress::new();
        let a = run(&cfg, &graph, &eval, &grid, &progress).unwrap();
        assert_eq!(progress.snapshot(), (4, 4, 8));
        let b = run(&cfg, &graph, &eval, &grid, &progress).unwrap();
        assert_eq!(
            a.to_json().to_string_compact(),
            b.to_json().to_string_compact(),
            "sweep reports must be byte-reproducible"
        );
        assert_eq!(a.surface.len(), 1);
        assert_eq!(a.ladder.len(), 3);
        // accuracy is a fraction of the eval set
        for c in &a.surface {
            assert!((0.0..=1.0).contains(&c.acc_mean), "{c:?}");
            assert!(c.acc_min <= c.acc_mean && c.acc_mean <= c.acc_max);
        }
    }
}
