//! L3 coordinator: QoS-tiered admission, dynamic batcher, worker pool
//! and metrics — the serving core behind `serve::gateway` (vLLM-router
//! shaped, built on std threads + channels; tokio is not in the offline
//! mirror).
//!
//! Flow: clients [`Server::submit_tier`] single images into bounded
//! per-tier queues ([`crate::serve::qos::TierQueues`]); admission past a
//! tier's bound fails fast with a typed [`SubmitError::Busy`] (the
//! gateway maps it to HTTP 429) instead of growing an unbounded queue.
//! The batcher thread drains tiers strictly by priority and coalesces
//! single-tier batches under a **hard deadline from first enqueue**,
//! then hands them to the worker pool over a *bounded* channel — when
//! every worker is busy the batcher blocks, the tier queues fill, and
//! pressure becomes visible to both admission (429) and the precision
//! governor ([`crate::serve::governor::Governor`]), which degrades
//! low-tier OSA thresholds under load and restores them when the queues
//! drain.
//!
//! Each worker keeps one **persistent** [`crate::nn::Executor`] per
//! backend it has served, built through the shared [`Engine`] — every
//! executor shares the engine's `sched::plan::PlanCache`, so a layer's
//! weight tiles are packed exactly once per process (the
//! weight-stationary hot path).  Per batch the worker re-programs the
//! backend's runtime knobs ([`BackendKnobs`]): the governor's current
//! per-tier OSE contract (OSA datapaths), plus any per-request
//! noise-seed / boundary overrides carried in [`InferOptions`].
//! A batch whose requests name different backends or overrides is split
//! into sub-groups, one engine forward each; the hot path (no
//! overrides) stays a single group.  A failed forward answers every
//! request in the group with an error [`Response`] instead of dropping
//! the channel.

use crate::config::SystemConfig;
use crate::energy::EnergyAccount;
use crate::engine::{Backend, BackendKnobs, Engine, InferRequest};
use crate::nn::{Executor, QGraph};
use crate::obs::{self, ServerObs, Stage};
use crate::sched::fleet;
use crate::sched::plan::{FleetDims, PlacementMode};
use crate::serve::governor::{Governor, GovernorSnapshot};
use crate::serve::qos::{Pop, QosConfig, SubmitError, Tier, TierQueues};
use crate::spec::MacroSpec;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

pub use crate::engine::{InferOptions, InferResponse as Response};

/// One inference request.
pub struct Request {
    pub id: u64,
    /// Trace/request id (`X-Request-Id`), minted by the gateway at
    /// accept or by `submit_*` for in-process callers — every span this
    /// request produces carries it.
    pub rid: u64,
    /// 32x32x3 uint8 image.
    pub image: Vec<u8>,
    /// Per-request options: QoS tier plus backend / noise-seed /
    /// boundary overrides (validated at submission).
    pub opts: InferOptions,
    pub submitted: Instant,
    respond: ResponseSink,
}

/// Where a finished [`Response`] is delivered.
///
/// `Channel` is the classic blocking shape: one private channel per
/// request, the submitter parks on `recv()` (connection-worker gateway,
/// in-process `submit*` callers).  `Routed` is the event-loop shape:
/// many in-flight requests share ONE completion channel, each tagged so
/// the receiver can route it back to its connection, and a `wake`
/// callback nudges the (never-blocking) event loop after every
/// delivery.  Workers stay oblivious: they call [`ResponseSink::send`]
/// either way.
enum ResponseSink {
    Channel(Sender<Response>),
    Routed { tag: u64, tx: Sender<(u64, Response)>, wake: Arc<dyn Fn() + Send + Sync> },
}

impl ResponseSink {
    /// Deliver the response; a vanished receiver is the submitter's
    /// problem (it hung up), never the worker's.
    fn send(&self, resp: Response) {
        match self {
            ResponseSink::Channel(tx) => {
                let _ = tx.send(resp);
            }
            ResponseSink::Routed { tag, tx, wake } => {
                if tx.send((*tag, resp)).is_ok() {
                    wake();
                }
            }
        }
    }
}

/// Per-tier serving statistics.  Latency percentiles come from the
/// shared [`ServerObs`] histograms — bounded memory, lock-free record —
/// not from per-sample vectors (which grew with traffic and needed the
/// metrics `Mutex` on every request).
#[derive(Debug, Clone)]
pub struct TierStats {
    pub requests: u64,
    pub errors: u64,
    /// Admissions refused with `Busy` (snapshot from the tier queues).
    pub rejected: u64,
    /// Boundary histogram of everything served for this tier
    /// (index = B value; higher B = more analog = cheaper).
    pub b_hist: [u64; 16],
    obs: Arc<ServerObs>,
    idx: usize,
}

impl Default for TierStats {
    fn default() -> Self {
        Self::with_obs(Arc::new(ServerObs::default()), 0)
    }
}

impl TierStats {
    fn with_obs(obs: Arc<ServerObs>, idx: usize) -> Self {
        TierStats { requests: 0, errors: 0, rejected: 0, b_hist: [0; 16], obs, idx }
    }

    pub fn p50_latency_us(&self) -> f64 {
        self.obs.tier_latency_us[self.idx].snapshot().percentile(0.50)
    }

    pub fn p99_latency_us(&self) -> f64 {
        self.obs.tier_latency_us[self.idx].snapshot().percentile(0.99)
    }

    /// Mean chosen boundary over the tier's served MAC tiles (0 when
    /// nothing ran through the OSE yet).
    pub fn mean_boundary(&self) -> f64 {
        let total: u64 = self.b_hist.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let weighted: f64 = self.b_hist.iter().enumerate().map(|(b, &c)| b as f64 * c as f64).sum();
        weighted / total as f64
    }
}

/// Aggregated serving metrics.  Counters/energy live here behind the
/// metrics `Mutex` (updated once per *batch*); per-request latency goes
/// straight into the [`ServerObs`] histograms, wait-free.
#[derive(Debug, Clone)]
pub struct Metrics {
    pub requests: u64,
    pub batches: u64,
    /// Requests answered with an error `Response` (forward failures).
    pub errors: u64,
    /// Admissions refused with `Busy` across all tiers.
    pub rejected: u64,
    /// Sum of dispatched batch sizes (mean = sum / batches).
    pub batch_size_sum: f64,
    pub account: EnergyAccount,
    pub b_hist: [u64; 16],
    /// Indexed by [`Tier::index`] (gold, silver, batch).
    pub per_tier: [TierStats; 3],
    pub started: Option<Instant>,
    pub finished: Option<Instant>,
    /// The observability registry the latency getters read from (shared
    /// with the gateway and every worker).
    pub obs: Arc<ServerObs>,
}

impl Default for Metrics {
    fn default() -> Self {
        Self::with_obs(Arc::new(ServerObs::default()))
    }
}

impl Metrics {
    /// Build over a shared registry (the server's own construction
    /// path; `Default` makes a private registry for tests).
    pub fn with_obs(obs: Arc<ServerObs>) -> Self {
        Metrics {
            requests: 0,
            batches: 0,
            errors: 0,
            rejected: 0,
            batch_size_sum: 0.0,
            account: EnergyAccount::default(),
            b_hist: [0; 16],
            per_tier: std::array::from_fn(|i| TierStats::with_obs(obs.clone(), i)),
            started: None,
            finished: None,
            obs,
        }
    }

    pub fn p50_latency_us(&self) -> f64 {
        self.obs.latency_us.snapshot().percentile(0.50)
    }

    pub fn p95_latency_us(&self) -> f64 {
        self.obs.latency_us.snapshot().percentile(0.95)
    }

    pub fn p99_latency_us(&self) -> f64 {
        self.obs.latency_us.snapshot().percentile(0.99)
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_size_sum / self.batches as f64
        }
    }

    pub fn tier(&self, tier: Tier) -> &TierStats {
        &self.per_tier[tier.index()]
    }

    /// Requests per second of wall-clock serving time.  The
    /// zero-served contract is explicit: a server shut down before
    /// serving anything (zero requests, or a start/finish window too
    /// short to measure) reports `0.0`.  Non-finite values can't arise
    /// here (the `f > s` guard keeps the denominator positive); the
    /// gateway additionally scrubs every derived stat via `fnum` before
    /// it reaches the `/metrics` payload.
    pub fn throughput_rps(&self) -> f64 {
        let secs = match (self.started, self.finished) {
            (Some(s), Some(f)) if f > s => (f - s).as_secs_f64(),
            _ => return 0.0,
        };
        if self.requests == 0 || secs <= 0.0 {
            return 0.0;
        }
        self.requests as f64 / secs
    }

    /// Modeled macro TOPS/W over everything served so far.
    pub fn tops_per_watt(&self, sp: &MacroSpec) -> f64 {
        self.account.tops_per_watt(sp)
    }

    pub fn report(&self, sp: &MacroSpec) -> String {
        format!(
            "requests={} batches={} errors={} rejected={} mean_batch={:.1} p50={:.1}ms \
             p95={:.1}ms throughput={:.1} req/s macro_tops_per_watt={:.2}",
            self.requests,
            self.batches,
            self.errors,
            self.rejected,
            self.mean_batch(),
            self.p50_latency_us() / 1e3,
            self.p95_latency_us() / 1e3,
            self.throughput_rps(),
            self.tops_per_watt(sp),
        )
    }
}

/// The serving coordinator.
pub struct Server {
    /// The unified engine every worker draws its backends from.
    engine: Arc<Engine>,
    queues: Arc<TierQueues<Request>>,
    governor: Arc<Governor>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<Mutex<Metrics>>,
    obs: Arc<ServerObs>,
    next_id: std::sync::atomic::AtomicU64,
}

/// Floor of the idle batcher's wake interval (the actual tick is
/// derived from `gov_hold_ms` — ticking much faster than the governor
/// can act would just burn idle wakeups).
const MIN_IDLE_TICK: Duration = Duration::from_millis(2);

/// Power observations are averaged over at least this window: energy is
/// deposited in lumps at batch completion, so shorter windows would
/// spike far above the true draw and flap the energy-budget term.
const WATTS_WINDOW: Duration = Duration::from_millis(100);

impl Server {
    /// Convenience: build a default [`Engine`] for the config and start
    /// on it.  Callers with their own builder wiring (shared pools,
    /// custom registries) use [`Server::with_engine`] directly.
    pub fn start(cfg: &SystemConfig, graph: Arc<QGraph>) -> Result<Self> {
        let engine = Engine::builder().config(cfg.clone()).graph(graph).build()?;
        Self::with_engine(Arc::new(engine))
    }

    /// Spin up the batcher + worker pool over an assembled engine.
    /// Every worker draws its backend instances from this one engine:
    /// one shared plan cache (a layer is packed once per process) and
    /// one shared tile pool (a lone gold-tier request can use every
    /// pool thread while concurrent batches interleave at work-unit
    /// granularity; the builder sizes auto pools to the machine's
    /// cores, so `workers x threads` oversubscription cannot happen —
    /// DESIGN.md §11/§12).
    pub fn with_engine(engine: Arc<Engine>) -> Result<Self> {
        let cfg = engine.config();
        let obs =
            Arc::new(ServerObs::new(cfg.obs_trace_capacity, cfg.obs_slow_ms, cfg.obs_trace));
        let mut seed_metrics = Metrics::with_obs(obs.clone());
        seed_metrics.started = Some(Instant::now());
        let metrics = Arc::new(Mutex::new(seed_metrics));
        let governor = Arc::new(Governor::from_system(cfg));
        let queues = Arc::new(TierQueues::new(QosConfig {
            queue_cap: cfg.queue_cap.max(1),
            max_batch: cfg.max_batch.max(1),
            base_window: Duration::from_micros(cfg.batch_timeout_us),
        }));
        let workers_n = cfg.workers.max(1);
        let idle_tick = Duration::from_millis(cfg.gov_hold_ms / 4).max(MIN_IDLE_TICK);

        // Bounded dispatch: when every worker is busy the batcher blocks
        // here, the tier queues fill, and overload surfaces as `Busy`.
        let (wtx, wrx) = sync_channel::<(Tier, Vec<Request>)>(workers_n);
        let shared_rx = Arc::new(Mutex::new(wrx));
        let mut workers = Vec::new();
        for wid in 0..workers_n {
            let engine = engine.clone();
            let metrics = metrics.clone();
            let governor = governor.clone();
            let shared_rx = shared_rx.clone();
            let obs = obs.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("cim-worker-{wid}"))
                    .spawn(move || worker_loop(shared_rx, engine, metrics, governor, obs))
                    .context("spawning worker")?,
            );
        }

        // The governor acts at most once per hold interval, so the idle
        // tick only needs to be a fraction of it.
        let batcher = std::thread::Builder::new()
            .name("cim-batcher".into())
            .spawn({
                let queues = queues.clone();
                let governor = governor.clone();
                let metrics = metrics.clone();
                let obs = obs.clone();
                move || batcher_loop(queues, wtx, governor, metrics, obs, idle_tick)
            })
            .context("spawning batcher")?;

        Ok(Self {
            engine,
            queues,
            governor,
            batcher: Some(batcher),
            workers,
            metrics,
            obs,
            next_id: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// The observability registry this server records into (request-id
    /// mint, latency/stage histograms, the trace-span ring).
    pub fn obs(&self) -> &Arc<ServerObs> {
        &self.obs
    }

    /// The engine this server executes on (registry, plan cache, pool).
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Plan-cache activity over the whole worker pool.  After warmup,
    /// `misses` equals the layer count — each layer was packed exactly
    /// once per process — and every further forward is a hit.
    pub fn plan_stats(&self) -> crate::sched::plan::PlanCacheStats {
        self.engine.plan_stats()
    }

    /// Submit one image at the configured default tier
    /// (`[serve] default_tier`, silver unless overridden) — the
    /// in-process twin of a wire request that names no tier.
    pub fn submit(&self, image: Vec<u8>) -> Result<Receiver<Response>, SubmitError> {
        self.submit_tier(image, self.engine.config().default_tier)
    }

    /// Submit one image under a tier's SLO contract.
    pub fn submit_tier(
        &self,
        image: Vec<u8>,
        tier: Tier,
    ) -> Result<Receiver<Response>, SubmitError> {
        self.submit_request(InferRequest::new(image).with_tier(tier))
    }

    /// Submit a typed [`InferRequest`] (the same struct `POST /v2/infer`
    /// deserializes into); returns the channel the response arrives on.
    /// Typed failures: [`SubmitError::Busy`] when the tier's bounded
    /// queue is full (backpressure, not silent growth),
    /// [`SubmitError::UnknownBackend`] / [`SubmitError::BackendUnavailable`]
    /// / [`SubmitError::InvalidOption`] for bad per-request options —
    /// validated here, before anything is enqueued.
    pub fn submit_request(&self, req: InferRequest) -> Result<Receiver<Response>, SubmitError> {
        let rid = self.obs.mint_rid();
        self.submit_request_with_rid(req, rid)
    }

    /// [`Server::submit_request`] with an explicit trace id — the
    /// gateway's path, where the id was minted at accept (or adopted
    /// from an inbound `X-Request-Id`) so wire and coordinator spans
    /// correlate.
    pub fn submit_request_with_rid(
        &self,
        req: InferRequest,
        rid: u64,
    ) -> Result<Receiver<Response>, SubmitError> {
        let (rtx, rrx) = channel();
        self.submit_with_sink(req, ResponseSink::Channel(rtx), rid)?;
        Ok(rrx)
    }

    /// Submit with a **routed** completion: the response arrives on the
    /// shared `tx` as `(tag, response)` and `wake` is invoked after the
    /// send.  This is the event-loop gateway's submission path — one
    /// completion channel for every in-flight request of the loop, no
    /// thread parked per request.  Validation and admission are
    /// identical to [`Server::submit_request`].
    pub fn submit_request_routed(
        &self,
        req: InferRequest,
        tag: u64,
        tx: Sender<(u64, Response)>,
        wake: Arc<dyn Fn() + Send + Sync>,
        rid: u64,
    ) -> Result<(), SubmitError> {
        self.submit_with_sink(req, ResponseSink::Routed { tag, tx, wake }, rid)
    }

    fn submit_with_sink(
        &self,
        req: InferRequest,
        sink: ResponseSink,
        rid: u64,
    ) -> Result<(), SubmitError> {
        let admit_start = obs::now_us();
        let InferRequest { image, options } = req;
        // the wire paths already 400 on bad sizes, but the typed API is
        // public too — a short image coalesced into a batch would shear
        // the flattened input buffer and silently mis-serve everything
        // behind it
        if image.len() != crate::serve::gateway::IMAGE_BYTES {
            return Err(SubmitError::InvalidOption {
                field: "image",
                detail: format!(
                    "must be {} bytes (32x32x3 uint8), got {}",
                    crate::serve::gateway::IMAGE_BYTES,
                    image.len()
                ),
            });
        }
        if let Some(name) = &options.backend {
            let reg = self.engine.registry();
            match reg.get(name) {
                None => {
                    return Err(SubmitError::UnknownBackend {
                        requested: name.clone(),
                        registered: reg.names().iter().map(|s| s.to_string()).collect(),
                    })
                }
                Some(spec) if !spec.available => {
                    return Err(SubmitError::BackendUnavailable {
                        name: name.clone(),
                        reason: spec.description.to_string(),
                    })
                }
                Some(_) => {}
            }
        }
        if let Some(b) = options.boundary {
            if !(0..16).contains(&b) {
                return Err(SubmitError::InvalidOption {
                    field: "boundary",
                    detail: format!("must be in 0..=15, got {b}"),
                });
            }
        }
        if let Some(p) = &options.placement {
            let Some(mode) = PlacementMode::parse(p) else {
                return Err(SubmitError::InvalidPlacement { requested: p.clone() });
            };
            // resident placement is strict: reject up front when the
            // model's raw tile demand exceeds the fleet's aggregate
            // residency, instead of silently repacking mid-serve
            let cfg = self.engine.config();
            let backend = options.backend.as_deref().unwrap_or(&cfg.backend);
            if mode == PlacementMode::Resident && backend == fleet::BACKEND_NAME {
                let dims = FleetDims {
                    macros: cfg.fleet_macros.max(1),
                    residency_tiles: cfg.fleet_residency_tiles.max(1),
                };
                let pp = fleet::plan_for_dims(
                    &self.engine.graph().gemm_dims(),
                    &cfg.spec,
                    dims,
                    mode,
                );
                if pp.total_tiles > pp.capacity_tiles() {
                    return Err(SubmitError::FleetCapacityExceeded {
                        required_tiles: pp.total_tiles,
                        capacity_tiles: pp.capacity_tiles(),
                    });
                }
            }
        }
        let tier = options.tier;
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let req =
            Request { id, rid, image, opts: options, submitted: Instant::now(), respond: sink };
        self.queues.push(tier, req)?;
        self.obs.span(
            rid,
            Stage::Admit,
            tier.index() as u8,
            u8::MAX,
            admit_start,
            obs::now_us().saturating_sub(admit_start),
            "",
        );
        Ok(())
    }

    /// Current queue depth per tier (gold, silver, batch).
    pub fn queue_depths(&self) -> [usize; 3] {
        self.queues.depths()
    }

    /// The precision governor's current per-tier contracts.
    pub fn governor(&self) -> GovernorSnapshot {
        self.governor.snapshot()
    }

    fn snapshot_metrics(&self) -> Metrics {
        let mut m = self.metrics.lock().unwrap().clone();
        m.finished = Some(Instant::now());
        let rejected = self.queues.rejected();
        for (i, r) in rejected.iter().enumerate() {
            m.per_tier[i].rejected = *r;
        }
        m.rejected = rejected.iter().sum();
        m
    }

    /// Snapshot the metrics.
    pub fn metrics(&self) -> Metrics {
        self.snapshot_metrics()
    }

    /// Drain and stop all threads.
    pub fn shutdown(mut self) -> Metrics {
        self.queues.close();
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.snapshot_metrics()
    }
}

fn batcher_loop(
    queues: Arc<TierQueues<Request>>,
    wtx: SyncSender<(Tier, Vec<Request>)>,
    governor: Arc<Governor>,
    metrics: Arc<Mutex<Metrics>>,
    obs: Arc<ServerObs>,
    idle_tick: Duration,
) {
    let mut last_energy_j = 0.0f64;
    let mut last_obs = Instant::now();
    let mut watts = 0.0f64;
    loop {
        // Observe load BEFORE popping: the queues hold everything that
        // accumulated while the workers chewed the previous dispatch,
        // which is exactly the pressure signal (popping first would
        // drain the queues and systematically under-read it).
        //
        // The power term is *windowed and smoothed* — modeled joules
        // over at least WATTS_WINDOW of wall time, EWMA-blended — not
        // the run-lifetime average: once traffic stops the estimate
        // decays to zero, so an energy-budget breach degrades tiers
        // only while work is actually flowing, and recovery is never
        // pinned by old history nor flapped by per-batch energy lumps.
        let now = Instant::now();
        if now - last_obs >= WATTS_WINDOW {
            let energy_j = metrics.lock().unwrap().account.total_energy_j();
            let inst = ((energy_j - last_energy_j) / (now - last_obs).as_secs_f64()).max(0.0);
            watts = 0.7 * watts + 0.3 * inst;
            last_energy_j = energy_j;
            last_obs = now;
        }
        governor.observe(queues.pressure(), watts);
        match queues.pop_batch(idle_tick) {
            Pop::Batch(tier, batch) => {
                // Coalesce span: first member's enqueue → dispatch, the
                // window this batch actually waited to assemble.
                if let Some(oldest) = batch.iter().map(|r| r.submitted).min() {
                    let waited = oldest.elapsed().as_micros() as u64;
                    obs.span(
                        batch[0].rid,
                        Stage::Coalesce,
                        tier.index() as u8,
                        u8::MAX,
                        obs::now_us().saturating_sub(waited),
                        waited,
                        "",
                    );
                }
                if wtx.send((tier, batch)).is_err() {
                    break; // worker pool is gone
                }
            }
            Pop::Idle => {} // next iteration observes the (empty) queues
            Pop::Closed => break,
        }
    }
    // dropping wtx closes the worker channel -> workers exit after drain
}

/// Requests that can share one engine forward: same backend, same
/// noise-seed override, same boundary override, same fleet placement.
/// `None` = the engine-default value, so the hot path (no overrides) is
/// one group.
#[derive(Debug, Clone, PartialEq, Eq)]
struct GroupKey {
    backend: String,
    noise_seed: Option<u64>,
    boundary: Option<i32>,
    placement: Option<String>,
}

/// A worker's persistent executors, one per backend name it has served.
type ExecMap<'g> = BTreeMap<String, Executor<'g, Box<dyn Backend>>>;

fn worker_loop(
    shared_rx: Arc<Mutex<Receiver<(Tier, Vec<Request>)>>>,
    engine: Arc<Engine>,
    metrics: Arc<Mutex<Metrics>>,
    governor: Arc<Governor>,
    obs: Arc<ServerObs>,
) {
    let cfg = engine.config().clone();
    let graph_arc = engine.graph().clone();
    let graph = graph_arc.as_ref();
    let base_name = engine.backend_name().to_string();
    // One persistent executor per (worker, backend): plans (packed
    // weight tiles) live in the engine's shared cache, so they survive
    // across batches, workers AND backends.  The active backend is
    // built (and preplanned) up front so even the first request pays no
    // packing cost; override backends are built lazily on first use.
    let mut execs: ExecMap<'_> = BTreeMap::new();
    match engine.backend() {
        Ok(b) => {
            let mut exec = Executor::new(graph, b);
            if let Err(e) = exec.preplan() {
                log::error!("worker preplan failed (plans will build lazily): {e:#}");
            }
            execs.insert(base_name.clone(), exec);
        }
        // validated at engine build; a failure here still must not kill
        // the worker — groups will answer with error responses
        Err(e) => log::error!("worker could not build backend {base_name:?}: {e:#}"),
    }
    loop {
        // Hold the lock only for the blocking recv; batches are handed
        // to whichever worker is idle first.
        let job = { shared_rx.lock().unwrap().recv() };
        let (tier, batch) = match job {
            Ok(j) => j,
            Err(_) => break,
        };
        // Split the batch into runnable sub-groups (order-preserving).
        // Requests without overrides — the overwhelming hot path — all
        // land in one group keyed by the active backend.  A request
        // with an explicit noise seed NEVER coalesces, even with an
        // identical seed: noise streams are per `(seed, layer, row,
        // N-tile)` and the row index is the offset inside the forward's
        // batch, so riding at offset 1 would draw different noise than
        // riding alone — the seed's whole point is bit-reproducibility,
        // so each seeded request runs as its own batch of one.
        let mut groups: Vec<(GroupKey, Vec<Request>)> = Vec::new();
        for r in batch {
            let key = GroupKey {
                backend: r.opts.backend.clone().unwrap_or_else(|| base_name.clone()),
                noise_seed: r.opts.noise_seed,
                boundary: r.opts.boundary,
                placement: r.opts.placement.clone(),
            };
            let mergeable = key.noise_seed.is_none();
            match groups.iter_mut().find(|(k, _)| mergeable && *k == key) {
                Some((_, g)) => g.push(r),
                None => groups.push((key, vec![r])),
            }
        }
        for (key, group) in groups {
            run_group(
                &mut execs,
                graph,
                &engine,
                &cfg,
                &governor,
                &metrics,
                &obs,
                tier,
                key,
                group,
            );
        }
    }
}

/// Execute one sub-group of a batch on its backend: resolve the
/// executor, program the runtime knobs, forward, respond.
#[allow(clippy::too_many_arguments)]
fn run_group<'g>(
    execs: &mut ExecMap<'g>,
    graph: &'g QGraph,
    engine: &Engine,
    cfg: &SystemConfig,
    governor: &Governor,
    metrics: &Mutex<Metrics>,
    obs: &ServerObs,
    tier: Tier,
    key: GroupKey,
    group: Vec<Request>,
) {
    // Submission validated the name against the registry, but
    // construction can still fail (e.g. a runtime that won't load) —
    // answer the group, never drop it.
    if !execs.contains_key(&key.backend) {
        match engine.backend_named(&key.backend) {
            Ok(b) => {
                execs.insert(key.backend.clone(), Executor::new(graph, b));
            }
            Err(e) => {
                let msg = format!("backend {:?} failed to build: {e:#}", key.backend);
                answer_error(metrics, tier, &key.backend, group, &msg);
                return;
            }
        }
    }
    let exec = execs.get_mut(&key.backend).expect("just inserted");

    // Program the run knobs: the governor's current tier contract
    // (backends with programmable OSE registers, i.e. the OSA
    // datapath), then seed/boundary — always re-applied from the
    // resolved values so a previous group's overrides never leak into
    // the next.
    let caps = exec.engine.capabilities();
    let knobs = BackendKnobs {
        noise_seed: Some(key.noise_seed.unwrap_or(cfg.noise_seed)),
        fixed_b: Some(key.boundary.unwrap_or(cfg.fixed_b)),
        thresholds: caps
            .programmable_thresholds
            .then(|| governor.thresholds_for(tier)),
        placement: Some(
            key.placement.clone().unwrap_or_else(|| cfg.fleet_placement.clone()),
        ),
    };
    if let Err(e) = exec.engine.apply(&knobs) {
        let msg = format!("programming engine knobs: {e:#}");
        answer_error(metrics, tier, &key.backend, group, &msg);
        return;
    }
    let backend_name = exec.engine.name().to_string();

    let n = group.len();
    let img_bytes = group[0].image.len();
    let mut images = Vec::with_capacity(n * img_bytes);
    for r in &group {
        images.extend_from_slice(&r.image);
    }
    let exec_started = Instant::now();
    let exec_start_us = obs::now_us();
    // Queue spans: enqueue → dispatch, one per member request.
    for r in &group {
        let waited = (exec_started - r.submitted).as_micros() as u64;
        obs.span(
            r.rid,
            Stage::Queue,
            tier.index() as u8,
            u8::MAX,
            exec_start_us.saturating_sub(waited),
            waited,
            "",
        );
    }
    match exec.forward(&images, n) {
        Ok((logits, stats)) => {
            let classes = graph.num_classes;
            let done = Instant::now();
            let exec_us = (done - exec_started).as_micros() as u64;
            let boundary = key.boundary.unwrap_or(cfg.fixed_b).clamp(0, 15) as u8;
            // Exec span (whole-batch forward) + per-layer sub-spans,
            // anchored on the first member's id.
            obs.span(
                group[0].rid,
                Stage::Exec,
                tier.index() as u8,
                boundary,
                exec_start_us,
                exec_us,
                &backend_name,
            );
            for layer in &stats.layers {
                obs.span(
                    group[0].rid,
                    Stage::Layer,
                    tier.index() as u8,
                    boundary,
                    exec_start_us + layer.offset_us,
                    layer.dur_us,
                    &layer.name,
                );
            }
            obs.record_layers(&stats.layers);
            // NaN-safe preds up front: a NaN-poisoned row (aggressive
            // ACIM noise) is *answered* through the error path — a
            // fabricated pred would be indistinguishable from a real
            // class-0 answer — and never aborts the worker mid-batch
            // the way the old max_by(partial_cmp).unwrap() did.
            let preds: Vec<Option<usize>> = (0..n)
                .map(|i| crate::nn::argmax(&logits[i * classes..(i + 1) * classes]))
                .collect();
            let nan_rows = preds.iter().filter(|p| p.is_none()).count() as u64;
            {
                let mut m = metrics.lock().unwrap();
                // poisoned rows count as errors (answered, not
                // served), mirroring the failed-forward branch
                m.requests += n as u64 - nan_rows;
                m.errors += nan_rows;
                m.batches += 1;
                m.batch_size_sum += n as f64;
                m.account.merge(&stats.account);
                m.per_tier[tier.index()].requests += n as u64 - nan_rows;
                m.per_tier[tier.index()].errors += nan_rows;
                // one fused pass each: the aggregate and per-tier
                // views must never diverge
                for (i, v) in stats.b_hist.iter().enumerate() {
                    m.b_hist[i] += v;
                    m.per_tier[tier.index()].b_hist[i] += v;
                }
                m.finished = Some(done);
            }
            // Per-request latency/stage recording: wait-free histogram
            // adds, outside any lock (the old per-sample Vec needed the
            // metrics Mutex on every request).
            let slow_us = obs.slow_us();
            for (r, pred) in group.iter().zip(&preds) {
                if pred.is_none() {
                    continue; // error responses carry no latency sample
                }
                let total_us = (done - r.submitted).as_micros() as u64;
                let queue_us = (exec_started - r.submitted).as_micros() as u64;
                obs.latency_us.record(total_us);
                obs.tier_latency_us[tier.index()].record(total_us);
                obs.tier_queue_us[tier.index()].record(queue_us);
                obs.tier_exec_us[tier.index()].record(exec_us);
                if slow_us > 0 && total_us >= slow_us {
                    log::warn!(
                        "slow request rid={} tier={} total_us={total_us} queue_us={queue_us} \
                         exec_us={exec_us} batch={n} backend={backend_name}",
                        obs::format_rid(r.rid),
                        tier.name(),
                    );
                }
            }
            // equal share of the batch forward's modeled joules (macro
            // breakdown + movement + fleet transfer) per member request
            let energy_j = stats.account.total_energy_j() / n as f64;
            for (i, r) in group.into_iter().enumerate() {
                let row = logits[i * classes..(i + 1) * classes].to_vec();
                r.respond.send(Response {
                    id: r.id,
                    pred: preds[i].unwrap_or(0),
                    logits: row,
                    tier,
                    backend: backend_name.clone(),
                    latency: done - r.submitted,
                    batch_size: n,
                    energy_j,
                    error: preds[i].is_none().then(|| {
                        "non-finite logits (NaN) — the row cannot express a prediction"
                            .to_string()
                    }),
                });
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            answer_error(metrics, tier, &backend_name, group, &msg);
        }
    }
}

/// Answer every request of a group with an error [`Response`] so
/// submitters never hang on a silently dropped batch.
fn answer_error(
    metrics: &Mutex<Metrics>,
    tier: Tier,
    backend: &str,
    group: Vec<Request>,
    msg: &str,
) {
    log::error!("worker error on backend {backend:?}: {msg}");
    let done = Instant::now();
    let n = group.len();
    {
        let mut m = metrics.lock().unwrap();
        m.errors += n as u64;
        m.per_tier[tier.index()].errors += n as u64;
    }
    for r in group {
        r.respond.send(Response {
            id: r.id,
            pred: 0,
            logits: Vec::new(),
            tier,
            backend: backend.to_string(),
            latency: done - r.submitted,
            batch_size: n,
            energy_j: 0.0,
            error: Some(msg.to_string()),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_math() {
        let mut m = Metrics::default();
        for v in [100u64, 200, 300, 400, 1000] {
            m.obs.latency_us.record(v);
        }
        m.batches = 2;
        m.batch_size_sum = 5.0;
        m.requests = 5;
        m.started = Some(Instant::now() - Duration::from_secs(1));
        m.finished = Some(Instant::now());
        // histogram percentiles are bucket-resolution: the estimate must
        // land in the same log bucket as the exact sample percentile
        use crate::obs::bucket_index;
        assert_eq!(bucket_index(m.p50_latency_us() as u64), bucket_index(300));
        assert!(m.p95_latency_us() >= m.p50_latency_us());
        assert!(m.p99_latency_us() >= m.p50_latency_us());
        assert_eq!(bucket_index(m.p99_latency_us() as u64), bucket_index(1000));
        assert!((m.mean_batch() - 2.5).abs() < 1e-9);
        assert!(m.throughput_rps() > 4.0 && m.throughput_rps() < 6.0);
        let report = m.report(&MacroSpec::default());
        assert!(report.contains("requests=5"));
        assert!(report.contains("rejected=0"));
    }

    #[test]
    fn latency_recording_is_flat_memory_over_100k_samples() {
        // the old per-sample Vec rings grew with traffic; the histogram
        // registry must not allocate at all while recording
        let m = Metrics::default();
        let before = m.obs.heap_bytes();
        for i in 0..100_000u64 {
            m.obs.latency_us.record(1 + i % 10_000);
            m.obs.tier_latency_us[(i % 3) as usize].record(1 + i % 10_000);
            m.obs.tier_queue_us[(i % 3) as usize].record(i % 500);
        }
        assert_eq!(m.obs.latency_us.count(), 100_000);
        assert_eq!(m.obs.heap_bytes(), before, "recording must never allocate");
        assert!(m.p50_latency_us() > 0.0);
        assert!(m.tier(Tier::Gold).p99_latency_us() > 0.0);
    }

    #[test]
    fn empty_server_metrics_are_zero_not_nan() {
        // a server shut down before serving anything: started == finished
        // (or within the same tick) and zero requests must report 0.0
        // everywhere, never NaN the /metrics payload
        let t = Instant::now();
        let m = Metrics { started: Some(t), finished: Some(t), ..Default::default() };
        assert_eq!(m.throughput_rps(), 0.0);
        assert_eq!(m.tops_per_watt(&MacroSpec::default()), 0.0);
        assert_eq!(m.account.watts(), 0.0);
        assert_eq!(m.mean_batch(), 0.0);
        let report = m.report(&MacroSpec::default());
        assert!(!report.contains("NaN"), "{report}");
        assert!(report.contains("throughput=0.0"), "{report}");
        // a finished stamp with elapsed time but zero requests: still 0.0
        let m = Metrics {
            started: Some(t - Duration::from_secs(1)),
            finished: Some(t),
            ..Default::default()
        };
        assert_eq!(m.throughput_rps(), 0.0);
    }

    #[test]
    fn tier_stats_mean_boundary() {
        let mut t = TierStats::default();
        assert_eq!(t.mean_boundary(), 0.0);
        t.b_hist[8] = 2;
        t.b_hist[10] = 2;
        assert!((t.mean_boundary() - 9.0).abs() < 1e-9);
    }

    // Live server tests need artifacts (the QGraph); they live in
    // rust/tests/coordinator_serve.rs and rust/tests/serve_gateway.rs.
}
