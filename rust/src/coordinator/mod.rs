//! L3 coordinator: request queue, dynamic batcher, worker pool and
//! metrics — the serving front of the CIM accelerator (vLLM-router
//! shaped, built on std threads + channels; tokio is not in the offline
//! mirror).
//!
//! Flow: clients [`Server::submit`] single images; the batcher thread
//! coalesces them (up to `max_batch`, bounded by `batch_timeout_us`) and
//! round-robins batches across workers; each worker keeps one
//! **persistent** [`nn::Executor`] over its own engine clone — the
//! engine clones share one `sched::plan::PlanCache` via `Arc`, so every
//! layer's weight tiles are packed exactly once per process and reused
//! by all workers for all batches (the weight-stationary hot path).
//! A failed forward answers every request in the batch with an error
//! [`Response`] instead of dropping the channel.  Energy/boundary
//! metrics from every forward are folded into the shared [`Metrics`].

use crate::config::SystemConfig;
use crate::energy::EnergyAccount;
use crate::nn::{Executor, QGraph};
use crate::sched::MacroGemm;
use crate::spec::MacroSpec;
use crate::util::percentile;
use anyhow::{Context, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// One inference request.
pub struct Request {
    pub id: u64,
    /// 32x32x3 uint8 image.
    pub image: Vec<u8>,
    pub submitted: Instant,
    respond: Sender<Response>,
}

/// One inference response.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub logits: Vec<f32>,
    pub pred: usize,
    pub latency: Duration,
    /// Size of the batch this request rode in (batching observability).
    pub batch_size: usize,
    /// Set when the worker's forward failed: the request was *answered*,
    /// not served (`logits` is empty, `pred` is meaningless).
    pub error: Option<String>,
}

/// Aggregated serving metrics.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub requests: u64,
    pub batches: u64,
    /// Requests answered with an error `Response` (forward failures).
    pub errors: u64,
    pub latencies_us: Vec<f64>,
    pub batch_sizes: Vec<f64>,
    pub account: EnergyAccount,
    pub b_hist: [u64; 16],
    pub started: Option<Instant>,
    pub finished: Option<Instant>,
}

impl Metrics {
    pub fn p50_latency_us(&self) -> f64 {
        percentile(&self.latencies_us, 50.0)
    }

    pub fn p95_latency_us(&self) -> f64 {
        percentile(&self.latencies_us, 95.0)
    }

    pub fn mean_batch(&self) -> f64 {
        crate::util::mean(&self.batch_sizes)
    }

    /// Requests per second of wall-clock serving time.
    pub fn throughput_rps(&self) -> f64 {
        match (self.started, self.finished) {
            (Some(s), Some(f)) if f > s => self.requests as f64 / (f - s).as_secs_f64(),
            _ => 0.0,
        }
    }

    /// Modeled macro TOPS/W over everything served so far.
    pub fn tops_per_watt(&self, sp: &MacroSpec) -> f64 {
        self.account.tops_per_watt(sp)
    }

    pub fn report(&self, sp: &MacroSpec) -> String {
        format!(
            "requests={} batches={} errors={} mean_batch={:.1} p50={:.1}ms p95={:.1}ms \
             throughput={:.1} req/s macro_tops_per_watt={:.2}",
            self.requests,
            self.batches,
            self.errors,
            self.mean_batch(),
            self.p50_latency_us() / 1e3,
            self.p95_latency_us() / 1e3,
            self.throughput_rps(),
            self.tops_per_watt(sp),
        )
    }
}

enum Job {
    One(Request),
    Shutdown,
}

/// The serving coordinator.
pub struct Server {
    tx: Sender<Job>,
    batcher: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
    metrics: Arc<Mutex<Metrics>>,
    next_id: std::sync::atomic::AtomicU64,
    /// The worker pool's shared plan cache (observability handle).
    plans: Arc<crate::sched::plan::PlanCache>,
}

impl Server {
    /// Spin up the batcher + worker pool for the given config.
    /// Workers run the *native* engine (each owns a clone); the PJRT
    /// engine path is exercised through `examples/e2e_inference` where a
    /// single runtime drives the batch loop directly.
    pub fn start(cfg: &SystemConfig, graph: Arc<QGraph>) -> Result<Self> {
        let gemm = MacroGemm::new(
            cfg.mode,
            cfg.spec,
            cfg.fixed_b,
            cfg.thresholds.clone(),
            cfg.noise_seed,
        )?;
        // Engine clones share this cache: one weight-packing per layer
        // per process, reused by every worker on every batch.
        let plans = gemm.plan_cache().clone();
        let metrics = Arc::new(Mutex::new(Metrics { started: Some(Instant::now()), ..Default::default() }));
        let (tx, rx) = channel::<Job>();
        let workers_n = cfg.workers.max(1);

        // per-worker channels, round-robin dispatch
        let mut worker_txs = Vec::new();
        let mut workers = Vec::new();
        for wid in 0..workers_n {
            let (wtx, wrx) = channel::<Vec<Request>>();
            worker_txs.push(wtx);
            let graph = graph.clone();
            let gemm = gemm.clone();
            let metrics = metrics.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("cim-worker-{wid}"))
                    .spawn(move || worker_loop(wrx, graph, gemm, metrics))
                    .context("spawning worker")?,
            );
        }

        let max_batch = cfg.max_batch.max(1);
        let timeout = Duration::from_micros(cfg.batch_timeout_us);
        let batcher = std::thread::Builder::new()
            .name("cim-batcher".into())
            .spawn(move || batcher_loop(rx, worker_txs, max_batch, timeout))
            .context("spawning batcher")?;

        Ok(Self {
            tx,
            batcher: Some(batcher),
            workers,
            metrics,
            next_id: std::sync::atomic::AtomicU64::new(0),
            plans,
        })
    }

    /// Plan-cache activity over the whole worker pool.  After warmup,
    /// `misses` equals the layer count — each layer was packed exactly
    /// once per process — and every further forward is a hit.
    pub fn plan_stats(&self) -> crate::sched::plan::PlanCacheStats {
        self.plans.stats()
    }

    /// Submit one image; returns the channel the response arrives on.
    pub fn submit(&self, image: Vec<u8>) -> Result<Receiver<Response>> {
        let (rtx, rrx) = channel();
        let id = self.next_id.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.tx
            .send(Job::One(Request { id, image, submitted: Instant::now(), respond: rtx }))
            .map_err(|_| anyhow::anyhow!("server is shut down"))?;
        Ok(rrx)
    }

    /// Snapshot the metrics.
    pub fn metrics(&self) -> Metrics {
        let mut m = self.metrics.lock().unwrap().clone();
        m.finished = Some(Instant::now());
        m
    }

    /// Drain and stop all threads.
    pub fn shutdown(mut self) -> Metrics {
        let _ = self.tx.send(Job::Shutdown);
        if let Some(b) = self.batcher.take() {
            let _ = b.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        let mut m = self.metrics.lock().unwrap().clone();
        m.finished = Some(Instant::now());
        m
    }
}

fn batcher_loop(
    rx: Receiver<Job>,
    worker_txs: Vec<Sender<Vec<Request>>>,
    max_batch: usize,
    timeout: Duration,
) {
    let mut next_worker = 0usize;
    'outer: loop {
        // block for the first request of a batch
        let first = match rx.recv() {
            Ok(Job::One(r)) => r,
            Ok(Job::Shutdown) | Err(_) => break 'outer,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + timeout;
        while batch.len() < max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(Job::One(r)) => batch.push(r),
                Ok(Job::Shutdown) => {
                    // batch always holds at least `first` — flush it
                    let _ = worker_txs[next_worker].send(batch);
                    break 'outer;
                }
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => break,
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => break 'outer,
            }
        }
        let _ = worker_txs[next_worker].send(batch);
        next_worker = (next_worker + 1) % worker_txs.len();
    }
    drop(worker_txs); // closes worker channels -> workers exit
}

fn worker_loop(
    rx: Receiver<Vec<Request>>,
    graph: Arc<QGraph>,
    gemm: MacroGemm,
    metrics: Arc<Mutex<Metrics>>,
) {
    // One persistent executor per worker: plans (packed weight tiles)
    // live in the engine's shared cache, so they survive across batches
    // and across workers.  Preplan the whole graph up front so even the
    // first request pays no packing cost.
    let mut exec = Executor::new(&graph, gemm);
    if let Err(e) = exec.preplan() {
        log::error!("worker preplan failed (plans will build lazily): {e:#}");
    }
    while let Ok(batch) = rx.recv() {
        let n = batch.len();
        let img_bytes = batch[0].image.len();
        let mut images = Vec::with_capacity(n * img_bytes);
        for r in &batch {
            images.extend_from_slice(&r.image);
        }
        match exec.forward(&images, n) {
            Ok((logits, stats)) => {
                let classes = graph.num_classes;
                let done = Instant::now();
                {
                    let mut m = metrics.lock().unwrap();
                    m.requests += n as u64;
                    m.batches += 1;
                    m.batch_sizes.push(n as f64);
                    m.account.merge(&stats.account);
                    for (i, v) in stats.b_hist.iter().enumerate() {
                        m.b_hist[i] += v;
                    }
                    for r in &batch {
                        m.latencies_us.push((done - r.submitted).as_micros() as f64);
                    }
                    m.finished = Some(done);
                }
                for (i, r) in batch.into_iter().enumerate() {
                    let row = logits[i * classes..(i + 1) * classes].to_vec();
                    let pred = row
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .map(|(j, _)| j)
                        .unwrap_or(0);
                    let _ = r.respond.send(Response {
                        id: r.id,
                        pred,
                        logits: row,
                        latency: done - r.submitted,
                        batch_size: n,
                        error: None,
                    });
                }
            }
            Err(e) => {
                log::error!("worker forward failed: {e:#}");
                let msg = format!("{e:#}");
                let done = Instant::now();
                metrics.lock().unwrap().errors += n as u64;
                // answer every request so submitters never hang on a
                // silently dropped batch
                for r in batch {
                    let _ = r.respond.send(Response {
                        id: r.id,
                        pred: 0,
                        logits: Vec::new(),
                        latency: done - r.submitted,
                        batch_size: n,
                        error: Some(msg.clone()),
                    });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn metrics_math() {
        let mut m = Metrics::default();
        m.latencies_us = vec![100.0, 200.0, 300.0, 400.0, 1000.0];
        m.batch_sizes = vec![2.0, 3.0];
        m.requests = 5;
        m.started = Some(Instant::now() - Duration::from_secs(1));
        m.finished = Some(Instant::now());
        assert_eq!(m.p50_latency_us(), 300.0);
        assert!(m.p95_latency_us() >= 400.0);
        assert!((m.mean_batch() - 2.5).abs() < 1e-9);
        assert!(m.throughput_rps() > 4.0 && m.throughput_rps() < 6.0);
        let report = m.report(&MacroSpec::default());
        assert!(report.contains("requests=5"));
    }

    // Live server tests need artifacts (the QGraph); they live in
    // rust/tests/coordinator_serve.rs.
}
