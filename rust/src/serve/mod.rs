//! The network serving subsystem (DESIGN.md §10): HTTP gateway →
//! QoS-tiered admission → dynamic precision governor.
//!
//! * [`gateway`] — `std::net` HTTP/1.1 front-end (versioned
//!   `POST /v2/infer` with typed per-request options, the `/v1/*`
//!   adapters `POST /v1/infer` + NDJSON `POST /v1/infer_batch`,
//!   `GET /metrics`, `GET /v1/version`, `GET /healthz`) with persistent
//!   connections, `405 + Allow` on known paths hit with the wrong
//!   method, and explicit `429 Busy` backpressure at both the
//!   connection and the tier-queue level;
//! * `event_loop` (crate-private) — the default unix serving mode: one
//!   readiness-driven thread (`epoll`, fallback `poll`) multiplexing
//!   every connection as a nonblocking state machine, with `max_conns`
//!   re-semanticized as a connection cap (the threaded worker pool
//!   remains as the `--no-event-loop` escape hatch and the non-unix
//!   default);
//! * [`qos`] — per-request SLO tiers (`gold`/`silver`/`batch`), bounded
//!   per-tier queues and deadline-aware single-tier batch coalescing
//!   (hard window from first enqueue);
//! * [`governor`] — the feedback loop that maps each tier onto an OSA
//!   loss profile and degrades/restores the effective digital↔analog
//!   boundary with load — serving-time on-the-fly saliency-aware
//!   precision;
//! * [`http`] — the hand-rolled HTTP substrate (no HTTP crates in the
//!   offline mirror): the blocking request reader, the incremental
//!   [`http::RequestParser`] the event loop feeds byte-at-a-time, and
//!   the blocking client used by tests/benches.

#[cfg(unix)]
pub(crate) mod event_loop;
pub mod gateway;
pub mod governor;
pub mod http;
pub mod qos;

pub use gateway::{ConnStats, EventLoopStats, Gateway};
pub use governor::{Governor, GovernorConfig, GovernorSnapshot};
pub use qos::{Pop, QosConfig, SubmitError, Tier, TierQueues};
