//! The network serving subsystem (DESIGN.md §10): HTTP gateway →
//! QoS-tiered admission → dynamic precision governor.
//!
//! * [`gateway`] — `std::net` HTTP/1.1 front-end (versioned
//!   `POST /v2/infer` with typed per-request options, the `/v1/*`
//!   adapters `POST /v1/infer` + NDJSON `POST /v1/infer_batch`,
//!   `GET /metrics`, `GET /v1/version`, `GET /healthz`) with persistent
//!   connections (a bounded connection-worker pool runs a keep-alive
//!   loop per socket), `405 + Allow` on known paths hit with the wrong
//!   method, and explicit `429 Busy` backpressure at both the
//!   connection and the tier-queue level;
//! * [`qos`] — per-request SLO tiers (`gold`/`silver`/`batch`), bounded
//!   per-tier queues and deadline-aware single-tier batch coalescing
//!   (hard window from first enqueue);
//! * [`governor`] — the feedback loop that maps each tier onto an OSA
//!   loss profile and degrades/restores the effective digital↔analog
//!   boundary with load — serving-time on-the-fly saliency-aware
//!   precision;
//! * [`http`] — the hand-rolled HTTP substrate (no HTTP crates in the
//!   offline mirror), plus the blocking client used by tests/benches.

pub mod gateway;
pub mod governor;
pub mod http;
pub mod qos;

pub use gateway::{ConnStats, Gateway};
pub use governor::{Governor, GovernorConfig, GovernorSnapshot};
pub use qos::{Pop, QosConfig, SubmitError, Tier, TierQueues};
