//! Network gateway: a `std::net::TcpListener` HTTP/1.1 JSON front-end
//! over the tier-aware coordinator (DESIGN.md §10).
//!
//! Routes:
//! * `POST /v1/infer` — body `{"tier": "gold|silver|batch", "image":
//!   [3072 uint8]}`; answers the prediction, or `429 Busy` when the
//!   tier's bounded queue is full (explicit backpressure), `400` on
//!   malformed input, `500` when the worker's forward failed.
//! * `GET /metrics` — JSON snapshot: aggregate + per-tier latency
//!   percentiles, boundary histograms, queue depths, rejection counts
//!   and the governor's current per-tier precision contracts.
//! * `GET /healthz` — liveness probe.
//!
//! Threading: one accept thread, one short-lived thread per connection
//! (one request per connection, `Connection: close`), the coordinator's
//! batcher + worker pool underneath.  Graceful [`Gateway::shutdown`]
//! drains in-flight connections before draining the coordinator.

use super::http::{self, HttpRequest};
use super::qos::{SubmitError, Tier};
use crate::config::SystemConfig;
use crate::coordinator::{Metrics, Server};
use crate::io::json::{self, arr, num, obj, s, JsonValue};
use crate::nn::QGraph;
use crate::spec::MacroSpec;
use anyhow::{Context, Result};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Expected image payload: 32x32x3 uint8.
pub const IMAGE_BYTES: usize = 32 * 32 * 3;

/// The serving gateway (listener + coordinator).
pub struct Gateway {
    server: Arc<Server>,
    addr: SocketAddr,
    accept: Option<std::thread::JoinHandle<()>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
    stop: Arc<AtomicBool>,
}

impl Gateway {
    /// Bind `listen` (e.g. `127.0.0.1:8080`, port 0 for ephemeral) and
    /// start serving the graph under the given config.
    pub fn start(cfg: &SystemConfig, graph: Arc<QGraph>, listen: &str) -> Result<Gateway> {
        // bind first: a failed bind (port in use) must not leave a live
        // batcher + worker pool behind with nothing to shut them down
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        let addr = listener.local_addr().context("local_addr")?;
        let server = Arc::new(Server::start(cfg, graph)?);
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let spec = cfg.spec;
        let accept = std::thread::Builder::new()
            .name("gateway-accept".into())
            .spawn({
                let server = server.clone();
                let stop = stop.clone();
                let conns = conns.clone();
                move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let stream = match incoming {
                            Ok(s) => s,
                            Err(e) => {
                                log::warn!("accept failed: {e}");
                                continue;
                            }
                        };
                        let server = server.clone();
                        let spawned = std::thread::Builder::new()
                            .name("gateway-conn".into())
                            .spawn(move || handle_conn(stream, server, spec));
                        match spawned {
                            Ok(h) => {
                                let mut c = conns.lock().unwrap();
                                c.retain(|h| !h.is_finished());
                                c.push(h);
                            }
                            Err(e) => log::error!("spawning connection handler: {e}"),
                        }
                    }
                }
            })
            .context("spawning accept loop")?;
        log::info!("gateway listening on {addr}");
        Ok(Gateway { server, addr, accept: Some(accept), conns, stop })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Block until the accept loop exits (i.e. until shutdown or
    /// process death) — the `osa-hcim serve --listen` foreground mode.
    pub fn wait(mut self) {
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
    }

    /// Stop accepting, drain in-flight connections, then drain the
    /// coordinator.  Returns the final serving metrics.
    pub fn shutdown(mut self) -> Metrics {
        self.stop.store(true, Ordering::SeqCst);
        // unblock the accept loop with one last connection
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        let handles: Vec<_> = self.conns.lock().unwrap().drain(..).collect();
        for h in handles {
            let _ = h.join();
        }
        match Arc::try_unwrap(self.server) {
            Ok(server) => server.shutdown(),
            // a straggler still holds a handle; fall back to a snapshot
            Err(server) => server.metrics(),
        }
    }
}

fn err_body(msg: &str) -> String {
    obj(vec![("error", s(msg))]).to_string_compact()
}

fn respond(stream: &mut TcpStream, status: u16, reason: &str, body: &str) {
    if let Err(e) = http::write_response(stream, status, reason, "application/json", body.as_bytes())
    {
        log::debug!("writing response: {e}");
    }
}

fn handle_conn(mut stream: TcpStream, server: Arc<Server>, spec: MacroSpec) {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let req = match http::read_request(&mut stream) {
        Ok(r) => r,
        Err(e) => {
            respond(&mut stream, 400, "Bad Request", &err_body(&format!("{e:#}")));
            return;
        }
    };
    // route on the path only — a query string must not 404 an endpoint
    let path = req.path.split('?').next().unwrap_or("");
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => {
            let body = obj(vec![("status", s("ok"))]).to_string_compact();
            respond(&mut stream, 200, "OK", &body);
        }
        ("GET", "/metrics") => {
            let body = metrics_json(&server, &spec).to_string_compact();
            respond(&mut stream, 200, "OK", &body);
        }
        ("POST", "/v1/infer") => handle_infer(&mut stream, &req, &server),
        _ => respond(&mut stream, 404, "Not Found", &err_body("no such route")),
    }
}

fn handle_infer(stream: &mut TcpStream, req: &HttpRequest, server: &Server) {
    let parsed = req.body_str().and_then(json::parse);
    let doc = match parsed {
        Ok(d) => d,
        Err(e) => {
            respond(stream, 400, "Bad Request", &err_body(&format!("bad JSON body: {e:#}")));
            return;
        }
    };
    // an absent tier defaults to silver; a present-but-invalid one is a
    // client error, never a silent SLO downgrade
    let tier_name = match doc.get("tier") {
        None => "silver",
        Some(v) => match v.as_str() {
            Some(name) => name,
            None => {
                respond(stream, 400, "Bad Request", &err_body("\"tier\" must be a string"));
                return;
            }
        },
    };
    let Some(tier) = Tier::parse(tier_name) else {
        respond(
            stream,
            400,
            "Bad Request",
            &err_body(&format!("unknown tier {tier_name:?} (gold|silver|batch)")),
        );
        return;
    };
    let Some(pixels) = doc.get("image").and_then(JsonValue::as_array) else {
        respond(stream, 400, "Bad Request", &err_body("missing \"image\" array"));
        return;
    };
    if pixels.len() != IMAGE_BYTES {
        respond(
            stream,
            400,
            "Bad Request",
            &err_body(&format!("image must be {IMAGE_BYTES} bytes, got {}", pixels.len())),
        );
        return;
    }
    let mut image = Vec::with_capacity(IMAGE_BYTES);
    for p in pixels {
        // as_i64 would silently truncate 1.9 -> 1; demand true integers
        match p.as_f64() {
            Some(v) if v.fract() == 0.0 && (0.0..=255.0).contains(&v) => image.push(v as u8),
            _ => {
                respond(
                    stream,
                    400,
                    "Bad Request",
                    &err_body("image values must be integers in 0..=255"),
                );
                return;
            }
        }
    }
    let rx = match server.submit_tier(image, tier) {
        Ok(rx) => rx,
        Err(e @ SubmitError::Busy { .. }) => {
            let body = obj(vec![
                ("error", s("busy")),
                ("detail", s(&e.to_string())),
                ("tier", s(tier.name())),
            ])
            .to_string_compact();
            respond(stream, 429, "Too Many Requests", &body);
            return;
        }
        Err(SubmitError::ShutDown) => {
            respond(stream, 503, "Service Unavailable", &err_body("server is shutting down"));
            return;
        }
    };
    let resp = match rx.recv() {
        Ok(r) => r,
        Err(_) => {
            respond(stream, 500, "Internal Server Error", &err_body("response channel dropped"));
            return;
        }
    };
    if let Some(msg) = &resp.error {
        respond(stream, 500, "Internal Server Error", &err_body(msg));
        return;
    }
    let body = obj(vec![
        ("id", num(resp.id as f64)),
        ("tier", s(resp.tier.name())),
        ("pred", num(resp.pred as f64)),
        ("logits", arr(resp.logits.iter().map(|&x| num(x as f64)))),
        ("latency_us", num(resp.latency.as_micros() as f64)),
        ("batch_size", num(resp.batch_size as f64)),
    ])
    .to_string_compact();
    respond(stream, 200, "OK", &body);
}

fn hist_json(h: &[u64; 16]) -> JsonValue {
    arr(h.iter().map(|&c| num(c as f64)))
}

/// A JSON number that is guaranteed well-formed: non-finite derived
/// stats (e.g. a ratio on a server that served nothing yet) serialize
/// as `0.0` instead of emitting a literal `NaN`/`inf` token that would
/// corrupt the whole `/metrics` payload.
fn fnum(x: f64) -> JsonValue {
    num(if x.is_finite() { x } else { 0.0 })
}

/// The `/metrics` document (also reused by the pipeline bench).
pub fn metrics_json(server: &Server, spec: &MacroSpec) -> JsonValue {
    let m = server.metrics();
    let depths = server.queue_depths();
    let gov = server.governor();
    let mut tier_objs = Vec::new();
    for tier in Tier::ALL {
        let t = m.tier(tier);
        tier_objs.push((
            tier.name(),
            obj(vec![
                ("requests", num(t.requests as f64)),
                ("errors", num(t.errors as f64)),
                ("rejected", num(t.rejected as f64)),
                ("queue_depth", num(depths[tier.index()] as f64)),
                ("p50_latency_us", fnum(t.p50_latency_us())),
                ("p99_latency_us", fnum(t.p99_latency_us())),
                ("mean_boundary", fnum(t.mean_boundary())),
                ("b_hist", hist_json(&t.b_hist)),
            ]),
        ));
    }
    let gov_tiers: Vec<(&str, JsonValue)> = gov
        .tiers
        .iter()
        .map(|c| {
            (
                c.tier.name(),
                obj(vec![
                    ("profile", s(c.profile)),
                    ("level", num(c.level as f64)),
                    ("thresholds", arr(c.thresholds.iter().map(|&t| num(t as f64)))),
                ]),
            )
        })
        .collect();
    obj(vec![
        ("requests", num(m.requests as f64)),
        ("batches", num(m.batches as f64)),
        ("errors", num(m.errors as f64)),
        ("rejected", num(m.rejected as f64)),
        ("mean_batch", fnum(m.mean_batch())),
        ("p50_latency_us", fnum(m.p50_latency_us())),
        ("p95_latency_us", fnum(m.p95_latency_us())),
        ("p99_latency_us", fnum(m.p99_latency_us())),
        ("throughput_rps", fnum(m.throughput_rps())),
        ("tops_per_watt", fnum(m.tops_per_watt(spec))),
        ("watts", fnum(m.account.watts())),
        ("b_hist", hist_json(&m.b_hist)),
        ("tiers", obj(tier_objs)),
        (
            "governor",
            obj(vec![
                ("enabled", JsonValue::Bool(gov.enabled)),
                ("transitions", num(gov.transitions as f64)),
                ("tiers", obj(gov_tiers)),
            ]),
        ),
    ])
}
