//! Network gateway: a `std::net::TcpListener` HTTP/1.1 front-end over
//! the tier-aware coordinator (DESIGN.md §10) with **persistent
//! connections**.
//!
//! Routes:
//! * `POST /v1/infer` — body `{"tier": "gold|silver|batch", "image":
//!   [3072 uint8]}`; answers the prediction, or `429 Busy` when the
//!   tier's bounded queue is full (explicit backpressure), `400` on
//!   malformed input, `500` when the worker's forward failed.
//! * `POST /v1/infer_batch` — NDJSON: one `{"tier": ..., "image":
//!   [...]}` object per line (tier optional per line, default silver;
//!   blank lines skipped).  Answers NDJSON, one result (or per-line
//!   error) per non-blank input line, in order, each tagged with its
//!   original input line number (`"line"`).  Batch-tier clients
//!   amortize connection AND request-parse cost across many images.
//! * `GET /metrics` — JSON snapshot: aggregate + per-tier latency
//!   percentiles, boundary histograms, queue depths, rejection counts,
//!   connection/reuse counters and the governor's current per-tier
//!   precision contracts.
//! * `GET /v2/topology` — fleet topology: macro geometry, the per-layer
//!   placement the active `[fleet]` policy produces, per-macro residency
//!   occupancy, and inter-macro transfer-cost totals.  On a single-macro
//!   backend the document degenerates to a one-macro fleet.
//! * `GET /v2/energy` — the declarative `[hardware]` memory hierarchy
//!   plus a per-layer per-memory-level dataflow trace for one inference
//!   (access counts and priced femtojoules, DESIGN.md §15), and the
//!   measured energy account so far.
//! * `GET /v2/device` — the active analog device model (name, sigma,
//!   operation-unit group size) and the swept accuracy floors the
//!   governor enforces: per-tier degrade-level caps under the
//!   configured device corner (DESIGN.md §16).
//! * `GET /healthz` — liveness probe.
//!
//! Two serving modes share one routing/rendering core (so they emit
//! byte-identical responses):
//!
//! * **Event loop** (default on unix, `[serve] event_loop = true`): a
//!   single readiness-driven thread multiplexes every connection —
//!   nonblocking accept, per-connection state machines over the
//!   incremental `http::RequestParser`, pooled buffers, a timer heap
//!   for read/slowloris/idle deadlines, and completions routed back
//!   from the coordinator's ExecPool without parking a thread per
//!   request (see `serve::event_loop`).  `max_conns` is a **connection
//!   cap**: up to `max_conns` connections are served concurrently,
//!   up to `max_conns` more are parked (accepted, not yet read), and
//!   anything beyond is answered `429` and closed.
//! * **Threaded** (`--no-event-loop`, and every non-unix build): the
//!   PR-4 bounded connection-worker pool — one accept thread feeding
//!   `max_conns` workers through an accept backlog of the same depth;
//!   each worker runs a blocking keep-alive loop (per-read timeout +
//!   whole-request slowloris deadline).
//!
//! In both modes a request persists the connection only when the
//! gateway allows it, the request allows it, and the gateway isn't
//! draining; graceful [`Gateway::shutdown`] stops accepting, finishes
//! in-flight requests (responses carry `Connection: close`), then
//! drains the coordinator.

use super::http::{self, HttpRequest, ReadError};
use super::qos::{SubmitError, Tier};
use crate::config::SystemConfig;
use crate::coordinator::{Metrics, Server};
use crate::energy::dataflow;
use crate::energy::hierarchy::{LEVEL_NAMES, NUM_LEVELS};
use crate::engine::{Engine, InferOptions, InferRequest};
use crate::io::json::{self, arr, num, obj, s, JsonValue};
use crate::nn::QGraph;
use crate::obs::{self, ServerObs, Stage};
use crate::sched::fleet;
use crate::sched::plan::{FleetDims, PlacementMode};
use crate::spec::MacroSpec;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::{BufReader, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Expected image payload: 32x32x3 uint8.
pub const IMAGE_BYTES: usize = 32 * 32 * 3;

/// Hard cap on `/v1/infer_batch` lines per request (the body-size bound
/// already limits this in practice; the explicit cap keeps the error
/// message honest).
pub const MAX_BATCH_LINES: usize = 256;

/// Connection-level counters (all monotonic; snapshot via `/metrics`).
#[derive(Debug, Default)]
pub struct ConnStats {
    /// Connections claimed by a connection worker.
    pub accepted: AtomicU64,
    /// Connections refused at admission (backlog full -> 429 + close).
    pub rejected: AtomicU64,
    /// HTTP requests served across all connections.
    pub requests: AtomicU64,
}

impl ConnStats {
    /// Fraction of requests that rode a reused connection:
    /// `1 - connections/requests`.  0 when every request paid a fresh
    /// TCP setup (the old one-shot gateway), -> 1 as keep-alive clients
    /// amortize the connection across many requests.
    pub fn reuse_rate(&self) -> f64 {
        let conns = self.accepted.load(Ordering::Relaxed);
        let reqs = self.requests.load(Ordering::Relaxed);
        if reqs == 0 {
            return 0.0;
        }
        1.0 - conns.min(reqs) as f64 / reqs as f64
    }
}

/// Event-loop observability (`/metrics` → `"event_loop"`).  Counters
/// are monotonic; `open_connections` / `parked_connections` are gauges
/// tracking current state.  Defined here (not in `serve::event_loop`)
/// so the `/metrics` surface exists on every platform even when the
/// loop itself is compiled out.
#[derive(Debug, Default)]
pub struct EventLoopStats {
    /// Admitted connections currently registered with the poller.
    pub open_connections: AtomicU64,
    /// Accepted connections parked awaiting a free active slot.
    pub parked_connections: AtomicU64,
    /// Poller returns (epoll_wait / poll), including timer-only ticks.
    pub wakeups: AtomicU64,
    /// Reads that hit `EAGAIN`/`WouldBlock` (socket buffer drained).
    pub eagain_reads: AtomicU64,
    /// Writes that hit `EAGAIN`/`WouldBlock` (kernel send buffer full;
    /// the response is re-armed on writability instead of blocking).
    pub eagain_writes: AtomicU64,
    /// Idle / slowloris / write / linger deadlines that actually fired.
    pub deadline_expirations: AtomicU64,
    /// Connection buffers recycled from the pool vs freshly allocated.
    pub pool_hits: AtomicU64,
    pub pool_misses: AtomicU64,
}

impl EventLoopStats {
    /// Fraction of buffer acquisitions served by the pool (0 before
    /// any connection arrived).
    pub fn pool_hit_rate(&self) -> f64 {
        let h = self.pool_hits.load(Ordering::Relaxed) as f64;
        let m = self.pool_misses.load(Ordering::Relaxed) as f64;
        if h + m == 0.0 {
            0.0
        } else {
            h / (h + m)
        }
    }
}

/// Connection-lifecycle knobs resolved from [`SystemConfig`] (shared
/// by both serving modes).
#[derive(Debug, Clone, Copy)]
pub(crate) struct ConnOpts {
    pub(crate) keep_alive: bool,
    /// Per-read / idle timeout (None = wait forever).
    pub(crate) read_timeout: Option<Duration>,
    /// Whole-request deadline anchored at the FIRST byte of a request
    /// (slowloris guard; ZERO = disabled).
    pub(crate) request_deadline: Duration,
    pub(crate) spec: MacroSpec,
    /// Tier assumed when a request names none (`[serve] default_tier`).
    pub(crate) default_tier: Tier,
}

/// Bounded queue of accepted-but-unclaimed connections (the accept
/// backlog).  Push past the bound fails fast — the accept thread
/// answers 429 — mirroring the QoS tier queues.
struct ConnQueue {
    state: Mutex<(VecDeque<TcpStream>, bool)>,
    cv: Condvar,
    cap: usize,
}

impl ConnQueue {
    fn new(cap: usize) -> Self {
        Self { state: Mutex::new((VecDeque::new(), false)), cv: Condvar::new(), cap }
    }

    /// Admit one connection, or hand it back when the backlog is full
    /// or the queue is closed.
    fn push(&self, stream: TcpStream) -> std::result::Result<(), TcpStream> {
        let mut st = self.state.lock().unwrap();
        if st.1 || st.0.len() >= self.cap {
            return Err(stream);
        }
        st.0.push_back(stream);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Block for the next connection; `None` once closed (queued
    /// connections left at close are dropped — they have no in-flight
    /// requests to finish).
    fn pop(&self) -> Option<TcpStream> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.1 {
                return None;
            }
            if let Some(s) = st.0.pop_front() {
                return Some(s);
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Stop handing out connections and drop anything still queued.
    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.1 = true;
        st.0.clear();
        drop(st);
        self.cv.notify_all();
    }
}

/// Everything a connection worker needs.
struct ConnCtx {
    server: Arc<Server>,
    opts: ConnOpts,
    stats: Arc<ConnStats>,
    /// Read-half clones of every connection currently inside a worker,
    /// keyed by a serial id: shutdown nudges blocked keep-alive readers
    /// awake via `Shutdown::Read` without touching in-flight writes.
    active: Mutex<HashMap<u64, TcpStream>>,
    next_conn: AtomicU64,
    stop: AtomicBool,
}

/// The serving gateway (listener + event loop or connection pool +
/// coordinator).
pub struct Gateway {
    addr: SocketAddr,
    stats: Arc<ConnStats>,
    inner: Inner,
}

enum Inner {
    /// The PR-4 bounded connection-worker pool — the `--no-event-loop`
    /// escape hatch, and the only mode on non-unix builds.
    Threaded {
        ctx: Arc<ConnCtx>,
        queue: Arc<ConnQueue>,
        accept: Option<std::thread::JoinHandle<()>>,
        workers: Vec<std::thread::JoinHandle<()>>,
    },
    /// Readiness-driven event loop: every connection multiplexed onto
    /// one thread; compute still runs on the coordinator's ExecPool.
    #[cfg(unix)]
    Event {
        server: Arc<Server>,
        shared: Arc<super::event_loop::Shared>,
        thread: Option<std::thread::JoinHandle<()>>,
    },
}

impl Gateway {
    /// Bind `listen` and serve a default [`Engine`] built for the
    /// config (convenience over [`Gateway::with_engine`]).
    pub fn start(cfg: &SystemConfig, graph: Arc<QGraph>, listen: &str) -> Result<Gateway> {
        let engine = Engine::builder().config(cfg.clone()).graph(graph).build()?;
        Self::with_engine(Arc::new(engine), listen)
    }

    /// Bind `listen` (e.g. `127.0.0.1:8080`, port 0 for ephemeral) and
    /// start serving on an assembled engine.
    pub fn with_engine(engine: Arc<Engine>, listen: &str) -> Result<Gateway> {
        let cfg = engine.config().clone();
        // bind first: a failed bind (port in use) must not leave a live
        // batcher + worker pool behind with nothing to shut them down
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        let addr = listener.local_addr().context("local_addr")?;
        let server = Arc::new(Server::with_engine(engine)?);
        let read_timeout = match cfg.read_timeout_ms {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        };
        let opts = ConnOpts {
            keep_alive: cfg.keep_alive,
            read_timeout,
            // a request must complete within a few read-timeouts even if
            // the peer trickles bytes to keep each individual read alive
            request_deadline: read_timeout.map(|t| t * 4).unwrap_or(Duration::ZERO),
            spec: cfg.spec,
            default_tier: cfg.default_tier,
        };
        let stats = Arc::new(ConnStats::default());
        let max_conns = cfg.max_conns.max(1);
        #[cfg(unix)]
        if cfg.event_loop {
            let (shared, thread) = super::event_loop::spawn(
                server.clone(),
                opts,
                max_conns,
                listener,
                stats.clone(),
            )?;
            log::info!(
                "gateway listening on {addr} (event loop, keep_alive={}, max_conns={max_conns})",
                cfg.keep_alive
            );
            return Ok(Gateway {
                addr,
                stats,
                inner: Inner::Event { server, shared, thread: Some(thread) },
            });
        }
        #[cfg(not(unix))]
        if cfg.event_loop {
            log::warn!(
                "[serve] event_loop has no poller on this platform; using the threaded gateway"
            );
        }
        Self::threaded(server, opts, max_conns, listener, stats, addr)
    }

    /// Start the bounded connection-worker pool (the threaded mode).
    fn threaded(
        server: Arc<Server>,
        opts: ConnOpts,
        max_conns: usize,
        listener: TcpListener,
        stats: Arc<ConnStats>,
        addr: SocketAddr,
    ) -> Result<Gateway> {
        let keep_alive = opts.keep_alive;
        let ctx = Arc::new(ConnCtx {
            server,
            opts,
            stats: stats.clone(),
            active: Mutex::new(HashMap::new()),
            next_conn: AtomicU64::new(0),
            stop: AtomicBool::new(false),
        });
        let queue = Arc::new(ConnQueue::new(max_conns));
        let mut workers = Vec::with_capacity(max_conns);
        for wid in 0..max_conns {
            let ctx = ctx.clone();
            let queue = queue.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("gateway-conn-{wid}"))
                    .spawn(move || conn_worker(&ctx, &queue))
                    .context("spawning connection worker")?,
            );
        }
        // Bounded budget of concurrent rejection threads: each 429 is
        // written + linger-closed off the accept thread (so a flood
        // cannot stall accepts), but never with unbounded thread growth
        // — past the budget a connection is shed silently, which is the
        // honest signal at that level of overload.
        const MAX_REJECTORS: u64 = 32;
        let rejectors = Arc::new(AtomicU64::new(0));
        let accept = std::thread::Builder::new()
            .name("gateway-accept".into())
            .spawn({
                let ctx = ctx.clone();
                let queue = queue.clone();
                let rejectors = rejectors.clone();
                move || {
                    for incoming in listener.incoming() {
                        if ctx.stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let stream = match incoming {
                            Ok(s) => s,
                            Err(e) => {
                                log::warn!("accept failed: {e}");
                                continue;
                            }
                        };
                        if let Err(mut stream) = queue.push(stream) {
                            // connection-level admission: the pool and
                            // its backlog are full — same explicit-429
                            // contract as the QoS tier queues.  The
                            // write + lingering close run on a short
                            // detached thread so the accept loop stays
                            // fast exactly when it is being flooded.
                            ctx.stats.rejected.fetch_add(1, Ordering::Relaxed);
                            if rejectors.load(Ordering::Relaxed) >= MAX_REJECTORS {
                                // even the rejection budget is gone:
                                // shed silently (drop = RST)
                                continue;
                            }
                            rejectors.fetch_add(1, Ordering::Relaxed);
                            let rejectors = rejectors.clone();
                            let e = SubmitError::Overloaded { max_conns };
                            let body = obj(vec![
                                ("error", s("busy")),
                                ("detail", s(&e.to_string())),
                            ])
                            .to_string_compact();
                            std::thread::spawn(move || {
                                let _ =
                                    stream.set_write_timeout(Some(Duration::from_secs(2)));
                                let _ = http::write_response(
                                    &mut stream,
                                    429,
                                    "Too Many Requests",
                                    "application/json",
                                    body.as_bytes(),
                                    false,
                                );
                                // the peer's request was never read at
                                // all: drain briefly so the 429 is not
                                // destroyed by an RST
                                linger_close(&stream, &mut (&stream));
                                rejectors.fetch_sub(1, Ordering::Relaxed);
                            });
                        }
                    }
                }
            })
            .context("spawning accept loop")?;
        log::info!(
            "gateway listening on {addr} (threaded, keep_alive={keep_alive}, max_conns={max_conns})"
        );
        Ok(Gateway {
            addr,
            stats,
            inner: Inner::Threaded { ctx, queue, accept: Some(accept), workers },
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connection-level counters (accepted / rejected / requests).
    pub fn conn_stats(&self) -> Arc<ConnStats> {
        self.stats.clone()
    }

    /// Event-loop counters (wakeups, EAGAINs, pool hit rate) — `None`
    /// in threaded mode.
    pub fn event_loop_stats(&self) -> Option<Arc<EventLoopStats>> {
        match &self.inner {
            Inner::Threaded { .. } => None,
            #[cfg(unix)]
            Inner::Event { shared, .. } => Some(shared.ev.clone()),
        }
    }

    /// The serving telemetry registry (trace spans, latency/stage
    /// histograms, layer attribution) — shared with the coordinator.
    /// The pipeline bench toggles span collection through it to measure
    /// tracing overhead.
    pub fn obs(&self) -> Arc<ServerObs> {
        match &self.inner {
            Inner::Threaded { ctx, .. } => ctx.server.obs().clone(),
            #[cfg(unix)]
            Inner::Event { server, .. } => server.obs().clone(),
        }
    }

    /// Block until the serving loop exits (i.e. until shutdown or
    /// process death) — the `osa-hcim serve --listen` foreground mode.
    pub fn wait(mut self) {
        match &mut self.inner {
            Inner::Threaded { accept, .. } => {
                if let Some(a) = accept.take() {
                    let _ = a.join();
                }
            }
            #[cfg(unix)]
            Inner::Event { thread, .. } => {
                if let Some(t) = thread.take() {
                    let _ = t.join();
                }
            }
        }
    }

    /// Stop accepting, finish in-flight requests (drain), then drain
    /// the coordinator.  Returns the final serving metrics.
    pub fn shutdown(self) -> Metrics {
        let addr = self.addr;
        match self.inner {
            Inner::Threaded { ctx, queue, mut accept, mut workers } => {
                ctx.stop.store(true, Ordering::SeqCst);
                // unblock the accept loop with one last connection
                let _ = TcpStream::connect(addr);
                if let Some(a) = accept.take() {
                    let _ = a.join();
                }
                // no new connections reach the workers; queued-but-idle
                // ones are dropped (they have no in-flight requests)
                queue.close();
                // wake workers blocked waiting for the NEXT request of
                // an idle keep-alive session: shutting down the read
                // half makes their blocked read return EOF (a clean
                // request boundary) without disturbing a response that
                // is still being written
                {
                    let active = ctx.active.lock().unwrap();
                    for stream in active.values() {
                        let _ = stream.shutdown(Shutdown::Read);
                    }
                }
                for w in workers.drain(..) {
                    let _ = w.join();
                }
                match Arc::try_unwrap(ctx) {
                    Ok(ctx) => match Arc::try_unwrap(ctx.server) {
                        Ok(server) => server.shutdown(),
                        Err(server) => server.metrics(),
                    },
                    // a straggler still holds a handle; fall back to a
                    // snapshot
                    Err(ctx) => ctx.server.metrics(),
                }
            }
            #[cfg(unix)]
            Inner::Event { server, shared, mut thread } => {
                // the loop thread owns the drain: it stops accepting,
                // finishes dispatched/writing connections, closes idle
                // ones, then exits
                shared.request_stop();
                if let Some(t) = thread.take() {
                    let _ = t.join();
                }
                drop(shared);
                match Arc::try_unwrap(server) {
                    Ok(server) => server.shutdown(),
                    Err(server) => server.metrics(),
                }
            }
        }
    }
}

fn conn_worker(ctx: &ConnCtx, queue: &ConnQueue) {
    while let Some(stream) = queue.pop() {
        ctx.stats.accepted.fetch_add(1, Ordering::Relaxed);
        let id = ctx.next_conn.fetch_add(1, Ordering::Relaxed);
        // register the read half BEFORE the first blocking read so a
        // concurrent shutdown can always nudge this connection
        if let Ok(clone) = stream.try_clone() {
            ctx.active.lock().unwrap().insert(id, clone);
        }
        // Panic containment, same invariant as the `sched::exec` pool
        // this design mirrors: one panicking handler loses ITS
        // connection, never a pool worker — an uncontained panic would
        // permanently shrink the bounded pool (with max_conns=1, into a
        // gateway that 429s everything forever).
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            handle_conn(stream, ctx);
        }));
        if result.is_err() {
            log::error!("connection handler panicked; connection dropped");
        }
        ctx.active.lock().unwrap().remove(&id);
    }
}

pub(crate) fn err_body(msg: &str) -> String {
    obj(vec![("error", s(msg))]).to_string_compact()
}

/// Lingering close for a connection whose request was NOT fully read
/// (parse reject, stall, admission 429): FIN the write half after the
/// final response, then briefly and boundedly discard whatever the
/// peer was still sending.  Dropping a socket with unread bytes queued
/// makes the kernel answer RST, and an RST purges the peer's receive
/// buffer — destroying the just-written error response before the
/// client can read it (invisible on loopback, real over networks).
fn linger_close(stream: &TcpStream, reader: &mut impl std::io::Read) {
    let _ = stream.shutdown(Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let mut scratch = [0u8; 4096];
    let mut budget = 64 * 1024usize;
    // hard wall-clock cap alongside the byte budget: a peer trickling
    // one byte per read-timeout would otherwise pin this pool worker
    // for hours (64K reads x 250ms) — the exact slowloris shape the
    // request deadline sheds
    let deadline = std::time::Instant::now() + Duration::from_secs(1);
    loop {
        if std::time::Instant::now() >= deadline {
            break;
        }
        match reader.read(&mut scratch) {
            Ok(0) => break, // peer saw the FIN and closed
            Ok(n) => {
                if n >= budget {
                    break;
                }
                budget -= n;
            }
            Err(_) => break, // grace window elapsed (or transport died)
        }
    }
}

/// Which wire API a dispatched request belongs to — selects the error
/// envelope and response tagging at render time.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Api {
    V1,
    V2,
}

/// One fully-decided HTTP response, independent of how (and when) its
/// bytes reach the socket: the threaded mode writes it immediately, the
/// event loop queues the bytes and arms writability.  Keeping rendering
/// separate from transport is what guarantees both modes answer
/// byte-identically.
pub(crate) struct Rendered {
    pub(crate) status: u16,
    pub(crate) reason: &'static str,
    pub(crate) content_type: &'static str,
    pub(crate) extra: Vec<(String, String)>,
    pub(crate) body: String,
    /// Whether the connection persists AFTER this response (also what
    /// the `Connection:` header says on the wire).
    pub(crate) keep: bool,
}

impl Rendered {
    pub(crate) fn json(status: u16, reason: &'static str, body: String, keep: bool) -> Rendered {
        Rendered {
            status,
            reason,
            content_type: "application/json",
            extra: Vec::new(),
            body,
            keep,
        }
    }

    /// Serialize onto `out` in the gateway's exact wire format.
    pub(crate) fn to_bytes(&self, out: &mut Vec<u8>) {
        self.to_bytes_with_rid(out, 0);
    }

    /// [`Rendered::to_bytes`] plus an `X-Request-Id` echo when the
    /// response answers a traced request (rid 0 = none, e.g. the
    /// admission 429 written before any request was parsed).
    pub(crate) fn to_bytes_with_rid(&self, out: &mut Vec<u8>, rid: u64) {
        let rid_text = if rid != 0 { Some(obs::format_rid(rid)) } else { None };
        let mut extra: Vec<(&str, &str)> =
            self.extra.iter().map(|(k, v)| (k.as_str(), v.as_str())).collect();
        if let Some(t) = &rid_text {
            extra.push(("X-Request-Id", t.as_str()));
        }
        http::format_response_into(
            out,
            self.status,
            self.reason,
            self.content_type,
            &extra,
            self.body.as_bytes(),
            self.keep,
        );
    }
}

/// Write one response; `false` means the write failed (possibly
/// part-way).  After a partial write the byte stream is misframed —
/// response N+1 would be consumed as the tail of N's body — so the
/// connection loop MUST close on `false`, never keep serving.
fn write_rendered(stream: &mut TcpStream, r: &Rendered) -> bool {
    write_rendered_rid(stream, r, 0)
}

/// [`write_rendered`] tagging the response with its trace id.
fn write_rendered_rid(stream: &mut TcpStream, r: &Rendered, rid: u64) -> bool {
    let mut out = Vec::new();
    r.to_bytes_with_rid(&mut out, rid);
    match stream.write_all(&out).and_then(|_| stream.flush()) {
        Ok(()) => true,
        Err(e) => {
            log::debug!("writing response: {e}");
            false
        }
    }
}

/// The methods a known path answers, `None` for unknown paths.  Drives
/// the 405-vs-404 split: a wrong method on a real endpoint must say so
/// (and name the right method in `Allow`) instead of denying the path
/// exists.
fn allowed_methods(path: &str) -> Option<&'static [&'static str]> {
    match path {
        "/healthz" | "/metrics" | "/v1/version" | "/debug/trace" => Some(&["GET"]),
        "/v2/topology" | "/v2/energy" | "/v2/device" => Some(&["GET"]),
        "/v1/infer" | "/v1/infer_batch" | "/v2/infer" => Some(&["POST"]),
        _ => None,
    }
}

/// The `GET /v1/version` document: crate version, active backend,
/// engine thread count, and every registered backend with availability
/// — what a fleet rollout checks before shifting traffic.
fn version_json(engine: &Engine) -> JsonValue {
    // the capability surface is additive: pre-fleet clients that only
    // know version/backend/backends keep parsing unchanged
    let caps = match engine.backend().ok().map(|b| b.capabilities()) {
        Some(c) => obj(vec![
            ("mode", s(c.mode.name())),
            ("macros", num(c.macros as f64)),
            ("residency_bytes", num(c.residency_bytes as f64)),
            ("programmable_thresholds", JsonValue::Bool(c.programmable_thresholds)),
            ("hybrid_boundary", JsonValue::Bool(c.hybrid_boundary)),
            ("pooling", JsonValue::Bool(c.pooling)),
            ("cost_model", s(c.cost_model)),
            ("memory_levels", num(c.memory_levels as f64)),
            // additive (PR 10): which analog device model the backend
            // routes conversion noise through (DESIGN.md §16)
            (
                "device",
                obj(vec![
                    ("model", s(c.device.model)),
                    ("sigma", fnum(c.device.sigma)),
                    ("s_ou", num(c.device.s_ou as f64)),
                ]),
            ),
        ]),
        None => JsonValue::Null,
    };
    let cfg = engine.config();
    obj(vec![
        ("version", s(env!("CARGO_PKG_VERSION"))),
        ("backend", s(engine.backend_name())),
        ("engine_threads", num(engine.threads() as f64)),
        ("api", arr(["v1", "v2"].into_iter().map(s))),
        ("capabilities", caps),
        (
            "fleet",
            obj(vec![
                ("macros", num(cfg.fleet_macros.max(1) as f64)),
                ("residency_tiles", num(cfg.fleet_residency_tiles.max(1) as f64)),
                ("placement", s(&cfg.fleet_placement)),
            ]),
        ),
        (
            "backends",
            arr(engine.registry().specs().iter().map(|sp| {
                obj(vec![
                    ("name", s(sp.name)),
                    ("available", JsonValue::Bool(sp.available)),
                    ("description", s(sp.description)),
                ])
            })),
        ),
    ])
}

/// The `GET /v2/topology` document: fleet geometry, the placement the
/// active `[fleet]` policy produces for the loaded graph, per-macro
/// residency occupancy, and the transfer cost charged so far.  Single-
/// macro backends report a degenerate one-macro fleet with no split
/// layers, so dashboards need no backend-specific casing.
fn topology_json(server: &Server) -> JsonValue {
    let engine = server.engine();
    let cfg = engine.config();
    let dims = FleetDims {
        macros: cfg.fleet_macros.max(1),
        residency_tiles: cfg.fleet_residency_tiles.max(1),
    };
    let mode = PlacementMode::parse(&cfg.fleet_placement).unwrap_or_default();
    let pp = fleet::plan_for_dims(&engine.graph().gemm_dims(), &cfg.spec, dims, mode);
    let m = server.metrics();
    obj(vec![
        ("backend", s(engine.backend_name())),
        (
            "fleet",
            obj(vec![
                ("macros", num(dims.macros as f64)),
                ("residency_tiles", num(dims.residency_tiles as f64)),
                (
                    "residency_bytes",
                    num((dims.residency_tiles as u64 * fleet::tile_bytes(&cfg.spec)) as f64),
                ),
                ("placement", s(mode.name())),
                ("hop_energy_fj", fnum(cfg.fleet_hop_energy_fj)),
                ("hop_latency_cycles", num(cfg.fleet_hop_latency_cycles as f64)),
            ]),
        ),
        (
            "tiles",
            obj(vec![
                ("total", num(pp.total_tiles as f64)),
                ("unique", num(pp.unique_tiles as f64)),
                ("capacity", num(pp.capacity_tiles() as f64)),
            ]),
        ),
        (
            "layers",
            arr(pp.layers.iter().map(|l| {
                obj(vec![
                    ("layer", num(l.layer_idx as f64)),
                    ("n_tiles", num(l.nt as f64)),
                    ("k_tiles", num(l.kt as f64)),
                    ("replicas", num(l.replicas as f64)),
                    ("macros_needed", num(l.macros_needed as f64)),
                    ("split_k", JsonValue::Bool(l.split_k())),
                    ("wrapped", JsonValue::Bool(l.wrapped)),
                ])
            })),
        ),
        ("macro_residency", arr(pp.macro_residency().into_iter().map(|t| num(t as f64)))),
        (
            "transfer",
            obj(vec![
                ("energy_fj", fnum(m.account.transfer_fj)),
                ("hops", num(m.account.transfer_hops as f64)),
                ("fraction_of_total", fnum(m.account.transfer_fraction())),
            ]),
        ),
        ("macro_cycles", arr(m.account.macro_cycles.iter().map(|&c| num(c as f64)))),
    ])
}

/// The `GET /v2/energy` document (DESIGN.md §15): the declarative
/// `[hardware]` memory hierarchy, a per-layer per-memory-level dataflow
/// trace for one inference (access counts + priced femtojoules, derived
/// from graph shapes and the active `[fleet]` placement — no request
/// needs to have been served), and the measured energy account so far.
/// Always answers; under `model = "compact"` the trace is advisory
/// (movement is not folded into served energy), which the `model` field
/// makes explicit.
fn energy_json(server: &Server) -> JsonValue {
    let engine = server.engine();
    let cfg = engine.config();
    let hier = &cfg.hardware;
    let dims = FleetDims {
        macros: cfg.fleet_macros.max(1),
        residency_tiles: cfg.fleet_residency_tiles.max(1),
    };
    let mode = PlacementMode::parse(&cfg.fleet_placement).unwrap_or_default();
    let pp = fleet::plan_for_dims(&engine.graph().gemm_dims(), &cfg.spec, dims, mode);
    let mut level_totals = [0.0f64; NUM_LEVELS];
    let mut hop_words_total = 0u64;
    let mut layer_objs = Vec::new();
    for shp in engine.graph().layer_shapes() {
        let placement = pp.layers.iter().find(|l| l.layer_idx == shp.layer_idx);
        let t = dataflow::trace_dims(shp.m, shp.n, shp.k, &cfg.spec, placement, hier);
        for (acc, fj) in level_totals.iter_mut().zip(&t.movement_fj) {
            *acc += fj;
        }
        hop_words_total += t.hop_words;
        let levels: Vec<(&str, JsonValue)> = LEVEL_NAMES
            .iter()
            .enumerate()
            .map(|(i, name)| {
                (
                    *name,
                    obj(vec![
                        ("reads", num(t.access[i].reads as f64)),
                        ("writes", num(t.access[i].writes as f64)),
                        ("movement_fj", fnum(t.movement_fj[i])),
                    ]),
                )
            })
            .collect();
        layer_objs.push(obj(vec![
            ("layer", num(shp.layer_idx as f64)),
            ("name", s(&shp.name)),
            ("m", num(shp.m as f64)),
            ("n", num(shp.n as f64)),
            ("k", num(shp.k as f64)),
            ("levels", obj(levels)),
            ("movement_fj", fnum(t.total_fj())),
            ("hop_words", num(t.hop_words as f64)),
        ]));
    }
    let hardware: Vec<(&str, JsonValue)> = LEVEL_NAMES
        .iter()
        .enumerate()
        .map(|(i, name)| {
            let lv = hier.level(i);
            (
                *name,
                obj(vec![
                    ("size_bytes", num(lv.size_bytes as f64)),
                    ("read_fj", fnum(lv.read_fj)),
                    ("write_fj", fnum(lv.write_fj)),
                    ("bandwidth_words", fnum(lv.bandwidth_words)),
                    ("ports", num(lv.ports as f64)),
                ]),
            )
        })
        .collect();
    let m = server.metrics();
    let trace_levels: Vec<(&str, JsonValue)> =
        LEVEL_NAMES.iter().zip(&level_totals).map(|(n, &fj)| (*n, fnum(fj))).collect();
    obj(vec![
        ("model", s(&cfg.hardware_model)),
        ("backend", s(engine.backend_name())),
        ("hardware", obj(hardware)),
        ("layers", arr(layer_objs)),
        (
            "trace",
            obj(vec![
                ("movement_fj", fnum(level_totals.iter().sum())),
                ("levels_fj", obj(trace_levels)),
                ("hop_words", num(hop_words_total as f64)),
            ]),
        ),
        (
            "account",
            obj(vec![
                ("energy_j", fnum(m.account.total_energy_j())),
                ("movement_fj", fnum(m.account.breakdown.movement_total_fj())),
                ("transfer_fj", fnum(m.account.transfer_fj)),
                ("requests", num(m.requests as f64)),
                (
                    "energy_per_request_j",
                    fnum(m.account.total_energy_j() / m.requests as f64),
                ),
            ]),
        ),
    ])
}

/// The `GET /v2/device` document (DESIGN.md §16): the analog device
/// model the active backend routes conversion noise through, the
/// `[device]` sweep-report feedback configuration, and — when a sweep
/// report is loaded — the per-tier degrade-level caps the governor
/// enforces at the swept corner sigma.  Without a report every cap is
/// unbounded and `floors_loaded` is `false`, so dashboards can tell
/// "no data" apart from "corner is clean".
fn device_json(server: &Server) -> JsonValue {
    let engine = server.engine();
    let cfg = engine.config();
    let gov = server.governor();
    let floors = gov.floors;
    let caps = match engine.backend().ok().map(|b| b.capabilities().device) {
        Some(d) => obj(vec![
            ("model", s(d.model)),
            ("sigma", fnum(d.sigma)),
            ("s_ou", num(d.s_ou as f64)),
        ]),
        None => JsonValue::Null,
    };
    let floors_loaded = floors.caps.iter().any(|&c| c != u32::MAX);
    let tier_objs: Vec<(&str, JsonValue)> = Tier::ALL
        .iter()
        .map(|&tier| {
            let contract = gov.tiers.iter().find(|c| c.tier == tier);
            let cap = floors.cap(tier);
            (
                tier.name(),
                obj(vec![
                    // u32::MAX means "no floor": render as null, not a
                    // 4-billion gauge that would wreck dashboard axes
                    (
                        "floor_cap",
                        if cap == u32::MAX { JsonValue::Null } else { num(cap as f64) },
                    ),
                    (
                        "level_cap",
                        num(contract.map(|c| c.level_cap).unwrap_or(0) as f64),
                    ),
                    ("level", num(contract.map(|c| c.level).unwrap_or(0) as f64)),
                ]),
            )
        })
        .collect();
    obj(vec![
        ("device", caps),
        (
            "sweep",
            obj(vec![
                ("report", s(&cfg.device_sweep_report)),
                ("corner_sigma", fnum(cfg.device_corner_sigma)),
                ("floors_loaded", JsonValue::Bool(floors_loaded)),
                ("floor_corner_sigma", fnum(floors.corner_sigma)),
            ]),
        ),
        (
            "sla",
            obj(vec![
                ("gold", fnum(cfg.device_sla_gold)),
                ("silver", fnum(cfg.device_sla_silver)),
                ("batch", fnum(cfg.device_sla_batch)),
            ]),
        ),
        ("tiers", obj(tier_objs)),
    ])
}

/// Everything the router needs to answer a request (borrowed — both
/// serving modes assemble one per request from their own state).
pub(crate) struct RouteCtx<'a> {
    pub(crate) server: &'a Server,
    pub(crate) spec: &'a MacroSpec,
    pub(crate) default_tier: Tier,
    pub(crate) stats: &'a ConnStats,
    /// Event-loop gauges for `/metrics`; `None` in threaded mode.
    pub(crate) ev: Option<&'a EventLoopStats>,
}

/// One line of an NDJSON batch after parse/validation, before submit.
pub(crate) enum BatchLine {
    Submit { line: usize, ireq: InferRequest },
    Err { line: usize, msg: String },
}

/// What the router decided for one parsed request: answer right away,
/// or hand compute to the coordinator and render when it completes.
/// The dispatch variants carry `keep` so the eventual render happens
/// long after the request itself is gone.
pub(crate) enum RouteOutcome {
    Respond(Rendered),
    Dispatch { ireq: InferRequest, api: Api, keep: bool },
    DispatchBatch { lines: Vec<BatchLine>, keep: bool },
}

/// Route one parsed request.  Pure with respect to transport: no
/// sockets, no blocking — both serving modes call this and then execute
/// the outcome their own way, which is what keeps their responses
/// byte-identical.
pub(crate) fn route(req: &HttpRequest, ctx: &RouteCtx<'_>, keep: bool) -> RouteOutcome {
    // route on the path only — a query string must not 404 an endpoint
    let path = req.path.split('?').next().unwrap_or("");
    match (req.method.as_str(), path) {
        ("GET", "/healthz") => {
            // enriched liveness: fleet rollouts verify what is
            // actually serving (backend, threads, crate version)
            let e = ctx.server.engine();
            let body = obj(vec![
                ("status", s("ok")),
                ("backend", s(e.backend_name())),
                ("engine_threads", num(e.threads() as f64)),
                ("version", s(env!("CARGO_PKG_VERSION"))),
                // additive: what a topology-aware rollout checks
                ("fleet_macros", num(e.config().fleet_macros.max(1) as f64)),
                ("placement", s(&e.config().fleet_placement)),
                // additive (PR 10): the active analog device model —
                // a variation-aware rollout refuses to shift traffic
                // onto a corner it has no sweep data for
                (
                    "device",
                    match e.backend().ok().map(|b| b.capabilities().device) {
                        Some(d) => obj(vec![
                            ("model", s(d.model)),
                            ("sigma", fnum(d.sigma)),
                            ("s_ou", num(d.s_ou as f64)),
                        ]),
                        None => JsonValue::Null,
                    },
                ),
            ])
            .to_string_compact();
            RouteOutcome::Respond(Rendered::json(200, "OK", body, keep))
        }
        ("GET", "/v1/version") => {
            let body = version_json(ctx.server.engine()).to_string_compact();
            RouteOutcome::Respond(Rendered::json(200, "OK", body, keep))
        }
        ("GET", "/v2/topology") => {
            let body = topology_json(ctx.server).to_string_compact();
            RouteOutcome::Respond(Rendered::json(200, "OK", body, keep))
        }
        ("GET", "/v2/energy") => {
            let body = energy_json(ctx.server).to_string_compact();
            RouteOutcome::Respond(Rendered::json(200, "OK", body, keep))
        }
        ("GET", "/v2/device") => {
            let body = device_json(ctx.server).to_string_compact();
            RouteOutcome::Respond(Rendered::json(200, "OK", body, keep))
        }
        ("GET", "/metrics") => {
            let query = req.path.split('?').nth(1).unwrap_or("");
            if wants_prometheus(query, req.header("accept")) {
                let body = metrics_prometheus(ctx.server, ctx.spec, Some(ctx.stats), ctx.ev);
                let mut r = Rendered::json(200, "OK", body, keep);
                r.content_type = obs::PROM_CONTENT_TYPE;
                RouteOutcome::Respond(r)
            } else {
                let body = metrics_json_ev(ctx.server, ctx.spec, Some(ctx.stats), ctx.ev)
                    .to_string_compact();
                RouteOutcome::Respond(Rendered::json(200, "OK", body, keep))
            }
        }
        ("GET", "/debug/trace") => {
            let telem = ctx.server.obs();
            let mut n = 256usize;
            for pair in req.path.split('?').nth(1).unwrap_or("").split('&') {
                if let Some(v) = pair.strip_prefix("n=") {
                    match v.parse::<usize>() {
                        Ok(k) => n = k,
                        Err(_) => {
                            return RouteOutcome::Respond(Rendered::json(
                                400,
                                "Bad Request",
                                err_body("\"n\" must be a non-negative integer"),
                                keep,
                            ))
                        }
                    }
                }
            }
            let spans = telem.spans_tail(n.min(telem.trace_capacity()));
            let body = obs::chrome_trace_doc(&spans).to_string_compact();
            RouteOutcome::Respond(Rendered::json(200, "OK", body, keep))
        }
        ("POST", "/v1/infer") => route_infer(req, ctx, Api::V1, keep),
        ("POST", "/v2/infer") => route_infer(req, ctx, Api::V2, keep),
        ("POST", "/v1/infer_batch") => route_infer_batch(req, ctx, keep),
        (_, path) => match allowed_methods(path) {
            // known path, wrong method: 405 + Allow, not a 404
            Some(methods) => {
                let mut r = Rendered::json(
                    405,
                    "Method Not Allowed",
                    err_body("method not allowed"),
                    keep,
                );
                r.extra.push(("Allow".to_string(), methods.join(", ")));
                RouteOutcome::Respond(r)
            }
            None => RouteOutcome::Respond(Rendered::json(
                404,
                "Not Found",
                err_body("no such route"),
                keep,
            )),
        },
    }
}

/// Shared `/v1/infer` + `/v2/infer` front half: body → JSON → typed
/// [`InferRequest`], or an immediate 400 in the API's own envelope.
fn route_infer(req: &HttpRequest, ctx: &RouteCtx<'_>, api: Api, keep: bool) -> RouteOutcome {
    let bad = |msg: &str, keep: bool| {
        let body = match api {
            Api::V1 => err_body(msg),
            Api::V2 => v2_err("bad_request", msg, vec![]),
        };
        RouteOutcome::Respond(Rendered::json(400, "Bad Request", body, keep))
    };
    let doc = match req.body_str().and_then(json::parse) {
        Ok(d) => d,
        Err(e) => return bad(&format!("bad JSON body: {e:#}"), keep),
    };
    let parsed = match api {
        Api::V1 => parse_infer_doc(&doc, ctx.default_tier),
        Api::V2 => parse_infer_doc_v2(&doc, ctx.default_tier),
    };
    match parsed {
        Ok(ireq) => RouteOutcome::Dispatch { ireq, api, keep },
        Err(msg) => bad(&msg, keep),
    }
}

/// `/v1/infer_batch` front half: NDJSON body → per-line parse results.
/// Line numbers are the client's own (interior blank lines preserved in
/// the numbering, skipped in the output).
fn route_infer_batch(req: &HttpRequest, ctx: &RouteCtx<'_>, keep: bool) -> RouteOutcome {
    let bad = |msg: &str| {
        RouteOutcome::Respond(Rendered::json(400, "Bad Request", err_body(msg), keep))
    };
    let text = match req.body_str() {
        Ok(t) => t,
        Err(e) => return bad(&format!("{e:#}")),
    };
    // enumerate BEFORE filtering so the "line" field in every result
    // refers to the client's own line numbers even when the input has
    // interior blank lines
    let lines: Vec<(usize, &str)> =
        text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty()).collect();
    if lines.is_empty() {
        return bad("empty NDJSON body");
    }
    if lines.len() > MAX_BATCH_LINES {
        return bad(&format!("too many lines ({}, max {MAX_BATCH_LINES})", lines.len()));
    }
    let mut out = Vec::with_capacity(lines.len());
    for (i, line) in &lines {
        let slot = match json::parse(line)
            .map_err(|e| format!("bad JSON line: {e:#}"))
            .and_then(|doc| parse_infer_doc(&doc, ctx.default_tier))
        {
            Ok(ireq) => BatchLine::Submit { line: *i, ireq },
            Err(msg) => BatchLine::Err { line: *i, msg },
        };
        out.push(slot);
    }
    RouteOutcome::DispatchBatch { lines: out, keep }
}

/// Render an admission rejection in the API's envelope (the back half
/// of a [`RouteOutcome::Dispatch`] that never reached a worker).
pub(crate) fn render_submit_err(api: Api, e: &SubmitError, tier: Tier, keep: bool) -> Rendered {
    match api {
        Api::V1 => match e {
            SubmitError::Busy { .. } | SubmitError::Overloaded { .. } => {
                let body = obj(vec![
                    ("error", s("busy")),
                    ("detail", s(&e.to_string())),
                    ("tier", s(tier.name())),
                ])
                .to_string_compact();
                Rendered::json(429, "Too Many Requests", body, keep)
            }
            SubmitError::ShutDown => Rendered::json(
                503,
                "Service Unavailable",
                err_body("server is shutting down"),
                false,
            ),
            // v1 never populates backend/placement overrides, but the
            // in-process option surface is shared — every variant is
            // named so a future rejection can't silently render as 400
            e @ (SubmitError::UnknownBackend { .. }
            | SubmitError::BackendUnavailable { .. }
            | SubmitError::InvalidOption { .. }
            | SubmitError::InvalidPlacement { .. }) => {
                Rendered::json(400, "Bad Request", err_body(&e.to_string()), keep)
            }
            e @ SubmitError::FleetCapacityExceeded { .. } => {
                Rendered::json(409, "Conflict", err_body(&e.to_string()), keep)
            }
        },
        Api::V2 => match e {
            SubmitError::UnknownBackend { requested, registered } => {
                let body = v2_err(
                    "unknown_backend",
                    &format!("unknown backend {requested:?}"),
                    vec![("backends", arr(registered.iter().map(|n| s(n))))],
                );
                Rendered::json(400, "Bad Request", body, keep)
            }
            SubmitError::BackendUnavailable { name, reason } => {
                let body = v2_err(
                    "backend_unavailable",
                    &format!("backend {name:?} is unavailable: {reason}"),
                    vec![],
                );
                Rendered::json(400, "Bad Request", body, keep)
            }
            e @ SubmitError::InvalidOption { .. } => Rendered::json(
                400,
                "Bad Request",
                v2_err("invalid_option", &e.to_string(), vec![]),
                keep,
            ),
            e @ SubmitError::InvalidPlacement { .. } => Rendered::json(
                400,
                "Bad Request",
                v2_err("invalid_placement", &e.to_string(), vec![]),
                keep,
            ),
            SubmitError::FleetCapacityExceeded { required_tiles, capacity_tiles } => {
                // 409, not 400: the request is well-formed — it conflicts
                // with the fleet's current capacity, which is operator-
                // changeable ([fleet] macros / residency_tiles)
                let body = v2_err(
                    "fleet_capacity_exceeded",
                    &e.to_string(),
                    vec![
                        ("required_tiles", num(*required_tiles as f64)),
                        ("capacity_tiles", num(*capacity_tiles as f64)),
                    ],
                );
                Rendered::json(409, "Conflict", body, keep)
            }
            e @ (SubmitError::Busy { .. } | SubmitError::Overloaded { .. }) => Rendered::json(
                429,
                "Too Many Requests",
                v2_err("busy", &e.to_string(), vec![("tier", s(tier.name()))]),
                keep,
            ),
            SubmitError::ShutDown => Rendered::json(
                503,
                "Service Unavailable",
                v2_err("shutting_down", "server is shutting down", vec![]),
                false,
            ),
        },
    }
}

/// Render a served response (which may still carry a worker error).
pub(crate) fn render_done(api: Api, resp: &crate::coordinator::Response, keep: bool) -> Rendered {
    if let Some(msg) = &resp.error {
        let body = match api {
            Api::V1 => err_body(msg),
            Api::V2 => v2_err("infer_failed", msg, vec![]),
        };
        return Rendered::json(500, "Internal Server Error", body, keep);
    }
    let mut o = response_json(resp);
    if api == Api::V2 {
        if let JsonValue::Object(map) = &mut o {
            map.insert("api".into(), s("v2"));
        }
    }
    Rendered::json(200, "OK", o.to_string_compact(), keep)
}

/// Render the bug-shaped 500 for a worker that dropped its response
/// channel.
pub(crate) fn render_channel_dropped(api: Api, keep: bool) -> Rendered {
    let body = match api {
        Api::V1 => err_body("response channel dropped"),
        Api::V2 => v2_err("internal", "response channel dropped", vec![]),
    };
    Rendered::json(500, "Internal Server Error", body, keep)
}

/// One NDJSON output line for a batch slot (`Err` = per-line error
/// string from parse/admission/transport, `Ok` = a served response).
pub(crate) fn batch_line_json(
    line: usize,
    result: std::result::Result<&crate::coordinator::Response, &str>,
) -> String {
    let o = match result {
        Err(msg) => obj(vec![("line", num(line as f64)), ("error", s(msg))]),
        Ok(resp) => match &resp.error {
            Some(msg) => obj(vec![("line", num(line as f64)), ("error", s(msg))]),
            None => {
                let mut o = response_json(resp);
                if let JsonValue::Object(map) = &mut o {
                    map.insert("line".into(), num(line as f64));
                }
                o
            }
        },
    };
    o.to_string_compact()
}

/// Assemble a finished batch (already in input order) into the NDJSON
/// response.
pub(crate) fn render_batch(body_lines: Vec<String>, keep: bool) -> Rendered {
    let mut out = String::new();
    for l in body_lines {
        out.push_str(&l);
        out.push('\n');
    }
    Rendered {
        status: 200,
        reason: "OK",
        content_type: "application/x-ndjson",
        extra: Vec::new(),
        body: out,
        keep,
    }
}

/// The keep-alive request loop for one connection — **threaded mode**
/// (DESIGN.md §10).  Returns when the peer closes, a read stalls past
/// the timeout, the request is malformed, the request asked for
/// `Connection: close`, or the gateway is shutting down — whichever
/// comes first.  Every response on the way out of the loop carries
/// `Connection: close`.
fn handle_conn(mut stream: TcpStream, ctx: &ConnCtx) {
    let _ = stream.set_read_timeout(ctx.opts.read_timeout);
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_nodelay(true);
    // ONE BufReader for the whole session: a pipelining client's next
    // request may already sit in the buffer, and a fresh reader per
    // request would silently drop it
    let mut reader = match stream.try_clone() {
        Ok(clone) => BufReader::new(clone),
        Err(e) => {
            log::debug!("cloning connection stream: {e}");
            return;
        }
    };
    loop {
        if ctx.stop.load(Ordering::SeqCst) {
            break;
        }
        let t_read = std::time::Instant::now();
        let req = match http::read_request_from(&mut reader, ctx.opts.request_deadline) {
            Ok(r) => r,
            // normal end of a keep-alive session
            Err(ReadError::Closed) => break,
            // idle keep-alive timeout: close silently; a stalled upload
            // gets told before the close (slowloris shed)
            Err(ReadError::TimedOut { mid_request }) => {
                if mid_request {
                    let r = Rendered::json(
                        408,
                        "Request Timeout",
                        err_body("request stalled mid-read"),
                        false,
                    );
                    write_rendered(&mut stream, &r);
                    linger_close(&stream, &mut reader);
                }
                break;
            }
            // protocol violation: answer 400 then drop the connection —
            // after a framing error the byte stream can't be trusted
            Err(ReadError::Malformed(msg)) => {
                let r = Rendered::json(400, "Bad Request", err_body(&msg), false);
                write_rendered(&mut stream, &r);
                // the rejected request's unread remainder (e.g. a body
                // we refused to frame) must not turn the 400 into an RST
                linger_close(&stream, &mut reader);
                break;
            }
            Err(ReadError::Io(e)) => {
                log::debug!("connection read failed: {e}");
                break;
            }
        };
        ctx.stats.requests.fetch_add(1, Ordering::Relaxed);
        // Parse span.  The blocking read starts before the request's
        // first byte exists, so in threaded mode this span includes the
        // wait on an idle keep-alive connection (the event loop anchors
        // at the true first byte instead).
        let telem = ctx.server.obs().clone();
        let rid = req
            .header("x-request-id")
            .and_then(obs::parse_rid)
            .unwrap_or_else(|| telem.mint_rid());
        let parse_dur_us = t_read.elapsed().as_micros() as u64;
        let now_us = obs::now_us();
        telem.parse_us.record(parse_dur_us);
        telem.span(
            rid,
            Stage::Parse,
            u8::MAX,
            u8::MAX,
            now_us.saturating_sub(parse_dur_us),
            parse_dur_us,
            &req.path,
        );
        // persist only when the gateway allows it, the request allows
        // it, and we aren't draining for shutdown
        let keep =
            ctx.opts.keep_alive && req.wants_keep_alive() && !ctx.stop.load(Ordering::SeqCst);
        let rctx = RouteCtx {
            server: &ctx.server,
            spec: &ctx.opts.spec,
            default_tier: ctx.opts.default_tier,
            stats: &ctx.stats,
            ev: None,
        };
        let mut tier_idx = u8::MAX;
        let rendered = match route(&req, &rctx, keep) {
            RouteOutcome::Respond(r) => r,
            RouteOutcome::Dispatch { ireq, api, keep } => {
                let tier = ireq.options.tier;
                tier_idx = tier.index() as u8;
                match dispatch(&ctx.server, ireq, rid) {
                    Dispatch::Rejected(e) => render_submit_err(api, &e, tier, keep),
                    Dispatch::ChannelDropped => render_channel_dropped(api, keep),
                    Dispatch::Done(resp) => render_done(api, &resp, keep),
                }
            }
            RouteOutcome::DispatchBatch { lines, keep } => {
                // submit phase: get every admissible line in flight
                // before waiting on any response — this is what lets one
                // HTTP request fill whole coordinator batches
                enum Pending {
                    Rx(usize, std::sync::mpsc::Receiver<crate::coordinator::Response>),
                    Err(usize, String),
                }
                let mut pending = Vec::with_capacity(lines.len());
                for l in lines {
                    pending.push(match l {
                        BatchLine::Err { line, msg } => Pending::Err(line, msg),
                        BatchLine::Submit { line, ireq } => {
                            // every line of one NDJSON batch shares the
                            // HTTP request's trace id
                            match ctx.server.submit_request_with_rid(ireq, rid) {
                                Ok(rx) => Pending::Rx(line, rx),
                                Err(e) => Pending::Err(line, e.to_string()),
                            }
                        }
                    });
                }
                // collect phase: input order, one NDJSON object per
                // non-blank line
                let mut body_lines = Vec::with_capacity(pending.len());
                for p in pending {
                    body_lines.push(match p {
                        Pending::Err(line, msg) => batch_line_json(line, Err(&msg)),
                        Pending::Rx(line, rx) => match rx.recv() {
                            Ok(resp) => batch_line_json(line, Ok(&resp)),
                            Err(_) => batch_line_json(line, Err("response channel dropped")),
                        },
                    });
                }
                render_batch(body_lines, keep)
            }
        };
        let write_start_us = obs::now_us();
        let wrote_ok = write_rendered_rid(&mut stream, &rendered, rid);
        let write_dur_us = obs::now_us().saturating_sub(write_start_us);
        telem.span(rid, Stage::Write, tier_idx, u8::MAX, write_start_us, write_dur_us, "");
        if (tier_idx as usize) < telem.tier_write_us.len() {
            telem.tier_write_us[tier_idx as usize].record(write_dur_us);
        }
        // a failed (possibly partial) write leaves the stream misframed:
        // the only safe continuation is no continuation
        if !wrote_ok || !rendered.keep {
            break;
        }
    }
}

/// Parse the `"image"` array of an infer document.
fn parse_image(doc: &JsonValue) -> std::result::Result<Vec<u8>, String> {
    let Some(pixels) = doc.get("image").and_then(JsonValue::as_array) else {
        return Err("missing \"image\" array".into());
    };
    if pixels.len() != IMAGE_BYTES {
        return Err(format!("image must be {IMAGE_BYTES} bytes, got {}", pixels.len()));
    }
    let mut image = Vec::with_capacity(IMAGE_BYTES);
    for p in pixels {
        // as_i64 would silently truncate 1.9 -> 1; demand true integers
        match p.as_f64() {
            Some(v) if v.fract() == 0.0 && (0.0..=255.0).contains(&v) => image.push(v as u8),
            _ => return Err("image values must be integers in 0..=255".into()),
        }
    }
    Ok(image)
}

/// Parse a tier name field; a present-but-invalid tier is a client
/// error, never a silent SLO downgrade.
fn parse_tier(v: &JsonValue, field: &str) -> std::result::Result<Tier, String> {
    let Some(name) = v.as_str() else {
        return Err(format!("{field:?} must be a string"));
    };
    Tier::parse(name).ok_or_else(|| format!("unknown tier {name:?} (gold|silver|batch)"))
}

/// Parse one **v1** infer document (`{"tier": optional, "image":
/// [u8; 3072]}`) into a typed [`InferRequest`]; the error string is
/// ready for a 400 / per-line error.  Shared by `/v1/infer` and
/// `/v1/infer_batch`.
fn parse_infer_doc(
    doc: &JsonValue,
    default_tier: Tier,
) -> std::result::Result<InferRequest, String> {
    let tier = match doc.get("tier") {
        None => default_tier,
        Some(v) => parse_tier(v, "tier")?,
    };
    Ok(InferRequest::new(parse_image(doc)?).with_tier(tier))
}

/// Parse one **v2** infer document: `{"image": [u8; 3072], "options":
/// {"tier": ..., "backend": ..., "seed": ..., "boundary": ...,
/// "placement": ...}}` — the wire twin of [`InferOptions`] (DESIGN.md
/// §12).  Like `backend`, the `placement` *name* is carried verbatim:
/// an unknown mode is rejected at admission with the typed
/// `invalid_placement` envelope, not a parse-stage 400.
fn parse_infer_doc_v2(
    doc: &JsonValue,
    default_tier: Tier,
) -> std::result::Result<InferRequest, String> {
    let image = parse_image(doc)?;
    let mut options = InferOptions { tier: default_tier, ..Default::default() };
    if let Some(o) = doc.get("options") {
        if !matches!(o, JsonValue::Object(_)) {
            return Err("\"options\" must be an object".into());
        }
        if let Some(v) = o.get("tier") {
            options.tier = parse_tier(v, "options.tier")?;
        }
        if let Some(v) = o.get("backend") {
            match v.as_str() {
                Some(name) => options.backend = Some(name.to_string()),
                None => return Err("\"options.backend\" must be a string".into()),
            }
        }
        if let Some(v) = o.get("placement") {
            match v.as_str() {
                Some(name) => options.placement = Some(name.to_string()),
                None => return Err("\"options.placement\" must be a string".into()),
            }
        }
        if let Some(v) = o.get("seed") {
            // the JSON substrate carries numbers as f64, which is only
            // exact up to 2^53 — beyond that distinct seeds would
            // silently collapse onto the same noise stream, so larger
            // values are rejected rather than rounded
            const SEED_MAX: f64 = (1u64 << 53) as f64;
            match v.as_f64() {
                Some(x) if x.fract() == 0.0 && (0.0..=SEED_MAX).contains(&x) => {
                    options.noise_seed = Some(x as u64)
                }
                _ => {
                    return Err(
                        "\"options.seed\" must be a non-negative integer <= 2^53".into()
                    )
                }
            }
        }
        if let Some(v) = o.get("boundary") {
            match v.as_f64() {
                Some(x) if x.fract() == 0.0 && (0.0..16.0).contains(&x) => {
                    options.boundary = Some(x as i32)
                }
                _ => return Err("\"options.boundary\" must be an integer in 0..=15".into()),
            }
        }
    }
    Ok(InferRequest { image, options })
}

/// A served response as a JSON object (shared by every infer route).
fn response_json(resp: &crate::coordinator::Response) -> JsonValue {
    obj(vec![
        ("id", num(resp.id as f64)),
        ("tier", s(resp.tier.name())),
        ("backend", s(&resp.backend)),
        ("pred", num(resp.pred as f64)),
        // logits scrubbed through fnum: a NaN logit (aggressive ACIM
        // noise) must not corrupt the whole JSON payload
        ("logits", arr(resp.logits.iter().map(|&x| fnum(x as f64)))),
        ("latency_us", num(resp.latency.as_micros() as f64)),
        ("batch_size", num(resp.batch_size as f64)),
        // modeled joules attributed to this request (its equal share of
        // the coalesced batch's forward energy)
        ("energy_j", fnum(resp.energy_j)),
    ])
}

/// How one dispatched request ended — the shared submit/await core
/// behind `/v1/infer` and `/v2/infer`; only the JSON rendering differs
/// per API version.
enum Dispatch {
    /// Served (the response may still carry a worker error).
    Done(Box<crate::coordinator::Response>),
    /// Rejected at admission.
    Rejected(SubmitError),
    /// The worker dropped the response channel (bug-shaped 500).
    ChannelDropped,
}

fn dispatch(server: &Server, req: InferRequest, rid: u64) -> Dispatch {
    match server.submit_request_with_rid(req, rid) {
        Err(e) => Dispatch::Rejected(e),
        Ok(rx) => match rx.recv() {
            Ok(resp) => Dispatch::Done(Box::new(resp)),
            Err(_) => Dispatch::ChannelDropped,
        },
    }
}

/// The machine-readable `/v2` error envelope:
/// `{"error": {"code": ..., "message": ..., ...extra}}`.
fn v2_err(code: &str, message: &str, extra: Vec<(&str, JsonValue)>) -> String {
    let mut fields = vec![("code", s(code)), ("message", s(message))];
    fields.extend(extra);
    obj(vec![("error", obj(fields))]).to_string_compact()
}

fn hist_json(h: &[u64; 16]) -> JsonValue {
    arr(h.iter().map(|&c| num(c as f64)))
}

/// A JSON number that is guaranteed well-formed: non-finite derived
/// stats (e.g. a ratio on a server that served nothing yet) serialize
/// as `0.0` instead of emitting a literal `NaN`/`inf` token that would
/// corrupt the whole `/metrics` payload.
fn fnum(x: f64) -> JsonValue {
    num(if x.is_finite() { x } else { 0.0 })
}

/// The `/metrics` document (also reused by the pipeline bench).
/// `conns` adds the gateway's connection-lifecycle counters when the
/// snapshot is taken through the HTTP surface.
pub fn metrics_json(server: &Server, spec: &MacroSpec, conns: Option<&ConnStats>) -> JsonValue {
    let m = server.metrics();
    let depths = server.queue_depths();
    let gov = server.governor();
    let telem = server.obs();
    let mut tier_objs = Vec::new();
    for tier in Tier::ALL {
        let t = m.tier(tier);
        let i = tier.index();
        let queue = telem.tier_queue_us[i].snapshot();
        let exec = telem.tier_exec_us[i].snapshot();
        let write = telem.tier_write_us[i].snapshot();
        tier_objs.push((
            tier.name(),
            obj(vec![
                ("requests", num(t.requests as f64)),
                ("errors", num(t.errors as f64)),
                ("rejected", num(t.rejected as f64)),
                ("queue_depth", num(depths[i] as f64)),
                ("p50_latency_us", fnum(t.p50_latency_us())),
                ("p99_latency_us", fnum(t.p99_latency_us())),
                ("mean_boundary", fnum(t.mean_boundary())),
                ("b_hist", hist_json(&t.b_hist)),
                // stage breakdown: where this tier's time actually goes
                ("p50_queue_us", fnum(queue.percentile(0.50))),
                ("p99_queue_us", fnum(queue.percentile(0.99))),
                ("p50_exec_us", fnum(exec.percentile(0.50))),
                ("p99_exec_us", fnum(exec.percentile(0.99))),
                ("p50_write_us", fnum(write.percentile(0.50))),
                ("p99_write_us", fnum(write.percentile(0.99))),
            ]),
        ));
    }
    // every emitted float goes through fnum — including the governor's
    // integral-by-construction gauges, so the scrub holds even if a
    // future contract carries derived floats
    let gov_tiers: Vec<(&str, JsonValue)> = gov
        .tiers
        .iter()
        .map(|c| {
            (
                c.tier.name(),
                obj(vec![
                    ("profile", s(c.profile)),
                    ("level", fnum(c.level as f64)),
                    // configured max_level, further capped by the
                    // swept device floors (DESIGN.md §16)
                    ("level_cap", fnum(c.level_cap as f64)),
                    ("thresholds", arr(c.thresholds.iter().map(|&t| fnum(t as f64)))),
                ]),
            )
        })
        .collect();
    let layers = telem.layer_snapshot();
    let layer_objs: Vec<(&str, JsonValue)> = layers
        .iter()
        .map(|(name, st)| {
            (
                name.as_str(),
                obj(vec![
                    ("calls", num(st.calls as f64)),
                    ("exec_us", num(st.exec_us as f64)),
                    ("energy_j", fnum(st.energy_j)),
                    // per-memory-level movement share of energy_j
                    // (LEVEL_NAMES order; all-zero under "compact")
                    ("movement_j", arr(st.movement_j.iter().map(|&j| fnum(j)))),
                    ("macro_ops", num(st.macro_ops as f64)),
                ]),
            )
        })
        .collect();
    let mut fields = vec![
        ("requests", num(m.requests as f64)),
        ("batches", num(m.batches as f64)),
        ("errors", num(m.errors as f64)),
        ("rejected", num(m.rejected as f64)),
        ("mean_batch", fnum(m.mean_batch())),
        ("p50_latency_us", fnum(m.p50_latency_us())),
        ("p95_latency_us", fnum(m.p95_latency_us())),
        ("p99_latency_us", fnum(m.p99_latency_us())),
        ("throughput_rps", fnum(m.throughput_rps())),
        ("tops_per_watt", fnum(m.tops_per_watt(spec))),
        ("watts", fnum(m.account.watts())),
        (
            "energy",
            obj(vec![
                // which cost model priced the account ("compact" keeps
                // the pre-PR-9 per-op pricing bit-for-bit)
                ("model", s(&server.engine().config().hardware_model)),
                ("total_j", fnum(m.account.total_energy_j())),
                ("movement_fj", fnum(m.account.breakdown.movement_total_fj())),
                (
                    "movement_levels_fj",
                    obj(LEVEL_NAMES
                        .iter()
                        .zip(&m.account.breakdown.movement_fj)
                        .map(|(n, &fj)| (*n, fnum(fj)))
                        .collect()),
                ),
                ("transfer_fj", fnum(m.account.transfer_fj)),
                (
                    "per_inference_j",
                    fnum(m.account.total_energy_j() / m.requests as f64),
                ),
            ]),
        ),
        (
            "fleet",
            obj(vec![
                ("macros", num(server.engine().config().fleet_macros.max(1) as f64)),
                ("transfer_energy_fj", fnum(m.account.transfer_fj)),
                ("transfer_hops", num(m.account.transfer_hops as f64)),
                ("transfer_fraction", fnum(m.account.transfer_fraction())),
            ]),
        ),
        ("b_hist", hist_json(&m.b_hist)),
        ("tiers", obj(tier_objs)),
        (
            "governor",
            obj(vec![
                ("enabled", JsonValue::Bool(gov.enabled)),
                ("transitions", fnum(gov.transitions as f64)),
                // device-corner floors feeding the level caps above
                (
                    "floors",
                    obj(vec![
                        (
                            "loaded",
                            JsonValue::Bool(gov.floors.caps.iter().any(|&c| c != u32::MAX)),
                        ),
                        ("corner_sigma", fnum(gov.floors.corner_sigma)),
                    ]),
                ),
                ("tiers", obj(gov_tiers)),
            ]),
        ),
        ("layers", obj(layer_objs)),
        (
            "obs",
            obj(vec![
                ("trace_enabled", JsonValue::Bool(telem.trace_enabled())),
                ("trace_capacity", num(telem.trace_capacity() as f64)),
                ("spans_recorded", num(telem.spans_recorded() as f64)),
                ("spans_dropped", num(telem.spans_dropped() as f64)),
                ("slow_ms", num((telem.slow_us() / 1000) as f64)),
                ("heap_bytes", num(telem.heap_bytes() as f64)),
            ]),
        ),
    ];
    if let Some(c) = conns {
        fields.push((
            "connections",
            obj(vec![
                ("accepted", num(c.accepted.load(Ordering::Relaxed) as f64)),
                ("rejected", num(c.rejected.load(Ordering::Relaxed) as f64)),
                ("http_requests", num(c.requests.load(Ordering::Relaxed) as f64)),
                ("reuse_rate", fnum(c.reuse_rate())),
            ]),
        ));
    }
    obj(fields)
}

/// `/metrics` content negotiation: an explicit `?format=` query wins,
/// then the `Accept` header; the default stays JSON (the pre-existing
/// contract, so old scrapers keep working unchanged).
fn wants_prometheus(query: &str, accept: Option<&str>) -> bool {
    for pair in query.split('&') {
        if let Some(v) = pair.strip_prefix("format=") {
            return v.eq_ignore_ascii_case("prometheus");
        }
    }
    match accept {
        Some(a) => {
            let a = a.to_ascii_lowercase();
            (a.contains("text/plain") || a.contains("openmetrics"))
                && !a.contains("application/json")
        }
        None => false,
    }
}

/// The `/metrics` document in Prometheus text exposition format
/// (`?format=prometheus`, content type [`obs::PROM_CONTENT_TYPE`]).
/// Metric names, labels and the bucket scheme are documented in
/// DESIGN.md §13 and pinned by the exposition round-trip test; every
/// value passes through the writer's non-finite scrub.
pub fn metrics_prometheus(
    server: &Server,
    spec: &MacroSpec,
    conns: Option<&ConnStats>,
    ev: Option<&EventLoopStats>,
) -> String {
    let m = server.metrics();
    let depths = server.queue_depths();
    let gov = server.governor();
    let telem = server.obs();
    let mut w = obs::PromWriter::new();
    w.counter("osa_requests_total", "Inference requests served.", &[], m.requests as f64);
    w.counter("osa_batches_total", "Coalesced batches executed.", &[], m.batches as f64);
    w.counter("osa_errors_total", "Requests that failed in a worker.", &[], m.errors as f64);
    w.counter("osa_rejected_total", "Requests rejected at admission.", &[], m.rejected as f64);
    w.gauge("osa_mean_batch", "Mean coalesced batch size.", &[], m.mean_batch());
    w.gauge("osa_throughput_rps", "Requests per second of serving time.", &[], m.throughput_rps());
    w.gauge(
        "osa_tops_per_watt",
        "Modeled efficiency at the macro spec.",
        &[],
        m.tops_per_watt(spec),
    );
    w.gauge("osa_watts", "Modeled macro power draw.", &[], m.account.watts());
    // energy by (component, level): the six macro components price at
    // the macro itself; movement prices per memory-hierarchy level
    // (all-zero under the "compact" cost model); split-K transfer
    // prices on the inter-macro interconnect
    const ENERGY_HELP: &str = "Modeled energy by component and memory level.";
    let b = &m.account.breakdown;
    for (component, fj) in [
        ("digital", b.digital_fj),
        ("adc", b.adc_fj),
        ("dac", b.dac_fj),
        ("nq", b.nq_fj),
        ("ose", b.ose_fj),
        ("ctrl", b.ctrl_fj),
    ] {
        w.counter(
            "osa_energy_joules_total",
            ENERGY_HELP,
            &[("component", component.to_string()), ("level", "macro".to_string())],
            fj * 1e-15,
        );
    }
    for (name, &fj) in LEVEL_NAMES.iter().zip(&b.movement_fj) {
        w.counter(
            "osa_energy_joules_total",
            ENERGY_HELP,
            &[("component", "movement".to_string()), ("level", name.to_string())],
            fj * 1e-15,
        );
    }
    w.counter(
        "osa_energy_joules_total",
        ENERGY_HELP,
        &[("component", "transfer".to_string()), ("level", "interconnect".to_string())],
        m.account.transfer_fj * 1e-15,
    );
    w.gauge(
        "osa_energy_per_inference_joules",
        "Mean modeled energy per served request.",
        &[],
        m.account.total_energy_j() / m.requests as f64,
    );
    w.counter(
        "osa_fleet_transfer_hops_total",
        "Inter-macro partial-sum hops charged by split-K layers.",
        &[],
        m.account.transfer_hops as f64,
    );
    w.counter(
        "osa_fleet_transfer_femtojoules_total",
        "Modeled inter-macro partial-sum transfer energy.",
        &[],
        m.account.transfer_fj,
    );
    w.gauge(
        "osa_fleet_transfer_fraction",
        "Transfer share of total modeled energy.",
        &[],
        m.account.transfer_fraction(),
    );
    for tier in Tier::ALL {
        let t = m.tier(tier);
        let i = tier.index();
        let lbl = [("tier", tier.name().to_string())];
        w.counter("osa_tier_requests_total", "Requests served per tier.", &lbl, t.requests as f64);
        w.counter("osa_tier_errors_total", "Worker failures per tier.", &lbl, t.errors as f64);
        w.counter(
            "osa_tier_rejected_total",
            "Admission rejections per tier.",
            &lbl,
            t.rejected as f64,
        );
        w.gauge("osa_queue_depth", "Requests waiting in the tier queue.", &lbl, depths[i] as f64);
        w.histogram(
            "osa_tier_latency_microseconds",
            "End-to-end latency per tier.",
            &lbl,
            &telem.tier_latency_us[i].snapshot(),
        );
        for (stage, h) in [
            ("queue", &telem.tier_queue_us[i]),
            ("exec", &telem.tier_exec_us[i]),
            ("write", &telem.tier_write_us[i]),
        ] {
            w.histogram(
                "osa_stage_duration_microseconds",
                "Per-stage request time (queue wait, execution, response write).",
                &[("tier", tier.name().to_string()), ("stage", stage.to_string())],
                &h.snapshot(),
            );
        }
    }
    w.histogram(
        "osa_request_latency_microseconds",
        "End-to-end request latency across all tiers.",
        &[],
        &telem.latency_us.snapshot(),
    );
    w.histogram(
        "osa_parse_duration_microseconds",
        "HTTP request parse span duration.",
        &[],
        &telem.parse_us.snapshot(),
    );
    for (b, &c) in m.b_hist.iter().enumerate() {
        w.counter(
            "osa_boundary_served_total",
            "Requests served per saliency boundary.",
            &[("b", b.to_string())],
            c as f64,
        );
    }
    w.gauge(
        "osa_governor_enabled",
        "Whether the precision governor is active.",
        &[],
        if gov.enabled { 1.0 } else { 0.0 },
    );
    w.counter(
        "osa_governor_transitions_total",
        "Governor level changes (escalations + recoveries).",
        &[],
        gov.transitions as f64,
    );
    for c in &gov.tiers {
        w.gauge(
            "osa_governor_level",
            "Current degrade level per tier (0 = base contract).",
            &[("tier", c.tier.name().to_string())],
            c.level as f64,
        );
        w.gauge(
            "osa_governor_level_cap",
            "Highest degrade level allowed per tier (max_level capped by device floors).",
            &[("tier", c.tier.name().to_string())],
            c.level_cap as f64,
        );
        for (i, &t) in c.thresholds.iter().enumerate() {
            w.gauge(
                "osa_governor_threshold",
                "Effective OSE threshold per layer-group index.",
                &[("tier", c.tier.name().to_string()), ("index", i.to_string())],
                t as f64,
            );
        }
    }
    for (name, st) in telem.layer_snapshot() {
        let lbl = [("layer", name.clone())];
        w.counter("osa_layer_calls_total", "Layer executions.", &lbl, st.calls as f64);
        w.counter(
            "osa_layer_exec_microseconds_total",
            "Cumulative layer execution time.",
            &lbl,
            st.exec_us as f64,
        );
        w.counter(
            "osa_layer_energy_joules_total",
            "Cumulative modeled layer energy.",
            &lbl,
            st.energy_j,
        );
        w.counter(
            "osa_layer_macro_ops_total",
            "Cumulative CIM macro operations per layer.",
            &lbl,
            st.macro_ops as f64,
        );
    }
    if let Some(c) = conns {
        w.counter(
            "osa_connections_accepted_total",
            "Connections claimed by the gateway.",
            &[],
            c.accepted.load(Ordering::Relaxed) as f64,
        );
        w.counter(
            "osa_connections_rejected_total",
            "Connections refused at admission.",
            &[],
            c.rejected.load(Ordering::Relaxed) as f64,
        );
        w.counter(
            "osa_http_requests_total",
            "HTTP requests across all connections.",
            &[],
            c.requests.load(Ordering::Relaxed) as f64,
        );
        w.gauge(
            "osa_connection_reuse_rate",
            "Fraction of requests on a reused connection.",
            &[],
            c.reuse_rate(),
        );
    }
    if let Some(ev) = ev {
        let g = |c: &AtomicU64| c.load(Ordering::Relaxed) as f64;
        w.gauge(
            "osa_event_loop_open_connections",
            "Admitted connections registered with the poller.",
            &[],
            g(&ev.open_connections),
        );
        w.gauge(
            "osa_event_loop_parked_connections",
            "Accepted connections awaiting a slot.",
            &[],
            g(&ev.parked_connections),
        );
        w.counter("osa_event_loop_wakeups_total", "Poller returns.", &[], g(&ev.wakeups));
        w.counter(
            "osa_event_loop_eagain_reads_total",
            "Reads that hit EAGAIN.",
            &[],
            g(&ev.eagain_reads),
        );
        w.counter(
            "osa_event_loop_eagain_writes_total",
            "Writes that hit EAGAIN.",
            &[],
            g(&ev.eagain_writes),
        );
        w.counter(
            "osa_event_loop_deadline_expirations_total",
            "Connection deadlines that fired.",
            &[],
            g(&ev.deadline_expirations),
        );
        w.gauge(
            "osa_event_loop_buffer_pool_hit_rate",
            "Buffer acquisitions served by the pool.",
            &[],
            ev.pool_hit_rate(),
        );
    }
    w.counter(
        "osa_trace_spans_recorded_total",
        "Trace spans written to the ring.",
        &[],
        telem.spans_recorded() as f64,
    );
    w.counter(
        "osa_trace_spans_dropped_total",
        "Trace spans dropped on slot contention.",
        &[],
        telem.spans_dropped() as f64,
    );
    w.finish()
}

/// [`metrics_json`] plus the event-loop gauges when the snapshot is
/// taken through an event-mode gateway.  Everything goes through
/// `fnum` so a pathological counter can never emit a non-finite token.
pub(crate) fn metrics_json_ev(
    server: &Server,
    spec: &MacroSpec,
    conns: Option<&ConnStats>,
    ev: Option<&EventLoopStats>,
) -> JsonValue {
    let mut doc = metrics_json(server, spec, conns);
    if let Some(ev) = ev {
        if let JsonValue::Object(map) = &mut doc {
            let g = |c: &AtomicU64| fnum(c.load(Ordering::Relaxed) as f64);
            map.insert(
                "event_loop".into(),
                obj(vec![
                    ("open_connections", g(&ev.open_connections)),
                    ("parked_connections", g(&ev.parked_connections)),
                    ("wakeups", g(&ev.wakeups)),
                    ("eagain_reads", g(&ev.eagain_reads)),
                    ("eagain_writes", g(&ev.eagain_writes)),
                    ("deadline_expirations", g(&ev.deadline_expirations)),
                    ("buffer_pool_hit_rate", fnum(ev.pool_hit_rate())),
                ]),
            );
        }
    }
    doc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnum_scrubs_non_finite() {
        assert_eq!(fnum(f64::NAN).to_string_compact(), "0");
        assert_eq!(fnum(f64::INFINITY).to_string_compact(), "0");
        assert_eq!(fnum(f64::NEG_INFINITY).to_string_compact(), "0");
        assert_eq!(fnum(2.5).to_string_compact(), "2.5");
    }

    #[test]
    fn metrics_content_negotiation() {
        // explicit query parameter wins over everything
        assert!(wants_prometheus("format=prometheus", None));
        assert!(wants_prometheus("format=Prometheus", Some("application/json")));
        assert!(!wants_prometheus("format=json", Some("text/plain")));
        // Accept header decides when no format= is given
        assert!(wants_prometheus("", Some("text/plain")));
        assert!(wants_prometheus("", Some("application/openmetrics-text")));
        assert!(!wants_prometheus("", Some("application/json")));
        assert!(!wants_prometheus("", Some("text/plain, application/json")));
        // the default stays JSON: pre-PR-7 scrapers see no change
        assert!(!wants_prometheus("", None));
        assert!(!wants_prometheus("n=5", None));
    }

    /// NaN injection: a non-finite value handed to the exposition
    /// writer must scrub to 0, not corrupt the scrape (the same
    /// contract `fnum` enforces on the JSON side).
    #[test]
    fn prometheus_writer_scrubs_injected_nan() {
        let mut w = obs::PromWriter::new();
        w.gauge("osa_test_gauge", "injected", &[], f64::NAN);
        w.counter("osa_test_total", "injected", &[], f64::INFINITY);
        let text = w.finish();
        assert!(!text.contains("NaN") && !text.contains("inf"), "{text}");
        let exp = obs::parse_exposition(&text).expect("valid exposition");
        assert_eq!(exp.value("osa_test_gauge", &[]), Some(0.0));
        assert_eq!(exp.value("osa_test_total", &[]), Some(0.0));
    }

    #[test]
    fn rendered_echoes_request_id() {
        let r = Rendered::json(200, "OK", "{}".into(), true);
        let mut out = Vec::new();
        r.to_bytes_with_rid(&mut out, 0x2a);
        let head = String::from_utf8_lossy(&out);
        assert!(head.contains("X-Request-Id: req-000000000000002a\r\n"), "{head}");
        // rid 0 = untraced response: no header
        let mut out = Vec::new();
        r.to_bytes(&mut out);
        assert!(!String::from_utf8_lossy(&out).contains("X-Request-Id"));
    }
}
