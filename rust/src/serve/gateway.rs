//! Network gateway: a `std::net::TcpListener` HTTP/1.1 front-end over
//! the tier-aware coordinator (DESIGN.md §10) with **persistent
//! connections**.
//!
//! Routes:
//! * `POST /v1/infer` — body `{"tier": "gold|silver|batch", "image":
//!   [3072 uint8]}`; answers the prediction, or `429 Busy` when the
//!   tier's bounded queue is full (explicit backpressure), `400` on
//!   malformed input, `500` when the worker's forward failed.
//! * `POST /v1/infer_batch` — NDJSON: one `{"tier": ..., "image":
//!   [...]}` object per line (tier optional per line, default silver;
//!   blank lines skipped).  Answers NDJSON, one result (or per-line
//!   error) per non-blank input line, in order, each tagged with its
//!   original input line number (`"line"`).  Batch-tier clients
//!   amortize connection AND request-parse cost across many images.
//! * `GET /metrics` — JSON snapshot: aggregate + per-tier latency
//!   percentiles, boundary histograms, queue depths, rejection counts,
//!   connection/reuse counters and the governor's current per-tier
//!   precision contracts.
//! * `GET /healthz` — liveness probe.
//!
//! Threading: one accept thread feeding a **bounded connection-worker
//! pool** (`[serve] max_conns` workers, same pattern as `sched::exec`)
//! through an accept backlog of the same depth.  A connection past the
//! backlog is answered `429` and closed — the connection-level twin of
//! the QoS queues' `SubmitError` admission.  Each worker runs the
//! keep-alive loop: read request (per-read timeout + whole-request
//! slowloris deadline), dispatch, respond `Connection: keep-alive`
//! until the client closes, errs, stalls, asks for `close`, or the
//! gateway shuts down.  Graceful [`Gateway::shutdown`] stops accepting,
//! finishes in-flight requests (responses carry `Connection: close`),
//! nudges idle keep-alive readers awake, then drains the coordinator.

use super::http::{self, HttpRequest, ReadError};
use super::qos::{SubmitError, Tier};
use crate::config::SystemConfig;
use crate::coordinator::{Metrics, Server};
use crate::engine::{Engine, InferOptions, InferRequest};
use crate::io::json::{self, arr, num, obj, s, JsonValue};
use crate::nn::QGraph;
use crate::spec::MacroSpec;
use anyhow::{Context, Result};
use std::collections::{HashMap, VecDeque};
use std::io::BufReader;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

/// Expected image payload: 32x32x3 uint8.
pub const IMAGE_BYTES: usize = 32 * 32 * 3;

/// Hard cap on `/v1/infer_batch` lines per request (the body-size bound
/// already limits this in practice; the explicit cap keeps the error
/// message honest).
pub const MAX_BATCH_LINES: usize = 256;

/// Connection-level counters (all monotonic; snapshot via `/metrics`).
#[derive(Debug, Default)]
pub struct ConnStats {
    /// Connections claimed by a connection worker.
    pub accepted: AtomicU64,
    /// Connections refused at admission (backlog full -> 429 + close).
    pub rejected: AtomicU64,
    /// HTTP requests served across all connections.
    pub requests: AtomicU64,
}

impl ConnStats {
    /// Fraction of requests that rode a reused connection:
    /// `1 - connections/requests`.  0 when every request paid a fresh
    /// TCP setup (the old one-shot gateway), -> 1 as keep-alive clients
    /// amortize the connection across many requests.
    pub fn reuse_rate(&self) -> f64 {
        let conns = self.accepted.load(Ordering::Relaxed);
        let reqs = self.requests.load(Ordering::Relaxed);
        if reqs == 0 {
            return 0.0;
        }
        1.0 - conns.min(reqs) as f64 / reqs as f64
    }
}

/// Connection-lifecycle knobs resolved from [`SystemConfig`].
#[derive(Debug, Clone, Copy)]
struct ConnOpts {
    keep_alive: bool,
    /// Per-read socket timeout (None = wait forever).
    read_timeout: Option<Duration>,
    /// Whole-request deadline (slowloris guard; ZERO = disabled).
    request_deadline: Duration,
    spec: MacroSpec,
    /// Tier assumed when a request names none (`[serve] default_tier`).
    default_tier: Tier,
}

/// Bounded queue of accepted-but-unclaimed connections (the accept
/// backlog).  Push past the bound fails fast — the accept thread
/// answers 429 — mirroring the QoS tier queues.
struct ConnQueue {
    state: Mutex<(VecDeque<TcpStream>, bool)>,
    cv: Condvar,
    cap: usize,
}

impl ConnQueue {
    fn new(cap: usize) -> Self {
        Self { state: Mutex::new((VecDeque::new(), false)), cv: Condvar::new(), cap }
    }

    /// Admit one connection, or hand it back when the backlog is full
    /// or the queue is closed.
    fn push(&self, stream: TcpStream) -> std::result::Result<(), TcpStream> {
        let mut st = self.state.lock().unwrap();
        if st.1 || st.0.len() >= self.cap {
            return Err(stream);
        }
        st.0.push_back(stream);
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Block for the next connection; `None` once closed (queued
    /// connections left at close are dropped — they have no in-flight
    /// requests to finish).
    fn pop(&self) -> Option<TcpStream> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.1 {
                return None;
            }
            if let Some(s) = st.0.pop_front() {
                return Some(s);
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Stop handing out connections and drop anything still queued.
    fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.1 = true;
        st.0.clear();
        drop(st);
        self.cv.notify_all();
    }
}

/// Everything a connection worker needs.
struct ConnCtx {
    server: Arc<Server>,
    opts: ConnOpts,
    stats: Arc<ConnStats>,
    /// Read-half clones of every connection currently inside a worker,
    /// keyed by a serial id: shutdown nudges blocked keep-alive readers
    /// awake via `Shutdown::Read` without touching in-flight writes.
    active: Mutex<HashMap<u64, TcpStream>>,
    next_conn: AtomicU64,
    stop: AtomicBool,
}

/// The serving gateway (listener + connection pool + coordinator).
pub struct Gateway {
    ctx: Arc<ConnCtx>,
    queue: Arc<ConnQueue>,
    addr: SocketAddr,
    accept: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Gateway {
    /// Bind `listen` and serve a default [`Engine`] built for the
    /// config (convenience over [`Gateway::with_engine`]).
    pub fn start(cfg: &SystemConfig, graph: Arc<QGraph>, listen: &str) -> Result<Gateway> {
        let engine = Engine::builder().config(cfg.clone()).graph(graph).build()?;
        Self::with_engine(Arc::new(engine), listen)
    }

    /// Bind `listen` (e.g. `127.0.0.1:8080`, port 0 for ephemeral) and
    /// start serving on an assembled engine.
    pub fn with_engine(engine: Arc<Engine>, listen: &str) -> Result<Gateway> {
        let cfg = engine.config().clone();
        // bind first: a failed bind (port in use) must not leave a live
        // batcher + worker pool behind with nothing to shut them down
        let listener =
            TcpListener::bind(listen).with_context(|| format!("binding {listen}"))?;
        let addr = listener.local_addr().context("local_addr")?;
        let server = Arc::new(Server::with_engine(engine)?);
        let read_timeout = match cfg.read_timeout_ms {
            0 => None,
            ms => Some(Duration::from_millis(ms)),
        };
        let opts = ConnOpts {
            keep_alive: cfg.keep_alive,
            read_timeout,
            // a request must complete within a few read-timeouts even if
            // the peer trickles bytes to keep each individual read alive
            request_deadline: read_timeout.map(|t| t * 4).unwrap_or(Duration::ZERO),
            spec: cfg.spec,
            default_tier: cfg.default_tier,
        };
        let ctx = Arc::new(ConnCtx {
            server,
            opts,
            stats: Arc::new(ConnStats::default()),
            active: Mutex::new(HashMap::new()),
            next_conn: AtomicU64::new(0),
            stop: AtomicBool::new(false),
        });
        let max_conns = cfg.max_conns.max(1);
        let queue = Arc::new(ConnQueue::new(max_conns));
        let mut workers = Vec::with_capacity(max_conns);
        for wid in 0..max_conns {
            let ctx = ctx.clone();
            let queue = queue.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("gateway-conn-{wid}"))
                    .spawn(move || conn_worker(&ctx, &queue))
                    .context("spawning connection worker")?,
            );
        }
        // Bounded budget of concurrent rejection threads: each 429 is
        // written + linger-closed off the accept thread (so a flood
        // cannot stall accepts), but never with unbounded thread growth
        // — past the budget a connection is shed silently, which is the
        // honest signal at that level of overload.
        const MAX_REJECTORS: u64 = 32;
        let rejectors = Arc::new(AtomicU64::new(0));
        let accept = std::thread::Builder::new()
            .name("gateway-accept".into())
            .spawn({
                let ctx = ctx.clone();
                let queue = queue.clone();
                let rejectors = rejectors.clone();
                move || {
                    for incoming in listener.incoming() {
                        if ctx.stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let stream = match incoming {
                            Ok(s) => s,
                            Err(e) => {
                                log::warn!("accept failed: {e}");
                                continue;
                            }
                        };
                        if let Err(mut stream) = queue.push(stream) {
                            // connection-level admission: the pool and
                            // its backlog are full — same explicit-429
                            // contract as the QoS tier queues.  The
                            // write + lingering close run on a short
                            // detached thread so the accept loop stays
                            // fast exactly when it is being flooded.
                            ctx.stats.rejected.fetch_add(1, Ordering::Relaxed);
                            if rejectors.load(Ordering::Relaxed) >= MAX_REJECTORS {
                                // even the rejection budget is gone:
                                // shed silently (drop = RST)
                                continue;
                            }
                            rejectors.fetch_add(1, Ordering::Relaxed);
                            let rejectors = rejectors.clone();
                            let e = SubmitError::Overloaded { max_conns };
                            let body = obj(vec![
                                ("error", s("busy")),
                                ("detail", s(&e.to_string())),
                            ])
                            .to_string_compact();
                            std::thread::spawn(move || {
                                let _ =
                                    stream.set_write_timeout(Some(Duration::from_secs(2)));
                                let _ = http::write_response(
                                    &mut stream,
                                    429,
                                    "Too Many Requests",
                                    "application/json",
                                    body.as_bytes(),
                                    false,
                                );
                                // the peer's request was never read at
                                // all: drain briefly so the 429 is not
                                // destroyed by an RST
                                linger_close(&stream, &mut (&stream));
                                rejectors.fetch_sub(1, Ordering::Relaxed);
                            });
                        }
                    }
                }
            })
            .context("spawning accept loop")?;
        log::info!(
            "gateway listening on {addr} (keep_alive={}, max_conns={max_conns})",
            cfg.keep_alive
        );
        Ok(Gateway { ctx, queue, addr, accept: Some(accept), workers })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connection-level counters (accepted / rejected / requests).
    pub fn conn_stats(&self) -> Arc<ConnStats> {
        self.ctx.stats.clone()
    }

    /// Block until the accept loop exits (i.e. until shutdown or
    /// process death) — the `osa-hcim serve --listen` foreground mode.
    pub fn wait(mut self) {
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
    }

    /// Stop accepting, finish in-flight requests (drain), then drain
    /// the coordinator.  Returns the final serving metrics.
    pub fn shutdown(mut self) -> Metrics {
        self.ctx.stop.store(true, Ordering::SeqCst);
        // unblock the accept loop with one last connection
        let _ = TcpStream::connect(self.addr);
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // no new connections reach the workers; queued-but-idle ones are
        // dropped (they have no in-flight requests)
        self.queue.close();
        // wake workers blocked waiting for the NEXT request of an idle
        // keep-alive session: shutting down the read half makes their
        // blocked read return EOF (a clean request boundary) without
        // disturbing a response that is still being written
        {
            let active = self.ctx.active.lock().unwrap();
            for stream in active.values() {
                let _ = stream.shutdown(Shutdown::Read);
            }
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        match Arc::try_unwrap(self.ctx) {
            Ok(ctx) => match Arc::try_unwrap(ctx.server) {
                Ok(server) => server.shutdown(),
                Err(server) => server.metrics(),
            },
            // a straggler still holds a handle; fall back to a snapshot
            Err(ctx) => ctx.server.metrics(),
        }
    }
}

fn conn_worker(ctx: &ConnCtx, queue: &ConnQueue) {
    while let Some(stream) = queue.pop() {
        ctx.stats.accepted.fetch_add(1, Ordering::Relaxed);
        let id = ctx.next_conn.fetch_add(1, Ordering::Relaxed);
        // register the read half BEFORE the first blocking read so a
        // concurrent shutdown can always nudge this connection
        if let Ok(clone) = stream.try_clone() {
            ctx.active.lock().unwrap().insert(id, clone);
        }
        // Panic containment, same invariant as the `sched::exec` pool
        // this design mirrors: one panicking handler loses ITS
        // connection, never a pool worker — an uncontained panic would
        // permanently shrink the bounded pool (with max_conns=1, into a
        // gateway that 429s everything forever).
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            handle_conn(stream, ctx);
        }));
        if result.is_err() {
            log::error!("connection handler panicked; connection dropped");
        }
        ctx.active.lock().unwrap().remove(&id);
    }
}

fn err_body(msg: &str) -> String {
    obj(vec![("error", s(msg))]).to_string_compact()
}

/// Lingering close for a connection whose request was NOT fully read
/// (parse reject, stall, admission 429): FIN the write half after the
/// final response, then briefly and boundedly discard whatever the
/// peer was still sending.  Dropping a socket with unread bytes queued
/// makes the kernel answer RST, and an RST purges the peer's receive
/// buffer — destroying the just-written error response before the
/// client can read it (invisible on loopback, real over networks).
fn linger_close(stream: &TcpStream, reader: &mut impl std::io::Read) {
    let _ = stream.shutdown(Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let mut scratch = [0u8; 4096];
    let mut budget = 64 * 1024usize;
    // hard wall-clock cap alongside the byte budget: a peer trickling
    // one byte per read-timeout would otherwise pin this pool worker
    // for hours (64K reads x 250ms) — the exact slowloris shape the
    // request deadline sheds
    let deadline = std::time::Instant::now() + Duration::from_secs(1);
    loop {
        if std::time::Instant::now() >= deadline {
            break;
        }
        match reader.read(&mut scratch) {
            Ok(0) => break, // peer saw the FIN and closed
            Ok(n) => {
                if n >= budget {
                    break;
                }
                budget -= n;
            }
            Err(_) => break, // grace window elapsed (or transport died)
        }
    }
}

/// Write one response; `false` means the write failed (possibly
/// part-way).  After a partial write the byte stream is misframed —
/// response N+1 would be consumed as the tail of N's body — so the
/// connection loop MUST close on `false`, never keep serving.
fn respond(stream: &mut TcpStream, status: u16, reason: &str, body: &str, keep: bool) -> bool {
    respond_typed(stream, status, reason, "application/json", body, keep)
}

fn respond_typed(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &str,
    keep: bool,
) -> bool {
    match http::write_response(stream, status, reason, content_type, body.as_bytes(), keep) {
        Ok(()) => true,
        Err(e) => {
            log::debug!("writing response: {e}");
            false
        }
    }
}

/// [`respond`] with extra response headers (the 405 `Allow` list).
fn respond_with_headers(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    extra_headers: &[(&str, &str)],
    body: &str,
    keep: bool,
) -> bool {
    match http::write_response_with(
        stream,
        status,
        reason,
        "application/json",
        extra_headers,
        body.as_bytes(),
        keep,
    ) {
        Ok(()) => true,
        Err(e) => {
            log::debug!("writing response: {e}");
            false
        }
    }
}

/// The methods a known path answers, `None` for unknown paths.  Drives
/// the 405-vs-404 split: a wrong method on a real endpoint must say so
/// (and name the right method in `Allow`) instead of denying the path
/// exists.
fn allowed_methods(path: &str) -> Option<&'static [&'static str]> {
    match path {
        "/healthz" | "/metrics" | "/v1/version" => Some(&["GET"]),
        "/v1/infer" | "/v1/infer_batch" | "/v2/infer" => Some(&["POST"]),
        _ => None,
    }
}

/// The `GET /v1/version` document: crate version, active backend,
/// engine thread count, and every registered backend with availability
/// — what a fleet rollout checks before shifting traffic.
fn version_json(engine: &Engine) -> JsonValue {
    obj(vec![
        ("version", s(env!("CARGO_PKG_VERSION"))),
        ("backend", s(engine.backend_name())),
        ("engine_threads", num(engine.threads() as f64)),
        ("api", arr(["v1", "v2"].into_iter().map(s))),
        (
            "backends",
            arr(engine.registry().specs().iter().map(|sp| {
                obj(vec![
                    ("name", s(sp.name)),
                    ("available", JsonValue::Bool(sp.available)),
                    ("description", s(sp.description)),
                ])
            })),
        ),
    ])
}

/// The keep-alive request loop for one connection (DESIGN.md §10).
/// Returns when the peer closes, a read stalls past the timeout, the
/// request is malformed, the request asked for `Connection: close`, or
/// the gateway is shutting down — whichever comes first.  Every
/// response on the way out of the loop carries `Connection: close`.
fn handle_conn(mut stream: TcpStream, ctx: &ConnCtx) {
    let _ = stream.set_read_timeout(ctx.opts.read_timeout);
    let _ = stream.set_write_timeout(Some(Duration::from_secs(10)));
    let _ = stream.set_nodelay(true);
    // ONE BufReader for the whole session: a pipelining client's next
    // request may already sit in the buffer, and a fresh reader per
    // request would silently drop it
    let mut reader = match stream.try_clone() {
        Ok(clone) => BufReader::new(clone),
        Err(e) => {
            log::debug!("cloning connection stream: {e}");
            return;
        }
    };
    loop {
        if ctx.stop.load(Ordering::SeqCst) {
            break;
        }
        let req = match http::read_request_from(&mut reader, ctx.opts.request_deadline) {
            Ok(r) => r,
            // normal end of a keep-alive session
            Err(ReadError::Closed) => break,
            // idle keep-alive timeout: close silently; a stalled upload
            // gets told before the close (slowloris shed)
            Err(ReadError::TimedOut { mid_request }) => {
                if mid_request {
                    respond(
                        &mut stream,
                        408,
                        "Request Timeout",
                        &err_body("request stalled mid-read"),
                        false,
                    );
                    linger_close(&stream, &mut reader);
                }
                break;
            }
            // protocol violation: answer 400 then drop the connection —
            // after a framing error the byte stream can't be trusted
            Err(ReadError::Malformed(msg)) => {
                respond(&mut stream, 400, "Bad Request", &err_body(&msg), false);
                // the rejected request's unread remainder (e.g. a body
                // we refused to frame) must not turn the 400 into an RST
                linger_close(&stream, &mut reader);
                break;
            }
            Err(ReadError::Io(e)) => {
                log::debug!("connection read failed: {e}");
                break;
            }
        };
        ctx.stats.requests.fetch_add(1, Ordering::Relaxed);
        // persist only when the gateway allows it, the request allows
        // it, and we aren't draining for shutdown
        let keep =
            ctx.opts.keep_alive && req.wants_keep_alive() && !ctx.stop.load(Ordering::SeqCst);
        // route on the path only — a query string must not 404 an endpoint
        let path = req.path.split('?').next().unwrap_or("");
        let wrote_ok = match (req.method.as_str(), path) {
            ("GET", "/healthz") => {
                // enriched liveness: fleet rollouts verify what is
                // actually serving (backend, threads, crate version)
                let e = ctx.server.engine();
                let body = obj(vec![
                    ("status", s("ok")),
                    ("backend", s(e.backend_name())),
                    ("engine_threads", num(e.threads() as f64)),
                    ("version", s(env!("CARGO_PKG_VERSION"))),
                ])
                .to_string_compact();
                respond(&mut stream, 200, "OK", &body, keep)
            }
            ("GET", "/v1/version") => {
                let body = version_json(ctx.server.engine()).to_string_compact();
                respond(&mut stream, 200, "OK", &body, keep)
            }
            ("GET", "/metrics") => {
                let body = metrics_json(&ctx.server, &ctx.opts.spec, Some(&ctx.stats))
                    .to_string_compact();
                respond(&mut stream, 200, "OK", &body, keep)
            }
            ("POST", "/v1/infer") => {
                handle_infer(&mut stream, &req, &ctx.server, ctx.opts.default_tier, keep)
            }
            ("POST", "/v1/infer_batch") => {
                handle_infer_batch(&mut stream, &req, &ctx.server, ctx.opts.default_tier, keep)
            }
            ("POST", "/v2/infer") => {
                handle_infer_v2(&mut stream, &req, &ctx.server, ctx.opts.default_tier, keep)
            }
            (_, path) => match allowed_methods(path) {
                // known path, wrong method: 405 + Allow, not a 404
                Some(methods) => {
                    let allow = methods.join(", ");
                    respond_with_headers(
                        &mut stream,
                        405,
                        "Method Not Allowed",
                        &[("Allow", allow.as_str())],
                        &err_body("method not allowed"),
                        keep,
                    )
                }
                None => {
                    respond(&mut stream, 404, "Not Found", &err_body("no such route"), keep)
                }
            },
        };
        // a failed (possibly partial) write leaves the stream misframed:
        // the only safe continuation is no continuation
        if !wrote_ok || !keep {
            break;
        }
    }
}

/// Parse the `"image"` array of an infer document.
fn parse_image(doc: &JsonValue) -> std::result::Result<Vec<u8>, String> {
    let Some(pixels) = doc.get("image").and_then(JsonValue::as_array) else {
        return Err("missing \"image\" array".into());
    };
    if pixels.len() != IMAGE_BYTES {
        return Err(format!("image must be {IMAGE_BYTES} bytes, got {}", pixels.len()));
    }
    let mut image = Vec::with_capacity(IMAGE_BYTES);
    for p in pixels {
        // as_i64 would silently truncate 1.9 -> 1; demand true integers
        match p.as_f64() {
            Some(v) if v.fract() == 0.0 && (0.0..=255.0).contains(&v) => image.push(v as u8),
            _ => return Err("image values must be integers in 0..=255".into()),
        }
    }
    Ok(image)
}

/// Parse a tier name field; a present-but-invalid tier is a client
/// error, never a silent SLO downgrade.
fn parse_tier(v: &JsonValue, field: &str) -> std::result::Result<Tier, String> {
    let Some(name) = v.as_str() else {
        return Err(format!("{field:?} must be a string"));
    };
    Tier::parse(name).ok_or_else(|| format!("unknown tier {name:?} (gold|silver|batch)"))
}

/// Parse one **v1** infer document (`{"tier": optional, "image":
/// [u8; 3072]}`) into a typed [`InferRequest`]; the error string is
/// ready for a 400 / per-line error.  Shared by `/v1/infer` and
/// `/v1/infer_batch`.
fn parse_infer_doc(
    doc: &JsonValue,
    default_tier: Tier,
) -> std::result::Result<InferRequest, String> {
    let tier = match doc.get("tier") {
        None => default_tier,
        Some(v) => parse_tier(v, "tier")?,
    };
    Ok(InferRequest::new(parse_image(doc)?).with_tier(tier))
}

/// Parse one **v2** infer document: `{"image": [u8; 3072], "options":
/// {"tier": ..., "backend": ..., "seed": ..., "boundary": ...}}` — the
/// wire twin of [`InferOptions`] (DESIGN.md §12).
fn parse_infer_doc_v2(
    doc: &JsonValue,
    default_tier: Tier,
) -> std::result::Result<InferRequest, String> {
    let image = parse_image(doc)?;
    let mut options = InferOptions { tier: default_tier, ..Default::default() };
    if let Some(o) = doc.get("options") {
        if !matches!(o, JsonValue::Object(_)) {
            return Err("\"options\" must be an object".into());
        }
        if let Some(v) = o.get("tier") {
            options.tier = parse_tier(v, "options.tier")?;
        }
        if let Some(v) = o.get("backend") {
            match v.as_str() {
                Some(name) => options.backend = Some(name.to_string()),
                None => return Err("\"options.backend\" must be a string".into()),
            }
        }
        if let Some(v) = o.get("seed") {
            // the JSON substrate carries numbers as f64, which is only
            // exact up to 2^53 — beyond that distinct seeds would
            // silently collapse onto the same noise stream, so larger
            // values are rejected rather than rounded
            const SEED_MAX: f64 = (1u64 << 53) as f64;
            match v.as_f64() {
                Some(x) if x.fract() == 0.0 && (0.0..=SEED_MAX).contains(&x) => {
                    options.noise_seed = Some(x as u64)
                }
                _ => {
                    return Err(
                        "\"options.seed\" must be a non-negative integer <= 2^53".into()
                    )
                }
            }
        }
        if let Some(v) = o.get("boundary") {
            match v.as_f64() {
                Some(x) if x.fract() == 0.0 && (0.0..16.0).contains(&x) => {
                    options.boundary = Some(x as i32)
                }
                _ => return Err("\"options.boundary\" must be an integer in 0..=15".into()),
            }
        }
    }
    Ok(InferRequest { image, options })
}

/// A served response as a JSON object (shared by every infer route).
fn response_json(resp: &crate::coordinator::Response) -> JsonValue {
    obj(vec![
        ("id", num(resp.id as f64)),
        ("tier", s(resp.tier.name())),
        ("backend", s(&resp.backend)),
        ("pred", num(resp.pred as f64)),
        // logits scrubbed through fnum: a NaN logit (aggressive ACIM
        // noise) must not corrupt the whole JSON payload
        ("logits", arr(resp.logits.iter().map(|&x| fnum(x as f64)))),
        ("latency_us", num(resp.latency.as_micros() as f64)),
        ("batch_size", num(resp.batch_size as f64)),
    ])
}

/// How one dispatched request ended — the shared submit/await core
/// behind `/v1/infer` and `/v2/infer`; only the JSON rendering differs
/// per API version.
enum Dispatch {
    /// Served (the response may still carry a worker error).
    Done(Box<crate::coordinator::Response>),
    /// Rejected at admission.
    Rejected(SubmitError),
    /// The worker dropped the response channel (bug-shaped 500).
    ChannelDropped,
}

fn dispatch(server: &Server, req: InferRequest) -> Dispatch {
    match server.submit_request(req) {
        Err(e) => Dispatch::Rejected(e),
        Ok(rx) => match rx.recv() {
            Ok(resp) => Dispatch::Done(Box::new(resp)),
            Err(_) => Dispatch::ChannelDropped,
        },
    }
}

fn handle_infer(
    stream: &mut TcpStream,
    req: &HttpRequest,
    server: &Server,
    default_tier: Tier,
    keep: bool,
) -> bool {
    let parsed = req.body_str().and_then(json::parse);
    let doc = match parsed {
        Ok(d) => d,
        Err(e) => {
            let body = err_body(&format!("bad JSON body: {e:#}"));
            return respond(stream, 400, "Bad Request", &body, keep);
        }
    };
    let ireq = match parse_infer_doc(&doc, default_tier) {
        Ok(x) => x,
        Err(msg) => return respond(stream, 400, "Bad Request", &err_body(&msg), keep),
    };
    let tier = ireq.options.tier;
    match dispatch(server, ireq) {
        Dispatch::Rejected(e @ (SubmitError::Busy { .. } | SubmitError::Overloaded { .. })) => {
            let body = obj(vec![
                ("error", s("busy")),
                ("detail", s(&e.to_string())),
                ("tier", s(tier.name())),
            ])
            .to_string_compact();
            respond(stream, 429, "Too Many Requests", &body, keep)
        }
        Dispatch::Rejected(SubmitError::ShutDown) => {
            let body = err_body("server is shutting down");
            respond(stream, 503, "Service Unavailable", &body, false)
        }
        // v1 never populates backend overrides, but the in-process
        // option surface is shared — keep the arm total, not reachable
        Dispatch::Rejected(e) => {
            respond(stream, 400, "Bad Request", &err_body(&e.to_string()), keep)
        }
        Dispatch::ChannelDropped => {
            let body = err_body("response channel dropped");
            respond(stream, 500, "Internal Server Error", &body, keep)
        }
        Dispatch::Done(resp) => {
            if let Some(msg) = &resp.error {
                return respond(stream, 500, "Internal Server Error", &err_body(msg), keep);
            }
            respond(stream, 200, "OK", &response_json(&resp).to_string_compact(), keep)
        }
    }
}

/// The machine-readable `/v2` error envelope:
/// `{"error": {"code": ..., "message": ..., ...extra}}`.
fn v2_err(code: &str, message: &str, extra: Vec<(&str, JsonValue)>) -> String {
    let mut fields = vec![("code", s(code)), ("message", s(message))];
    fields.extend(extra);
    obj(vec![("error", obj(fields))]).to_string_compact()
}

/// `POST /v2/infer` — the versioned typed surface: per-request tier,
/// backend, noise-seed and boundary options, a consistent error
/// envelope, and a response tagged with the serving backend.
fn handle_infer_v2(
    stream: &mut TcpStream,
    req: &HttpRequest,
    server: &Server,
    default_tier: Tier,
    keep: bool,
) -> bool {
    let doc = match req.body_str().and_then(json::parse) {
        Ok(d) => d,
        Err(e) => {
            let body = v2_err("bad_request", &format!("bad JSON body: {e:#}"), vec![]);
            return respond(stream, 400, "Bad Request", &body, keep);
        }
    };
    let ireq = match parse_infer_doc_v2(&doc, default_tier) {
        Ok(x) => x,
        Err(msg) => {
            return respond(stream, 400, "Bad Request", &v2_err("bad_request", &msg, vec![]), keep)
        }
    };
    let tier = ireq.options.tier;
    match dispatch(server, ireq) {
        Dispatch::Rejected(SubmitError::UnknownBackend { requested, registered }) => {
            let body = v2_err(
                "unknown_backend",
                &format!("unknown backend {requested:?}"),
                vec![("backends", arr(registered.iter().map(|n| s(n))))],
            );
            respond(stream, 400, "Bad Request", &body, keep)
        }
        Dispatch::Rejected(SubmitError::BackendUnavailable { name, reason }) => {
            let body = v2_err(
                "backend_unavailable",
                &format!("backend {name:?} is unavailable: {reason}"),
                vec![],
            );
            respond(stream, 400, "Bad Request", &body, keep)
        }
        Dispatch::Rejected(e @ SubmitError::InvalidOption { .. }) => {
            let body = v2_err("invalid_option", &e.to_string(), vec![]);
            respond(stream, 400, "Bad Request", &body, keep)
        }
        Dispatch::Rejected(e @ (SubmitError::Busy { .. } | SubmitError::Overloaded { .. })) => {
            let body = v2_err("busy", &e.to_string(), vec![("tier", s(tier.name()))]);
            respond(stream, 429, "Too Many Requests", &body, keep)
        }
        Dispatch::Rejected(SubmitError::ShutDown) => {
            let body = v2_err("shutting_down", "server is shutting down", vec![]);
            respond(stream, 503, "Service Unavailable", &body, false)
        }
        Dispatch::ChannelDropped => {
            let body = v2_err("internal", "response channel dropped", vec![]);
            respond(stream, 500, "Internal Server Error", &body, keep)
        }
        Dispatch::Done(resp) => {
            if let Some(msg) = &resp.error {
                let body = v2_err("infer_failed", msg, vec![]);
                return respond(stream, 500, "Internal Server Error", &body, keep);
            }
            let mut o = response_json(&resp);
            if let JsonValue::Object(map) = &mut o {
                map.insert("api".into(), s("v2"));
            }
            respond(stream, 200, "OK", &o.to_string_compact(), keep)
        }
    }
}

/// NDJSON batch inference: parse every line, submit the valid ones (so
/// they pipeline into the coordinator's coalescing window), then
/// collect in input order.  Per-line failures (parse error, tier queue
/// Busy, worker error) become per-line `{"error": ...}` objects; the
/// HTTP status stays 200 unless the request itself is malformed.
fn handle_infer_batch(
    stream: &mut TcpStream,
    req: &HttpRequest,
    server: &Server,
    default_tier: Tier,
    keep: bool,
) -> bool {
    let text = match req.body_str() {
        Ok(t) => t,
        Err(e) => {
            return respond(stream, 400, "Bad Request", &err_body(&format!("{e:#}")), keep)
        }
    };
    // enumerate BEFORE filtering so the "line" field in every result
    // refers to the client's own line numbers even when the input has
    // interior blank lines
    let lines: Vec<(usize, &str)> =
        text.lines().enumerate().filter(|(_, l)| !l.trim().is_empty()).collect();
    if lines.is_empty() {
        return respond(stream, 400, "Bad Request", &err_body("empty NDJSON body"), keep);
    }
    if lines.len() > MAX_BATCH_LINES {
        return respond(
            stream,
            400,
            "Bad Request",
            &err_body(&format!("too many lines ({}, max {MAX_BATCH_LINES})", lines.len())),
            keep,
        );
    }
    // submit phase: get every admissible line in flight before waiting
    // on any response — this is what lets one HTTP request fill whole
    // coordinator batches
    enum Pending {
        Rx(std::sync::mpsc::Receiver<crate::coordinator::Response>),
        Err(String),
    }
    let mut pending = Vec::with_capacity(lines.len());
    for (i, line) in &lines {
        let slot = match json::parse(line)
            .map_err(|e| format!("bad JSON line: {e:#}"))
            .and_then(|doc| parse_infer_doc(&doc, default_tier))
        {
            Ok(ireq) => match server.submit_request(ireq) {
                Ok(rx) => Pending::Rx(rx),
                Err(e) => Pending::Err(e.to_string()),
            },
            Err(msg) => Pending::Err(msg),
        };
        pending.push((*i, slot));
    }
    // collect phase: input order, one NDJSON object per non-blank line
    let mut out = String::new();
    for (i, slot) in pending {
        let line_obj = match slot {
            Pending::Err(msg) => obj(vec![("line", num(i as f64)), ("error", s(&msg))]),
            Pending::Rx(rx) => match rx.recv() {
                Err(_) => obj(vec![
                    ("line", num(i as f64)),
                    ("error", s("response channel dropped")),
                ]),
                Ok(resp) => match &resp.error {
                    Some(msg) => obj(vec![("line", num(i as f64)), ("error", s(msg))]),
                    None => {
                        let mut o = response_json(&resp);
                        if let JsonValue::Object(map) = &mut o {
                            map.insert("line".into(), num(i as f64));
                        }
                        o
                    }
                },
            },
        };
        out.push_str(&line_obj.to_string_compact());
        out.push('\n');
    }
    respond_typed(stream, 200, "OK", "application/x-ndjson", &out, keep)
}

fn hist_json(h: &[u64; 16]) -> JsonValue {
    arr(h.iter().map(|&c| num(c as f64)))
}

/// A JSON number that is guaranteed well-formed: non-finite derived
/// stats (e.g. a ratio on a server that served nothing yet) serialize
/// as `0.0` instead of emitting a literal `NaN`/`inf` token that would
/// corrupt the whole `/metrics` payload.
fn fnum(x: f64) -> JsonValue {
    num(if x.is_finite() { x } else { 0.0 })
}

/// The `/metrics` document (also reused by the pipeline bench).
/// `conns` adds the gateway's connection-lifecycle counters when the
/// snapshot is taken through the HTTP surface.
pub fn metrics_json(server: &Server, spec: &MacroSpec, conns: Option<&ConnStats>) -> JsonValue {
    let m = server.metrics();
    let depths = server.queue_depths();
    let gov = server.governor();
    let mut tier_objs = Vec::new();
    for tier in Tier::ALL {
        let t = m.tier(tier);
        tier_objs.push((
            tier.name(),
            obj(vec![
                ("requests", num(t.requests as f64)),
                ("errors", num(t.errors as f64)),
                ("rejected", num(t.rejected as f64)),
                ("queue_depth", num(depths[tier.index()] as f64)),
                ("p50_latency_us", fnum(t.p50_latency_us())),
                ("p99_latency_us", fnum(t.p99_latency_us())),
                ("mean_boundary", fnum(t.mean_boundary())),
                ("b_hist", hist_json(&t.b_hist)),
            ]),
        ));
    }
    let gov_tiers: Vec<(&str, JsonValue)> = gov
        .tiers
        .iter()
        .map(|c| {
            (
                c.tier.name(),
                obj(vec![
                    ("profile", s(c.profile)),
                    ("level", num(c.level as f64)),
                    ("thresholds", arr(c.thresholds.iter().map(|&t| num(t as f64)))),
                ]),
            )
        })
        .collect();
    let mut fields = vec![
        ("requests", num(m.requests as f64)),
        ("batches", num(m.batches as f64)),
        ("errors", num(m.errors as f64)),
        ("rejected", num(m.rejected as f64)),
        ("mean_batch", fnum(m.mean_batch())),
        ("p50_latency_us", fnum(m.p50_latency_us())),
        ("p95_latency_us", fnum(m.p95_latency_us())),
        ("p99_latency_us", fnum(m.p99_latency_us())),
        ("throughput_rps", fnum(m.throughput_rps())),
        ("tops_per_watt", fnum(m.tops_per_watt(spec))),
        ("watts", fnum(m.account.watts())),
        ("b_hist", hist_json(&m.b_hist)),
        ("tiers", obj(tier_objs)),
        (
            "governor",
            obj(vec![
                ("enabled", JsonValue::Bool(gov.enabled)),
                ("transitions", num(gov.transitions as f64)),
                ("tiers", obj(gov_tiers)),
            ]),
        ),
    ];
    if let Some(c) = conns {
        fields.push((
            "connections",
            obj(vec![
                ("accepted", num(c.accepted.load(Ordering::Relaxed) as f64)),
                ("rejected", num(c.rejected.load(Ordering::Relaxed) as f64)),
                ("http_requests", num(c.requests.load(Ordering::Relaxed) as f64)),
                ("reuse_rate", fnum(c.reuse_rate())),
            ]),
        ));
    }
    obj(fields)
}
