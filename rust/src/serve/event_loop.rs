//! Readiness-driven serving loop (the default gateway mode on unix).
//!
//! One thread multiplexes every connection through an OS readiness
//! poller — `epoll(7)` on Linux, `poll(2)` elsewhere — instead of the
//! thread-per-connection pool in `serve::gateway`.  Connections are
//! nonblocking state machines (DESIGN.md §10):
//!
//! ```text
//! accept → Reading ⇄ Dispatched → Writing → (Reading | Lingering | close)
//! ```
//!
//! * **Reading**: bytes stream into the connection's incremental
//!   [`http::RequestParser`] as they arrive; a request may take any
//!   number of wakeups to complete.  The per-read idle timeout and the
//!   whole-request slowloris deadline (anchored at the FIRST byte of
//!   the request, surviving arbitrarily many wakeups) are enforced by a
//!   timer heap, not socket timeouts.
//! * **Dispatched**: compute runs on the coordinator's ExecPool exactly
//!   as in threaded mode; no gateway thread parks on the response.  The
//!   worker routes the finished [`Response`] back over a channel and
//!   nudges the loop through a self-pipe waker.  Reads are disarmed
//!   while a request is in flight — unread pipelined bytes stay in the
//!   kernel buffer, which is the backpressure.
//! * **Writing**: the rendered bytes are flushed until `EAGAIN`, then
//!   re-armed on writability so one slow reader can never stall the
//!   loop.
//! * **Lingering**: after an error response the write half is FIN'd and
//!   the peer's unread request remainder is discarded (bounded budget +
//!   deadline) so the kernel doesn't RST the response away.
//!
//! `max_conns` is the **connection cap**: up to `max_conns` admitted
//! (served) connections plus up to `max_conns` parked ones (accepted
//! but not yet read — promoted oldest-first as active slots free up);
//! beyond that a connection is answered `429` and closed.
//!
//! Per-connection buffers (parser + response) are recycled through a
//! small pool, so a keep-alive session allocates nothing per request on
//! the hot path.

use super::gateway::{
    batch_line_json, err_body, render_batch, render_done, render_submit_err, route, Api,
    BatchLine, ConnOpts, ConnStats, EventLoopStats, Rendered, RouteCtx, RouteOutcome,
};
use super::http::{ReadError, RequestParser};
use super::qos::SubmitError;
use crate::coordinator::{Response, Server};
use crate::io::json::{obj, s};
use crate::obs::{self, Stage};
use anyhow::{Context, Result};
use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, VecDeque};
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Poller token of the TCP listener.
const TOK_LISTENER: u64 = 0;
/// Poller token of the self-pipe waker.
const TOK_WAKER: u64 = 1;
/// First token handed to an admitted connection.
const TOK_CONN0: u64 = 2;

/// How long a response write may sit blocked on a slow reader.
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);
/// Grace window for the lingering close.
const LINGER_TIMEOUT: Duration = Duration::from_secs(1);
/// Byte budget discarded during a lingering close.
const LINGER_BUDGET: usize = 64 * 1024;
/// Hard wall-clock cap on the shutdown drain.
const DRAIN_CAP: Duration = Duration::from_secs(30);
/// Buffers kept in the recycle pool.
const POOL_CAP: usize = 64;
/// A buffer that grew past this is dropped instead of pooled, so one
/// huge body can't pin memory forever.
const POOL_MAX_BUF: usize = 64 * 1024;

/// Handle shared between the loop thread, the [`Gateway`], and the
/// coordinator workers (through the wake closure).
///
/// [`Gateway`]: super::gateway::Gateway
pub(crate) struct Shared {
    pub(crate) stop: AtomicBool,
    pub(crate) ev: Arc<EventLoopStats>,
    /// Write end of the self-pipe; one byte = "something to process".
    waker: UnixStream,
}

impl Shared {
    /// Nudge the loop out of its poller wait.  Nonblocking: if the pipe
    /// is already full the loop is guaranteed to wake anyway.
    pub(crate) fn wake(&self) {
        let _ = (&self.waker).write(&[1u8]);
    }

    /// Ask the loop to drain and exit (idempotent).
    pub(crate) fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.wake();
    }
}

/// Start the event loop on its own thread.
pub(crate) fn spawn(
    server: Arc<Server>,
    opts: ConnOpts,
    max_conns: usize,
    listener: TcpListener,
    stats: Arc<ConnStats>,
) -> Result<(Arc<Shared>, std::thread::JoinHandle<()>)> {
    listener.set_nonblocking(true).context("nonblocking listener")?;
    let (wtx, wrx) = UnixStream::pair().context("waker pipe")?;
    wtx.set_nonblocking(true).context("nonblocking waker")?;
    wrx.set_nonblocking(true).context("nonblocking waker")?;
    let ev = Arc::new(EventLoopStats::default());
    let shared = Arc::new(Shared { stop: AtomicBool::new(false), ev, waker: wtx });
    let poller = sys::Poller::new().context("creating poller")?;
    let thread = std::thread::Builder::new()
        .name("gateway-loop".into())
        .spawn({
            let shared = shared.clone();
            move || {
                let (comp_tx, comp_rx) = channel();
                let wake_fn: Arc<dyn Fn() + Send + Sync> = {
                    let shared = shared.clone();
                    Arc::new(move || shared.wake())
                };
                let mut lp = EventLoop {
                    server,
                    opts,
                    max_conns,
                    listener,
                    stats,
                    shared,
                    waker_rx: wrx,
                    poller,
                    conns: HashMap::new(),
                    parked: VecDeque::new(),
                    timers: BinaryHeap::new(),
                    pool: Vec::new(),
                    next_token: TOK_CONN0,
                    comp_tx,
                    comp_rx,
                    wake_fn,
                    tags: HashMap::new(),
                    next_tag: 0,
                    draining_since: None,
                };
                if let Err(e) = lp.run() {
                    log::error!("gateway event loop failed: {e}");
                }
            }
        })
        .context("spawning event loop")?;
    Ok((shared, thread))
}

/// Where a connection is in its lifecycle.
enum Phase {
    /// Accumulating request bytes into the parser.
    Reading,
    /// A request is on the coordinator; reads disarmed.
    Dispatched,
    /// Flushing a rendered response.
    Writing,
    /// Error response sent; discarding the peer's unread remainder.
    Lingering,
}

/// In-flight coordinator work owned by one connection.
enum PendingWork {
    Single {
        api: Api,
        keep: bool,
        tag: u64,
    },
    Batch {
        /// `(client line number, rendered NDJSON line when done)` in
        /// input order.
        slots: Vec<(usize, Option<String>)>,
        remaining: usize,
        keep: bool,
        tags: Vec<u64>,
    },
}

impl PendingWork {
    fn tags(&self) -> Vec<u64> {
        match self {
            PendingWork::Single { tag, .. } => vec![*tag],
            PendingWork::Batch { tags, .. } => tags.clone(),
        }
    }
}

/// One admitted connection.
struct Conn {
    stream: TcpStream,
    parser: RequestParser,
    /// Response bytes being flushed (`out_pos` already written).
    out: Vec<u8>,
    out_pos: usize,
    phase: Phase,
    /// Last instant a byte arrived (idle / per-read deadline anchor).
    last_byte: Instant,
    /// First byte of the CURRENT request (whole-request slowloris
    /// deadline anchor); `None` between requests.
    req_start: Option<Instant>,
    write_deadline: Option<Instant>,
    linger_deadline: Option<Instant>,
    linger_budget: usize,
    /// Keep serving after the current response flushes?
    keep_after_write: bool,
    /// Linger-close after the current response flushes (error path)?
    drain_after_write: bool,
    /// Currently armed poller interest `(read, write)`.
    interest: (bool, bool),
    pending: Option<PendingWork>,
    /// Trace id of the request currently owning this connection
    /// (0 = none); adopted from `X-Request-Id` or minted at parse.
    rid: u64,
    /// Tier index of the in-flight single dispatch (`u8::MAX` = N/A,
    /// e.g. a batch mixing tiers or a non-inference route).
    cur_tier: u8,
    /// obs-clock µs when the current response write began.
    write_start_us: u64,
}

struct EventLoop {
    server: Arc<Server>,
    opts: ConnOpts,
    max_conns: usize,
    listener: TcpListener,
    stats: Arc<ConnStats>,
    shared: Arc<Shared>,
    waker_rx: UnixStream,
    poller: sys::Poller,
    conns: HashMap<u64, Conn>,
    /// Accepted connections waiting for a free active slot (FIFO).
    parked: VecDeque<TcpStream>,
    /// Min-heap of `(deadline, token)`; entries are lazily invalidated
    /// by recomputing the true deadline on pop.
    timers: BinaryHeap<Reverse<(Instant, u64)>>,
    /// Recycled connection buffers.
    pool: Vec<Vec<u8>>,
    next_token: u64,
    comp_tx: Sender<(u64, Response)>,
    comp_rx: Receiver<(u64, Response)>,
    wake_fn: Arc<dyn Fn() + Send + Sync>,
    /// In-flight tag → (connection token, batch slot index).
    tags: HashMap<u64, (u64, usize)>,
    next_tag: u64,
    draining_since: Option<Instant>,
}

impl EventLoop {
    fn run(&mut self) -> io::Result<()> {
        self.poller.add(self.listener.as_raw_fd(), TOK_LISTENER, true, false)?;
        self.poller.add(self.waker_rx.as_raw_fd(), TOK_WAKER, true, false)?;
        let mut events: Vec<sys::Event> = Vec::with_capacity(256);
        loop {
            self.drain_completions();
            if self.shared.stop.load(Ordering::SeqCst) {
                self.begin_drain();
                if self.drained() {
                    return Ok(());
                }
            }
            let timeout = self.next_timeout();
            self.poller.wait(&mut events, timeout)?;
            self.shared.ev.wakeups.fetch_add(1, Ordering::Relaxed);
            let now = Instant::now();
            for ev in events.drain(..) {
                match ev.token {
                    TOK_LISTENER => self.accept_ready(now),
                    TOK_WAKER => self.drain_waker(),
                    token => self.conn_event(token, ev, now),
                }
            }
            self.drain_completions();
            self.process_timers(Instant::now());
        }
    }

    /// The poller wait bound: the nearest timer (possibly stale — it is
    /// re-validated on expiry), capped during drain so the hard drain
    /// deadline is observed.
    fn next_timeout(&self) -> Option<Duration> {
        let now = Instant::now();
        let mut t = self
            .timers
            .peek()
            .map(|Reverse((when, _))| when.saturating_duration_since(now));
        if self.draining_since.is_some() {
            let cap = Duration::from_millis(250);
            t = Some(t.map_or(cap, |x| x.min(cap)));
        }
        t
    }

    // ---- admission -----------------------------------------------------

    fn accept_ready(&mut self, now: Instant) {
        loop {
            match self.listener.accept() {
                Ok((stream, _)) => self.admit(stream, now),
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break,
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    log::warn!("accept failed: {e}");
                    break;
                }
            }
        }
    }

    fn admit(&mut self, stream: TcpStream, now: Instant) {
        if self.shared.stop.load(Ordering::SeqCst) {
            return;
        }
        if self.conns.len() < self.max_conns {
            self.activate(stream, now);
        } else if self.parked.len() < self.max_conns {
            // accepted but not served yet: reads stay unarmed, so the
            // peer just sees a connected-but-quiet server until a slot
            // frees up — the event-loop analogue of the accept backlog
            self.parked.push_back(stream);
            self.shared
                .ev
                .parked_connections
                .store(self.parked.len() as u64, Ordering::Relaxed);
        } else {
            self.reject_429(stream);
        }
    }

    fn activate(&mut self, stream: TcpStream, now: Instant) {
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let _ = stream.set_nodelay(true);
        let token = self.next_token;
        self.next_token += 1;
        if let Err(e) = self.poller.add(stream.as_raw_fd(), token, true, false) {
            log::warn!("registering connection: {e}");
            return;
        }
        self.stats.accepted.fetch_add(1, Ordering::Relaxed);
        let parser = RequestParser::with_buffer(self.take_buf());
        let out = self.take_buf();
        self.conns.insert(
            token,
            Conn {
                stream,
                parser,
                out,
                out_pos: 0,
                phase: Phase::Reading,
                last_byte: now,
                req_start: None,
                write_deadline: None,
                linger_deadline: None,
                linger_budget: 0,
                keep_after_write: false,
                drain_after_write: false,
                interest: (true, false),
                pending: None,
                rid: 0,
                cur_tier: u8::MAX,
                write_start_us: 0,
            },
        );
        self.shared
            .ev
            .open_connections
            .store(self.conns.len() as u64, Ordering::Relaxed);
        self.arm_timer(token);
    }

    /// Over both caps: explicit backpressure, same contract (and body)
    /// as the threaded accept path.  The write targets a fresh socket's
    /// empty send buffer, so it effectively never blocks the loop; the
    /// short timeout bounds the pathological case.
    fn reject_429(&mut self, stream: TcpStream) {
        self.stats.rejected.fetch_add(1, Ordering::Relaxed);
        let e = SubmitError::Overloaded { max_conns: self.max_conns };
        let body =
            obj(vec![("error", s("busy")), ("detail", s(&e.to_string()))]).to_string_compact();
        let r = Rendered::json(429, "Too Many Requests", body, false);
        let mut out = Vec::new();
        r.to_bytes(&mut out);
        let _ = stream.set_nonblocking(false);
        let _ = stream.set_write_timeout(Some(Duration::from_millis(500)));
        let _ = (&stream).write_all(&out);
        // FIN after the data: the peer (which never sent a byte, so no
        // unread input can RST the response away) reads the 429, then EOF
        let _ = stream.shutdown(Shutdown::Write);
    }

    fn promote_parked(&mut self, now: Instant) {
        while self.conns.len() < self.max_conns {
            let Some(stream) = self.parked.pop_front() else { break };
            self.activate(stream, now);
        }
        self.shared
            .ev
            .parked_connections
            .store(self.parked.len() as u64, Ordering::Relaxed);
    }

    // ---- buffer pool ---------------------------------------------------

    fn take_buf(&mut self) -> Vec<u8> {
        match self.pool.pop() {
            Some(b) => {
                self.shared.ev.pool_hits.fetch_add(1, Ordering::Relaxed);
                b
            }
            None => {
                self.shared.ev.pool_misses.fetch_add(1, Ordering::Relaxed);
                Vec::with_capacity(4096)
            }
        }
    }

    fn put_buf(&mut self, mut b: Vec<u8>) {
        if self.pool.len() < POOL_CAP && b.capacity() <= POOL_MAX_BUF {
            b.clear();
            self.pool.push(b);
        }
    }

    // ---- socket readiness ----------------------------------------------

    fn conn_event(&mut self, token: u64, ev: sys::Event, now: Instant) {
        if ev.readable {
            self.on_readable(token, now);
        }
        if ev.writable {
            self.try_flush(token, now);
        }
        if ev.hangup && !ev.readable && !ev.writable {
            // pure HUP/ERR (no data left to read): the peer is gone.
            // This is also how a Dispatched connection (interest fully
            // disarmed) learns its client vanished.
            self.close_conn(token, now);
        }
    }

    fn on_readable(&mut self, token: u64, now: Instant) {
        match self.conns.get(&token).map(|c| matches!(c.phase, Phase::Lingering)) {
            None => return,
            Some(true) => {
                self.linger_read(token, now);
                return;
            }
            Some(false) => {}
        }
        let mut scratch = [0u8; 16 * 1024];
        loop {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if !matches!(conn.phase, Phase::Reading) {
                // a parsed request transitioned the connection away;
                // pipelined bytes wait in the kernel buffer
                return;
            }
            match (&conn.stream).read(&mut scratch) {
                Ok(0) => {
                    // normal end of a keep-alive session (peer close)
                    self.close_conn(token, now);
                    return;
                }
                Ok(n) => {
                    conn.last_byte = now;
                    conn.parser.push(&scratch[..n]);
                    self.advance_conn(token, now);
                }
                Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                    self.shared.ev.eagain_reads.fetch_add(1, Ordering::Relaxed);
                    return;
                }
                Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(e) => {
                    log::debug!("connection read failed: {e}");
                    self.close_conn(token, now);
                    return;
                }
            }
        }
    }

    /// Parse-and-serve loop over whatever the parser holds.  Called
    /// after every read and after each response completes (pipelining).
    fn advance_conn(&mut self, token: u64, now: Instant) {
        loop {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if !matches!(conn.phase, Phase::Reading) {
                return;
            }
            match conn.parser.poll() {
                Ok(None) => {
                    // partial request: the whole-request (slowloris)
                    // deadline anchors at its FIRST byte and sticks
                    // across however many wakeups the request takes
                    if conn.parser.mid_request() {
                        if conn.req_start.is_none() {
                            conn.req_start = Some(now);
                        }
                    } else {
                        conn.req_start = None;
                    }
                    self.arm_timer(token);
                    return;
                }
                Ok(Some(req)) => {
                    // Parse span: from the request's first byte to now.
                    // A request that arrived whole in one read has no
                    // recorded first byte — its parse duration is ~0.
                    let parse_dur_us = conn
                        .req_start
                        .take()
                        .map(|t0| now.saturating_duration_since(t0).as_micros() as u64)
                        .unwrap_or(0);
                    self.stats.requests.fetch_add(1, Ordering::Relaxed);
                    let keep = self.opts.keep_alive
                        && req.wants_keep_alive()
                        && !self.shared.stop.load(Ordering::SeqCst);
                    let telem = self.server.obs().clone();
                    let rid = req
                        .header("x-request-id")
                        .and_then(obs::parse_rid)
                        .unwrap_or_else(|| telem.mint_rid());
                    let now_us = obs::now_us();
                    telem.parse_us.record(parse_dur_us);
                    telem.span(
                        rid,
                        Stage::Parse,
                        u8::MAX,
                        u8::MAX,
                        now_us.saturating_sub(parse_dur_us),
                        parse_dur_us,
                        &req.path,
                    );
                    if let Some(c) = self.conns.get_mut(&token) {
                        c.rid = rid;
                    }
                    self.handle_request(token, &req, keep, now);
                }
                Err(e) => {
                    // protocol violation: 400, then drop the connection
                    // — after a framing error the byte stream can't be
                    // trusted
                    let msg = match e {
                        ReadError::Malformed(m) => m,
                        ReadError::Io(err) => err.to_string(),
                        // the incremental parser never produces these
                        ReadError::Closed | ReadError::TimedOut { .. } => {
                            "connection error".into()
                        }
                    };
                    let r = Rendered::json(400, "Bad Request", err_body(&msg), false);
                    self.queue_response(token, &r, true, now);
                    return;
                }
            }
        }
    }

    fn handle_request(
        &mut self,
        token: u64,
        req: &super::http::HttpRequest,
        keep: bool,
        now: Instant,
    ) {
        let rid = self.conns.get(&token).map_or(0, |c| c.rid);
        let outcome = {
            let rctx = RouteCtx {
                server: &self.server,
                spec: &self.opts.spec,
                default_tier: self.opts.default_tier,
                stats: &self.stats,
                ev: Some(&self.shared.ev),
            };
            route(req, &rctx, keep)
        };
        match outcome {
            RouteOutcome::Respond(r) => self.queue_response(token, &r, false, now),
            RouteOutcome::Dispatch { ireq, api, keep } => {
                let tier = ireq.options.tier;
                let tag = self.next_tag;
                self.next_tag += 1;
                match self.server.submit_request_routed(
                    ireq,
                    tag,
                    self.comp_tx.clone(),
                    self.wake_fn.clone(),
                    rid,
                ) {
                    Ok(()) => {
                        self.tags.insert(tag, (token, 0));
                        if let Some(conn) = self.conns.get_mut(&token) {
                            conn.phase = Phase::Dispatched;
                            conn.cur_tier = tier.index() as u8;
                            conn.pending = Some(PendingWork::Single { api, keep, tag });
                        }
                        self.set_interest(token, false, false);
                    }
                    Err(e) => {
                        let r = render_submit_err(api, &e, tier, keep);
                        self.queue_response(token, &r, false, now);
                    }
                }
            }
            RouteOutcome::DispatchBatch { lines, keep } => {
                self.dispatch_batch(token, lines, keep, now)
            }
        }
    }

    /// Submit every admissible batch line before any response lands —
    /// the same pipelining-into-the-coalescing-window property as the
    /// threaded submit/collect phases, without parking a thread.
    fn dispatch_batch(&mut self, token: u64, lines: Vec<BatchLine>, keep: bool, now: Instant) {
        // every line of one NDJSON batch shares the HTTP request's id
        let rid = self.conns.get(&token).map_or(0, |c| c.rid);
        let mut slots: Vec<(usize, Option<String>)> = Vec::with_capacity(lines.len());
        let mut tags = Vec::new();
        let mut remaining = 0usize;
        for l in lines {
            match l {
                BatchLine::Err { line, msg } => {
                    slots.push((line, Some(batch_line_json(line, Err(&msg)))));
                }
                BatchLine::Submit { line, ireq } => {
                    let tag = self.next_tag;
                    self.next_tag += 1;
                    match self.server.submit_request_routed(
                        ireq,
                        tag,
                        self.comp_tx.clone(),
                        self.wake_fn.clone(),
                        rid,
                    ) {
                        Ok(()) => {
                            self.tags.insert(tag, (token, slots.len()));
                            tags.push(tag);
                            slots.push((line, None));
                            remaining += 1;
                        }
                        Err(e) => {
                            slots.push((line, Some(batch_line_json(line, Err(&e.to_string())))));
                        }
                    }
                }
            }
        }
        if remaining == 0 {
            let body: Vec<String> =
                slots.into_iter().filter_map(|(_, rendered)| rendered).collect();
            let r = render_batch(body, keep);
            self.queue_response(token, &r, false, now);
            return;
        }
        if let Some(conn) = self.conns.get_mut(&token) {
            conn.phase = Phase::Dispatched;
            conn.pending = Some(PendingWork::Batch { slots, remaining, keep, tags });
        }
        self.set_interest(token, false, false);
    }

    // ---- completions ---------------------------------------------------

    fn drain_completions(&mut self) {
        while let Ok((tag, resp)) = self.comp_rx.try_recv() {
            let Some((token, idx)) = self.tags.remove(&tag) else {
                // connection died while the request was in flight
                continue;
            };
            let now = Instant::now();
            // take the pending work, fold the response in, and either
            // finish (a Rendered to queue) or put the rest back
            let finished = {
                let Some(conn) = self.conns.get_mut(&token) else { continue };
                match conn.pending.take() {
                    None => None,
                    Some(PendingWork::Single { api, keep, .. }) => {
                        Some(render_done(api, &resp, keep))
                    }
                    Some(PendingWork::Batch { mut slots, mut remaining, keep, tags }) => {
                        if let Some(slot) = slots.get_mut(idx) {
                            if slot.1.is_none() {
                                slot.1 = Some(batch_line_json(slot.0, Ok(&resp)));
                                remaining -= 1;
                            }
                        }
                        if remaining == 0 {
                            let body: Vec<String> = slots
                                .into_iter()
                                .map(|(line, rendered)| {
                                    rendered.unwrap_or_else(|| {
                                        batch_line_json(line, Err("response channel dropped"))
                                    })
                                })
                                .collect();
                            Some(render_batch(body, keep))
                        } else {
                            conn.pending =
                                Some(PendingWork::Batch { slots, remaining, keep, tags });
                            None
                        }
                    }
                }
            };
            if let Some(r) = finished {
                self.queue_response(token, &r, false, now);
            }
        }
    }

    // ---- writing -------------------------------------------------------

    /// Render `r` into the connection's (pooled) output buffer and
    /// start flushing.  `drain` = linger-close afterwards (error path
    /// where the peer's request was not fully read).
    fn queue_response(&mut self, token: u64, r: &Rendered, drain: bool, now: Instant) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        conn.out.clear();
        conn.out_pos = 0;
        r.to_bytes_with_rid(&mut conn.out, conn.rid);
        conn.keep_after_write = r.keep;
        conn.drain_after_write = drain;
        conn.phase = Phase::Writing;
        conn.write_start_us = obs::now_us();
        conn.write_deadline = Some(now + WRITE_TIMEOUT);
        self.arm_timer(token);
        self.try_flush(token, now);
    }

    fn try_flush(&mut self, token: u64, now: Instant) {
        enum Flush {
            Done,
            Blocked,
            Dead,
        }
        let res = {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            if !matches!(conn.phase, Phase::Writing) {
                return;
            }
            loop {
                if conn.out_pos >= conn.out.len() {
                    break Flush::Done;
                }
                match (&conn.stream).write(&conn.out[conn.out_pos..]) {
                    Ok(0) => break Flush::Dead,
                    Ok(n) => conn.out_pos += n,
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => break Flush::Blocked,
                    Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        log::debug!("writing response: {e}");
                        break Flush::Dead;
                    }
                }
            }
        };
        match res {
            Flush::Done => self.post_write(token, now),
            Flush::Blocked => {
                self.shared.ev.eagain_writes.fetch_add(1, Ordering::Relaxed);
                self.set_interest(token, false, true);
            }
            // a failed (possibly partial) write leaves the stream
            // misframed: the only safe continuation is no continuation
            Flush::Dead => self.close_conn(token, now),
        }
    }

    /// One response fully flushed: linger (error path), close
    /// (`Connection: close` / draining), or go back to Reading — where
    /// a pipelined next request may already sit in the parser.
    fn post_write(&mut self, token: u64, now: Instant) {
        let stop = self.shared.stop.load(Ordering::SeqCst);
        let Some(conn) = self.conns.get_mut(&token) else { return };
        if conn.rid != 0 {
            let dur_us = obs::now_us().saturating_sub(conn.write_start_us);
            let telem = self.server.obs();
            telem.span(
                conn.rid,
                Stage::Write,
                conn.cur_tier,
                u8::MAX,
                conn.write_start_us,
                dur_us,
                "",
            );
            if (conn.cur_tier as usize) < telem.tier_write_us.len() {
                telem.tier_write_us[conn.cur_tier as usize].record(dur_us);
            }
            conn.rid = 0;
            conn.cur_tier = u8::MAX;
        }
        conn.write_deadline = None;
        conn.out.clear();
        conn.out_pos = 0;
        if conn.drain_after_write {
            conn.phase = Phase::Lingering;
            let _ = conn.stream.shutdown(Shutdown::Write);
            conn.linger_deadline = Some(now + LINGER_TIMEOUT);
            conn.linger_budget = LINGER_BUDGET;
            self.set_interest(token, true, false);
            self.arm_timer(token);
            return;
        }
        if !conn.keep_after_write || stop {
            self.close_conn(token, now);
            return;
        }
        conn.phase = Phase::Reading;
        conn.last_byte = now;
        self.set_interest(token, true, false);
        self.advance_conn(token, now);
    }

    // ---- lingering close ----------------------------------------------

    /// Discard the peer's unread bytes (bounded) so the kernel doesn't
    /// RST away the error response we just wrote (see the threaded
    /// `linger_close` for the full rationale).
    fn linger_read(&mut self, token: u64, now: Instant) {
        let mut scratch = [0u8; 4096];
        let done = {
            let Some(conn) = self.conns.get_mut(&token) else { return };
            loop {
                match (&conn.stream).read(&mut scratch) {
                    Ok(0) => break true, // peer saw the FIN and closed
                    Ok(n) => {
                        if n >= conn.linger_budget {
                            break true;
                        }
                        conn.linger_budget -= n;
                    }
                    Err(ref e) if e.kind() == io::ErrorKind::WouldBlock => {
                        self.shared.ev.eagain_reads.fetch_add(1, Ordering::Relaxed);
                        break false;
                    }
                    Err(ref e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(_) => break true,
                }
            }
        };
        if done {
            self.close_conn(token, now);
        }
    }

    // ---- teardown ------------------------------------------------------

    fn close_conn(&mut self, token: u64, now: Instant) {
        let Some(conn) = self.conns.remove(&token) else { return };
        let _ = self.poller.del(conn.stream.as_raw_fd());
        // orphan in-flight completions: their tags no longer resolve,
        // so drain_completions drops the responses on the floor
        if let Some(p) = &conn.pending {
            for t in p.tags() {
                self.tags.remove(&t);
            }
        }
        self.put_buf(conn.parser.into_buffer());
        self.put_buf(conn.out);
        self.shared
            .ev
            .open_connections
            .store(self.conns.len() as u64, Ordering::Relaxed);
        self.promote_parked(now);
    }

    /// First observation of the stop flag: stop accepting, drop parked
    /// connections (nothing in flight), close idle/lingering ones, and
    /// keep only Dispatched/Writing connections until they finish.
    fn begin_drain(&mut self) {
        if self.draining_since.is_some() {
            return;
        }
        self.draining_since = Some(Instant::now());
        let _ = self.poller.del(self.listener.as_raw_fd());
        self.parked.clear();
        self.shared.ev.parked_connections.store(0, Ordering::Relaxed);
        let idle: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| matches!(c.phase, Phase::Reading | Phase::Lingering))
            .map(|(t, _)| *t)
            .collect();
        let now = Instant::now();
        for t in idle {
            self.close_conn(t, now);
        }
    }

    fn drained(&self) -> bool {
        if self.conns.is_empty() {
            return true;
        }
        match self.draining_since {
            // past the cap, stragglers are cut off rather than holding
            // shutdown hostage
            Some(t) => t.elapsed() > DRAIN_CAP,
            None => false,
        }
    }

    // ---- timers --------------------------------------------------------

    /// The connection's TRUE deadline right now (timer heap entries are
    /// only hints; this is authoritative).
    fn deadline_of(&self, conn: &Conn) -> Option<Instant> {
        match conn.phase {
            Phase::Reading => {
                let rt = self.opts.read_timeout?;
                let mut d = conn.last_byte + rt;
                if !self.opts.request_deadline.is_zero() {
                    if let Some(start) = conn.req_start {
                        d = d.min(start + self.opts.request_deadline);
                    }
                }
                Some(d)
            }
            Phase::Dispatched => None, // compute takes what it takes
            Phase::Writing => conn.write_deadline,
            Phase::Lingering => conn.linger_deadline,
        }
    }

    fn arm_timer(&mut self, token: u64) {
        let d = match self.conns.get(&token) {
            Some(conn) => self.deadline_of(conn),
            None => return,
        };
        if let Some(d) = d {
            self.timers.push(Reverse((d, token)));
        }
    }

    fn process_timers(&mut self, now: Instant) {
        while let Some(&Reverse((when, token))) = self.timers.peek() {
            if when > now {
                break;
            }
            self.timers.pop();
            // lazily re-validate: the connection may be gone, or in a
            // different phase with a different (or no) deadline
            let true_deadline = match self.conns.get(&token) {
                Some(conn) => self.deadline_of(conn),
                None => continue,
            };
            match true_deadline {
                None => continue,
                Some(d) if d > now => self.timers.push(Reverse((d, token))),
                Some(_) => self.expire(token, now),
            }
        }
    }

    fn expire(&mut self, token: u64, now: Instant) {
        self.shared.ev.deadline_expirations.fetch_add(1, Ordering::Relaxed);
        let Some(conn) = self.conns.get_mut(&token) else { return };
        match conn.phase {
            Phase::Reading => {
                if conn.parser.mid_request() {
                    // stalled upload / slowloris: tell the peer before
                    // shedding it
                    let r = Rendered::json(
                        408,
                        "Request Timeout",
                        err_body("request stalled mid-read"),
                        false,
                    );
                    self.queue_response(token, &r, true, now);
                } else {
                    // idle keep-alive timeout: close silently
                    self.close_conn(token, now);
                }
            }
            Phase::Writing | Phase::Lingering => self.close_conn(token, now),
            Phase::Dispatched => {}
        }
    }

    // ---- poller plumbing -----------------------------------------------

    fn set_interest(&mut self, token: u64, read: bool, write: bool) {
        let Some(conn) = self.conns.get_mut(&token) else { return };
        if conn.interest == (read, write) {
            return;
        }
        conn.interest = (read, write);
        if let Err(e) = self.poller.modify(conn.stream.as_raw_fd(), token, read, write) {
            log::debug!("poller modify failed: {e}");
        }
    }

    fn drain_waker(&mut self) {
        let mut scratch = [0u8; 256];
        loop {
            match (&self.waker_rx).read(&mut scratch) {
                Ok(0) => break,
                Ok(_) => continue,
                Err(_) => break,
            }
        }
    }
}

/// Poller timeout in milliseconds (`-1` = wait forever), rounded UP so
/// a deadline under 1ms away doesn't make the loop spin.
fn timeout_ms(timeout: Option<Duration>) -> i32 {
    match timeout {
        None => -1,
        Some(d) => {
            let whole = d.as_millis();
            let ms = if d > Duration::from_millis(whole as u64) { whole + 1 } else { whole };
            ms.min(i32::MAX as u128) as i32
        }
    }
}

/// Linux: `epoll(7)`.
#[cfg(target_os = "linux")]
mod sys {
    use std::io;
    use std::os::raw::c_int;
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLL_CTL_ADD: c_int = 1;
    const EPOLL_CTL_DEL: c_int = 2;
    const EPOLL_CTL_MOD: c_int = 3;
    const EPOLL_CLOEXEC: c_int = 0o2000000;

    /// The kernel ABI struct: packed on x86 (no padding between
    /// `events` and `data`), naturally aligned elsewhere.
    #[repr(C)]
    #[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: c_int) -> c_int;
        fn epoll_ctl(epfd: c_int, op: c_int, fd: c_int, event: *mut EpollEvent) -> c_int;
        fn epoll_wait(
            epfd: c_int,
            events: *mut EpollEvent,
            maxevents: c_int,
            timeout: c_int,
        ) -> c_int;
        fn close(fd: c_int) -> c_int;
    }

    /// One readiness notification, poller-agnostic.
    #[derive(Clone, Copy, Debug)]
    pub(super) struct Event {
        pub(super) token: u64,
        pub(super) readable: bool,
        pub(super) writable: bool,
        pub(super) hangup: bool,
    }

    pub(super) struct Poller {
        epfd: c_int,
    }

    impl Poller {
        pub(super) fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller { epfd })
        }

        fn ctl(
            &self,
            op: c_int,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            let mut bits = 0u32;
            if read {
                bits |= EPOLLIN;
            }
            if write {
                bits |= EPOLLOUT;
            }
            let mut ev = EpollEvent { events: bits, data: token };
            let arg: *mut EpollEvent =
                if op == EPOLL_CTL_DEL { std::ptr::null_mut() } else { &mut ev };
            if unsafe { epoll_ctl(self.epfd, op, fd, arg) } < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        pub(super) fn add(&self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, read, write)
        }

        pub(super) fn modify(
            &self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, read, write)
        }

        pub(super) fn del(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, false, false)
        }

        pub(super) fn wait(
            &self,
            out: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            out.clear();
            let mut buf = [EpollEvent { events: 0, data: 0 }; 256];
            let ms = super::timeout_ms(timeout);
            let n = unsafe { epoll_wait(self.epfd, buf.as_mut_ptr(), buf.len() as c_int, ms) };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            for ev in buf.iter().take(n as usize) {
                // copy out of the (possibly packed) struct before use
                let bits = ev.events;
                let data = ev.data;
                out.push(Event {
                    token: data,
                    readable: bits & EPOLLIN != 0,
                    writable: bits & EPOLLOUT != 0,
                    hangup: bits & (EPOLLHUP | EPOLLERR) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }
}

/// Non-Linux unix: `poll(2)` over an explicit registration table.
#[cfg(all(unix, not(target_os = "linux")))]
mod sys {
    use std::cell::RefCell;
    use std::collections::HashMap;
    use std::io;
    use std::os::raw::{c_int, c_short, c_uint};
    use std::os::unix::io::RawFd;
    use std::time::Duration;

    const POLLIN: c_short = 0x001;
    const POLLOUT: c_short = 0x004;
    const POLLERR: c_short = 0x008;
    const POLLHUP: c_short = 0x010;
    const POLLNVAL: c_short = 0x020;

    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: c_int,
        events: c_short,
        revents: c_short,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: c_uint, timeout: c_int) -> c_int;
    }

    /// One readiness notification, poller-agnostic.
    #[derive(Clone, Copy, Debug)]
    pub(super) struct Event {
        pub(super) token: u64,
        pub(super) readable: bool,
        pub(super) writable: bool,
        pub(super) hangup: bool,
    }

    pub(super) struct Poller {
        reg: RefCell<HashMap<RawFd, (u64, bool, bool)>>,
    }

    impl Poller {
        pub(super) fn new() -> io::Result<Poller> {
            Ok(Poller { reg: RefCell::new(HashMap::new()) })
        }

        pub(super) fn add(&self, fd: RawFd, token: u64, read: bool, write: bool) -> io::Result<()> {
            self.reg.borrow_mut().insert(fd, (token, read, write));
            Ok(())
        }

        pub(super) fn modify(
            &self,
            fd: RawFd,
            token: u64,
            read: bool,
            write: bool,
        ) -> io::Result<()> {
            self.reg.borrow_mut().insert(fd, (token, read, write));
            Ok(())
        }

        pub(super) fn del(&self, fd: RawFd) -> io::Result<()> {
            self.reg.borrow_mut().remove(&fd);
            Ok(())
        }

        pub(super) fn wait(
            &self,
            out: &mut Vec<Event>,
            timeout: Option<Duration>,
        ) -> io::Result<()> {
            out.clear();
            let mut fds: Vec<PollFd> = self
                .reg
                .borrow()
                .iter()
                .map(|(&fd, &(_, read, write))| {
                    let mut events: c_short = 0;
                    if read {
                        events |= POLLIN;
                    }
                    if write {
                        events |= POLLOUT;
                    }
                    PollFd { fd, events, revents: 0 }
                })
                .collect();
            let ms = super::timeout_ms(timeout);
            let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as c_uint, ms) };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(e);
            }
            let reg = self.reg.borrow();
            for pfd in &fds {
                if pfd.revents == 0 {
                    continue;
                }
                let Some(&(token, _, _)) = reg.get(&pfd.fd) else { continue };
                out.push(Event {
                    token,
                    readable: pfd.revents & POLLIN != 0,
                    writable: pfd.revents & POLLOUT != 0,
                    hangup: pfd.revents & (POLLERR | POLLHUP | POLLNVAL) != 0,
                });
            }
            Ok(())
        }
    }
}
