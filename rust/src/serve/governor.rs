//! Dynamic precision governor (DESIGN.md §10): the serving-time
//! realization of on-the-fly saliency-aware precision.
//!
//! Each QoS tier maps onto an OSA loss-constraint profile
//! ([`crate::osa::loss_profile`]): gold → `tight`, silver → `normal`,
//! batch → `loose`.  The configured thresholds are the *calibrated*
//! (silver / `normal`) operating point; a tier's base thresholds are
//! derived by scaling each level with the ratio of its profile's loss
//! budget to the normal budget — a looser budget admits a higher
//! saliency threshold, steering more MACs across the digital/analog
//! boundary into the cheap analog domain (paper Fig 9: efficiency is
//! monotone in the loss constraint).
//!
//! On top of the static per-tier contract sits a feedback loop:
//! [`Governor::observe`] folds queue pressure (and, optionally, the
//! modeled power draw vs an energy budget) into a per-tier *degrade
//! level* with hysteresis.  Each level doubles the effective thresholds
//! — more samples fall below them, the OSE resolves a coarser boundary
//! (higher B in this codebase's candidate list `[10..5]`, i.e. more
//! analog, cheaper, slightly lossier) — batch first, then silver; gold
//! never degrades.  When the queues drain, levels step back down and
//! the calibrated contract is restored.

use super::qos::Tier;
use crate::config::SystemConfig;
use crate::device::sweep::DeviceFloors;
use crate::osa;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Feedback-loop knobs (defaults in [`SystemConfig`]).
#[derive(Debug, Clone, Copy)]
pub struct GovernorConfig {
    /// Master switch: disabled ⇒ every tier stays at its base contract.
    pub enabled: bool,
    /// Queue pressure (worst tier fill fraction) above which one tier
    /// degrades one level.
    pub high_watermark: f64,
    /// Pressure below which one tier recovers one level.
    pub low_watermark: f64,
    /// Max degrade levels per tier (each level doubles thresholds).
    pub max_level: u32,
    /// Minimum time between level changes (hysteresis hold).
    pub hold: Duration,
    /// Modeled macro power budget in watts; 0 disables the energy term.
    /// Running above budget counts as full pressure.
    pub energy_budget_w: f64,
}

impl GovernorConfig {
    pub fn from_system(cfg: &SystemConfig) -> Self {
        Self {
            enabled: cfg.governor,
            high_watermark: cfg.gov_high_watermark,
            low_watermark: cfg.gov_low_watermark,
            max_level: cfg.gov_max_level,
            hold: Duration::from_millis(cfg.gov_hold_ms),
            energy_budget_w: cfg.energy_budget_w,
        }
    }
}

/// Point-in-time view of one tier's precision contract (for `/metrics`).
#[derive(Debug, Clone)]
pub struct TierContract {
    pub tier: Tier,
    pub profile: &'static str,
    pub level: u32,
    /// Highest degrade level this tier may reach: the configured
    /// `max_level`, further capped by the device sweep's accuracy
    /// floors when a report is wired in (DESIGN.md §16).
    pub level_cap: u32,
    /// Effective OSE thresholds at the current degrade level.
    pub thresholds: Vec<i32>,
}

/// Point-in-time view of the whole governor (for `/metrics` and tests).
#[derive(Debug, Clone)]
pub struct GovernorSnapshot {
    pub enabled: bool,
    pub tiers: Vec<TierContract>,
    /// Total level changes since start (escalations + recoveries).
    pub transitions: u64,
    /// Device-corner accuracy floors in force (unbounded when no sweep
    /// report is configured).
    pub floors: DeviceFloors,
}

/// The per-tier dynamic precision controller.  Cheap to share: workers
/// read per-batch thresholds with two atomic loads and a small alloc.
pub struct Governor {
    cfg: GovernorConfig,
    /// Per-tier base thresholds (profile-scaled calibrated thresholds).
    base: [Vec<i32>; 3],
    /// Per-tier degrade level, 0 = base contract.
    levels: [AtomicU32; 3],
    /// Device-corner accuracy floors: per-tier caps on the degrade
    /// ladder, from a `SWEEP_*.json` report (unbounded by default).
    floors: DeviceFloors,
    transitions: AtomicU64,
    last_change: Mutex<Instant>,
}

impl Governor {
    /// Derive per-tier contracts from the calibrated thresholds (the
    /// profile scaling itself lives in [`osa::profile_thresholds`],
    /// shared with `engine::EngineBuilder::loss_profile`).
    pub fn new(calibrated: &[i32], cfg: GovernorConfig) -> Self {
        let mut base: [Vec<i32>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for tier in Tier::ALL {
            base[tier.index()] = osa::profile_thresholds(calibrated, tier.profile())
                .expect("tier profile exists");
        }
        Self {
            cfg,
            base,
            levels: [AtomicU32::new(0), AtomicU32::new(0), AtomicU32::new(0)],
            floors: DeviceFloors::unbounded(),
            transitions: AtomicU64::new(0),
            last_change: Mutex::new(Instant::now()),
        }
    }

    /// Cap the degrade ladder with device-corner accuracy floors from a
    /// sweep report: a tier never escalates past its swept floor.
    pub fn with_floors(mut self, floors: DeviceFloors) -> Self {
        self.floors = floors;
        self
    }

    pub fn from_system(cfg: &SystemConfig) -> Self {
        let g = Self::new(&cfg.thresholds, GovernorConfig::from_system(cfg));
        if cfg.device_sweep_report.is_empty() {
            return g;
        }
        let path = std::path::Path::new(&cfg.device_sweep_report);
        match DeviceFloors::load(path, DeviceFloors::slas(cfg)) {
            Ok(floors) => {
                log::info!(
                    "governor device floors from {}: caps={:?} corner_sigma={}",
                    cfg.device_sweep_report,
                    floors.caps,
                    floors.corner_sigma
                );
                g.with_floors(floors)
            }
            Err(e) => {
                log::warn!(
                    "ignoring device sweep report {}: {e:#}",
                    cfg.device_sweep_report
                );
                g
            }
        }
    }

    /// Highest level a tier may be degraded to: the configured
    /// `max_level`, further capped by the device floors.
    pub fn level_cap(&self, tier: Tier) -> u32 {
        self.cfg.max_level.min(self.floors.cap(tier))
    }

    /// The device floors in force.
    pub fn floors(&self) -> DeviceFloors {
        self.floors
    }

    /// Current degrade level of a tier.
    pub fn level(&self, tier: Tier) -> u32 {
        self.levels[tier.index()].load(Ordering::Relaxed)
    }

    /// Effective OSE thresholds for a tier at its current level.  Each
    /// level doubles the base thresholds (saturating), so fewer samples
    /// clear them and the OSE resolves coarser boundaries.
    pub fn thresholds_for(&self, tier: Tier) -> Vec<i32> {
        let level = self.level(tier).min(31);
        self.base[tier.index()]
            .iter()
            .map(|&t| ((t as i64) << level).clamp(i32::MIN as i64, i32::MAX as i64) as i32)
            .collect()
    }

    /// Feed one load observation into the feedback loop.  `pressure` is
    /// the worst tier queue fill fraction in [0, 1]; `watts` the modeled
    /// macro power (ignored unless an energy budget is configured).
    /// At most one tier moves one level per `hold` interval.
    pub fn observe(&self, pressure: f64, watts: f64) {
        if !self.cfg.enabled {
            return;
        }
        let mut p = pressure;
        if self.cfg.energy_budget_w > 0.0 && watts > self.cfg.energy_budget_w {
            p = 1.0;
        }
        let mut last = self.last_change.lock().unwrap();
        let now = Instant::now();
        if now.duration_since(*last) < self.cfg.hold {
            return;
        }
        if p >= self.cfg.high_watermark {
            // degrade the lowest tier that still has headroom; gold
            // never, and no tier past its device-floor cap
            for tier in [Tier::Batch, Tier::Silver] {
                let l = self.levels[tier.index()].load(Ordering::Relaxed);
                if l < self.level_cap(tier) {
                    self.levels[tier.index()].store(l + 1, Ordering::Relaxed);
                    self.transitions.fetch_add(1, Ordering::Relaxed);
                    *last = now;
                    log::info!(
                        "governor degrade tier={} level={} pressure={p:.2} watts={watts:.2}",
                        tier.name(),
                        l + 1
                    );
                    return;
                }
            }
        } else if p <= self.cfg.low_watermark {
            // recover the highest tier first so silver heals before batch
            for tier in [Tier::Silver, Tier::Batch] {
                let l = self.levels[tier.index()].load(Ordering::Relaxed);
                if l > 0 {
                    self.levels[tier.index()].store(l - 1, Ordering::Relaxed);
                    self.transitions.fetch_add(1, Ordering::Relaxed);
                    *last = now;
                    log::info!(
                        "governor recover tier={} level={} pressure={p:.2} watts={watts:.2}",
                        tier.name(),
                        l - 1
                    );
                    return;
                }
            }
        }
    }

    pub fn snapshot(&self) -> GovernorSnapshot {
        GovernorSnapshot {
            enabled: self.cfg.enabled,
            tiers: Tier::ALL
                .iter()
                .map(|&t| TierContract {
                    tier: t,
                    profile: t.profile(),
                    level: self.level(t),
                    level_cap: self.level_cap(t),
                    thresholds: self.thresholds_for(t),
                })
                .collect(),
            transitions: self.transitions.load(Ordering::Relaxed),
            floors: self.floors,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gcfg() -> GovernorConfig {
        GovernorConfig {
            enabled: true,
            high_watermark: 0.75,
            low_watermark: 0.25,
            max_level: 3,
            hold: Duration::ZERO,
            energy_budget_w: 0.0,
        }
    }

    const CAL: [i32; 5] = [0, 0, 32, 94, 1024];

    #[test]
    fn tier_contracts_scale_with_profile_looseness() {
        let g = Governor::new(&CAL, gcfg());
        let gold = g.thresholds_for(Tier::Gold);
        let silver = g.thresholds_for(Tier::Silver);
        let batch = g.thresholds_for(Tier::Batch);
        // silver IS the calibrated operating point
        assert_eq!(silver, CAL.to_vec());
        // tighter budget -> lower thresholds -> finer boundaries
        assert!(gold.iter().zip(&silver).all(|(a, b)| a <= b), "{gold:?} vs {silver:?}");
        assert!(batch.iter().zip(&silver).all(|(a, b)| a >= b), "{batch:?} vs {silver:?}");
        assert!(batch.iter().sum::<i32>() > silver.iter().sum::<i32>());
        // all contracts stay ascending (Ose::new requirement)
        for ts in [&gold, &silver, &batch] {
            assert!(ts.windows(2).all(|w| w[0] <= w[1]), "{ts:?}");
        }
    }

    #[test]
    fn escalates_batch_then_silver_never_gold() {
        let g = Governor::new(&CAL, gcfg());
        for _ in 0..10 {
            g.observe(1.0, 0.0);
        }
        assert_eq!(g.level(Tier::Batch), 3, "batch pinned at max level");
        assert_eq!(g.level(Tier::Silver), 3, "silver degrades after batch maxes");
        assert_eq!(g.level(Tier::Gold), 0, "gold must never degrade");
        // degraded thresholds are the base shifted left by the level
        let batch0: Vec<i32> = Governor::new(&CAL, gcfg()).thresholds_for(Tier::Batch);
        let batch3 = g.thresholds_for(Tier::Batch);
        for (a, b) in batch0.iter().zip(&batch3) {
            assert_eq!(*b, a << 3);
        }
    }

    #[test]
    fn recovers_silver_first_then_batch() {
        let g = Governor::new(&CAL, gcfg());
        for _ in 0..2 {
            g.observe(1.0, 0.0); // batch -> 2
        }
        for _ in 0..4 {
            g.observe(1.0, 0.0); // batch -> 3, silver -> 3
        }
        g.observe(0.0, 0.0);
        assert_eq!(g.level(Tier::Silver), 2, "silver recovers first");
        for _ in 0..10 {
            g.observe(0.0, 0.0);
        }
        assert_eq!(g.level(Tier::Silver), 0);
        assert_eq!(g.level(Tier::Batch), 0, "calibrated contract restored after drain");
        assert!(g.snapshot().transitions >= 8);
    }

    #[test]
    fn hysteresis_band_holds_levels() {
        let g = Governor::new(&CAL, gcfg());
        g.observe(1.0, 0.0);
        assert_eq!(g.level(Tier::Batch), 1);
        // mid-band pressure changes nothing in either direction
        for _ in 0..5 {
            g.observe(0.5, 0.0);
        }
        assert_eq!(g.level(Tier::Batch), 1);
    }

    #[test]
    fn hold_interval_rate_limits_changes() {
        let mut cfg = gcfg();
        cfg.hold = Duration::from_secs(3600);
        let g = Governor::new(&CAL, cfg);
        for _ in 0..5 {
            g.observe(1.0, 0.0);
        }
        // the hold window from construction hasn't elapsed
        assert_eq!(g.level(Tier::Batch), 0);
    }

    #[test]
    fn energy_budget_counts_as_pressure() {
        let mut cfg = gcfg();
        cfg.energy_budget_w = 0.5;
        let g = Governor::new(&CAL, cfg);
        g.observe(0.0, 1.0); // over budget, empty queues
        assert_eq!(g.level(Tier::Batch), 1);
    }

    #[test]
    fn device_floors_cap_the_degrade_ladder() {
        // sweep said: batch accuracy collapses past level 1, silver
        // past level 2 — the governor must refuse those levels even
        // under sustained full pressure
        let floors = DeviceFloors { corner_sigma: 0.45, caps: [0, 2, 1] };
        let g = Governor::new(&CAL, gcfg()).with_floors(floors);
        for _ in 0..20 {
            g.observe(1.0, 0.0);
        }
        assert_eq!(g.level(Tier::Batch), 1, "batch stops at its swept floor");
        assert_eq!(g.level(Tier::Silver), 2, "silver stops at its swept floor");
        assert_eq!(g.level(Tier::Gold), 0);
        let snap = g.snapshot();
        assert_eq!(snap.tiers[Tier::Batch.index()].level_cap, 1);
        assert_eq!(snap.tiers[Tier::Silver.index()].level_cap, 2);
        assert_eq!(snap.floors, floors);
        // without floors the same pressure reaches max_level
        let g = Governor::new(&CAL, gcfg());
        for _ in 0..20 {
            g.observe(1.0, 0.0);
        }
        assert_eq!(g.level(Tier::Batch), 3);
        assert_eq!(g.snapshot().tiers[Tier::Batch.index()].level_cap, 3);
    }

    #[test]
    fn disabled_governor_is_inert() {
        let mut cfg = gcfg();
        cfg.enabled = false;
        let g = Governor::new(&CAL, cfg);
        for _ in 0..5 {
            g.observe(1.0, 1e9);
        }
        assert_eq!(g.level(Tier::Batch), 0);
        assert!(!g.snapshot().enabled);
    }
}
