//! Minimal HTTP/1.1 substrate for the gateway (no HTTP crates in the
//! offline mirror — hand-rolled in-repo, like `io::json`).
//!
//! Scope: exactly what `serve::gateway` needs.  **Persistent
//! connections** (HTTP/1.1 keep-alive with correct `Connection` /
//! `Content-Length` semantics), request line + headers +
//! `Content-Length` body, bounded sizes, and a typed [`ReadError`] so
//! the gateway's connection loop can tell a clean keep-alive close from
//! a stalled peer from a protocol violation.  Also provides the
//! blocking clients used by the integration tests and benches: the
//! one-shot [`request`] (sends `Connection: close`) and the persistent
//! [`Client`] (many requests over one TCP connection).
//!
//! Hardening (request-smuggling shapes are rejected, not normalized):
//! duplicate *framing* headers are a 400 (two `Content-Length` values
//! must never silently last-write-win; other repeated headers combine
//! per RFC 7230 list semantics, as multi-hop proxies legitimately
//! produce), `Content-Length` must be pure ASCII digits
//! (`parse::<usize>` alone would accept a leading `+`), and
//! `Transfer-Encoding` is refused outright (chunked bodies are not
//! implemented, so ignoring the header would desynchronize framing).
//!
//! Two parse front-ends share one grammar: the blocking
//! [`read_request_from`] (connection-worker gateway, tests, benches)
//! and the incremental [`RequestParser`] (the event-loop gateway feeds
//! it whatever bytes a readiness wakeup produced).  Both call the same
//! request-line / header-insert / framing-validation helpers, so a
//! request split at any byte boundary parses — or is rejected — with
//! byte-identical semantics and error strings.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Parsing bounds (a request violating them is a 400).
const MAX_HEADER_LINE: usize = 16 * 1024;
const MAX_HEADERS: usize = 64;
const MAX_BODY: usize = 4 * 1024 * 1024;

/// One parsed request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// `HTTP/1.0` or `HTTP/1.1` (anything else is rejected at parse).
    pub version: String,
    /// Header names lower-cased.
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(String::as_str)
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not UTF-8")
    }

    /// Whether the peer allows this connection to persist after the
    /// response: HTTP/1.1 defaults to keep-alive unless the request says
    /// `Connection: close`; HTTP/1.0 persists only on an explicit
    /// `Connection: keep-alive`.  `Connection` is a comma-separated
    /// token list (RFC 7230 §6.1) — and this parser itself merges
    /// repeated non-framing headers into one list — so the tokens are
    /// scanned individually, never the whole value compared.
    pub fn wants_keep_alive(&self) -> bool {
        let conn = self.header("connection").unwrap_or("");
        let has = |token: &str| conn.split(',').any(|t| t.trim().eq_ignore_ascii_case(token));
        if self.version == "HTTP/1.0" {
            has("keep-alive")
        } else {
            !has("close")
        }
    }
}

/// Why [`read_request_from`] produced no request.  The connection loop
/// keys its lifecycle off this: `Closed` ends the session quietly,
/// `TimedOut`/`Malformed` end it with (at most) one final response,
/// `Io` ends it silently — the transport is already broken.
#[derive(Debug)]
pub enum ReadError {
    /// The peer closed cleanly at a request boundary (EOF before any
    /// byte of a new request) — the normal end of a keep-alive session.
    Closed,
    /// A read timed out (the socket's per-read timeout elapsed) or the
    /// whole-request deadline passed (slowloris guard).  `mid_request`
    /// distinguishes a stalled upload (answer 408) from an idle
    /// keep-alive connection that simply went quiet (close silently).
    TimedOut { mid_request: bool },
    /// The bytes were not a well-formed request within bounds (400).
    Malformed(String),
    /// Transport failure (peer reset, EOF mid-request, ...).
    Io(std::io::Error),
}

impl std::fmt::Display for ReadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ReadError::Closed => write!(f, "connection closed at a request boundary"),
            ReadError::TimedOut { mid_request: true } => write!(f, "request stalled mid-read"),
            ReadError::TimedOut { mid_request: false } => write!(f, "idle connection timed out"),
            ReadError::Malformed(msg) => write!(f, "{msg}"),
            ReadError::Io(e) => write!(f, "transport error: {e}"),
        }
    }
}

impl std::error::Error for ReadError {}

/// A blocked read returning `WouldBlock`/`TimedOut` is the socket's
/// read-timeout firing (platform-dependent which kind).
fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut)
}

fn malformed(msg: &str) -> ReadError {
    ReadError::Malformed(msg.to_string())
}

/// Split a request line into (method, path, version).  Shared by the
/// blocking and incremental parsers so both reject the same shapes
/// with the same words.
fn parse_request_line(line: &str) -> Result<(String, String, String), ReadError> {
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| malformed("empty request line"))?.to_string();
    let path = parts.next().ok_or_else(|| malformed("request line missing path"))?.to_string();
    let version = parts.next().ok_or_else(|| malformed("request line missing version"))?;
    if !version.starts_with("HTTP/1.") {
        return Err(ReadError::Malformed(format!("unsupported protocol {version:?}")));
    }
    Ok((method, path, version.to_string()))
}

/// Insert one header line into the map: lower-cased names, rejected
/// duplicate *framing* headers (the request-smuggling shape), RFC 7230
/// list-merge for every other repeat.
fn insert_header(headers: &mut BTreeMap<String, String>, line: &str) -> Result<(), ReadError> {
    let (name, value) = line.split_once(':').ok_or_else(|| malformed("malformed header line"))?;
    let name = name.trim().to_ascii_lowercase();
    if name.is_empty() {
        return Err(malformed("empty header name"));
    }
    match headers.entry(name) {
        std::collections::btree_map::Entry::Vacant(slot) => {
            slot.insert(value.trim().to_string());
        }
        std::collections::btree_map::Entry::Occupied(mut slot) => {
            // A repeated *framing* header is rejected outright: two
            // `Content-Length` values is the classic request-smuggling
            // shape, and silently keeping the last one (the old
            // `BTreeMap::insert` behavior) means this parser and any
            // intermediary can disagree on where the body ends.  Other
            // repeats are legal for list-valued fields (Via,
            // X-Forwarded-For from multi-hop proxies) — combine them
            // per RFC 7230 §3.2.2.
            let key = slot.key();
            if key == "content-length" || key == "transfer-encoding" {
                return Err(ReadError::Malformed(format!("duplicate header {key:?}")));
            }
            let merged = slot.get_mut();
            merged.push_str(", ");
            merged.push_str(value.trim());
        }
    }
    Ok(())
}

/// Validate body framing once the header block is complete: refuse
/// `Transfer-Encoding`, demand a pure-digit in-bounds `Content-Length`.
/// Returns the body length.
fn validate_framing(headers: &BTreeMap<String, String>) -> Result<usize, ReadError> {
    if headers.contains_key("transfer-encoding") {
        // not implemented; ignoring it would desynchronize body framing
        return Err(malformed("Transfer-Encoding is not supported (use Content-Length)"));
    }
    let len = match headers.get("content-length") {
        None => 0,
        Some(v) => {
            // strict digits only: Rust's usize::parse accepts a leading
            // '+' which no HTTP grammar does
            if v.is_empty() || !v.bytes().all(|b| b.is_ascii_digit()) {
                return Err(ReadError::Malformed(format!("bad Content-Length {v:?}")));
            }
            v.parse::<usize>()
                .map_err(|_| ReadError::Malformed(format!("Content-Length {v:?} out of range")))?
        }
    };
    if len > MAX_BODY {
        return Err(ReadError::Malformed(format!("body too large ({len} bytes, max {MAX_BODY})")));
    }
    Ok(len)
}

/// Read one `\n`-terminated line of at most `MAX_HEADER_LINE` bytes.
/// `Ok(None)` = clean EOF before any byte (a request boundary).
///
/// The read loop goes through `fill_buf` chunk by chunk so `deadline`
/// is re-checked *between chunks*: the per-read socket timeout resets
/// on every arriving byte, so without this a peer trickling one byte
/// per timeout could hold a bounded-pool worker on a single header
/// line for hours (the slowloris shape the deadline exists to shed).
fn read_line_bounded(
    r: &mut impl BufRead,
    deadline: Option<Instant>,
) -> Result<Option<String>, ReadError> {
    // buf is bounded by MAX_HEADER_LINE + 1: a peer streaming garbage
    // can never cost unbounded memory here.
    let mut buf: Vec<u8> = Vec::new();
    loop {
        if deadline.is_some_and(|d| Instant::now() >= d) {
            return Err(ReadError::TimedOut { mid_request: !buf.is_empty() });
        }
        let avail = match r.fill_buf() {
            Ok(a) => a,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => {
                return Err(ReadError::TimedOut { mid_request: !buf.is_empty() })
            }
            Err(e) => return Err(ReadError::Io(e)),
        };
        if avail.is_empty() {
            // EOF: clean only at a line (= request) boundary
            if buf.is_empty() {
                return Ok(None);
            }
            return Err(ReadError::Io(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed mid-line",
            )));
        }
        let take = avail.len().min(MAX_HEADER_LINE + 1 - buf.len());
        match avail[..take].iter().position(|&b| b == b'\n') {
            Some(pos) => {
                buf.extend_from_slice(&avail[..=pos]);
                r.consume(pos + 1);
                break;
            }
            None => {
                buf.extend_from_slice(&avail[..take]);
                r.consume(take);
                if buf.len() > MAX_HEADER_LINE {
                    return Err(ReadError::Malformed(format!(
                        "header line too long (over {MAX_HEADER_LINE} bytes)"
                    )));
                }
            }
        }
    }
    if buf.len() > MAX_HEADER_LINE {
        return Err(ReadError::Malformed(format!(
            "header line too long (over {MAX_HEADER_LINE} bytes)"
        )));
    }
    let line = String::from_utf8(buf)
        .map_err(|_| ReadError::Malformed("header line is not UTF-8".into()))?;
    Ok(Some(line.trim_end_matches(|c| c == '\r' || c == '\n').to_string()))
}

/// Read one request from a persistent reader.  The reader MUST be
/// reused across calls on a keep-alive connection — a pipelining client
/// may land bytes of request N+1 in the buffer while N is being read,
/// and a fresh `BufReader` would silently drop them.
///
/// `deadline` bounds the wall-clock time a request may take to arrive
/// in full, armed from the moment we start waiting for it (the
/// slowloris guard: per-read socket timeouts alone let a peer trickle
/// one byte per timeout forever).  An *idle* keep-alive connection
/// still surfaces as `TimedOut { mid_request: false }` via the shorter
/// per-read timeout before this deadline can fire.  `Duration::ZERO`
/// disables the guard.
pub fn read_request_from(
    reader: &mut impl BufRead,
    deadline: Duration,
) -> Result<HttpRequest, ReadError> {
    let deadline_at =
        if deadline.is_zero() { None } else { Some(Instant::now() + deadline) };
    let expired = || deadline_at.is_some_and(|d| Instant::now() >= d);
    // --- request line: EOF here is a clean keep-alive close ----------
    let request_line = match read_line_bounded(reader, deadline_at)? {
        Some(l) => l,
        None => return Err(ReadError::Closed),
    };
    let (method, path, version) = parse_request_line(&request_line)?;

    // --- headers ------------------------------------------------------
    let mut headers = BTreeMap::new();
    // the bound counts header LINES, not distinct names: duplicate
    // merging below must not let a peer grow one entry without limit
    let mut header_lines = 0usize;
    loop {
        if expired() {
            return Err(ReadError::TimedOut { mid_request: true });
        }
        let line = match read_line_bounded(reader, deadline_at) {
            Ok(Some(l)) => l,
            // EOF inside the header block is a broken request, not a boundary
            Ok(None) => {
                return Err(ReadError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection closed inside headers",
                )))
            }
            // any stall past the request line is mid-request
            Err(ReadError::TimedOut { .. }) => {
                return Err(ReadError::TimedOut { mid_request: true })
            }
            Err(e) => return Err(e),
        };
        if line.is_empty() {
            break;
        }
        header_lines += 1;
        if header_lines > MAX_HEADERS {
            return Err(malformed("too many headers"));
        }
        insert_header(&mut headers, &line)?;
    }

    // --- body ---------------------------------------------------------
    let len = validate_framing(&headers)?;
    let mut body = vec![0u8; len];
    let mut off = 0usize;
    while off < len {
        if expired() {
            return Err(ReadError::TimedOut { mid_request: true });
        }
        // chunked reads so the deadline is re-checked while a slow peer
        // trickles the body in
        let want = (len - off).min(64 * 1024);
        match reader.read(&mut body[off..off + want]) {
            Ok(0) => {
                return Err(ReadError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection closed inside the body",
                )))
            }
            Ok(n) => off += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) if is_timeout(&e) => return Err(ReadError::TimedOut { mid_request: true }),
            Err(e) => return Err(ReadError::Io(e)),
        }
    }
    Ok(HttpRequest { method, path, version, headers, body })
}

/// Incremental (push-based) request parser for the event-loop gateway.
///
/// Feed it whatever bytes a readiness wakeup produced with
/// [`RequestParser::push`], then ask [`RequestParser::poll`] whether a
/// complete request materialized.  The grammar, bounds and error
/// strings are shared with the blocking [`read_request_from`] (same
/// request-line / header / framing helpers), so a request split at any
/// byte boundary — mid-header-name, mid-`Content-Length` value,
/// mid-body — parses or 400s identically to the whole-buffer path.
///
/// The parser is reusable across requests on one connection: after a
/// request is returned, leftover pipelined bytes stay buffered and the
/// next [`RequestParser::poll`] resumes on them.  Memory is bounded:
/// completed lines are consumed eagerly, so the raw buffer never holds
/// more than one in-progress header line (≤ `MAX_HEADER_LINE`) plus
/// unconsumed pipelined input, and the body accumulator is capped by
/// `MAX_BODY` via the shared framing validation.
pub struct RequestParser {
    /// Raw unconsumed bytes (`pos..` is live; compacted periodically).
    buf: Vec<u8>,
    pos: usize,
    state: ParseState,
}

enum ParseState {
    /// Waiting for (or mid-way through) the request line.
    RequestLine,
    /// Request line parsed; accumulating the header block.
    Headers {
        method: String,
        path: String,
        version: String,
        headers: BTreeMap<String, String>,
        header_lines: usize,
    },
    /// Headers complete; accumulating `need` body bytes.
    Body {
        method: String,
        path: String,
        version: String,
        headers: BTreeMap<String, String>,
        body: Vec<u8>,
        need: usize,
    },
}

/// Extract one `\n`-terminated line from `buf[*pos..]` without copying
/// the scan, enforcing the same `MAX_HEADER_LINE` bound (newline
/// included) as the blocking `read_line_bounded`.  `Ok(None)` = the
/// line is still incomplete.
fn take_line(buf: &[u8], pos: &mut usize) -> Result<Option<String>, ReadError> {
    let avail = &buf[*pos..];
    match avail.iter().position(|&b| b == b'\n') {
        Some(i) => {
            if i + 1 > MAX_HEADER_LINE {
                return Err(ReadError::Malformed(format!(
                    "header line too long (over {MAX_HEADER_LINE} bytes)"
                )));
            }
            let text = std::str::from_utf8(&avail[..i])
                .map_err(|_| malformed("header line is not UTF-8"))?;
            let line = text.trim_end_matches(|c| c == '\r' || c == '\n').to_string();
            *pos += i + 1;
            Ok(Some(line))
        }
        None => {
            if avail.len() > MAX_HEADER_LINE {
                return Err(ReadError::Malformed(format!(
                    "header line too long (over {MAX_HEADER_LINE} bytes)"
                )));
            }
            Ok(None)
        }
    }
}

impl Default for RequestParser {
    fn default() -> Self {
        Self::new()
    }
}

impl RequestParser {
    pub fn new() -> RequestParser {
        Self::with_buffer(Vec::new())
    }

    /// Build a parser around a recycled buffer (the event loop's
    /// per-connection buffer pool); the buffer is cleared first.
    pub fn with_buffer(mut buf: Vec<u8>) -> RequestParser {
        buf.clear();
        RequestParser { buf, pos: 0, state: ParseState::RequestLine }
    }

    /// Append freshly read bytes.  Cheap; parsing happens in `poll`.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// True when some bytes of a new request have been consumed (or
    /// buffered) but the request is not complete — the distinction
    /// between a stalled upload (408) and an idle keep-alive connection
    /// (silent close), same contract as `ReadError::TimedOut`'s
    /// `mid_request` flag.
    pub fn mid_request(&self) -> bool {
        !matches!(self.state, ParseState::RequestLine) || self.pos < self.buf.len()
    }

    /// Reclaim the raw buffer (hand it back to the pool on close).
    pub fn into_buffer(self) -> Vec<u8> {
        self.buf
    }

    /// Drop consumed bytes once they dominate the buffer so a
    /// long-lived connection's buffer stays proportional to what is
    /// actually pending, not to everything it ever received.
    fn compact(&mut self) {
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos >= 4096 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
    }

    /// Advance the state machine over the buffered bytes.  `Ok(None)` =
    /// need more input; `Ok(Some(req))` = one complete request (leftover
    /// pipelined bytes remain buffered for the next call); `Err` = the
    /// connection is poisoned (400 + close, exactly like the blocking
    /// path's `ReadError::Malformed`).
    pub fn poll(&mut self) -> Result<Option<HttpRequest>, ReadError> {
        loop {
            match std::mem::replace(&mut self.state, ParseState::RequestLine) {
                ParseState::RequestLine => match take_line(&self.buf, &mut self.pos)? {
                    None => {
                        self.compact();
                        return Ok(None);
                    }
                    Some(line) => {
                        let (method, path, version) = parse_request_line(&line)?;
                        self.state = ParseState::Headers {
                            method,
                            path,
                            version,
                            headers: BTreeMap::new(),
                            header_lines: 0,
                        };
                    }
                },
                ParseState::Headers { method, path, version, mut headers, mut header_lines } => {
                    match take_line(&self.buf, &mut self.pos)? {
                        None => {
                            self.state = ParseState::Headers {
                                method,
                                path,
                                version,
                                headers,
                                header_lines,
                            };
                            self.compact();
                            return Ok(None);
                        }
                        Some(line) if line.is_empty() => {
                            let need = validate_framing(&headers)?;
                            if need == 0 {
                                self.compact();
                                return Ok(Some(HttpRequest {
                                    method,
                                    path,
                                    version,
                                    headers,
                                    body: Vec::new(),
                                }));
                            }
                            self.state = ParseState::Body {
                                method,
                                path,
                                version,
                                headers,
                                body: Vec::new(),
                                need,
                            };
                        }
                        Some(line) => {
                            header_lines += 1;
                            if header_lines > MAX_HEADERS {
                                return Err(malformed("too many headers"));
                            }
                            insert_header(&mut headers, &line)?;
                            self.state = ParseState::Headers {
                                method,
                                path,
                                version,
                                headers,
                                header_lines,
                            };
                        }
                    }
                }
                ParseState::Body { method, path, version, headers, mut body, need } => {
                    let take = (need - body.len()).min(self.buf.len() - self.pos);
                    body.extend_from_slice(&self.buf[self.pos..self.pos + take]);
                    self.pos += take;
                    if body.len() == need {
                        self.compact();
                        return Ok(Some(HttpRequest { method, path, version, headers, body }));
                    }
                    self.state = ParseState::Body { method, path, version, headers, body, need };
                    self.compact();
                    return Ok(None);
                }
            }
        }
    }
}

/// Read one request from the stream (one-shot convenience for tests).
/// The gateway's keep-alive loop uses [`read_request_from`] with a
/// persistent `BufReader` instead.
///
/// Sets a read timeout on the socket: the 30s deadline below is only
/// re-checked when reads *return*, so without a socket timeout a peer
/// that connects and sends nothing would block this thread forever and
/// the deadline would never be consulted.
pub fn read_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    let _ = stream.set_read_timeout(Some(Duration::from_secs(30)));
    let mut reader = BufReader::new(&mut *stream);
    read_request_from(&mut reader, Duration::from_secs(30)).map_err(|e| anyhow::anyhow!("{e}"))
}

/// Write one response and flush.  `keep_alive` selects the
/// `Connection` header: the gateway keeps the socket open only when the
/// request allowed it AND the server isn't shutting down.
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write_response_with(stream, status, reason, content_type, &[], body, keep_alive)
}

/// [`write_response`] with extra response headers (e.g. the `Allow`
/// list a 405 must carry per RFC 9110 §15.5.6).
pub fn write_response_with(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    let mut out = Vec::with_capacity(body.len() + 128);
    format_response_into(&mut out, status, reason, content_type, extra_headers, body, keep_alive);
    stream.write_all(&out)?;
    stream.flush()
}

/// Serialize one response (head + body) into `out`.  This is THE wire
/// format: both the blocking writer above and the event-loop gateway's
/// buffered writes go through it, so the two serving modes emit
/// byte-identical responses.
pub fn format_response_into(
    out: &mut Vec<u8>,
    status: u16,
    reason: &str,
    content_type: &str,
    extra_headers: &[(&str, &str)],
    body: &[u8],
    keep_alive: bool,
) {
    let conn = if keep_alive { "keep-alive" } else { "close" };
    let mut head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: {conn}\r\n",
        body.len()
    );
    for (name, value) in extra_headers {
        head.push_str(name);
        head.push_str(": ");
        head.push_str(value);
        head.push_str("\r\n");
    }
    head.push_str("\r\n");
    out.extend_from_slice(head.as_bytes());
    out.extend_from_slice(body);
}

/// `POST /v1/infer` body for one image at one tier — the wire format
/// `gateway::handle_infer` parses.  Lives here so the tests and the
/// pipeline bench build requests from one definition.
pub fn infer_body(tier: &str, img: &[u8]) -> String {
    let mut body = String::with_capacity(img.len() * 4 + 64);
    body.push_str("{\"tier\":\"");
    body.push_str(tier);
    body.push_str("\",\"image\":[");
    for (i, b) in img.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&b.to_string());
    }
    body.push_str("]}");
    body
}

/// `POST /v1/infer_batch` body: NDJSON, one `infer_body` line per
/// (tier, image) pair.
pub fn infer_batch_body(lines: &[(&str, &[u8])]) -> String {
    let mut body = String::new();
    for (tier, img) in lines {
        body.push_str(&infer_body(tier, img));
        body.push('\n');
    }
    body
}

/// Parse a response head + `Content-Length` body from a persistent
/// reader.  Returns (status, body).
fn read_response_from(
    reader: &mut impl BufRead,
) -> Result<(u16, BTreeMap<String, String>, Vec<u8>)> {
    let status_line = match read_line_bounded(reader, None).map_err(|e| anyhow::anyhow!("{e}"))? {
        Some(l) => l,
        None => bail!("connection closed before a response arrived"),
    };
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .context("malformed status line")?
        .parse()
        .context("non-numeric status")?;
    let mut headers = BTreeMap::new();
    loop {
        let line = match read_line_bounded(reader, None).map_err(|e| anyhow::anyhow!("{e}"))? {
            Some(l) => l,
            None => bail!("connection closed inside response headers"),
        };
        if line.is_empty() {
            break;
        }
        let (name, value) = line.split_once(':').context("malformed response header")?;
        headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
    }
    let len = headers
        .get("content-length")
        .map(|v| v.parse::<usize>().context("bad response Content-Length"))
        .transpose()?
        .unwrap_or(0);
    if len > MAX_BODY {
        bail!("response body too large ({len} bytes)");
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).context("reading response body")?;
    Ok((status, headers, body))
}

/// Blocking **persistent-connection** client: many requests over one
/// TCP connection (HTTP/1.1 keep-alive).  Used by the keep-alive e2e
/// tests and the pipeline bench's connection-reuse measurements.
pub struct Client {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    host: String,
    closed: bool,
}

impl Client {
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true).ok();
        let reader = BufReader::new(stream.try_clone().context("cloning client stream")?);
        Ok(Client { stream, reader, host: addr.to_string(), closed: false })
    }

    /// The server announced `Connection: close` (or the transport died):
    /// this client can issue no further requests.
    pub fn is_closed(&self) -> bool {
        self.closed
    }

    /// Raw socket access (tests use it to inject malformed bytes
    /// mid-stream or to stall deliberately).
    pub fn stream_mut(&mut self) -> &mut TcpStream {
        &mut self.stream
    }

    /// Send one request on the persistent connection and read the full
    /// response.  Returns (status, body).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        self.request_typed(method, path, "application/json", body)
    }

    /// Like [`Client::request`] but also returning the response headers
    /// (names lower-cased) — e.g. the `Allow` list on a 405.
    pub fn request_with_headers(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<(u16, BTreeMap<String, String>, String)> {
        let (status, headers, body) =
            self.request_full(method, path, "application/json", body)?;
        Ok((status, headers, body))
    }

    /// Like [`Client::request`] with an explicit request content type
    /// (the NDJSON batch endpoint).
    pub fn request_typed(
        &mut self,
        method: &str,
        path: &str,
        content_type: &str,
        body: Option<&str>,
    ) -> Result<(u16, String)> {
        let (status, _, body) = self.request_full(method, path, content_type, body)?;
        Ok((status, body))
    }

    fn request_full(
        &mut self,
        method: &str,
        path: &str,
        content_type: &str,
        body: Option<&str>,
    ) -> Result<(u16, BTreeMap<String, String>, String)> {
        if self.closed {
            bail!("connection was closed by the server");
        }
        let payload = body.unwrap_or("");
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nContent-Type: {content_type}\r\n\
             Content-Length: {}\r\n\r\n{payload}",
            self.host,
            payload.len()
        );
        let sent = self.stream.write_all(req.as_bytes()).and_then(|_| self.stream.flush());
        if let Err(e) = sent {
            self.closed = true;
            return Err(e).context("sending request");
        }
        let (status, headers, resp_body) = match read_response_from(&mut self.reader) {
            Ok(r) => r,
            Err(e) => {
                self.closed = true;
                return Err(e);
            }
        };
        if headers.get("connection").map(String::as_str) == Some("close") {
            self.closed = true;
        }
        Ok((status, headers, String::from_utf8_lossy(&resp_body).into_owned()))
    }
}

/// Blocking one-shot client: returns (status, body).  Sends
/// `Connection: close` — one request per connection, the baseline the
/// keep-alive bench compares against.
pub fn request(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let payload = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{payload}",
        payload.len()
    );
    stream.write_all(req.as_bytes())?;
    stream.flush()?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw).context("reading response")?;
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .context("malformed status line")?
        .parse()
        .context("non-numeric status")?;
    let resp_body = match raw.find("\r\n\r\n") {
        Some(i) => raw[i + 4..].to_string(),
        None => String::new(),
    };
    Ok((status, resp_body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Round-trip a raw request through a real socket pair.
    fn roundtrip(raw: &str) -> Result<HttpRequest> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_string();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(raw.as_bytes()).unwrap();
        });
        let (mut server_side, _) = listener.accept().unwrap();
        let req = read_request(&mut server_side);
        client.join().unwrap();
        req
    }

    #[test]
    fn parses_post_with_body() {
        let body = "{\"tier\":\"gold\"}";
        let raw = format!(
            "POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let req = roundtrip(&raw).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/infer");
        assert_eq!(req.version, "HTTP/1.1");
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.body_str().unwrap(), body);
        assert!(req.wants_keep_alive());
    }

    #[test]
    fn parses_get_without_body() {
        let req = roundtrip("GET /healthz HTTP/1.1\r\nX-Trace: 7\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.header("x-trace"), Some("7"));
        assert_eq!(req.header("X-Trace"), Some("7"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn keep_alive_semantics_per_version() {
        let req = roundtrip("GET /x HTTP/1.1\r\n\r\n").unwrap();
        assert!(req.wants_keep_alive(), "1.1 defaults to keep-alive");
        let req = roundtrip("GET /x HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        assert!(!req.wants_keep_alive());
        let req = roundtrip("GET /x HTTP/1.0\r\n\r\n").unwrap();
        assert!(!req.wants_keep_alive(), "1.0 defaults to close");
        let req = roundtrip("GET /x HTTP/1.0\r\nConnection: keep-alive\r\n\r\n").unwrap();
        assert!(req.wants_keep_alive());
        // Connection is a token LIST: close buried in a list (or
        // produced by this parser's own duplicate-header merging) must
        // still close — whole-string comparison would miss it
        let req = roundtrip("GET /x HTTP/1.1\r\nConnection: close, TE\r\n\r\n").unwrap();
        assert!(!req.wants_keep_alive(), "close inside a token list");
        let req =
            roundtrip("GET /x HTTP/1.1\r\nConnection: close\r\nConnection: close\r\n\r\n")
                .unwrap();
        assert!(!req.wants_keep_alive(), "merged duplicate close, close");
        let req = roundtrip("GET /x HTTP/1.0\r\nConnection: keep-alive, TE\r\n\r\n").unwrap();
        assert!(req.wants_keep_alive());
    }

    #[test]
    fn rejects_garbage() {
        assert!(roundtrip("not http at all\r\n\r\n").is_err());
        assert!(roundtrip("GET /x SPDY/99\r\n\r\n").is_err());
        assert!(roundtrip("GET /x HTTP/1.1\r\nbadheader\r\n\r\n").is_err());
        assert!(roundtrip("POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n").is_err());
        // body shorter than Content-Length -> UnexpectedEof at close
        assert!(roundtrip("POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").is_err());
    }

    #[test]
    fn rejects_smuggling_shapes() {
        // duplicate Content-Length: the old BTreeMap::insert silently
        // kept the second value
        let err = roundtrip(
            "POST /x HTTP/1.1\r\nContent-Length: 3\r\nContent-Length: 0\r\n\r\nabc",
        )
        .unwrap_err();
        assert!(err.to_string().contains("duplicate"), "{err}");
        // non-framing repeats are NOT smuggling: they combine per RFC
        // 7230 list semantics (what multi-hop proxies emit for Via /
        // X-Forwarded-For) instead of 400ing the whole request
        let req = roundtrip("GET /x HTTP/1.1\r\nVia: 1.1 a\r\nVia: 1.1 b\r\n\r\n").unwrap();
        assert_eq!(req.header("via"), Some("1.1 a, 1.1 b"));
        // a leading '+' parses under usize::parse but is not HTTP
        let err =
            roundtrip("POST /x HTTP/1.1\r\nContent-Length: +3\r\n\r\nabc").unwrap_err();
        assert!(err.to_string().contains("Content-Length"), "{err}");
        // signs, spaces, hex: all refused
        assert!(roundtrip("POST /x HTTP/1.1\r\nContent-Length: -3\r\n\r\n").is_err());
        assert!(roundtrip("POST /x HTTP/1.1\r\nContent-Length: 0x3\r\n\r\n").is_err());
        // Transfer-Encoding would desynchronize framing if ignored
        let err = roundtrip(
            "POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n",
        )
        .unwrap_err();
        assert!(err.to_string().contains("Transfer-Encoding"), "{err}");
    }

    #[test]
    fn repeated_header_lines_stay_bounded() {
        // duplicate merging must not bypass MAX_HEADERS: the bound is
        // on header LINES, so one endlessly-repeated name still trips it
        let mut raw = String::from("GET /x HTTP/1.1\r\n");
        for i in 0..(MAX_HEADERS + 1) {
            raw.push_str(&format!("Via: 1.1 hop{i}\r\n"));
        }
        raw.push_str("\r\n");
        let err = roundtrip(&raw).unwrap_err();
        assert!(err.to_string().contains("too many headers"), "{err}");
    }

    #[test]
    fn persistent_reader_serves_pipelined_requests() {
        // two requests land in one write: the shared BufReader must hand
        // back both, in order, without dropping buffered bytes
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(
                b"GET /first HTTP/1.1\r\n\r\nPOST /second HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi",
            )
            .unwrap();
        });
        let (server_side, _) = listener.accept().unwrap();
        let mut reader = BufReader::new(server_side);
        let r1 = read_request_from(&mut reader, Duration::from_secs(5)).unwrap();
        assert_eq!(r1.path, "/first");
        let r2 = read_request_from(&mut reader, Duration::from_secs(5)).unwrap();
        assert_eq!(r2.path, "/second");
        assert_eq!(r2.body_str().unwrap(), "hi");
        // after the peer closes: a clean boundary EOF
        client.join().unwrap();
        match read_request_from(&mut reader, Duration::from_secs(5)) {
            Err(ReadError::Closed) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
    }

    #[test]
    fn slowloris_trickle_hits_request_deadline() {
        // One byte per 10ms, never a newline: every byte resets the
        // per-read socket timeout (200ms here), so only the
        // whole-request deadline can shed this peer — and it must do so
        // even though the trickle starts on the REQUEST LINE itself.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let writer = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            for b in b"GET /never-finishes".iter().cycle().take(60) {
                if s.write_all(&[*b]).is_err() {
                    break; // server hung up (expected)
                }
                s.flush().ok();
                std::thread::sleep(Duration::from_millis(10));
            }
        });
        let (server_side, _) = listener.accept().unwrap();
        server_side.set_read_timeout(Some(Duration::from_millis(200))).unwrap();
        let mut reader = BufReader::new(server_side);
        let t0 = Instant::now();
        match read_request_from(&mut reader, Duration::from_millis(120)) {
            Err(ReadError::TimedOut { mid_request: true }) => {}
            other => panic!("expected deadline timeout, got {other:?}"),
        }
        assert!(
            t0.elapsed() < Duration::from_millis(600),
            "deadline fired late: {:?}",
            t0.elapsed()
        );
        drop(reader);
        writer.join().unwrap();
    }

    #[test]
    fn idle_timeout_vs_mid_request_stall() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        server_side.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let mut reader = BufReader::new(server_side);
        // nothing sent at all: idle boundary timeout
        match read_request_from(&mut reader, Duration::from_secs(5)) {
            Err(ReadError::TimedOut { mid_request: false }) => {}
            other => panic!("expected idle timeout, got {other:?}"),
        }
        // a partial request line then silence: mid-request stall
        let mut w = client.try_clone().unwrap();
        w.write_all(b"GET /slow").unwrap();
        w.flush().unwrap();
        match read_request_from(&mut reader, Duration::from_secs(5)) {
            Err(ReadError::TimedOut { mid_request: true }) => {}
            other => panic!("expected mid-request stall, got {other:?}"),
        }
        drop(client);
    }

    #[test]
    fn response_writer_and_client_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let req = read_request(&mut s).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.body_str().unwrap(), "{\"x\":1}");
            write_response(&mut s, 200, "OK", "application/json", b"{\"ok\":true}", false)
                .unwrap();
        });
        let (status, body) = request(&addr, "POST", "/echo", Some("{\"x\":1}")).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "{\"ok\":true}");
        server.join().unwrap();
    }

    #[test]
    fn persistent_client_two_requests_one_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            // ONE accept: both requests must arrive on the same socket
            let (stream, _) = listener.accept().unwrap();
            let mut writer = stream.try_clone().unwrap();
            let mut reader = BufReader::new(stream);
            for i in 0..2u32 {
                let req = read_request_from(&mut reader, Duration::from_secs(5)).unwrap();
                assert!(req.wants_keep_alive());
                let body = format!("{{\"n\":{i}}}");
                write_response(&mut writer, 200, "OK", "application/json", body.as_bytes(), i == 0)
                    .unwrap();
            }
        });
        let mut c = Client::connect(&addr).unwrap();
        let (status, body) = c.request("GET", "/a", None).unwrap();
        assert_eq!((status, body.as_str()), (200, "{\"n\":0}"));
        assert!(!c.is_closed());
        let (status, body) = c.request("GET", "/b", None).unwrap();
        assert_eq!((status, body.as_str()), (200, "{\"n\":1}"));
        // the second response said Connection: close
        assert!(c.is_closed());
        assert!(c.request("GET", "/c", None).is_err());
        server.join().unwrap();
    }

    /// Parse `raw` through the incremental parser in one push.
    fn parse_whole(raw: &[u8]) -> Result<Option<HttpRequest>, ReadError> {
        let mut p = RequestParser::new();
        p.push(raw);
        p.poll()
    }

    /// The deterministic shape of a parsed request, for split-point
    /// equivalence checks.
    fn fingerprint(r: &HttpRequest) -> (String, String, String, Vec<(String, String)>, Vec<u8>) {
        (
            r.method.clone(),
            r.path.clone(),
            r.version.clone(),
            r.headers.iter().map(|(k, v)| (k.clone(), v.clone())).collect(),
            r.body.clone(),
        )
    }

    #[test]
    fn incremental_parser_byte_by_byte_matches_whole_buffer() {
        let body = "{\"tier\":\"gold\"}";
        let raw = format!(
            "POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let whole = parse_whole(raw.as_bytes()).unwrap().unwrap();
        let mut p = RequestParser::new();
        let mut got = None;
        for (i, b) in raw.as_bytes().iter().enumerate() {
            p.push(&[*b]);
            if let Some(req) = p.poll().unwrap() {
                assert_eq!(i, raw.len() - 1, "request completed before its last byte");
                got = Some(req);
            }
        }
        let got = got.expect("byte-by-byte feed never produced the request");
        assert_eq!(fingerprint(&got), fingerprint(&whole));
        assert!(!p.mid_request(), "clean boundary after a complete request");
    }

    #[test]
    fn incremental_parser_adversarial_split_points() {
        let body = "{\"tier\":\"silver\",\"image\":[1,2,3]}";
        let raw = format!(
            "POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let whole = parse_whole(raw.as_bytes()).unwrap().unwrap();
        // split mid-header-NAME, mid-Content-Length VALUE, and mid-body
        let cut_name = raw.find("Content-Le").unwrap() + 6;
        let cut_value = raw.find(": 3").map(|i| i + 3).unwrap_or(raw.len() - 8);
        let cut_body = raw.len() - body.len() / 2;
        for cut in [cut_name, cut_value, cut_body] {
            let mut p = RequestParser::new();
            p.push(&raw.as_bytes()[..cut]);
            assert!(p.poll().unwrap().is_none(), "split at {cut} produced an early request");
            assert!(p.mid_request(), "split at {cut} must read as mid-request");
            p.push(&raw.as_bytes()[cut..]);
            let req = p.poll().unwrap().expect("second half must complete the request");
            assert_eq!(fingerprint(&req), fingerprint(&whole), "split at {cut} diverged");
        }
    }

    #[test]
    fn incremental_parser_pipelined_requests_and_leftover() {
        let mut p = RequestParser::new();
        p.push(b"GET /first HTTP/1.1\r\n\r\nPOST /second HTTP/1.1\r\nContent-Length: 2\r\n\r\nhi");
        let r1 = p.poll().unwrap().unwrap();
        assert_eq!(r1.path, "/first");
        assert!(p.mid_request(), "pipelined leftover bytes are a pending request");
        let r2 = p.poll().unwrap().unwrap();
        assert_eq!(r2.path, "/second");
        assert_eq!(r2.body_str().unwrap(), "hi");
        assert!(p.poll().unwrap().is_none());
        assert!(!p.mid_request());
    }

    #[test]
    fn incremental_parser_rejects_same_smuggling_shapes() {
        // the error STRINGS must match the blocking parser: the gateway
        // 400 bodies are part of the observable contract
        let err =
            parse_whole(b"POST /x HTTP/1.1\r\nContent-Length: 3\r\nContent-Length: 0\r\n\r\nabc")
                .unwrap_err();
        assert_eq!(err.to_string(), "duplicate header \"content-length\"");
        let err = parse_whole(b"POST /x HTTP/1.1\r\nContent-Length: +3\r\n\r\nabc").unwrap_err();
        assert_eq!(err.to_string(), "bad Content-Length \"+3\"");
        let err =
            parse_whole(b"POST /x HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n")
                .unwrap_err();
        assert_eq!(err.to_string(), "Transfer-Encoding is not supported (use Content-Length)");
        // non-framing repeats still merge per RFC 7230 list semantics
        let req = parse_whole(b"GET /x HTTP/1.1\r\nVia: 1.1 a\r\nVia: 1.1 b\r\n\r\n")
            .unwrap()
            .unwrap();
        assert_eq!(req.header("via"), Some("1.1 a, 1.1 b"));
    }

    #[test]
    fn incremental_parser_enforces_bounds() {
        // an endless header line with no newline trips MAX_HEADER_LINE
        let mut p = RequestParser::new();
        p.push(b"GET /x HTTP/1.1\r\nX-Big: ");
        // enough 1 KiB chunks to blow past MAX_HEADER_LINE without a newline
        for _ in 0..(MAX_HEADER_LINE / 1024 + 1) {
            p.push(&[b'a'; 1024]);
        }
        let err = p.poll().unwrap_err();
        assert!(err.to_string().contains("header line too long"), "{err}");
        // too many header lines (duplicate merging must not bypass it)
        let mut raw = String::from("GET /x HTTP/1.1\r\n");
        for i in 0..(MAX_HEADERS + 1) {
            raw.push_str(&format!("Via: 1.1 hop{i}\r\n"));
        }
        raw.push_str("\r\n");
        let err = parse_whole(raw.as_bytes()).unwrap_err();
        assert!(err.to_string().contains("too many headers"), "{err}");
    }

    #[test]
    fn infer_batch_body_is_one_line_per_image() {
        let a = [1u8, 2];
        let b = [3u8];
        let body = infer_batch_body(&[("gold", &a[..]), ("batch", &b[..])]);
        let lines: Vec<&str> = body.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(lines[0], infer_body("gold", &a));
        assert_eq!(lines[1], infer_body("batch", &b));
    }
}
