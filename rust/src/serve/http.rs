//! Minimal HTTP/1.1 substrate for the gateway (no HTTP crates in the
//! offline mirror — hand-rolled in-repo, like `io::json`).
//!
//! Scope: exactly what `serve::gateway` needs.  One request per
//! connection (`Connection: close` on every response), request line +
//! headers + `Content-Length` body, bounded sizes.  Also provides the
//! tiny blocking client used by the integration tests and benches.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

/// Parsing bounds (a request violating them is a 400).
const MAX_HEADER_LINE: usize = 16 * 1024;
const MAX_HEADERS: usize = 64;
const MAX_BODY: usize = 4 * 1024 * 1024;

/// One parsed request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// Header names lower-cased.
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(String::as_str)
    }

    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("request body is not UTF-8")
    }
}

fn read_line_bounded(r: &mut impl BufRead) -> Result<String> {
    // `take` bounds how much a newline-less line can buffer: a peer
    // streaming garbage can cost at most MAX_HEADER_LINE + 1 bytes here,
    // never unbounded memory.
    let mut buf = Vec::new();
    let n = r
        .by_ref()
        .take(MAX_HEADER_LINE as u64 + 1)
        .read_until(b'\n', &mut buf)
        .context("reading header line")?;
    if n == 0 {
        bail!("connection closed before a full request arrived");
    }
    if buf.len() > MAX_HEADER_LINE {
        bail!("header line too long (over {MAX_HEADER_LINE} bytes)");
    }
    let line = String::from_utf8(buf).context("header line is not UTF-8")?;
    Ok(line.trim_end_matches(|c| c == '\r' || c == '\n').to_string())
}

/// Read one request from the stream.
pub fn read_request(stream: &mut TcpStream) -> Result<HttpRequest> {
    let mut reader = BufReader::new(&mut *stream);
    let request_line = read_line_bounded(&mut reader)?;
    let mut parts = request_line.split_whitespace();
    let method = parts.next().context("empty request line")?.to_string();
    let path = parts.next().context("request line missing path")?.to_string();
    let version = parts.next().context("request line missing version")?;
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol {version:?}");
    }
    let mut headers = BTreeMap::new();
    loop {
        let line = read_line_bounded(&mut reader)?;
        if line.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            bail!("too many headers");
        }
        let (name, value) = line.split_once(':').context("malformed header line")?;
        headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
    }
    let len = match headers.get("content-length") {
        Some(v) => v.parse::<usize>().context("bad Content-Length")?,
        None => 0,
    };
    if len > MAX_BODY {
        bail!("body too large ({len} bytes, max {MAX_BODY})");
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).context("reading request body")?;
    Ok(HttpRequest { method, path, headers, body })
}

/// Write one response and flush.  Always closes after (the gateway is
/// one-request-per-connection).
pub fn write_response(
    stream: &mut TcpStream,
    status: u16,
    reason: &str,
    content_type: &str,
    body: &[u8],
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()
}

/// `POST /v1/infer` body for one image at one tier — the wire format
/// `gateway::handle_infer` parses.  Lives here so the tests and the
/// pipeline bench build requests from one definition.
pub fn infer_body(tier: &str, img: &[u8]) -> String {
    let mut body = String::with_capacity(img.len() * 4 + 64);
    body.push_str("{\"tier\":\"");
    body.push_str(tier);
    body.push_str("\",\"image\":[");
    for (i, b) in img.iter().enumerate() {
        if i > 0 {
            body.push(',');
        }
        body.push_str(&b.to_string());
    }
    body.push_str("]}");
    body
}

/// Blocking one-shot client: returns (status, body).  Used by the
/// integration tests, the pipeline bench and `examples/serve_requests`.
pub fn request(addr: &str, method: &str, path: &str, body: Option<&str>) -> Result<(u16, String)> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    let payload = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{payload}",
        payload.len()
    );
    stream.write_all(req.as_bytes())?;
    stream.flush()?;
    let mut raw = String::new();
    stream.read_to_string(&mut raw).context("reading response")?;
    let status: u16 = raw
        .split_whitespace()
        .nth(1)
        .context("malformed status line")?
        .parse()
        .context("non-numeric status")?;
    let resp_body = match raw.find("\r\n\r\n") {
        Some(i) => raw[i + 4..].to_string(),
        None => String::new(),
    };
    Ok((status, resp_body))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// Round-trip a raw request through a real socket pair.
    fn roundtrip(raw: &str) -> Result<HttpRequest> {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let raw = raw.to_string();
        let client = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(raw.as_bytes()).unwrap();
        });
        let (mut server_side, _) = listener.accept().unwrap();
        let req = read_request(&mut server_side);
        client.join().unwrap();
        req
    }

    #[test]
    fn parses_post_with_body() {
        let body = "{\"tier\":\"gold\"}";
        let raw = format!(
            "POST /v1/infer HTTP/1.1\r\nHost: x\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let req = roundtrip(&raw).unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/infer");
        assert_eq!(req.header("content-type"), Some("application/json"));
        assert_eq!(req.body_str().unwrap(), body);
    }

    #[test]
    fn parses_get_without_body() {
        let req = roundtrip("GET /healthz HTTP/1.1\r\nX-Trace: 7\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.header("x-trace"), Some("7"));
        assert_eq!(req.header("X-Trace"), Some("7"));
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(roundtrip("not http at all\r\n\r\n").is_err());
        assert!(roundtrip("GET /x SPDY/99\r\n\r\n").is_err());
        assert!(roundtrip("GET /x HTTP/1.1\r\nbadheader\r\n\r\n").is_err());
        assert!(roundtrip("POST /x HTTP/1.1\r\nContent-Length: nope\r\n\r\n").is_err());
        // body shorter than Content-Length -> read_exact fails at EOF
        assert!(roundtrip("POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").is_err());
    }

    #[test]
    fn response_writer_and_client_roundtrip() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            let (mut s, _) = listener.accept().unwrap();
            let req = read_request(&mut s).unwrap();
            assert_eq!(req.method, "POST");
            assert_eq!(req.body_str().unwrap(), "{\"x\":1}");
            write_response(&mut s, 200, "OK", "application/json", b"{\"ok\":true}").unwrap();
        });
        let (status, body) = request(&addr, "POST", "/echo", Some("{\"x\":1}")).unwrap();
        assert_eq!(status, 200);
        assert_eq!(body, "{\"ok\":true}");
        server.join().unwrap();
    }
}
