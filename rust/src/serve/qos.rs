//! QoS-tiered admission: per-request SLO tiers, bounded per-tier queues
//! and deadline-aware batch coalescing (DESIGN.md §10).
//!
//! Three tiers map onto the OSA loss-constraint profiles of Fig 9:
//! `gold` (interactive, tight loss budget, short coalescing window),
//! `silver` (default, the calibrated operating point) and `batch`
//! (throughput traffic, loose budget, long window).  Each tier owns a
//! bounded FIFO; admission past the bound fails fast with a typed
//! [`SubmitError::Busy`] instead of growing an unbounded queue — the
//! gateway maps it to HTTP 429.
//!
//! The consumer ([`TierQueues::pop_batch`]) drains strictly by priority
//! and coalesces one single-tier batch at a time, because the precision
//! governor configures the engine *per batch* — mixing tiers in a batch
//! would mix precision contracts.  The coalescing window is a **hard
//! deadline counted from the first request's enqueue time**: a trickle
//! of later arrivals can never extend it (the seed batcher's window
//! restarted at dequeue time, so queued requests aged invisibly).

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

/// Per-request service tier, highest priority first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Tier {
    /// Interactive: tight loss profile, shortest coalescing window.
    Gold,
    /// Default: the calibrated (`normal` profile) operating point.
    Silver,
    /// Throughput: loose loss profile, full coalescing window; the
    /// governor degrades this tier first under load.
    Batch,
}

impl Tier {
    /// All tiers, highest priority first (the drain order).
    pub const ALL: [Tier; 3] = [Tier::Gold, Tier::Silver, Tier::Batch];

    pub fn parse(text: &str) -> Option<Tier> {
        match text {
            "gold" => Some(Tier::Gold),
            "silver" => Some(Tier::Silver),
            "batch" => Some(Tier::Batch),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Tier::Gold => "gold",
            Tier::Silver => "silver",
            Tier::Batch => "batch",
        }
    }

    /// Index into per-tier arrays (== priority rank, 0 first).
    pub fn index(&self) -> usize {
        match self {
            Tier::Gold => 0,
            Tier::Silver => 1,
            Tier::Batch => 2,
        }
    }

    /// The OSA loss-constraint profile this tier's precision contract
    /// maps onto ([`crate::osa::loss_profile`]).
    pub fn profile(&self) -> &'static str {
        match self {
            Tier::Gold => "tight",
            Tier::Silver => "normal",
            Tier::Batch => "loose",
        }
    }

    /// Coalescing window for this tier given the configured base window:
    /// gold flushes almost immediately, batch uses the full window.
    pub fn coalesce_window(&self, base: Duration) -> Duration {
        let w = match self {
            Tier::Gold => base / 8,
            Tier::Silver => base / 2,
            Tier::Batch => base,
        };
        w.max(Duration::from_micros(1))
    }
}

/// Typed admission error surfaced by [`TierQueues::push`] (and
/// `coordinator::Server::submit*`).  `Busy` is the backpressure signal:
/// the caller should shed or retry later; the gateway answers 429.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// The tier's bounded queue is at capacity.
    Busy { tier: Tier, cap: usize },
    /// The gateway's connection budget is spent — `max_conns` served
    /// connections plus an equal parked/backlog allowance (event loop
    /// and threaded pool respectively) — the connection-level twin of
    /// `Busy` (both map to HTTP 429).
    Overloaded { max_conns: usize },
    /// The server is shutting down (or already shut down).
    ShutDown,
    /// A per-request backend override named nothing in the engine's
    /// registry (HTTP 400; the listing keeps the error actionable).
    UnknownBackend { requested: String, registered: Vec<String> },
    /// The named backend is registered but cannot run in this build
    /// (e.g. `pjrt` without the `pjrt` feature) — HTTP 400.
    BackendUnavailable { name: String, reason: String },
    /// A per-request option failed validation (HTTP 400).
    InvalidOption { field: &'static str, detail: String },
    /// A per-request `placement` override is not a known fleet placement
    /// mode (`auto` / `replicate` / `resident`) — HTTP 400.
    InvalidPlacement { requested: String },
    /// `resident` placement demands more packed weight tiles than the
    /// fleet's aggregate residency holds — HTTP 409 (the request is
    /// well-formed; this fleet cannot honor it).
    FleetCapacityExceeded { required_tiles: usize, capacity_tiles: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy { tier, cap } => {
                write!(f, "{} tier queue is full ({cap} pending) — busy, retry later", tier.name())
            }
            SubmitError::Overloaded { max_conns } => {
                write!(f, "connection cap reached ({max_conns} conns + backlog) — busy")
            }
            SubmitError::ShutDown => write!(f, "server is shut down"),
            SubmitError::UnknownBackend { requested, registered } => {
                let names = registered.join(", ");
                write!(f, "unknown backend {requested:?} (registered: {names})")
            }
            SubmitError::BackendUnavailable { name, reason } => {
                write!(f, "backend {name:?} is unavailable: {reason}")
            }
            SubmitError::InvalidOption { field, detail } => {
                write!(f, "invalid option {field:?}: {detail}")
            }
            SubmitError::InvalidPlacement { requested } => {
                write!(f, "unknown placement {requested:?} (one of: auto, replicate, resident)")
            }
            SubmitError::FleetCapacityExceeded { required_tiles, capacity_tiles } => write!(
                f,
                "resident placement needs {required_tiles} weight tiles but the fleet holds \
                 {capacity_tiles} — add macros, raise residency_tiles, or use auto placement"
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Admission / coalescing knobs.
#[derive(Debug, Clone, Copy)]
pub struct QosConfig {
    /// Bound of each tier's queue (admission past it returns `Busy`).
    pub queue_cap: usize,
    /// Max requests per coalesced batch.
    pub max_batch: usize,
    /// Base coalescing window; tiers derive theirs via
    /// [`Tier::coalesce_window`].
    pub base_window: Duration,
}

/// Result of one [`TierQueues::pop_batch`] call.
#[derive(Debug)]
pub enum Pop<T> {
    /// One single-tier batch, highest-priority tier first.
    Batch(Tier, Vec<T>),
    /// No work arrived within the idle tick — a chance for the caller
    /// to run periodic upkeep (governor observation).
    Idle,
    /// Closed and fully drained: no more batches will ever come.
    Closed,
}

struct QueueState<T> {
    queues: [VecDeque<(Instant, T)>; 3],
    rejected: [u64; 3],
    closed: bool,
}

/// Bounded, prioritized, deadline-coalescing tier queues (single
/// consumer, many producers).
pub struct TierQueues<T> {
    state: Mutex<QueueState<T>>,
    cv: Condvar,
    cfg: QosConfig,
}

impl<T> TierQueues<T> {
    pub fn new(mut cfg: QosConfig) -> Self {
        // a zero bound would admit nothing / coalesce nothing
        cfg.queue_cap = cfg.queue_cap.max(1);
        cfg.max_batch = cfg.max_batch.max(1);
        Self {
            state: Mutex::new(QueueState {
                queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
                rejected: [0; 3],
                closed: false,
            }),
            cv: Condvar::new(),
            cfg,
        }
    }

    pub fn config(&self) -> &QosConfig {
        &self.cfg
    }

    /// Admit one item, or fail fast when the tier's bound is reached.
    pub fn push(&self, tier: Tier, item: T) -> Result<(), SubmitError> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            return Err(SubmitError::ShutDown);
        }
        if st.queues[tier.index()].len() >= self.cfg.queue_cap {
            st.rejected[tier.index()] += 1;
            return Err(SubmitError::Busy { tier, cap: self.cfg.queue_cap });
        }
        st.queues[tier.index()].push_back((Instant::now(), item));
        self.cv.notify_all();
        Ok(())
    }

    /// Stop admitting; wake the consumer.  Items already queued are
    /// still drained by `pop_batch` before it reports `Closed`.
    pub fn close(&self) {
        let mut st = self.state.lock().unwrap();
        st.closed = true;
        self.cv.notify_all();
    }

    /// Queue depth per tier (gold, silver, batch).
    pub fn depths(&self) -> [usize; 3] {
        let st = self.state.lock().unwrap();
        [st.queues[0].len(), st.queues[1].len(), st.queues[2].len()]
    }

    /// Rejections (Busy) per tier since start.
    pub fn rejected(&self) -> [u64; 3] {
        self.state.lock().unwrap().rejected
    }

    /// Load signal for the governor: the worst per-tier fill fraction,
    /// in [0, 1].  A single saturated tier is full pressure — that is
    /// the tier whose latency contract is already breaking.
    pub fn pressure(&self) -> f64 {
        let st = self.state.lock().unwrap();
        let cap = self.cfg.queue_cap.max(1) as f64;
        st.queues.iter().map(|q| q.len() as f64 / cap).fold(0.0, f64::max)
    }

    /// Block for the next single-tier batch (priority drain, hard
    /// per-tier coalescing deadline), or `Idle` after `idle_tick`
    /// without work, or `Closed` once closed and drained.
    pub fn pop_batch(&self, idle_tick: Duration) -> Pop<T> {
        let mut st = self.state.lock().unwrap();
        // Wait for the first item (bounded so the caller can tick).
        while st.queues.iter().all(|q| q.is_empty()) {
            if st.closed {
                return Pop::Closed;
            }
            let (guard, res) = self.cv.wait_timeout(st, idle_tick).unwrap();
            st = guard;
            if res.timed_out() && st.queues.iter().all(|q| q.is_empty()) {
                return if st.closed { Pop::Closed } else { Pop::Idle };
            }
        }
        let tier = *Tier::ALL
            .iter()
            .find(|t| !st.queues[t.index()].is_empty())
            .expect("some queue is non-empty");
        let window = tier.coalesce_window(self.cfg.base_window);
        let mut batch: Vec<(Instant, T)> = Vec::new();
        loop {
            while batch.len() < self.cfg.max_batch {
                match st.queues[tier.index()].pop_front() {
                    Some(x) => batch.push(x),
                    None => break,
                }
            }
            // Hard deadline from the FIRST request's enqueue time: a
            // trickle of later arrivals can never extend the window.
            let deadline = batch[0].0 + window;
            let now = Instant::now();
            let higher_waiting =
                Tier::ALL[..tier.index()].iter().any(|t| !st.queues[t.index()].is_empty());
            if batch.len() >= self.cfg.max_batch || now >= deadline || st.closed || higher_waiting
            {
                drop(st);
                return Pop::Batch(tier, batch.into_iter().map(|(_, x)| x).collect());
            }
            let (guard, _res) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn cfg(cap: usize, max_batch: usize, window_ms: u64) -> QosConfig {
        QosConfig { queue_cap: cap, max_batch, base_window: Duration::from_millis(window_ms) }
    }

    #[test]
    fn tier_parse_and_names() {
        for t in Tier::ALL {
            assert_eq!(Tier::parse(t.name()), Some(t));
        }
        assert_eq!(Tier::parse("bronze"), None);
        assert!(crate::osa::loss_profile(Tier::Gold.profile()).is_some());
        assert!(crate::osa::loss_profile(Tier::Silver.profile()).is_some());
        assert!(crate::osa::loss_profile(Tier::Batch.profile()).is_some());
    }

    #[test]
    fn coalesce_windows_ordered_by_priority() {
        let base = Duration::from_millis(8);
        assert!(Tier::Gold.coalesce_window(base) < Tier::Silver.coalesce_window(base));
        assert!(Tier::Silver.coalesce_window(base) < Tier::Batch.coalesce_window(base));
        // never zero, even for a zero base window
        assert!(Tier::Gold.coalesce_window(Duration::ZERO) > Duration::ZERO);
    }

    #[test]
    fn priority_drain_order() {
        let q = TierQueues::new(cfg(8, 1, 1));
        q.push(Tier::Batch, 30u32).unwrap();
        q.push(Tier::Silver, 20).unwrap();
        q.push(Tier::Gold, 10).unwrap();
        let tick = Duration::from_millis(50);
        for expect in [(Tier::Gold, 10u32), (Tier::Silver, 20), (Tier::Batch, 30)] {
            match q.pop_batch(tick) {
                Pop::Batch(t, items) => {
                    assert_eq!(t, expect.0);
                    assert_eq!(items, vec![expect.1]);
                }
                other => panic!("expected a batch, got {other:?}"),
            }
        }
    }

    #[test]
    fn busy_at_cap_and_rejected_counter() {
        let q = TierQueues::new(cfg(2, 4, 1));
        q.push(Tier::Gold, 1u32).unwrap();
        q.push(Tier::Gold, 2).unwrap();
        let err = q.push(Tier::Gold, 3).unwrap_err();
        assert_eq!(err, SubmitError::Busy { tier: Tier::Gold, cap: 2 });
        assert!(err.to_string().contains("busy"));
        // the connection-level twin reads as busy too (both are 429s)
        assert!(SubmitError::Overloaded { max_conns: 4 }.to_string().contains("busy"));
        assert_eq!(q.rejected(), [1, 0, 0]);
        // other tiers are bounded independently
        q.push(Tier::Batch, 4).unwrap();
        assert_eq!(q.depths(), [2, 0, 1]);
        assert!(q.pressure() > 0.99);
    }

    #[test]
    fn close_drains_then_reports_closed() {
        let q = TierQueues::new(cfg(8, 16, 1));
        q.push(Tier::Silver, 1u32).unwrap();
        q.push(Tier::Silver, 2).unwrap();
        q.close();
        assert_eq!(q.push(Tier::Silver, 3).unwrap_err(), SubmitError::ShutDown);
        match q.pop_batch(Duration::from_millis(10)) {
            Pop::Batch(t, items) => {
                assert_eq!(t, Tier::Silver);
                assert_eq!(items, vec![1, 2]);
            }
            other => panic!("expected drained batch, got {other:?}"),
        }
        assert!(matches!(q.pop_batch(Duration::from_millis(10)), Pop::Closed));
    }

    #[test]
    fn idle_tick_without_work() {
        let q: TierQueues<u32> = TierQueues::new(cfg(8, 16, 1));
        let t0 = Instant::now();
        assert!(matches!(q.pop_batch(Duration::from_millis(5)), Pop::Idle));
        assert!(t0.elapsed() >= Duration::from_millis(4));
    }

    #[test]
    fn hard_deadline_from_first_enqueue_stops_trickle_extension() {
        // Arrivals every 20ms for 8 items (span ~140ms) against a 60ms
        // batch-tier window: the first batch must flush on the deadline
        // of its FIRST item, not keep absorbing the trickle.
        let q = Arc::new(TierQueues::new(cfg(64, 100, 60)));
        let prod = {
            let q = q.clone();
            std::thread::spawn(move || {
                for i in 0..8u32 {
                    q.push(Tier::Batch, i).unwrap();
                    std::thread::sleep(Duration::from_millis(20));
                }
            })
        };
        // wait for the first arrival, then time the batch
        let batch = loop {
            match q.pop_batch(Duration::from_millis(5)) {
                Pop::Batch(_, items) => break items,
                _ => continue,
            }
        };
        assert!(
            batch.len() < 8,
            "trickle extended the window: {} items coalesced into one batch",
            batch.len()
        );
        assert!(!batch.is_empty());
        prod.join().unwrap();
    }

    #[test]
    fn gold_arrival_preempts_batch_coalescing() {
        let q = Arc::new(TierQueues::new(cfg(8, 100, 400)));
        q.push(Tier::Batch, 1u32).unwrap();
        let pusher = {
            let q = q.clone();
            std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(30));
                q.push(Tier::Gold, 2).unwrap();
            })
        };
        let t0 = Instant::now();
        match q.pop_batch(Duration::from_millis(5)) {
            Pop::Batch(t, items) => {
                assert_eq!(t, Tier::Batch);
                assert_eq!(items, vec![1]);
            }
            other => panic!("expected the preempted batch, got {other:?}"),
        }
        // flushed well before the 400ms batch window because gold arrived
        assert!(t0.elapsed() < Duration::from_millis(300), "no preemption: {:?}", t0.elapsed());
        match q.pop_batch(Duration::from_millis(50)) {
            Pop::Batch(t, items) => {
                assert_eq!(t, Tier::Gold);
                assert_eq!(items, vec![2]);
            }
            other => panic!("expected the gold batch, got {other:?}"),
        }
        pusher.join().unwrap();
    }
}
