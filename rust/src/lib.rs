//! # osa-hcim — full-system reproduction of OSA-HCIM (arXiv cs.AR 2023)
//!
//! *On-the-fly Saliency-Aware Hybrid SRAM CIM with Dynamic Precision
//! Configuration* (Chen, Ando, Fujiki, Takamaeda-Yamazaki, Yoshioka).
//!
//! This crate is the Layer-3 coordinator of a three-layer Rust + JAX +
//! Pallas stack (see `DESIGN.md`):
//!
//! * [`macrosim`] — cycle-level behavioral model of the 64b x 144b hybrid
//!   SRAM macro (8 HMUs x 144 HCIMAs, DAT, N/Q, 3-bit SAR ADC, OSE);
//! * [`osa`] — the On-the-fly Saliency-Aware precision configuration
//!   scheme and its threshold-calibration algorithm (paper Fig. 4b);
//! * [`sched`] — im2col tiling of DNN layers onto macros plus the
//!   digital/analog workload allocation of paper Fig. 5a;
//! * [`nn`] — the quantized integer CNN engine (ResNet-mini) driven
//!   through the macro datapath;
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas tile
//!   artifacts (`artifacts/*.hlo.txt`); Python never runs at inference;
//! * [`engine`] — the unified front door: an object-safe [`engine::Backend`]
//!   abstraction, a string-selectable [`engine::BackendRegistry`]
//!   (`macro-hybrid` / `macro-dcim` / `macro-acim` / `pjrt`), the
//!   [`engine::EngineBuilder`] that owns plan-cache/pool wiring, and the
//!   typed [`engine::InferRequest`]/[`engine::InferResponse`] structs
//!   shared by in-process callers and `POST /v2/infer`;
//! * [`coordinator`] — threaded request router / batcher / server loop
//!   with QoS-tiered bounded admission;
//! * [`serve`] — the network surface: HTTP/1.1 gateway, per-tier SLO
//!   queues and the dynamic precision governor (tier → OSA loss
//!   profile, degraded under load, restored on drain);
//! * [`obs`] — the observability substrate: per-request trace spans in
//!   a lock-free ring, bounded atomic latency histograms, Chrome
//!   trace-event export and Prometheus text exposition;
//! * [`energy`] — per-component energy/area/latency model calibrated to
//!   the paper's reported breakdowns, producing TOPS/W;
//! * substrates built in-repo because the offline crate mirror only
//!   carries the `xla` closure: [`cli`] (argument parsing), [`config`]
//!   (TOML-subset), [`io::json`] (JSON), [`ptest`] (property testing),
//!   [`benchkit`] (benchmark harness), [`util::prng`] (SplitMix64 shared
//!   bit-exactly with Python).

// Repo idiom: configs/metrics are built as `let mut x = X::default()`
// followed by field overrides (mirrors the TOML/CLI override flow).
#![allow(clippy::field_reassign_with_default)]

pub mod analog;
pub mod benchkit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod energy;
pub mod engine;
pub mod figures;
pub mod io;
pub mod macrosim;
pub mod nn;
pub mod obs;
pub mod osa;
pub mod ptest;
pub mod quant;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod spec;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
