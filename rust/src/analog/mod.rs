//! Analog-domain models: variable-precision DAC slice, charge-sharing
//! accumulation, and the 3-bit SAR ADC transfer function.
//!
//! [`adc_transfer`] mirrors `kernels/ref.py::adc_transfer` **operation by
//! operation in f32** so the native simulator and the PJRT artifact agree
//! bit-exactly on the same noise buffer (DESIGN.md §3).

use crate::spec::MacroSpec;

/// Full scale of the charge-share rail for an `nbits`-wide DAC slice.
#[inline]
pub fn full_scale(nbits: i32, sp: &MacroSpec) -> f32 {
    let span = ((1i32 << nbits) - 1) as f32;
    sp.cols as f32 * span * sp.adc_fs_frac
}

/// 3-bit SAR ADC: charge-share voltage -> code -> integer reconstruction.
///
/// * `amac`  — non-negative analog accumulation (sum over columns of
///   `w_bit * slice_value`)
/// * `nbits` — DAC precision of the slice (1..=ANALOG_BAND)
/// * `noise` — input-referred noise in code units (explicit, from the
///   shared PRNG; never sampled here)
#[inline]
pub fn adc_transfer(amac: i32, nbits: i32, noise: f32, sp: &MacroSpec) -> i32 {
    let levels = sp.adc_levels() as f32;
    let fs = full_scale(nbits, sp);
    let scale = levels / fs;
    let v = amac as f32 * scale;
    // mid-tread (unbiased) quantizer: code = round(v), rec = code * step.
    // A midpoint (mid-riser) reconstruction would add a systematic
    // +step/2 offset to every conversion — amplified by 2^(i+j_lo) and
    // accumulated over 8 groups that wrecks the BN-folded biases of the
    // network (measured: ResNet-mini drops to ~50% at B=8).
    // Scrub non-finite noise: a NaN would flow through floor/clamp (both
    // NaN-preserving) into the `as i32` cast and silently saturate the
    // reconstruction — a poisoned logit, not a degraded one.  ±inf is
    // clamped safely but gets the same treatment for symmetry.  Finite
    // noise takes the branch untouched, so this is bit-free on the
    // legacy path (normals_f32 can never produce non-finite values).
    let noise = if noise.is_finite() { noise } else { 0.0 };
    let code = (v + 0.5f32 + noise).floor().clamp(0.0, levels - 1.0);
    (code * (fs / levels) + 0.5f32).floor() as i32
}

/// Device-aware ADC transfer: like [`adc_transfer`] but with an f32
/// accumulation input (per-column static gains make `amac` fractional),
/// an additive code-unit `offset`, and a multiplicative conversion
/// `gain` (DESIGN.md §16).  With `offset == 0.0`, `gain == 1.0` and an
/// integer-valued `amac` this reduces operation-for-operation to
/// [`adc_transfer`]: `amac as f32` is exact up to 2^24 and the largest
/// physical accumulation is `cols * 255` ≈ 2^15.2.
#[inline]
pub fn adc_transfer_dev(
    amac: f32,
    nbits: i32,
    noise: f32,
    offset: f32,
    gain: f32,
    sp: &MacroSpec,
) -> i32 {
    let levels = sp.adc_levels() as f32;
    let fs = full_scale(nbits, sp);
    let scale = levels / fs;
    let v = amac * gain * scale + offset;
    let noise = if noise.is_finite() { noise } else { 0.0 };
    let code = (v + 0.5f32 + noise).floor().clamp(0.0, levels - 1.0);
    (code * (fs / levels) + 0.5f32).floor() as i32
}

/// The DAC slice value of an activation: bits [j_lo, j_hi] as an integer
/// (what the switch-matrix DAC drives onto the GBL).
#[inline]
pub fn dac_slice(a: i32, j_lo: i32, j_hi: i32) -> i32 {
    debug_assert!(j_lo <= j_hi);
    (a >> j_lo) & ((1 << (j_hi - j_lo + 1)) - 1)
}

/// Analog activation-plane range for weight plane `i` at boundary `b`
/// (`None` when the group is empty).  Orders `b-band <= k < b`.
#[inline]
pub fn analog_group_bounds(i: i32, b: i32, sp: &MacroSpec) -> Option<(i32, i32)> {
    let j_lo = (b - sp.analog_band - i).max(0);
    let j_hi = (b - 1 - i).min(sp.a_bits as i32 - 1);
    (j_hi >= j_lo).then_some((j_lo, j_hi))
}

/// Ideal (noise-free, infinite-precision) analog accumulation of a slice
/// — used by SNR analyses to separate quantization from thermal noise.
pub fn ideal_amac(a: &[i32], w_plane_bits: impl Fn(usize) -> i32, j_lo: i32, j_hi: i32) -> i32 {
    a.iter()
        .enumerate()
        .map(|(c, &av)| w_plane_bits(c) * dac_slice(av, j_lo, j_hi))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest::check;

    fn sp() -> MacroSpec {
        MacroSpec::default()
    }

    #[test]
    fn full_scale_value() {
        // 144 * 15 * 0.25 = 540 for a 4-bit slice
        assert_eq!(full_scale(4, &sp()), 540.0);
        assert_eq!(full_scale(1, &sp()), 36.0);
    }

    #[test]
    fn adc_zero_input_is_zero() {
        // mid-tread: no systematic offset at zero input
        assert_eq!(adc_transfer(0, 4, 0.0, &sp()), 0);
        assert_eq!(adc_transfer(0, 1, 0.0, &sp()), 0);
    }

    #[test]
    fn adc_saturates() {
        let hi = adc_transfer(1_000_000, 4, 0.0, &sp());
        let fs = full_scale(4, &sp());
        assert_eq!(hi, ((7.0f32 / 8.0) * fs + 0.5).floor() as i32);
        // negative noise cannot push below code 0
        let lo = adc_transfer(0, 4, -100.0, &sp());
        assert_eq!(lo, 0);
    }

    #[test]
    fn adc_unbiased_over_linear_range() {
        let s = sp();
        let fs = full_scale(4, &s) as i32;
        let mut bias = 0.0f64;
        let mut count = 0usize;
        for amac in 0..fs {
            bias += (adc_transfer(amac, 4, 0.0, &s) - amac) as f64;
            count += 1;
        }
        let step = full_scale(4, &s) as f64 / 8.0;
        assert!((bias / count as f64).abs() < step * 0.15, "bias {}", bias / count as f64);
    }

    #[test]
    fn adc_monotone_in_input() {
        let s = sp();
        let mut prev = i32::MIN;
        for amac in (0..=2160).step_by(20) {
            let r = adc_transfer(amac, 4, 0.0, &s);
            assert!(r >= prev);
            prev = r;
        }
    }

    #[test]
    fn adc_noise_shifts_codes() {
        let s = sp();
        let mid = 270; // half of 4-bit FS
        let base = adc_transfer(mid, 4, 0.0, &s);
        let up = adc_transfer(mid, 4, 1.0, &s);
        assert!(up > base);
    }

    #[test]
    fn adc_nan_noise_degrades_not_poisons() {
        // regression: NaN noise used to flow through floor/clamp into
        // the i32 cast (saturating to 0 silently); it must now behave
        // as a zero-noise conversion at every input level
        let s = sp();
        for amac in [0, 36, 270, 540, 2160] {
            let clean = adc_transfer(amac, 4, 0.0, &s);
            assert_eq!(adc_transfer(amac, 4, f32::NAN, &s), clean, "amac={amac}");
            assert_eq!(adc_transfer(amac, 4, f32::INFINITY, &s), clean, "amac={amac}");
            assert_eq!(adc_transfer(amac, 4, f32::NEG_INFINITY, &s), clean, "amac={amac}");
            assert_eq!(
                adc_transfer_dev(amac as f32, 4, f32::NAN, 0.0, 1.0, &s),
                clean,
                "amac={amac}"
            );
        }
    }

    #[test]
    fn adc_dev_reduces_to_legacy_when_trivial() {
        let s = sp();
        for nbits in 1..=4 {
            let fs = full_scale(nbits, &s) as i32;
            for amac in (0..=fs + 50).step_by(7) {
                for noise in [-1.5f32, -0.3, 0.0, 0.3, 1.5] {
                    assert_eq!(
                        adc_transfer_dev(amac as f32, nbits, noise, 0.0, 1.0, &s),
                        adc_transfer(amac, nbits, noise, &s),
                        "nbits={nbits} amac={amac} noise={noise}"
                    );
                }
            }
        }
    }

    #[test]
    fn adc_dev_offset_and_gain_shift_codes() {
        let s = sp();
        let mid = 270.0; // half of 4-bit FS
        let base = adc_transfer_dev(mid, 4, 0.0, 0.0, 1.0, &s);
        assert!(adc_transfer_dev(mid, 4, 0.0, 1.0, 1.0, &s) > base);
        assert!(adc_transfer_dev(mid, 4, 0.0, 0.0, 1.5, &s) > base);
        assert!(adc_transfer_dev(mid, 4, 0.0, 0.0, 0.5, &s) < base);
        // saturation still holds under extreme gain
        let levels = s.adc_levels() as f32;
        let fs = full_scale(4, &s);
        let top = ((levels - 1.0) * (fs / levels) + 0.5).floor() as i32;
        assert_eq!(adc_transfer_dev(mid, 4, 0.0, 0.0, 100.0, &s), top);
        assert_eq!(adc_transfer_dev(mid, 4, 0.0, -100.0, 1.0, &s), 0);
    }

    #[test]
    fn dac_slice_extraction() {
        assert_eq!(dac_slice(0b1011_0110, 2, 5), 0b1101);
        assert_eq!(dac_slice(255, 4, 7), 15);
        assert_eq!(dac_slice(255, 0, 0), 1);
    }

    #[test]
    fn group_bounds_match_python_semantics() {
        let s = sp();
        // B=8, i=0 -> j in [4, 7]
        assert_eq!(analog_group_bounds(0, 8, &s), Some((4, 7)));
        // B=8, i=7 -> j in [0, 0]
        assert_eq!(analog_group_bounds(7, 8, &s), Some((0, 0)));
        // B=0 -> no analog anywhere
        for i in 0..8 {
            assert_eq!(analog_group_bounds(i, 0, &s), None);
        }
        // B=5, i=7 -> j_hi = -3 < 0: empty
        assert_eq!(analog_group_bounds(7, 5, &s), None);
    }

    #[test]
    fn group_width_at_most_band() {
        let s = sp();
        check("analog group width <= band", 200, |g| {
            let i = g.i32_in(0, 8);
            let b = g.i32_in(0, 16);
            if let Some((lo, hi)) = analog_group_bounds(i, b, &s) {
                assert!(hi - lo + 1 <= s.analog_band);
                assert!(lo >= 0 && hi < s.a_bits as i32);
                // all orders in the group are inside [b-band, b)
                assert!(i + lo >= b - s.analog_band);
                assert!(i + hi < b);
            }
        });
    }
}
