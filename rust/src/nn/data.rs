//! Dataset + golden loaders for the artifacts produced by `make
//! artifacts` (SynthCIFAR images, labels, float/DCIM golden logits).

use crate::io::rten;
use anyhow::{ensure, Context, Result};
use std::path::Path;

/// SynthCIFAR in memory.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub train_x: Vec<u8>,
    pub train_y: Vec<i32>,
    pub test_x: Vec<u8>,
    pub test_y: Vec<i32>,
    pub img_bytes: usize,
}

impl Dataset {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let m = rten::read(&artifacts_dir.join("dataset.rten"))
            .context("loading dataset.rten (run `make artifacts`)")?;
        let tx = m.get("train_x").context("train_x")?;
        let img_bytes: usize = tx.shape[1..].iter().product();
        ensure!(tx.shape[1..] == [32, 32, 3], "unexpected image shape {:?}", tx.shape);
        Ok(Self {
            train_x: tx.as_u8()?.to_vec(),
            train_y: m.get("train_y").context("train_y")?.as_i32()?.to_vec(),
            test_x: m.get("test_x").context("test_x")?.as_u8()?.to_vec(),
            test_y: m.get("test_y").context("test_y")?.as_i32()?.to_vec(),
            img_bytes,
        })
    }

    pub fn train_n(&self) -> usize {
        self.train_y.len()
    }

    pub fn test_n(&self) -> usize {
        self.test_y.len()
    }

    /// Test images `[start, start+n)` as a contiguous byte slice.
    pub fn test_batch(&self, start: usize, n: usize) -> (&[u8], &[i32]) {
        let end = (start + n).min(self.test_n());
        (&self.test_x[start * self.img_bytes..end * self.img_bytes], &self.test_y[start..end])
    }

    pub fn train_batch(&self, start: usize, n: usize) -> (&[u8], &[i32]) {
        let end = (start + n).min(self.train_n());
        (
            &self.train_x[start * self.img_bytes..end * self.img_bytes],
            &self.train_y[start..end],
        )
    }
}

/// Build-time goldens: float logits for the whole test set, DCIM logits
/// for the first `golden_n` images.
#[derive(Debug, Clone)]
pub struct Golden {
    pub float_logits: Vec<f32>,
    pub dcim_logits: Vec<f32>,
    pub labels: Vec<i32>,
    pub golden_n: usize,
    pub classes: usize,
    pub float_acc: f32,
}

impl Golden {
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let m = rten::read(&artifacts_dir.join("golden.rten"))
            .context("loading golden.rten (run `make artifacts`)")?;
        let fl = m.get("float_logits").context("float_logits")?;
        let classes = fl.shape[1];
        Ok(Self {
            float_logits: fl.as_f32()?.to_vec(),
            dcim_logits: m.get("dcim_logits").context("dcim_logits")?.as_f32()?.to_vec(),
            labels: m.get("labels").context("labels")?.as_i32()?.to_vec(),
            golden_n: m.get("golden_n").context("golden_n")?.as_i32()?[0] as usize,
            classes,
            float_acc: m.get("float_acc").context("float_acc")?.as_f32()?[0],
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::io::rten::{Tensor, TensorMap};

    #[test]
    fn dataset_batching() {
        let mut m = TensorMap::new();
        let imgs: Vec<u8> = (0..4 * 32 * 32 * 3).map(|i| (i % 251) as u8).collect();
        m.insert("train_x".into(), Tensor::u8(vec![4, 32, 32, 3], imgs.clone()));
        m.insert("train_y".into(), Tensor::i32(vec![4], vec![0, 1, 2, 3]));
        m.insert("test_x".into(), Tensor::u8(vec![4, 32, 32, 3], imgs));
        m.insert("test_y".into(), Tensor::i32(vec![4], vec![3, 2, 1, 0]));
        let dir = std::env::temp_dir().join(format!("ds_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        crate::io::rten::write(&dir.join("dataset.rten"), &m).unwrap();
        let ds = Dataset::load(&dir).unwrap();
        assert_eq!(ds.test_n(), 4);
        let (x, y) = ds.test_batch(1, 2);
        assert_eq!(y, &[2, 1]);
        assert_eq!(x.len(), 2 * ds.img_bytes);
        // clamped end
        let (_, y) = ds.test_batch(3, 10);
        assert_eq!(y, &[0]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_errors() {
        let dir = std::env::temp_dir().join("definitely_missing_osa_hcim");
        assert!(Dataset::load(&dir).is_err());
        assert!(Golden::load(&dir).is_err());
    }
}
