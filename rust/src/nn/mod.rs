//! Quantized integer CNN engine (ResNet-mini) driven through the macro
//! datapath — the Rust mirror of `python/compile/model.py::quant_forward`.
//!
//! The op graph (`graph.json`) and weights (`weights.rten`) are produced
//! at build time by `python -m compile.aot`; Python never runs here.
//! Any [`GemmEngine`] can back the convolutions: the native cycle-level
//! simulator (`sched::MacroGemm`) or the AOT PJRT artifacts
//! (`runtime::PjrtGemm`).  The executor itself is single-threaded and
//! cheap — each conv's GEMM is where the time goes, and the engine
//! shards it across the shared `sched::exec` pool (DESIGN.md §11), so
//! one `forward` call can use every pool thread.

pub mod data;

use crate::energy::EnergyAccount;
use crate::io::json::JsonValue;
use crate::io::rten;
use crate::obs::LayerSample;
use crate::quant::quantize_act;
use crate::sched::im2col::{im2col, ConvShape};
use crate::sched::{GemmEngine, GemmResult};
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Instant;

/// One quantized conv layer (weights in im2col `[cout, kh*kw*cin]` layout).
#[derive(Debug, Clone)]
pub struct QConv {
    pub name: String,
    pub kh: usize,
    pub kw: usize,
    pub cin: usize,
    pub cout: usize,
    pub stride: usize,
    pub act_scale: f32,
    pub w_scale: f32,
    pub w_q: Vec<i32>,
    pub bias_q: Vec<i32>,
}

/// The quantized FC head.
#[derive(Debug, Clone)]
pub struct QFc {
    pub cin: usize,
    pub cout: usize,
    pub act_scale: f32,
    pub w_scale: f32,
    pub w_q: Vec<i32>,
    pub bias_q: Vec<i32>,
}

/// Graph op, in execution order.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Op {
    /// conv on the running buffer; `relu` applies to the conv output.
    QConv { name: String, relu: bool },
    /// projection shortcut conv on the block input.
    QConvShortcut { name: String },
    /// `h = relu(t + shortcut)`.
    ResidualRelu,
    /// global average pool.
    Gap,
    /// FC head (always exact integer — it is tiny).
    QFc,
}

/// GEMM geometry of one conv layer for a single input image — see
/// [`QGraph::layer_shapes`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerShape {
    /// Engine layer index (same assignment as [`QGraph::gemm_dims`]).
    pub layer_idx: u64,
    pub name: String,
    /// im2col rows for one image: `out_h * out_w`.
    pub m: usize,
    /// Output channels.
    pub n: usize,
    /// Reduction depth: `kh * kw * cin`.
    pub k: usize,
}

/// Loaded quantized model.
#[derive(Debug, Clone)]
pub struct QGraph {
    pub convs: BTreeMap<String, QConv>,
    pub fc: QFc,
    pub ops: Vec<Op>,
    pub num_classes: usize,
}

impl QGraph {
    /// Load `graph.json` + `weights.rten` from the artifacts directory.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let gtext = std::fs::read_to_string(artifacts_dir.join("graph.json"))
            .context("reading graph.json (run `make artifacts`)")?;
        let g = crate::io::json::parse(&gtext)?;
        let weights = rten::read(&artifacts_dir.join("weights.rten"))?;
        Self::from_parts(&g, &weights)
    }

    pub fn from_parts(g: &JsonValue, weights: &rten::TensorMap) -> Result<Self> {
        let mut convs = BTreeMap::new();
        for c in g.get("convs").and_then(JsonValue::as_array).context("graph.convs")? {
            let name = c.get("name").and_then(JsonValue::as_str).context("conv.name")?;
            let get = |k: &str| -> Result<usize> {
                c.get(k).and_then(JsonValue::as_usize).with_context(|| format!("conv.{k}"))
            };
            let w_t = weights
                .get(&format!("{name}.w_q"))
                .with_context(|| format!("{name}.w_q missing from weights.rten"))?;
            let scales = weights
                .get(&format!("{name}.scales"))
                .with_context(|| format!("{name}.scales missing"))?
                .as_f32()?;
            let bias = weights
                .get(&format!("{name}.bias_q"))
                .with_context(|| format!("{name}.bias_q missing"))?
                .as_i32()?
                .to_vec();
            let (kh, kw, cin, cout, stride) =
                (get("kh")?, get("kw")?, get("cin")?, get("cout")?, get("stride")?);
            let w_q: Vec<i32> = w_t.as_i8()?.iter().map(|&x| x as i32).collect();
            if w_t.shape != vec![cout, kh * kw * cin] {
                bail!("{name}: weight shape {:?} != [{cout}, {}]", w_t.shape, kh * kw * cin);
            }
            convs.insert(
                name.to_string(),
                QConv {
                    name: name.to_string(),
                    kh,
                    kw,
                    cin,
                    cout,
                    stride,
                    act_scale: scales[0],
                    w_scale: scales[1],
                    w_q,
                    bias_q: bias,
                },
            );
        }

        let fcj = g.get("fc").context("graph.fc")?;
        let fc_w = weights.get("fc.w_q").context("fc.w_q")?;
        let fc_scales = weights.get("fc.scales").context("fc.scales")?.as_f32()?;
        let fc = QFc {
            cin: fcj.get("cin").and_then(JsonValue::as_usize).context("fc.cin")?,
            cout: fcj.get("cout").and_then(JsonValue::as_usize).context("fc.cout")?,
            act_scale: fc_scales[0],
            w_scale: fc_scales[1],
            w_q: fc_w.as_i8()?.iter().map(|&x| x as i32).collect(),
            bias_q: weights.get("fc.bias_q").context("fc.bias_q")?.as_i32()?.to_vec(),
        };

        let mut ops = Vec::new();
        for o in g.get("ops").and_then(JsonValue::as_array).context("graph.ops")? {
            let kind = o.get("op").and_then(JsonValue::as_str).context("op.op")?;
            ops.push(match kind {
                "qconv" => Op::QConv {
                    name: o.get("name").and_then(JsonValue::as_str).context("op.name")?.into(),
                    relu: o.get("relu").and_then(JsonValue::as_bool).unwrap_or(false),
                },
                "qconv_shortcut" => Op::QConvShortcut {
                    name: o.get("name").and_then(JsonValue::as_str).context("op.name")?.into(),
                },
                "residual_relu" => Op::ResidualRelu,
                "gap" => Op::Gap,
                "qfc" => Op::QFc,
                other => bail!("unknown op {other}"),
            });
        }
        let num_classes =
            g.get("num_classes").and_then(JsonValue::as_usize).context("num_classes")?;
        Ok(Self { convs, fc, ops, num_classes })
    }

    pub fn conv(&self, name: &str) -> Result<&QConv> {
        self.convs.get(name).with_context(|| format!("no conv named {name}"))
    }

    /// `(layer_idx, n, k)` of every GEMM the executor hands the engine,
    /// with the same layer-index assignment as [`Executor::preplan`] /
    /// forward (conv layers only — the FC head runs exact on the host).
    /// The fleet placement planner and `GET /v2/topology` read this.
    pub fn gemm_dims(&self) -> Vec<(u64, usize, usize)> {
        let mut dims = Vec::new();
        let mut layer_idx: u64 = 0;
        for op in &self.ops {
            let name = match op {
                Op::QConv { name, .. } | Op::QConvShortcut { name } => name,
                _ => continue,
            };
            if let Some(conv) = self.convs.get(name) {
                dims.push((layer_idx, conv.cout, conv.kh * conv.kw * conv.cin));
            }
            layer_idx += 1;
        }
        dims
    }

    /// GEMM geometry of every conv layer for a single input image
    /// (`batch = 1`): mirrors the spatial bookkeeping of
    /// [`Executor::forward`] without touching weights or activations.
    /// `m` is the im2col row count (`out_h * out_w`), `(n, k)` match
    /// [`QGraph::gemm_dims`].  The energy dataflow tracer
    /// (`GET /v2/energy`) prices one inference from these shapes.
    pub fn layer_shapes(&self) -> Vec<LayerShape> {
        let out_dims = |conv: &QConv, h: usize, w: usize| {
            let pad = (conv.kh - 1) / 2;
            let oh = (h + 2 * pad - conv.kh) / conv.stride + 1;
            let ow = (w + 2 * pad - conv.kw) / conv.stride + 1;
            (oh, ow)
        };
        let mut shapes = Vec::new();
        let mut cur = (32usize, 32usize); // running buffer `h`
        let mut t_dims = cur; // conv1 output `t`
        let mut block_dims = cur; // block input (shortcut source)
        let mut layer_idx: u64 = 0;
        for op in &self.ops {
            match op {
                Op::QConv { name, .. } => {
                    if let Some(conv) = self.convs.get(name) {
                        let is_conv1 = name.ends_with(".conv1");
                        let input = if name == "stem" || is_conv1 {
                            if is_conv1 {
                                block_dims = cur;
                            }
                            cur
                        } else {
                            t_dims
                        };
                        let (oh, ow) = out_dims(conv, input.0, input.1);
                        shapes.push(LayerShape {
                            layer_idx,
                            name: conv.name.clone(),
                            m: oh * ow,
                            n: conv.cout,
                            k: conv.kh * conv.kw * conv.cin,
                        });
                        if name == "stem" {
                            cur = (oh, ow);
                        } else {
                            t_dims = (oh, ow);
                        }
                    }
                    layer_idx += 1;
                }
                Op::QConvShortcut { name } => {
                    if let Some(conv) = self.convs.get(name) {
                        let (oh, ow) = out_dims(conv, block_dims.0, block_dims.1);
                        shapes.push(LayerShape {
                            layer_idx,
                            name: conv.name.clone(),
                            m: oh * ow,
                            n: conv.cout,
                            k: conv.kh * conv.kw * conv.cin,
                        });
                    }
                    layer_idx += 1;
                }
                Op::ResidualRelu => cur = t_dims,
                Op::Gap | Op::QFc => {}
            }
        }
        shapes
    }

    /// A tiny self-contained graph (stem conv -> GAP -> FC) with
    /// deterministic pseudo-random weights — the stand-in used by benches
    /// and integration tests when the AOT artifacts are not built.  It
    /// exercises the full dataflow (quantize -> im2col -> macro GEMM ->
    /// requantize -> head) on real 32x32x3 inputs; the logits are not
    /// meaningful, only deterministic.
    pub fn synthetic() -> Self {
        let (kh, kw, cin, cout, classes) = (3usize, 3usize, 3usize, 8usize, 10usize);
        let k = kh * kw * cin;
        let mut g = crate::util::prng::SplitMix64::new(0x51D_CA7);
        let w_q: Vec<i32> = (0..cout * k).map(|_| g.next_range_i32(-64, 64)).collect();
        let stem = QConv {
            name: "stem".into(),
            kh,
            kw,
            cin,
            cout,
            stride: 1,
            act_scale: 1.0 / 255.0,
            w_scale: 0.05,
            w_q,
            bias_q: vec![0; cout],
        };
        let fc_w: Vec<i32> = (0..classes * cout).map(|_| g.next_range_i32(-64, 64)).collect();
        let fc = QFc {
            cin: cout,
            cout: classes,
            act_scale: 0.05,
            w_scale: 0.05,
            w_q: fc_w,
            bias_q: vec![0; classes],
        };
        let mut convs = BTreeMap::new();
        convs.insert("stem".to_string(), stem);
        Self {
            convs,
            fc,
            ops: vec![Op::QConv { name: "stem".into(), relu: true }, Op::Gap, Op::QFc],
            num_classes: classes,
        }
    }
}

/// Float NHWC activation buffer.
#[derive(Debug, Clone)]
pub struct FTensor {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<f32>,
}

impl FTensor {
    pub fn new(n: usize, h: usize, w: usize, c: usize) -> Self {
        Self { n, h, w, c, data: vec![0.0; n * h * w * c] }
    }

    pub fn numel(&self) -> usize {
        self.n * self.h * self.w * self.c
    }
}

/// Per-forward statistics: energy, boundary usage, per-layer B_D/A maps,
/// and per-layer timing/energy attribution for the observability spans.
#[derive(Debug, Clone, Default)]
pub struct ForwardStats {
    pub account: EnergyAccount,
    pub b_hist: [u64; 16],
    /// (layer name, out_h, out_w, n_tiles, bda `[n*ho*wo, n_tiles]`).
    pub bda_maps: Vec<(String, usize, usize, usize, Vec<i32>)>,
    /// One sample per executed layer; `offset_us` is relative to the
    /// start of the forward pass (the caller anchors it in wall time).
    pub layers: Vec<LayerSample>,
}

impl ForwardStats {
    fn absorb(&mut self, name: &str, ho: usize, wo: usize, r: &GemmResult, keep_maps: bool) {
        self.account.merge(&r.account);
        for (i, v) in r.b_hist.iter().enumerate() {
            self.b_hist[i] += v;
        }
        if keep_maps {
            self.bda_maps.push((name.to_string(), ho, wo, r.n_tiles, r.bda.clone()));
        }
    }
}

/// The model executor.
pub struct Executor<'a, E: GemmEngine> {
    pub graph: &'a QGraph,
    pub engine: E,
    /// Collect per-layer B_D/A maps (Fig 8) — off by default.
    pub collect_bda: bool,
}

impl<'a, E: GemmEngine> Executor<'a, E> {
    pub fn new(graph: &'a QGraph, engine: E) -> Self {
        Self { graph, engine, collect_bda: false }
    }

    /// Build the engine's execution plan for every conv layer of the
    /// graph up front, with the same layer-index assignment as
    /// [`Self::forward`] — so the executor holds plans for the whole
    /// `QGraph` and the first forward pays no weight-packing cost.
    /// Idempotent: already-cached plans are reused.
    pub fn preplan(&mut self) -> Result<()> {
        let graph = self.graph;
        let mut layer_idx: u64 = 0;
        for op in &graph.ops {
            let name = match op {
                Op::QConv { name, .. } | Op::QConvShortcut { name } => name,
                _ => continue,
            };
            let conv = graph.conv(name)?;
            let k = conv.kh * conv.kw * conv.cin;
            self.engine.prepare(&conv.w_q, conv.cout, k, layer_idx)?;
            layer_idx += 1;
        }
        Ok(())
    }

    /// Quantize a float buffer and run one conv through the engine.
    /// `fwd_start` anchors the layer's timing sample to the forward pass.
    fn qconv(
        &mut self,
        conv: &QConv,
        x: &FTensor,
        layer_idx: u64,
        stats: &mut ForwardStats,
        fwd_start: Instant,
    ) -> Result<FTensor> {
        let t0 = Instant::now();
        let offset_us = t0.duration_since(fwd_start).as_micros() as u64;
        let shape = ConvShape {
            n: x.n,
            h: x.h,
            w: x.w,
            c: x.c,
            kh: conv.kh,
            kw: conv.kw,
            stride: conv.stride,
            pad: (conv.kh - 1) / 2,
        };
        if x.c != conv.cin {
            bail!("{}: input C {} != cin {}", conv.name, x.c, conv.cin);
        }
        let a_q: Vec<i32> = x.data.iter().map(|&v| quantize_act(v, conv.act_scale)).collect();
        let patches = im2col(&a_q, &shape);
        let (m, k) = (shape.rows(), shape.k());
        let r = self.engine.gemm(&patches, m, k, &conv.w_q, conv.cout, layer_idx)?;
        let (ho, wo) = (shape.out_h(), shape.out_w());
        stats.absorb(&conv.name, ho, wo, &r, self.collect_bda);
        let scale = (conv.act_scale as f64 * conv.w_scale as f64) as f32;
        let mut out = FTensor::new(x.n, ho, wo, conv.cout);
        for row in 0..m {
            for c in 0..conv.cout {
                let acc = r.out[row * conv.cout + c] + conv.bias_q[c];
                out.data[row * conv.cout + c] = acc as f32 * scale;
            }
        }
        stats.layers.push(LayerSample {
            name: conv.name.clone(),
            offset_us,
            dur_us: t0.elapsed().as_micros() as u64,
            energy_fj: r.account.breakdown.total_fj(),
            movement_fj: r.account.breakdown.movement_fj,
            macro_ops: r.account.macro_ops,
        });
        Ok(out)
    }

    /// Forward a batch of uint8 images `[n, 32, 32, 3]`.
    /// Returns (logits `[n, classes]`, stats).
    pub fn forward(&mut self, images: &[u8], n: usize) -> Result<(Vec<f32>, ForwardStats)> {
        let (ih, iw, ic) = (32usize, 32usize, 3usize);
        if images.len() != n * ih * iw * ic {
            bail!("expected {} image bytes, got {}", n * ih * iw * ic, images.len());
        }
        let fwd_start = Instant::now();
        let mut stats = ForwardStats::default();
        let mut h = FTensor::new(n, ih, iw, ic);
        for (dst, &src) in h.data.iter_mut().zip(images) {
            *dst = src as f32 / 255.0;
        }
        let mut t: Option<FTensor> = None;
        let mut block_input: Option<FTensor> = None;
        let mut shortcut: Option<FTensor> = None;
        let mut gap: Option<Vec<f32>> = None;
        let mut logits: Option<Vec<f32>> = None;
        let mut layer_idx: u64 = 0;

        for op in &self.graph.ops {
            match op {
                Op::QConv { name, relu } => {
                    let conv = self.graph.conv(name)?;
                    let is_conv1 = name.ends_with(".conv1");
                    let input = if name == "stem" || is_conv1 {
                        if is_conv1 {
                            block_input = Some(h.clone());
                        }
                        &h
                    } else {
                        t.as_ref().context("conv2 before conv1")?
                    };
                    let mut out = self.qconv(conv, input, layer_idx, &mut stats, fwd_start)?;
                    layer_idx += 1;
                    if *relu {
                        for v in &mut out.data {
                            *v = v.max(0.0);
                        }
                    }
                    if name == "stem" {
                        h = out;
                    } else {
                        t = Some(out);
                    }
                }
                Op::QConvShortcut { name } => {
                    let conv = self.graph.conv(name)?;
                    let input = block_input.as_ref().context("shortcut outside block")?;
                    let out = self.qconv(conv, input, layer_idx, &mut stats, fwd_start)?;
                    layer_idx += 1;
                    shortcut = Some(out);
                }
                Op::ResidualRelu => {
                    let tv = t.take().context("residual without conv2")?;
                    let sc = match shortcut.take() {
                        Some(s) => s,
                        None => block_input.take().context("residual without block input")?,
                    };
                    if tv.numel() != sc.numel() {
                        bail!("residual shape mismatch");
                    }
                    let mut out = tv;
                    for (v, s) in out.data.iter_mut().zip(&sc.data) {
                        *v = (*v + s).max(0.0);
                    }
                    block_input = None;
                    h = out;
                }
                Op::Gap => {
                    let hw = (h.h * h.w) as f32;
                    let mut pooled = vec![0.0f32; h.n * h.c];
                    for img in 0..h.n {
                        for y in 0..h.h {
                            for x_ in 0..h.w {
                                for c in 0..h.c {
                                    pooled[img * h.c + c] +=
                                        h.data[((img * h.h + y) * h.w + x_) * h.c + c];
                                }
                            }
                        }
                    }
                    for v in &mut pooled {
                        *v /= hw;
                    }
                    gap = Some(pooled);
                }
                Op::QFc => {
                    let t0 = Instant::now();
                    let fc_offset_us = t0.duration_since(fwd_start).as_micros() as u64;
                    let fc = &self.graph.fc;
                    let input = gap.take().context("fc before gap")?;
                    let scale = (fc.act_scale as f64 * fc.w_scale as f64) as f32;
                    let mut out = vec![0.0f32; n * fc.cout];
                    for img in 0..n {
                        for c in 0..fc.cout {
                            let mut acc = fc.bias_q[c];
                            for i in 0..fc.cin {
                                let q = quantize_act(input[img * fc.cin + i], fc.act_scale);
                                acc += q * fc.w_q[c * fc.cin + i];
                            }
                            out[img * fc.cout + c] = acc as f32 * scale;
                        }
                    }
                    logits = Some(out);
                    // the FC head runs exact on the host — no macro energy
                    stats.layers.push(LayerSample {
                        name: "fc".to_string(),
                        offset_us: fc_offset_us,
                        dur_us: t0.elapsed().as_micros() as u64,
                        energy_fj: 0.0,
                        movement_fj: [0.0; crate::energy::hierarchy::NUM_LEVELS],
                        macro_ops: 0,
                    });
                }
            }
        }
        let logits = logits.context("graph produced no logits")?;
        Ok((logits, stats))
    }
}

/// Index of the largest logit, by `f32::total_cmp`.  `None` for an
/// empty row or one containing any NaN: a NaN-poisoned row (aggressive
/// ACIM noise settings can produce one) cannot express a prediction, so
/// callers count it as a miss or answer a sentinel — the old
/// `max_by(partial_cmp).unwrap()` aborted the whole process instead.
pub fn argmax(row: &[f32]) -> Option<usize> {
    if row.is_empty() || row.iter().any(|v| v.is_nan()) {
        return None;
    }
    row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).map(|(j, _)| j)
}

/// Classification accuracy of logits against labels.  A row with any
/// NaN logit counts as a miss (never a panic).
pub fn accuracy(logits: &[f32], labels: &[i32], classes: usize) -> f64 {
    let n = labels.len();
    let mut correct = 0usize;
    for i in 0..n {
        let row = &logits[i * classes..(i + 1) * classes];
        if argmax(row).map(|p| p as i32) == Some(labels[i]) {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

/// Mean cross-entropy of logits against labels (the calibration loss).
pub fn cross_entropy(logits: &[f32], labels: &[i32], classes: usize) -> f64 {
    let n = labels.len();
    let mut total = 0.0f64;
    for i in 0..n {
        let row = &logits[i * classes..(i + 1) * classes];
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
        let lse = (row.iter().map(|&x| ((x as f64) - max).exp()).sum::<f64>()).ln() + max;
        total += lse - logits[i * classes + labels[i] as usize] as f64;
    }
    total / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_and_ce() {
        let logits = vec![2.0, 0.0, 0.0, 3.0]; // 2 samples, 2 classes
        let labels = vec![0, 1];
        assert_eq!(accuracy(&logits, &labels, 2), 1.0);
        let labels_bad = vec![1, 0];
        assert_eq!(accuracy(&logits, &labels_bad, 2), 0.0);
        let ce = cross_entropy(&logits, &labels, 2);
        assert!(ce > 0.0 && ce < 0.2, "{ce}");
    }

    #[test]
    fn nan_logits_are_a_miss_not_an_abort() {
        // regression: NaN logits used to panic max_by(partial_cmp)
        let logits = vec![f32::NAN, 0.0, 2.0, 1.0]; // 2 samples, 2 classes
        let labels = vec![0, 0];
        // sample 0 is NaN-poisoned -> miss even though NaN sits at the
        // label index; sample 1 predicts class 0 -> hit
        assert_eq!(accuracy(&logits, &labels, 2), 0.5);
        // all-NaN rows: zero accuracy, no panic
        let poisoned = vec![f32::NAN; 4];
        assert_eq!(accuracy(&poisoned, &labels, 2), 0.0);
    }

    #[test]
    fn argmax_semantics() {
        assert_eq!(argmax(&[1.0, 3.0, 2.0]), Some(1));
        assert_eq!(argmax(&[]), None);
        assert_eq!(argmax(&[1.0, f32::NAN]), None);
        // -inf/+inf are ordinary, orderable values
        assert_eq!(argmax(&[f32::NEG_INFINITY, 0.0, f32::INFINITY]), Some(2));
        // ties resolve to the LAST maximal index (max_by keeps later
        // elements on Equal) — stable, documented behavior
        assert_eq!(argmax(&[5.0, 5.0]), Some(1));
    }

    #[test]
    fn ftensor_shapes() {
        let t = FTensor::new(2, 4, 4, 3);
        assert_eq!(t.numel(), 96);
        assert_eq!(t.data.len(), 96);
    }

    #[test]
    fn synthetic_graph_forward_and_preplan() {
        let graph = QGraph::synthetic();
        let gemm = crate::sched::MacroGemm::with_mode(crate::config::CimMode::Dcim);
        let plans = gemm.plan_cache().clone();
        let mut exec = Executor::new(&graph, gemm);
        exec.preplan().unwrap();
        assert_eq!(plans.stats().misses as usize, graph.convs.len());
        let img = vec![128u8; 32 * 32 * 3];
        let (logits, stats) = exec.forward(&img, 1).unwrap();
        assert_eq!(logits.len(), graph.num_classes);
        assert!(stats.account.macro_ops > 0);
        // per-layer attribution: one sample per conv plus the FC head
        assert_eq!(stats.layers.len(), graph.convs.len() + 1);
        assert_eq!(stats.layers[0].name, "stem");
        assert_eq!(stats.layers.last().unwrap().name, "fc");
        assert!(stats.layers[0].energy_fj > 0.0);
        assert_eq!(stats.layers[0].macro_ops, stats.account.macro_ops);
        // forward reused the preplanned layers — no extra packing
        let s = plans.stats();
        assert_eq!(s.misses as usize, graph.convs.len(), "forward re-packed a layer");
        assert!(s.hits >= 1);
    }

    #[test]
    fn layer_shapes_match_gemm_dims() {
        let graph = QGraph::synthetic();
        let shapes = graph.layer_shapes();
        let dims = graph.gemm_dims();
        assert_eq!(shapes.len(), dims.len());
        for (s, (idx, n, k)) in shapes.iter().zip(&dims) {
            assert_eq!(s.layer_idx, *idx);
            assert_eq!(s.n, *n);
            assert_eq!(s.k, *k);
        }
        // stem: 3x3 stride 1 pad 1 on 32x32 -> 32x32 = 1024 rows
        assert_eq!(shapes[0].name, "stem");
        assert_eq!(shapes[0].m, 1024);
    }

    // Full graph execution is covered by rust/tests/nn_end_to_end.rs
    // (requires artifacts) and the quant_parity integration test.
}
