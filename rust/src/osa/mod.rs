//! OSA precision-configuration scheme: threshold calibration (paper
//! Fig. 4b) and the loss-constraint profiles used by Fig. 9.
//!
//! The algorithm is the paper's: given the boundary candidate list
//! `B = [B_0..B_{b-1}]` (coarse -> fine) and user loss constraints
//! `L = [L_0..L_{b-2}]`, iteratively explore each threshold `T_i`
//! between its neighbours to the largest value whose induced loss stays
//! within `L_i`.  Thresholds are "pre-trained, hence they do not incur
//! any additional overhead during the inference".
//!
//! The search is black-box over a loss evaluator (a closure running the
//! quantized model in OSA mode on a calibration set), so the same code
//! calibrates the native simulator and the PJRT path.

use anyhow::{ensure, Result};

/// One step of the calibration log.
#[derive(Debug, Clone)]
pub struct CalStep {
    pub level: usize,
    pub threshold: i32,
    pub loss: f64,
}

/// Calibration output.
#[derive(Debug, Clone)]
pub struct CalibrationResult {
    /// Ascending thresholds, ready for [`crate::macrosim::ose::Ose`].
    pub thresholds: Vec<i32>,
    /// Loss of the final configuration.
    pub final_loss: f64,
    /// Number of evaluator invocations.
    pub evals: usize,
    /// Per-step search log (for EXPERIMENTS.md).
    pub log: Vec<CalStep>,
}

/// Named loss-constraint profiles (the "L" knob of Fig. 9).
/// Values are *allowed loss increase* over the all-digital baseline,
/// per threshold level, in nats of cross-entropy.
pub fn loss_profile(name: &str) -> Option<Vec<f64>> {
    let v: Vec<f64> = match name {
        // < 0.1 % accuracy drop regime
        "tight" => vec![0.002, 0.004, 0.006, 0.008, 0.010],
        "normal" => vec![0.01, 0.02, 0.03, 0.04, 0.05],
        "loose" => vec![0.05, 0.08, 0.12, 0.16, 0.20],
        // maximum-efficiency regime of Table I (5.79 TOPS/W)
        "max-eff" => vec![0.20, 0.30, 0.40, 0.50, 0.60],
        _ => return None,
    };
    Some(v)
}

/// All profile names, in increasing-efficiency order.
pub const PROFILES: [&str; 4] = ["tight", "normal", "loose", "max-eff"];

/// Scale the *calibrated* (`normal`-profile) thresholds onto another
/// loss-constraint profile: each level is multiplied by the ratio of
/// the profile's loss budget to the normal budget (a looser budget
/// admits a higher saliency threshold, steering more MACs into the
/// cheap analog domain), then clamped to stay ascending — the
/// [`crate::macrosim::ose::Ose`] register requirement.
///
/// This is the static flavor of the serving governor's per-tier
/// contract derivation, shared by `serve::governor` and
/// `engine::EngineBuilder::loss_profile`.  `None` for unknown profiles.
pub fn profile_thresholds(calibrated: &[i32], profile: &str) -> Option<Vec<i32>> {
    let normal = loss_profile("normal")?;
    let prof = loss_profile(profile)?;
    let mut ts = Vec::with_capacity(calibrated.len());
    let mut hi = i32::MIN;
    for (i, &t) in calibrated.iter().enumerate() {
        let scale = prof[i % prof.len()] / normal[i % normal.len()].max(1e-12);
        let v = ((t as f64) * scale).round();
        let v = v.clamp(i32::MIN as f64, i32::MAX as f64) as i32;
        // keep ascending even for non-monotone scale ratios
        hi = hi.max(v);
        ts.push(hi);
    }
    Some(ts)
}

/// Calibrate OSE thresholds against a loss evaluator.
///
/// * `loss_fn(thresholds)` — runs the OSA model and returns the loss.
/// * `baseline_loss` — loss of the all-digital (DCIM) configuration.
/// * `constraints` — allowed loss increase per level (len = thresholds).
/// * `s_max` — upper bound of the saliency range to search
///   (e.g. max observed S on the calibration set).
///
/// Level `i` sends samples with `S < T_i` (and above earlier thresholds)
/// to the coarser candidate `B_i`; the search pushes each `T_i` as high
/// as the constraint allows, starting from the coarsest level.  While
/// exploring level `i`, later thresholds are pinned to `T_i` so all
/// higher-saliency samples run at the most precise candidate — exactly
/// the "explore T_i within boundaries B_i and B_i+1" loop of Fig. 4b.
pub fn calibrate_thresholds(
    loss_fn: &mut dyn FnMut(&[i32]) -> f64,
    baseline_loss: f64,
    constraints: &[f64],
    s_max: i32,
    search_steps: u32,
) -> Result<CalibrationResult> {
    ensure!(!constraints.is_empty(), "need at least one loss constraint");
    ensure!(s_max > 0, "s_max must be positive");
    let n = constraints.len();
    let mut thresholds = vec![0i32; n];
    let mut evals = 0usize;
    let mut log = Vec::new();
    let mut final_loss = baseline_loss;

    let mut lower_bound = 0i32;
    for level in 0..n {
        let budget = baseline_loss + constraints[level];
        let mut lo = lower_bound; // loss(T=lo) is within budget (T=prev keeps level empty)
        let mut hi = s_max;
        // pin: thresholds[level..] = candidate T while searching
        let eval_at = |t: i32, ts_now: &[i32], loss_fn: &mut dyn FnMut(&[i32]) -> f64| {
            let mut ts = ts_now.to_vec();
            for slot in ts.iter_mut().skip(level) {
                *slot = t;
            }
            loss_fn(&ts)
        };
        // check if the loosest setting already satisfies the budget
        let loss_hi = eval_at(hi, &thresholds, loss_fn);
        evals += 1;
        if loss_hi <= budget {
            thresholds[level] = hi;
            final_loss = loss_hi;
            log.push(CalStep { level, threshold: hi, loss: loss_hi });
        } else {
            for _ in 0..search_steps {
                let mid = lo + (hi - lo) / 2;
                if mid == lo {
                    break;
                }
                let loss = eval_at(mid, &thresholds, loss_fn);
                evals += 1;
                log.push(CalStep { level, threshold: mid, loss });
                if loss <= budget {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            thresholds[level] = lo;
            final_loss = eval_at(lo, &thresholds, loss_fn);
            evals += 1;
        }
        lower_bound = thresholds[level];
    }
    Ok(CalibrationResult { thresholds, final_loss, evals, log })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic loss model: loss grows with the number of "samples"
    /// (uniform S in [0, 1000]) that land on coarse boundaries.
    fn synthetic_loss(ts: &[i32]) -> f64 {
        // weight coarser levels as lossier
        let mut loss = 0.1; // baseline
        let mut prev = 0i32;
        for (i, &t) in ts.iter().enumerate() {
            let frac = ((t - prev).max(0) as f64) / 1000.0;
            let coarseness = (ts.len() - i) as f64; // level 0 = coarsest
            loss += frac * 0.05 * coarseness;
            prev = t.max(prev);
        }
        loss
    }

    #[test]
    fn calibration_meets_constraints() {
        let mut f = synthetic_loss;
        let baseline = 0.1;
        let constraints = vec![0.02, 0.04, 0.06, 0.08, 0.10];
        let r = calibrate_thresholds(&mut f, baseline, &constraints, 1000, 10).unwrap();
        assert_eq!(r.thresholds.len(), 5);
        // ascending
        for w in r.thresholds.windows(2) {
            assert!(w[0] <= w[1], "{:?}", r.thresholds);
        }
        // final loss within the last constraint
        assert!(r.final_loss <= baseline + constraints[4] + 1e-9);
        // nontrivial: at least one threshold moved off zero
        assert!(r.thresholds.iter().any(|&t| t > 0), "{:?}", r.thresholds);
        assert!(r.evals > 0);
    }

    #[test]
    fn looser_constraints_push_thresholds_higher() {
        let mut f1 = synthetic_loss;
        let mut f2 = synthetic_loss;
        let tight = calibrate_thresholds(&mut f1, 0.1, &[0.005; 5], 1000, 10).unwrap();
        let loose = calibrate_thresholds(&mut f2, 0.1, &[0.08; 5], 1000, 10).unwrap();
        let sum_t: i32 = tight.thresholds.iter().sum();
        let sum_l: i32 = loose.thresholds.iter().sum();
        assert!(sum_l > sum_t, "loose {sum_l} <= tight {sum_t}");
    }

    #[test]
    fn zero_budget_keeps_thresholds_at_zero() {
        let mut f = synthetic_loss;
        let r = calibrate_thresholds(&mut f, 0.1, &[0.0; 5], 1000, 10).unwrap();
        assert!(r.thresholds.iter().all(|&t| t == 0), "{:?}", r.thresholds);
    }

    #[test]
    fn unconstrained_budget_saturates() {
        let mut f = synthetic_loss;
        let r = calibrate_thresholds(&mut f, 0.1, &[10.0; 5], 1000, 10).unwrap();
        assert!(r.thresholds.iter().all(|&t| t == 1000), "{:?}", r.thresholds);
    }

    #[test]
    fn profiles_exist_and_order() {
        let mut prev_last = 0.0;
        for name in PROFILES {
            let p = loss_profile(name).unwrap();
            assert_eq!(p.len(), 5);
            assert!(p.windows(2).all(|w| w[0] <= w[1]));
            assert!(p[4] >= prev_last);
            prev_last = p[4];
        }
        assert!(loss_profile("bogus").is_none());
    }

    #[test]
    fn unknown_profile_is_none_known_profiles_parse() {
        assert!(loss_profile("bogus").is_none());
        assert!(loss_profile("").is_none());
        assert!(loss_profile("TIGHT").is_none(), "profile names are case-sensitive");
        for name in PROFILES {
            let p = loss_profile(name).expect(name);
            assert!(p.iter().all(|&x| x > 0.0), "{name}: non-positive budget {p:?}");
            // budgets grow strictly with the level (coarser levels get
            // strictly more loss headroom)
            assert!(p.windows(2).all(|w| w[0] < w[1]), "{name}: not strictly ascending {p:?}");
        }
    }

    #[test]
    fn calibrated_thresholds_ascending_under_every_profile() {
        for name in PROFILES {
            let mut f = synthetic_loss;
            let constraints = loss_profile(name).unwrap();
            let r = calibrate_thresholds(&mut f, 0.1, &constraints, 1000, 10).unwrap();
            assert!(
                r.thresholds.windows(2).all(|w| w[0] <= w[1]),
                "{name}: {:?}",
                r.thresholds
            );
        }
    }

    #[test]
    fn eval_count_budget_respected() {
        // Per level: one probe at the loose end, at most `search_steps`
        // bisection probes, and one final evaluation.
        let mut f = synthetic_loss;
        let constraints = vec![0.02, 0.04, 0.06, 0.08, 0.10];
        let steps = 6u32;
        let r = calibrate_thresholds(&mut f, 0.1, &constraints, 1000, steps).unwrap();
        let budget = constraints.len() * (steps as usize + 2);
        assert!(r.evals <= budget, "evals {} exceeded budget {budget}", r.evals);
        // the log never records more steps than the evaluator ran
        assert!(r.log.len() <= r.evals);
    }

    #[test]
    fn unconstrained_level_early_stops_with_one_eval() {
        // When the loosest threshold already satisfies every budget the
        // search takes exactly one evaluation per level — the Fig 4b
        // early-stop — instead of burning the full bisection budget.
        let mut f = synthetic_loss;
        let r = calibrate_thresholds(&mut f, 0.1, &[10.0; 5], 1000, 10).unwrap();
        assert_eq!(r.evals, 5, "early-stop should probe each level once");
        assert!(r.thresholds.iter().all(|&t| t == 1000));
    }

    #[test]
    fn input_validation() {
        let mut f = synthetic_loss;
        assert!(calibrate_thresholds(&mut f, 0.1, &[], 1000, 8).is_err());
        assert!(calibrate_thresholds(&mut f, 0.1, &[0.1], 0, 8).is_err());
    }
}
