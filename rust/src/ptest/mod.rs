//! Property-testing mini-framework (proptest is not in the offline
//! mirror — DESIGN.md §1).
//!
//! Deterministic by default (seed from `OSA_HCIM_PTEST_SEED` or a fixed
//! constant), with simple halving/shrink-to-smaller-case support for the
//! built-in generators.  Usage:
//!
//! ```no_run
//! use osa_hcim::ptest::{check, Gen};
//! check("sum is commutative", 200, |g| {
//!     let a = g.i32_in(-1000, 1000);
//!     let b = g.i32_in(-1000, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use crate::util::prng::SplitMix64;

/// Generator handle passed to property bodies.
pub struct Gen {
    rng: SplitMix64,
    /// Log of draws for failure reporting.
    trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed), trace: Vec::new() }
    }

    pub fn u64(&mut self) -> u64 {
        let v = self.rng.next_u64();
        self.trace.push(format!("u64={v}"));
        v
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi);
        let v = lo + self.rng.next_below(hi - lo);
        self.trace.push(format!("usize={v}"));
        v
    }

    pub fn i32_in(&mut self, lo: i32, hi: i32) -> i32 {
        let v = self.rng.next_range_i32(lo, hi);
        self.trace.push(format!("i32={v}"));
        v
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = lo + self.rng.next_f64() * (hi - lo);
        self.trace.push(format!("f64={v:.6}"));
        v
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Vector of i32s in [lo, hi) with the given length.
    pub fn vec_i32(&mut self, len: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..len).map(|_| self.rng.next_range_i32(lo, hi)).collect()
    }

    /// uint8-activation-shaped vector (0..=255).
    pub fn acts(&mut self, len: usize) -> Vec<i32> {
        self.vec_i32(len, 0, 256)
    }

    /// int8-weight-shaped vector (-128..=127).
    pub fn weights(&mut self, len: usize) -> Vec<i32> {
        self.vec_i32(len, -128, 128)
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.next_below(xs.len())]
    }
}

fn base_seed() -> u64 {
    std::env::var("OSA_HCIM_PTEST_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x05A1_1CE5)
}

/// Run `cases` executions of `prop` with independent deterministic seeds.
/// Panics (with the failing seed and draw trace) on the first failure.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, cases: u32, prop: F) {
    let base = base_seed();
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(crate::util::prng::GOLDEN);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
            g
        });
        if let Err(err) = result {
            // replay to capture the trace for the failure report
            let mut g = Gen::new(seed);
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (seed {seed:#x}):\n  {msg}\n  draws: [{}]\n  \
                 reproduce with OSA_HCIM_PTEST_SEED={base}",
                g.trace.join(", ")
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        check("add commutes", 100, |g| {
            let a = g.i32_in(-1000, 1000);
            let b = g.i32_in(-1000, 1000);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check("always fails", 10, |g| {
            let v = g.i32_in(0, 100);
            assert!(v < 0, "v = {v}");
        });
    }

    #[test]
    fn generators_in_range() {
        check("ranges", 50, |g| {
            assert!(g.usize_in(3, 10) >= 3);
            assert!((-5..5).contains(&g.i32_in(-5, 5)));
            let f = g.f64_in(1.0, 2.0);
            assert!((1.0..2.0).contains(&f));
            let acts = g.acts(16);
            assert!(acts.iter().all(|&a| (0..=255).contains(&a)));
            let ws = g.weights(16);
            assert!(ws.iter().all(|&w| (-128..=127).contains(&w)));
            let pick = *g.choose(&[1, 2, 3]);
            assert!([1, 2, 3].contains(&pick));
        });
    }

    #[test]
    fn deterministic_across_runs() {
        let mut g1 = Gen::new(5);
        let mut g2 = Gen::new(5);
        assert_eq!(g1.vec_i32(8, 0, 100), g2.vec_i32(8, 0, 100));
    }
}
