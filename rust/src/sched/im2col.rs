//! im2col lowering of NHWC activations to GEMM rows.
//!
//! Patch layout is (dy, dx, c) with c fastest — identical to
//! `python/compile/model.py::im2col` and the `[cout, kh*kw*cin]` weight
//! matrices stored in `weights.rten`.

/// Shape of an im2col result.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvShape {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvShape {
    pub fn out_h(&self) -> usize {
        (self.h + 2 * self.pad - self.kh) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// GEMM M dimension: one row per output pixel.
    pub fn rows(&self) -> usize {
        self.n * self.out_h() * self.out_w()
    }

    /// GEMM K dimension.
    pub fn k(&self) -> usize {
        self.kh * self.kw * self.c
    }
}

/// Lower `[n, h, w, c]` (row-major i32) to `[rows, k]` patches with zero
/// padding.
pub fn im2col(x: &[i32], shape: &ConvShape) -> Vec<i32> {
    let ConvShape { n, h, w, c, kh, kw, stride, pad } = *shape;
    assert_eq!(x.len(), n * h * w * c, "input length mismatch");
    let (ho, wo) = (shape.out_h(), shape.out_w());
    let k = shape.k();
    let mut out = vec![0i32; shape.rows() * k];
    for img in 0..n {
        for oy in 0..ho {
            for ox in 0..wo {
                let row = ((img * ho + oy) * wo + ox) * k;
                for dy in 0..kh {
                    let iy = (oy * stride + dy) as isize - pad as isize;
                    if iy < 0 || iy >= h as isize {
                        continue;
                    }
                    for dx in 0..kw {
                        let ix = (ox * stride + dx) as isize - pad as isize;
                        if ix < 0 || ix >= w as isize {
                            continue;
                        }
                        let src = ((img * h + iy as usize) * w + ix as usize) * c;
                        let dst = row + (dy * kw + dx) * c;
                        out[dst..dst + c].copy_from_slice(&x[src..src + c]);
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_1x1() {
        let shape = ConvShape { n: 1, h: 2, w: 2, c: 3, kh: 1, kw: 1, stride: 1, pad: 0 };
        let x: Vec<i32> = (0..12).collect();
        assert_eq!(im2col(&x, &shape), x);
        assert_eq!(shape.rows(), 4);
        assert_eq!(shape.k(), 3);
    }

    #[test]
    fn same_padding_3x3() {
        let shape = ConvShape { n: 1, h: 3, w: 3, c: 1, kh: 3, kw: 3, stride: 1, pad: 1 };
        let x: Vec<i32> = (1..=9).collect();
        let p = im2col(&x, &shape);
        assert_eq!(shape.out_h(), 3);
        // center pixel (1,1) sees the full image
        let center = &p[4 * 9..5 * 9];
        assert_eq!(center, &x[..]);
        // corner pixel (0,0): top-left patch has zeros above/left
        let corner = &p[0..9];
        assert_eq!(corner, &[0, 0, 0, 0, 1, 2, 0, 4, 5]);
    }

    #[test]
    fn stride2_shapes() {
        let shape = ConvShape { n: 2, h: 8, w: 8, c: 4, kh: 3, kw: 3, stride: 2, pad: 1 };
        assert_eq!(shape.out_h(), 4);
        assert_eq!(shape.out_w(), 4);
        let x = vec![1i32; 2 * 8 * 8 * 4];
        let p = im2col(&x, &shape);
        assert_eq!(p.len(), shape.rows() * shape.k());
    }

    #[test]
    fn conv_via_im2col_matches_direct() {
        // brute-force direct convolution vs im2col + dot
        let shape = ConvShape { n: 1, h: 5, w: 5, c: 2, kh: 3, kw: 3, stride: 1, pad: 1 };
        let x: Vec<i32> = (0..5 * 5 * 2).map(|i| (i * 7 % 23) as i32).collect();
        let wt: Vec<i32> = (0..3 * 3 * 2).map(|i| (i as i32 % 5) - 2).collect(); // one filter
        let p = im2col(&x, &shape);
        let k = shape.k();
        for oy in 0..5usize {
            for ox in 0..5usize {
                let row = (oy * 5 + ox) * k;
                let got: i32 = (0..k).map(|i| p[row + i] * wt[i]).sum();
                // direct
                let mut want = 0i32;
                for dy in 0..3usize {
                    for dx in 0..3usize {
                        for c in 0..2usize {
                            let iy = oy as isize + dy as isize - 1;
                            let ix = ox as isize + dx as isize - 1;
                            if iy < 0 || iy >= 5 || ix < 0 || ix >= 5 {
                                continue;
                            }
                            let xv = x[((iy as usize * 5) + ix as usize) * 2 + c];
                            want += xv * wt[(dy * 3 + dx) * 2 + c];
                        }
                    }
                }
                assert_eq!(got, want, "pixel ({oy},{ox})");
            }
        }
    }

    #[test]
    #[should_panic(expected = "input length mismatch")]
    fn length_mismatch_panics() {
        let shape = ConvShape { n: 1, h: 2, w: 2, c: 1, kh: 1, kw: 1, stride: 1, pad: 0 };
        im2col(&[1, 2, 3], &shape);
    }
}
