//! Multi-macro fleet execution: shard a layer's `(row-chunk, N-tile)`
//! plan tiles across K simulated HCIM macros (DESIGN.md §14).
//!
//! A single 64x144 macro holds at most one packed weight tile at a time;
//! a *fleet* models K macros, each with a weight-stationary residency
//! budget of `residency_tiles` packed tiles (`rows x cols` bit-planes,
//! [`tile_bytes`] each).  The placement planner ([`super::plan`]) maps
//! every layer tile to a macro, preferring whole output columns per
//! macro (no reduce cost) and splitting the K dimension only when one
//! column's K-tiles exceed a macro's residency.  Split-K is the case
//! that costs extra: partial sums must hop between macros to reduce, and
//! [`FleetGemm`] charges an explicit per-hop energy + latency for it on
//! top of the unchanged per-macro op energy.
//!
//! **Determinism contract**: execution reuses the exact single-macro
//! work units ([`super::cim_unit`]) with the exact per-`(seed, layer,
//! row, N-tile)` noise streams — placement can never shift a logit.  The
//! fleet only *reorders* unit execution into per-macro work queues
//! (units sorted by owning macro, then unit index) and merges results in
//! that fixed queue order.  At K=1 the queue order is the identity, so
//! logits, `b_hist`, *and the f64 energy totals* are bit-identical to
//! [`MacroGemm`].  For K>1 the merge order differs, so energy f64s may
//! differ across K in the last ulps while logits stay bit-identical.
//!
//! [`WeightPool`] is the CIMPool-style spill strategy (arxiv
//! 2503.22044): identical packed tiles are stored once in a shared pool
//! with an index map, shrinking a layer's residency demand by its dedup
//! ratio when a model exceeds aggregate fleet capacity.

use super::plan::{
    weight_fingerprint, FleetDims, LayerPlacement, LayerPlan, PlacementMode, PlacementPlan,
    PlanScope,
};
use super::{cim_unit, pad_cols, GemmEngine, GemmResult, MacroGemm, UNIT_ROWS};
use crate::config::CimMode;
use crate::energy::EnergyAccount;
use crate::quant::PackedBits;
use crate::spec::MacroSpec;
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Registry name of the fleet backend.
pub const BACKEND_NAME: &str = "macro-fleet";

/// Bytes of SRAM one packed weight tile occupies: `rows x cols` cells,
/// one bit each, with rows = hmus * w_bits already encoding the
/// bit-planes (64 x 144 / 8 = 1152 B on the paper geometry).
pub fn tile_bytes(sp: &MacroSpec) -> u64 {
    (sp.hmus * sp.w_bits * sp.cols) as u64 / 8
}

/// Tile geometry `(nt, kt)` of a `[n, k]` GEMM on this spec.
pub fn layer_tiles(n: usize, k: usize, sp: &MacroSpec) -> (usize, usize) {
    (n.div_ceil(sp.hmus).max(1), k.div_ceil(sp.cols).max(1))
}

/// Whole-model placement for a list of `(layer_idx, n, k)` GEMM dims —
/// what `GET /v2/topology` reports.  Residency demand is the raw
/// (un-pooled) tile count; execution-side placement additionally dedups
/// via [`WeightPool`] in `auto` mode.
pub fn plan_for_dims(
    dims: &[(u64, usize, usize)],
    sp: &MacroSpec,
    fleet: FleetDims,
    mode: PlacementMode,
) -> PlacementPlan {
    let layers: Vec<(u64, usize, usize, usize)> = dims
        .iter()
        .map(|&(idx, n, k)| {
            let (nt, kt) = layer_tiles(n, k, sp);
            (idx, nt, kt, nt * kt)
        })
        .collect();
    PlacementPlan::plan(&layers, fleet, mode)
}

/// CIMPool-style weight pool: a layer's packed tiles deduplicated into
/// shared storage plus an index map.  Lossless — [`WeightPool::reconstruct`]
/// rebuilds the exact `[n, k]` weight matrix.
#[derive(Debug, Clone)]
pub struct WeightPool {
    pub nt: usize,
    pub kt: usize,
    pub hmus: usize,
    pub cols: usize,
    /// Unique padded tiles, `hmus * cols` i32 each.
    pub tiles: Vec<Vec<i32>>,
    /// Logical tile `(ni, ki)` (index `ni*kt + ki`) -> pool slot.
    pub index: Vec<u32>,
}

impl WeightPool {
    /// Pool a built layer plan's packed tiles.  Dedup is by content
    /// (fingerprint bucket + full compare, so a fingerprint collision
    /// can never alias two different tiles).
    pub fn from_plan(plan: &LayerPlan) -> Self {
        let sp = plan.spec;
        let mut tiles: Vec<Vec<i32>> = Vec::new();
        let mut index = Vec::with_capacity(plan.nt * plan.kt);
        let mut buckets: HashMap<u64, Vec<u32>> = HashMap::new();
        for ni in 0..plan.nt {
            for ki in 0..plan.kt {
                let w = plan.unit(ni, ki).weights();
                let bucket = buckets.entry(weight_fingerprint(w)).or_default();
                let slot = match bucket.iter().copied().find(|&s| tiles[s as usize] == w) {
                    Some(s) => s,
                    None => {
                        let s = tiles.len() as u32;
                        tiles.push(w.to_vec());
                        bucket.push(s);
                        s
                    }
                };
                index.push(slot);
            }
        }
        Self { nt: plan.nt, kt: plan.kt, hmus: sp.hmus, cols: sp.cols, tiles, index }
    }

    /// Unique tiles actually stored (the pooled residency demand).
    pub fn unique_tiles(&self) -> usize {
        self.tiles.len()
    }

    /// Logical tiles the layer addresses (`nt * kt`).
    pub fn logical_tiles(&self) -> usize {
        self.index.len()
    }

    /// Dedup ratio, logical / unique (>= 1.0).
    pub fn compression(&self) -> f64 {
        self.logical_tiles() as f64 / self.unique_tiles().max(1) as f64
    }

    /// Rebuild the exact `[n, k]` weight matrix from the pool + index
    /// map (padding columns/rows are dropped).
    pub fn reconstruct(&self, n: usize, k: usize) -> Vec<i32> {
        let mut out = vec![0i32; n * k];
        for ni in 0..self.nt {
            for ki in 0..self.kt {
                let tile = &self.tiles[self.index[ni * self.kt + ki] as usize];
                let c0 = ki * self.cols;
                let width = self.cols.min(k.saturating_sub(c0));
                for h in 0..self.hmus {
                    let row = ni * self.hmus + h;
                    if row >= n || width == 0 {
                        continue;
                    }
                    out[row * k + c0..row * k + c0 + width]
                        .copy_from_slice(&tile[h * self.cols..h * self.cols + width]);
                }
            }
        }
        out
    }
}

/// Fleet GEMM engine: [`MacroGemm`] semantics sharded over K simulated
/// macros with per-macro work queues, split-K transfer accounting, and
/// per-macro cycle attribution (the modeled fleet-scaling curve).
///
/// Cloning shares the plan cache, the placement cache, and the exec
/// pool with the source engine, like [`MacroGemm`].
#[derive(Debug, Clone)]
pub struct FleetGemm {
    base: MacroGemm,
    fleet: FleetDims,
    placement_mode: PlacementMode,
    /// Energy per partial sum per inter-macro hop, femtojoules.
    pub hop_energy_fj: f64,
    /// Latency per inter-macro hop, analog-clock cycles.
    pub hop_latency_cycles: u64,
    /// Per-layer placements, shared across clones (same lifetime rules
    /// as the plan cache: stable `layer_idx` per weight matrix).
    placements: Arc<Mutex<HashMap<u64, Arc<LayerPlacement>>>>,
}

impl FleetGemm {
    /// Wrap a configured single-macro engine into a fleet.  The base
    /// engine's plan-cache scope is re-pinned to the fleet's
    /// `(backend, fleet_k, placement)` key so fleet plans never collide
    /// with single-macro plans in a shared cache.
    pub fn new(
        base: MacroGemm,
        fleet: FleetDims,
        placement_mode: PlacementMode,
        hop_energy_fj: f64,
        hop_latency_cycles: u64,
    ) -> Self {
        let scope = PlanScope::for_backend(BACKEND_NAME, fleet.macros, placement_mode);
        Self {
            base: base.with_plan_scope(scope),
            fleet,
            placement_mode,
            hop_energy_fj,
            hop_latency_cycles,
            placements: Arc::new(Mutex::new(HashMap::new())),
        }
    }

    pub fn base(&self) -> &MacroGemm {
        &self.base
    }

    /// Mutable access to the wrapped single-macro engine — the scalar
    /// knob path (`noise_seed`, `fixed_b`, OSE registers).
    pub fn base_mut(&mut self) -> &mut MacroGemm {
        &mut self.base
    }

    pub fn fleet(&self) -> FleetDims {
        self.fleet
    }

    pub fn placement_mode(&self) -> PlacementMode {
        self.placement_mode
    }

    /// The placement chosen for `layer_idx` (planned on first use).
    pub fn placement_of(&self, layer_idx: u64) -> Option<Arc<LayerPlacement>> {
        self.placements.lock().unwrap().get(&layer_idx).cloned()
    }

    fn placement_for(&self, plan: &Arc<LayerPlan>) -> Arc<LayerPlacement> {
        let mut map = self.placements.lock().unwrap();
        map.entry(plan.layer_idx)
            .or_insert_with(|| {
                // pooling (auto only) shrinks the residency demand fed
                // to the planner by the layer's dedup ratio
                let unique = if self.placement_mode == PlacementMode::Auto {
                    WeightPool::from_plan(plan).unique_tiles()
                } else {
                    plan.nt * plan.kt
                };
                Arc::new(LayerPlacement::plan(
                    plan.layer_idx,
                    plan.nt,
                    plan.kt,
                    unique,
                    self.fleet,
                    self.placement_mode,
                ))
            })
            .clone()
    }

    /// Per-K-tile cycle count for a row that resolved boundary `b` —
    /// the same op-count template [`EnergyAccount::record`] charged, so
    /// per-macro attribution sums exactly to the aggregate `cycles`.
    fn tile_cycles(&self, plan: &LayerPlan, b: i32) -> u64 {
        let counts = match self.base.mode {
            CimMode::Pg | CimMode::Drq => unreachable!("dual precision delegates to the base"),
            CimMode::Dcim => plan.counts(0, false),
            CimMode::Acim => plan.acim_counts(),
            CimMode::Hcim => plan.counts(b, false),
            CimMode::Osa => plan.counts(b, true),
        };
        counts.total_cycles() as u64
    }

    /// Fleet CIM executor: same prologue and work units as
    /// [`MacroGemm`]'s CIM path, but units run in per-macro queue order
    /// and the merge adds split-K transfer cost + per-macro cycles.
    fn execute_cim_fleet(
        &self,
        plan: &Arc<LayerPlan>,
        a: &[i32],
        m: usize,
        k: usize,
        layer_idx: u64,
    ) -> Result<GemmResult> {
        let sp = self.base.spec;
        let (kt, nt, k_pad, n_pad, n) = (plan.kt, plan.nt, plan.k_pad, plan.n_pad, plan.n);
        let lp = self.placement_for(plan);
        let a_p: Arc<Vec<i32>> = Arc::new(pad_cols(a, m, k, k_pad));

        let mut packed = Vec::new();
        if self.base.mode != CimMode::Dcim {
            packed.reserve(m * kt);
            for s in 0..m {
                for ki in 0..kt {
                    let tile = &a_p[s * k_pad + ki * sp.cols..s * k_pad + (ki + 1) * sp.cols];
                    packed.push(PackedBits::pack(tile, sp.a_bits, false));
                }
            }
        }
        let a_packed: Arc<Vec<PackedBits>> = Arc::new(packed);

        let n_slices = self.base.n_slices();
        let chunks = m.div_ceil(UNIT_ROWS).max(1);
        let nu = chunks * nt;

        // Per-macro work queues: a unit is owned by the macro holding
        // its replica's first K-tile; queues drain in unit-index order.
        // At K=1 every owner is macro 0, so the order is the identity —
        // the bit-parity guarantee with the single-macro path.
        let owner = |u: usize| {
            let (ci, ni) = (u / nt, u % nt);
            lp.macro_of(ni, 0, ci % lp.replicas)
        };
        let mut order: Vec<usize> = (0..nu).collect();
        order.sort_by_key(|&u| (owner(u), u));

        let results = self.base.pool().run_indexed(nu, |slot| {
            let u = order[slot];
            let (ci, ni) = (u / nt, u % nt);
            let (s0, s1) = (ci * UNIT_ROWS, ((ci + 1) * UNIT_ROWS).min(m));
            let plan = plan.clone();
            let a_p = a_p.clone();
            let a_packed = a_packed.clone();
            let mode = self.base.mode;
            let ose = self.base.ose.clone();
            let energy = self.base.energy;
            let fixed_b = self.base.fixed_b;
            let noise_seed = self.base.noise_seed;
            let device = self.base.device().clone();
            move || {
                cim_unit(
                    &plan, &a_p, &a_packed, mode, &ose, energy, fixed_b, noise_seed, layer_idx,
                    k, s0, s1, ni, n_slices, &device,
                )
            }
        });

        let mut out = vec![0i32; m * n_pad];
        let mut account = EnergyAccount::default();
        let mut b_hist = [0u64; 16];
        let mut bda = vec![0i32; m * nt];
        let mut macro_cycles = vec![0u64; self.fleet.macros.max(1)];
        for (slot, unit) in results.iter().enumerate() {
            let u = order[slot];
            let (ci, ni) = (u / nt, u % nt);
            let s0 = ci * UNIT_ROWS;
            let replica = ci % lp.replicas;
            let span = lp.k_span(ni);
            for (r, &b) in unit.boundaries.iter().enumerate() {
                let s = s0 + r;
                bda[s * nt + ni] = b;
                if (0..16).contains(&b) {
                    b_hist[b as usize] += kt as u64;
                }
                out[s * n_pad + ni * sp.hmus..s * n_pad + (ni + 1) * sp.hmus]
                    .copy_from_slice(&unit.vals[r * sp.hmus..(r + 1) * sp.hmus]);
                // per-macro cycle attribution: each K-tile's op runs on
                // the macro that holds the tile
                let per_tile = self.tile_cycles(plan, b);
                for ki in 0..kt {
                    macro_cycles[lp.macro_of(ni, ki, replica)] += per_tile;
                }
                // split-K reduce: (span-1) hops per row, each carrying
                // the N-tile's hmus partial sums; latency lands on the
                // macro that owns the reduce tail
                if span > 1 {
                    let hops = (span - 1) as u64 * sp.hmus as u64;
                    account.transfer_hops += hops;
                    account.transfer_fj += hops as f64 * self.hop_energy_fj;
                    let lat = (span - 1) as u64 * self.hop_latency_cycles;
                    account.cycles += lat;
                    macro_cycles[lp.macro_of(ni, kt - 1, replica)] += lat;
                }
            }
            account.merge(&unit.account);
        }
        account.macro_cycles = macro_cycles;

        let mut final_out = vec![0i32; m * n];
        for s in 0..m {
            final_out[s * n..(s + 1) * n].copy_from_slice(&out[s * n_pad..s * n_pad + n]);
        }
        // hierarchy cost model: price this call's data movement from
        // the plan + placement geometry — a deterministic post-pass, so
        // fleet merge order can never shift the f64s (hops themselves
        // stay priced via transfer_fj above, never double-counted)
        self.base.price_movement(&mut account, m, plan, Some(&lp));
        Ok(GemmResult { out: final_out, m, n, account, b_hist, bda, n_tiles: nt })
    }

    /// The placement's dataflow trace for a hypothetical `m`-row call of
    /// `layer_idx` (for `GET /v2/energy`); `None` until the layer has
    /// been planned or when running the compact model.
    pub fn movement_trace(
        &self,
        layer_idx: u64,
        m: usize,
        plan: &LayerPlan,
    ) -> Option<crate::energy::dataflow::DataflowTrace> {
        let hier = self.base.hierarchy()?;
        let lp = self.placement_of(layer_idx)?;
        Some(crate::energy::dataflow::trace_layer(m, plan, Some(&lp), hier))
    }
}

impl GemmEngine for FleetGemm {
    fn name(&self) -> &str {
        BACKEND_NAME
    }

    fn prepare(&mut self, w: &[i32], n: usize, k: usize, layer_idx: u64) -> Result<()> {
        self.base.prepare(w, n, k, layer_idx)
    }

    fn gemm(
        &mut self,
        a: &[i32],
        m: usize,
        k: usize,
        w: &[i32],
        n: usize,
        layer_idx: u64,
    ) -> Result<GemmResult> {
        // PG/DRQ are all-digital dual-precision baselines with no macro
        // residency story; they run the base executor unchanged.
        if matches!(self.base.mode, CimMode::Pg | CimMode::Drq) {
            return self.base.gemm(a, m, k, w, n, layer_idx);
        }
        let plan = self.base.plan_cache().get_or_build_scoped(
            self.base.plan_scope(),
            layer_idx,
            w,
            n,
            k,
            self.base.spec,
        )?;
        self.execute_cim_fleet(&plan, a, m, k, layer_idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::SplitMix64;

    fn rand_mat(g: &mut SplitMix64, rows: usize, cols: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..rows * cols).map(|_| g.next_range_i32(lo, hi)).collect()
    }

    fn fleet_of(mode: CimMode, macros: usize, residency_tiles: usize) -> FleetGemm {
        FleetGemm::new(
            MacroGemm::with_mode(mode),
            FleetDims { macros, residency_tiles },
            PlacementMode::Auto,
            120.0,
            2,
        )
    }

    #[test]
    fn tile_bytes_matches_paper_geometry() {
        // 64 rows x 144 cols, one bit per cell = 1152 bytes
        assert_eq!(tile_bytes(&MacroSpec::default()), 1152);
    }

    #[test]
    fn k1_fleet_is_bit_identical_to_single_macro() {
        let mut rng = SplitMix64::new(11);
        let (m, k, n) = (20, 300, 20);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        for mode in [CimMode::Osa, CimMode::Hcim, CimMode::Dcim, CimMode::Acim] {
            let base = MacroGemm::with_mode(mode).gemm(&a, m, k, &w, n, 7).unwrap();
            let fleet = fleet_of(mode, 1, 1).gemm(&a, m, k, &w, n, 7).unwrap();
            assert_eq!(fleet.out, base.out, "{mode:?} logits");
            assert_eq!(fleet.bda, base.bda, "{mode:?} bda");
            assert_eq!(fleet.b_hist, base.b_hist, "{mode:?} b_hist");
            assert_eq!(
                fleet.account.total_energy_j().to_bits(),
                base.account.total_energy_j().to_bits(),
                "{mode:?} energy must be f64-bit-identical at K=1"
            );
            assert_eq!(fleet.account.cycles, base.account.cycles, "{mode:?} cycles");
            assert_eq!(fleet.account.transfer_fj, 0.0);
            // per-macro attribution covers the whole execution exactly
            assert_eq!(fleet.account.macro_cycles, vec![base.account.cycles]);
        }
    }

    #[test]
    fn split_k_charges_transfer_but_never_shifts_logits() {
        let mut rng = SplitMix64::new(12);
        // kt = 3 > residency 1 -> every column spans 3 macros
        let (m, k, n) = (8, 3 * crate::spec::COLS, 16);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let base = MacroGemm::with_mode(CimMode::Osa).gemm(&a, m, k, &w, n, 3).unwrap();
        let mut fleet = fleet_of(CimMode::Osa, 4, 1);
        let r = fleet.gemm(&a, m, k, &w, n, 3).unwrap();
        assert_eq!(r.out, base.out, "placement must never shift logits");
        assert_eq!(r.bda, base.bda);
        let lp = fleet.placement_of(3).unwrap();
        assert!(lp.split_k());
        assert!(r.account.transfer_fj > 0.0);
        assert!(r.account.transfer_hops > 0);
        assert!(r.account.transfer_fraction() > 0.0);
        // reduce latency is on top of the base compute cycles
        assert!(r.account.cycles > base.account.cycles);
        // work landed on more than one macro
        let busy = r.account.macro_cycles.iter().filter(|&&c| c > 0).count();
        assert!(busy > 1, "macro_cycles = {:?}", r.account.macro_cycles);
        // expected hop count: (span-1) * hmus partial sums per row per
        // N-tile column
        let spans: u64 = (0..lp.nt).map(|ni| (lp.k_span(ni) - 1) as u64).sum();
        let hmus = MacroSpec::default().hmus as u64;
        assert_eq!(r.account.transfer_hops, m as u64 * spans * hmus);
        assert_eq!(
            r.account.transfer_fj,
            r.account.transfer_hops as f64 * fleet.hop_energy_fj
        );
    }

    #[test]
    fn fleet_runs_are_repeatable_per_k() {
        let mut rng = SplitMix64::new(13);
        let (m, k, n) = (10, 300, 12);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let k1 = fleet_of(CimMode::Osa, 1, 64).gemm(&a, m, k, &w, n, 0).unwrap();
        for macros in [2, 4] {
            let mut f = fleet_of(CimMode::Osa, macros, 1);
            let r1 = f.gemm(&a, m, k, &w, n, 0).unwrap();
            let r2 = f.gemm(&a, m, k, &w, n, 0).unwrap();
            assert_eq!(r1.out, r2.out, "K={macros} repeatable");
            assert_eq!(
                r1.account.total_energy_j().to_bits(),
                r2.account.total_energy_j().to_bits(),
                "K={macros} energy repeatable"
            );
            assert_eq!(r1.out, k1.out, "K={macros} logits match K=1");
        }
    }

    #[test]
    fn replicated_layers_spread_work_across_the_fleet() {
        let mut rng = SplitMix64::new(14);
        // one tile per layer, fleet of 4 with room: replicas = 4, row
        // chunks round-robin across them
        let (m, k, n) = (64, 100, 8);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let mut f = fleet_of(CimMode::Hcim, 4, 4);
        let r = f.gemm(&a, m, k, &w, n, 0).unwrap();
        let lp = f.placement_of(0).unwrap();
        assert_eq!(lp.replicas, 4);
        assert!(!lp.split_k());
        assert_eq!(r.account.transfer_fj, 0.0, "replication alone costs no transfer");
        let busy = r.account.macro_cycles.iter().filter(|&&c| c > 0).count();
        assert_eq!(busy, 4, "macro_cycles = {:?}", r.account.macro_cycles);
        // attribution is exhaustive: per-macro cycles sum to the
        // aggregate (no reduce latency here)
        assert_eq!(r.account.macro_cycles.iter().sum::<u64>(), r.account.cycles);
        assert!(r.account.fleet_seconds() < r.account.seconds());
    }

    #[test]
    fn weight_pool_round_trips_exactly() {
        let sp = MacroSpec::default();
        let mut rng = SplitMix64::new(15);
        let (n, k) = (20, 300);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let plan = LayerPlan::build(&w, n, k, 0, sp).unwrap();
        let pool = WeightPool::from_plan(&Arc::new(plan));
        assert_eq!(pool.logical_tiles(), pool.nt * pool.kt);
        assert_eq!(pool.reconstruct(n, k), w, "pool + index map must rebuild exact weights");
    }

    #[test]
    fn weight_pool_dedups_identical_tiles() {
        let sp = MacroSpec::default();
        // two K-tiles per row with identical contents: w = [t | t]
        let (n, k) = (8, 2 * sp.cols);
        let mut rng = SplitMix64::new(16);
        let half = rand_mat(&mut rng, n, sp.cols, -128, 128);
        let mut w = Vec::with_capacity(n * k);
        for r in 0..n {
            w.extend_from_slice(&half[r * sp.cols..(r + 1) * sp.cols]);
            w.extend_from_slice(&half[r * sp.cols..(r + 1) * sp.cols]);
        }
        let plan = LayerPlan::build(&w, n, k, 0, sp).unwrap();
        let pool = WeightPool::from_plan(&plan);
        assert_eq!(pool.logical_tiles(), 2);
        assert_eq!(pool.unique_tiles(), 1, "identical K-tiles must share one pool slot");
        assert!((pool.compression() - 2.0).abs() < 1e-12);
        assert_eq!(pool.reconstruct(n, k), w);
    }

    #[test]
    fn plan_for_dims_reports_topology() {
        let sp = MacroSpec::default();
        let fleet = FleetDims { macros: 4, residency_tiles: 1 };
        // layer 0: k = 2*cols -> kt=2 > residency -> split-K
        let pp = plan_for_dims(
            &[(0, 8, 2 * sp.cols), (1, 8, 100)],
            &sp,
            fleet,
            PlacementMode::Auto,
        );
        assert_eq!(pp.layers.len(), 2);
        assert!(pp.layers[0].split_k());
        assert!(!pp.layers[1].split_k());
        assert_eq!(pp.capacity_tiles(), 4);
        assert_eq!(pp.macro_residency().len(), 4);
    }
}
