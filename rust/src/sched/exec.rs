//! Persistent tile-execution pool — the parallel substrate under the
//! macro GEMM (DESIGN.md §11).
//!
//! The HCIMA derives its throughput from many macros firing
//! concurrently (split-port 6T cells let the DCIM and ACIM paths run in
//! the same cycle); this module is the simulator-side analogue: a
//! std-only pool of worker threads (rayon is not in the offline mirror)
//! that executes a layer's `(row-chunk, N-tile)` work units in any
//! order on any number of cores.
//!
//! Determinism contract: a work unit's result may depend only on the
//! unit's *coordinates*, never on the execution schedule.  Engines
//! enforce this by seeding every unit's noise stream from
//! `prng::unit_noise_seed(seed, layer, row, tile)` and by merging unit
//! results in index order ([`ExecPool::run_indexed`]) — so outputs,
//! boundary maps and even the f64 energy totals are bit-identical for
//! any thread count, including 1.
//!
//! Sharing contract: one pool per process (or per server) is the rule —
//! coordinator workers all submit onto the same pool, so tile-level
//! parallelism is bounded by the pool size rather than multiplied by
//! the worker count, and concurrent requests interleave at work-unit
//! granularity (a lone gold-tier request can use every pool thread).
//!
//! Shutdown contract: dropping the last handle drains every queued job
//! before the workers exit — no work unit is ever lost, and a panicking
//! job is contained to its unit (the worker survives; the submitter
//! sees the missing unit).  Jobs must never block on the pool they run
//! on (no nested submission).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, OnceLock};

type Job = Box<dyn FnOnce() + Send + 'static>;

struct PoolState {
    jobs: VecDeque<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    cv: Condvar,
}

/// A fixed-size pool of persistent worker threads executing boxed jobs
/// from one FIFO queue.  Cheap to share via `Arc`; see the module docs
/// for the determinism / sharing / shutdown contracts.
pub struct ExecPool {
    shared: Arc<Shared>,
    threads: usize,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl std::fmt::Debug for ExecPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ExecPool").field("threads", &self.threads).finish()
    }
}

/// Engine worker count when nothing is configured: the
/// `OSA_ENGINE_THREADS` env override, else every available core.
pub fn auto_threads() -> usize {
    std::env::var("OSA_ENGINE_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1))
}

impl ExecPool {
    /// Spawn a pool of `threads` workers (clamped to at least 1).
    pub fn new(threads: usize) -> Arc<Self> {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState { jobs: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        });
        let mut workers = Vec::with_capacity(threads);
        for wid in 0..threads {
            let shared = shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("exec-{wid}"))
                .spawn(move || worker_loop(&shared))
                .expect("spawning exec pool worker");
            workers.push(handle);
        }
        Arc::new(Self { shared, threads, workers: Mutex::new(workers) })
    }

    /// The process-wide default pool, sized by [`auto_threads`] on first
    /// use.  Engines built without an explicit pool share this one.
    pub fn global() -> Arc<ExecPool> {
        static GLOBAL: OnceLock<Arc<ExecPool>> = OnceLock::new();
        GLOBAL.get_or_init(|| ExecPool::new(auto_threads())).clone()
    }

    /// Worker count of this pool.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Queued (not yet started) job count — observability only.
    pub fn queued(&self) -> usize {
        self.shared.state.lock().unwrap().jobs.len()
    }

    /// Enqueue one fire-and-forget job.
    pub fn spawn<F: FnOnce() + Send + 'static>(&self, job: F) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.jobs.push_back(Box::new(job));
        }
        self.shared.cv.notify_one();
    }

    /// Run `n` independent work units on the pool and return their
    /// results **in unit-index order** (the deterministic-merge
    /// primitive).  `make(i)` builds unit `i`'s closure; units must be
    /// independent and must not submit onto this pool.
    ///
    /// Panics if a unit's result never arrives (i.e. the unit itself
    /// panicked) — a lost work unit is a bug, never silent data loss.
    pub fn run_indexed<T, J, F>(&self, n: usize, make: F) -> Vec<T>
    where
        T: Send + 'static,
        J: FnOnce() -> T + Send + 'static,
        F: Fn(usize) -> J,
    {
        let (tx, rx) = std::sync::mpsc::channel::<(usize, T)>();
        for i in 0..n {
            let unit = make(i);
            let tx = tx.clone();
            self.spawn(move || {
                let _ = tx.send((i, unit()));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<T>> = (0..n).map(|_| None).collect();
        for (i, v) in rx {
            slots[i] = Some(v);
        }
        slots
            .into_iter()
            .enumerate()
            .map(|(i, s)| s.unwrap_or_else(|| panic!("work unit {i} lost (panicked?)")))
            .collect()
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.cv.notify_all();
        let mut workers = self.workers.lock().unwrap();
        for h in workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if let Some(job) = st.jobs.pop_front() {
                    break job;
                }
                // drain-then-exit: shutdown only takes effect once the
                // queue is empty, so no submitted unit is ever dropped
                if st.shutdown {
                    return;
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        // contain a panicking unit to that unit: the worker (and the
        // queue mutex, which is not held here) survive
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_indexed_returns_results_in_order() {
        let pool = ExecPool::new(4);
        let out = pool.run_indexed(257, |i| move || i * i);
        assert_eq!(out.len(), 257);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn single_thread_pool_completes_everything() {
        let pool = ExecPool::new(1);
        let out = pool.run_indexed(64, |i| move || i + 1);
        assert_eq!(out, (1..=64).collect::<Vec<_>>());
        assert_eq!(pool.threads(), 1);
    }

    #[test]
    fn zero_thread_request_clamps_to_one() {
        let pool = ExecPool::new(0);
        assert_eq!(pool.threads(), 1);
        assert_eq!(pool.run_indexed(3, |i| move || i), vec![0, 1, 2]);
    }

    #[test]
    fn shutdown_under_load_drains_every_job() {
        // drop the pool while hundreds of jobs are still queued: every
        // one must run before the workers exit
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ExecPool::new(2);
            for _ in 0..500 {
                let counter = counter.clone();
                pool.spawn(move || {
                    counter.fetch_add(1, Ordering::SeqCst);
                });
            }
            // pool dropped here: Drop joins after draining
        }
        assert_eq!(counter.load(Ordering::SeqCst), 500, "shutdown lost queued work units");
    }

    #[test]
    fn panicking_job_does_not_poison_the_pool() {
        let pool = ExecPool::new(2);
        pool.spawn(|| panic!("unit under test explodes"));
        // the pool (workers + queue mutex) must survive and keep serving
        let out = pool.run_indexed(32, |i| move || i * 2);
        assert_eq!(out[31], 62);
        assert_eq!(pool.queued(), 0);
    }

    #[test]
    fn global_pool_is_shared() {
        let a = ExecPool::global();
        let b = ExecPool::global();
        assert!(Arc::ptr_eq(&a, &b));
        assert!(a.threads() >= 1);
    }
}
