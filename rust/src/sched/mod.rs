//! Layer scheduler: im2col lowering, K/N tiling onto 64x144 macros, and
//! the digital/analog workload allocation of paper Fig. 5a.
//!
//! Execution follows a **plan/execute split** (DESIGN.md §5): [`plan`]
//! builds an immutable, weight-stationary [`plan::LayerPlan`] once per
//! layer (packed `MacroUnit` tiles + op-count templates), cached by
//! `layer_idx` in a [`plan::PlanCache`] shared across engine clones;
//! [`MacroGemm::gemm`] is a thin executor over that plan.  The
//! dual-precision PG/DRQ baselines run through the same plan tiles as
//! the CIM modes instead of a bespoke flat-K loop.
//!
//! On top of that split sits the **parallel tile engine** (DESIGN.md
//! §11): a GEMM is sharded into `(row-chunk, N-tile)` work units submitted
//! onto a persistent [`exec::ExecPool`]; each unit fuses the SE pass
//! (OSA) with the computing pass over every K-tile of its N-tile, the
//! simulator-side analogue of the split-port macro firing its digital
//! and analog paths concurrently.
//!
//! [`MacroGemm`] is the native (bit-exact, cycle-accounted) execution
//! engine; `runtime::PjrtGemm` implements the same [`GemmEngine`]
//! interface on top of the AOT PJRT artifacts.  Both follow the *same
//! noise-stream convention* as `python/compile/model.py::MacroGemm`
//! (DESIGN.md §6): one independent SplitMix64 stream per `(layer, row,
//! N-tile)` work unit, seeded by `prng::unit_noise_seed` and advanced
//! K-tile-major, drawing `hmus*w_bits` normals per K-tile.  Because a
//! unit's stream depends only on its coordinates, outputs are
//! bit-identical for any thread count (including 1) and for any unit
//! schedule; streams are re-seeded per *call*, not per plan, so caching
//! plans never shifts the noise either.

pub mod exec;
pub mod fleet;
pub mod im2col;
pub mod plan;

use crate::config::CimMode;
use crate::device::DeviceModel;
use crate::energy::hierarchy::{MemoryHierarchy, MODEL_COMPACT, MODEL_HIERARCHY};
use crate::energy::{dataflow, EnergyAccount, EnergyParams};
use crate::macrosim::ose::{Ose, SaliencyAccumulator};
use crate::macrosim::DevCtx;
use crate::quant::PackedBits;
use crate::spec::MacroSpec;
use crate::util::prng::{unit_noise_seed, SplitMix64};
use anyhow::Result;
use exec::ExecPool;
use plan::{LayerPlan, PlanCache, PlanCacheStats, PlanScope};
use std::sync::Arc;

/// Rows per work unit: small enough that concurrent requests interleave
/// at fine granularity on a shared pool, large enough to amortize the
/// per-unit queue hop.  Purely a scheduling knob — noise streams are
/// per *row*, so the chunk size can never shift results.
pub(crate) const UNIT_ROWS: usize = 16;

/// Pad a row-major `[m, k]` matrix to `[m, k_pad]` with zeros.
pub fn pad_cols(a: &[i32], m: usize, k: usize, k_pad: usize) -> Vec<i32> {
    assert_eq!(a.len(), m * k);
    if k == k_pad {
        return a.to_vec();
    }
    let mut out = vec![0i32; m * k_pad];
    for r in 0..m {
        out[r * k_pad..r * k_pad + k].copy_from_slice(&a[r * k..(r + 1) * k]);
    }
    out
}

/// Pad a row-major `[n, k]` matrix to `[n_pad, k_pad]` with zeros.
pub fn pad_matrix(w: &[i32], n: usize, k: usize, n_pad: usize, k_pad: usize) -> Vec<i32> {
    assert_eq!(w.len(), n * k);
    let mut out = vec![0i32; n_pad * k_pad];
    for r in 0..n {
        out[r * k_pad..r * k_pad + k].copy_from_slice(&w[r * k..(r + 1) * k]);
    }
    out
}

/// Result of one tiled GEMM through the macro datapath.
#[derive(Debug, Clone)]
pub struct GemmResult {
    /// `[m, n]` row-major i32 accumulators.
    pub out: Vec<i32>,
    pub m: usize,
    pub n: usize,
    /// Energy/cycle accounting over all macro ops.
    pub account: EnergyAccount,
    /// Histogram of chosen boundaries (index = B value, 0..16).
    pub b_hist: [u64; 16],
    /// Chosen boundary per (sample, N-tile), `[m, n_tiles]` row-major
    /// (0 for DCIM, fixed B for HCIM, OSE-selected for OSA; -1 for ACIM).
    pub bda: Vec<i32>,
    pub n_tiles: usize,
}

/// Abstract GEMM engine so `nn::Executor` can run on either the native
/// simulator or the PJRT artifacts.  The public, runtime-selectable
/// face of this trait is `engine::Backend` (an object-safe rework with
/// a capability surface); `Box<dyn engine::Backend>` implements
/// `GemmEngine`, so anything generic over this trait also runs on a
/// registry-selected backend.
pub trait GemmEngine {
    /// `a`: `[m, k]` uint8-as-i32 row-major; `w`: `[n, k]` int8-as-i32.
    fn gemm(&mut self, a: &[i32], m: usize, k: usize, w: &[i32], n: usize, layer_idx: u64)
        -> Result<GemmResult>;

    /// Build (and cache) the execution plan for a layer ahead of time so
    /// the first `gemm` call doesn't pay the weight-packing cost.
    /// No-op default for engines without a plan cache.
    fn prepare(&mut self, _w: &[i32], _n: usize, _k: usize, _layer_idx: u64) -> Result<()> {
        Ok(())
    }

    /// Engine label for logs/metrics (borrowed from the engine so
    /// `dyn`-backed engines can report their registry name).
    fn name(&self) -> &str;
}

/// Native tiled macro GEMM (the cycle-level path).
///
/// Cloning is cheap and shares the plan cache: every clone (e.g. one per
/// coordinator worker) executes over the same packed weight tiles, so a
/// layer is packed exactly once per process.
#[derive(Debug, Clone)]
pub struct MacroGemm {
    pub mode: CimMode,
    pub spec: MacroSpec,
    pub fixed_b: i32,
    pub ose: Ose,
    pub noise_seed: u64,
    pub energy: EnergyParams,
    /// PG baseline: low-order pass is skipped when the high-order
    /// partial's magnitude stays below this (accumulator units).
    pub pg_delta: i32,
    /// DRQ baseline: inputs whose tile mean is below this (uint8 units)
    /// run at 4-bit precision.
    pub drq_thresh: i32,
    /// Weight-stationary layer plans, shared across clones.
    plans: Arc<PlanCache>,
    /// Plan-cache scope this engine builds/fetches under.  Stays
    /// [`PlanScope::SINGLE`] for the single-macro path; the fleet engine
    /// sets its `(backend, fleet_k, placement)` scope so differently
    /// sharded plans never collide in a shared cache.
    plan_scope: PlanScope,
    /// Tile-execution pool, shared across clones.  `None` = fall back
    /// to [`ExecPool::global`] lazily at execution time, so merely
    /// constructing an engine never spawns threads.
    pool: Option<Arc<ExecPool>>,
    /// Memory hierarchy for the dataflow cost model (`[hardware]
    /// model = "hierarchy"`).  `None` = compact model: per-op constants
    /// only, `movement_fj` stays all-zero — the bit-compatible default.
    hier: Option<Arc<MemoryHierarchy>>,
    /// Analog device model (DESIGN.md §16).  The default
    /// (`gaussian-thermal` at the spec's `sigma_code`) reports
    /// `is_baseline()` and keeps the bit-preserved legacy compute path;
    /// any other model/knob routes conversions through the
    /// device-aware `compute_*_dev` paths — same unit streams, so still
    /// bit-reproducible at every thread count and fleet K.
    device: Arc<dyn DeviceModel>,
}

impl MacroGemm {
    pub fn new(
        mode: CimMode,
        spec: MacroSpec,
        fixed_b: i32,
        thresholds: Vec<i32>,
        noise_seed: u64,
    ) -> Result<Self> {
        Ok(Self {
            mode,
            spec,
            fixed_b,
            ose: Ose::with_default_candidates(thresholds)?,
            noise_seed,
            energy: EnergyParams::default(),
            pg_delta: 1 << 13,
            drq_thresh: 48,
            plans: Arc::new(PlanCache::new()),
            plan_scope: PlanScope::SINGLE,
            pool: None,
            hier: None,
            device: crate::device::default_model(spec.sigma_code),
        })
    }

    /// Convenience constructor for a mode with default knobs.
    pub fn with_mode(mode: CimMode) -> Self {
        Self {
            mode,
            spec: MacroSpec::default(),
            fixed_b: 8,
            ose: Ose::with_default_candidates(vec![0, 0, 32, 94, 1024]).unwrap(),
            noise_seed: 0xC1A0_2024,
            energy: EnergyParams::default(),
            pg_delta: 1 << 13,
            drq_thresh: 48,
            plans: Arc::new(PlanCache::new()),
            plan_scope: PlanScope::SINGLE,
            pool: None,
            hier: None,
            device: crate::device::default_model(crate::spec::SIGMA_CODE),
        }
    }

    /// Attach an externally shared plan cache (e.g. one per `FigCtx` or
    /// per server, so plans survive engine reconstruction).
    pub fn with_plan_cache(mut self, plans: Arc<PlanCache>) -> Self {
        self.plans = plans;
        self
    }

    /// Scope plan-cache lookups to a `(backend, fleet_k, placement)`
    /// key (see [`PlanScope::for_backend`]).
    pub fn with_plan_scope(mut self, scope: PlanScope) -> Self {
        self.plan_scope = scope;
        self
    }

    /// Attach an execution pool (e.g. one per server, shared by every
    /// coordinator worker's engine clone; or an explicitly sized pool
    /// for the thread-scaling benches and parity tests).
    pub fn with_pool(mut self, pool: Arc<ExecPool>) -> Self {
        self.pool = Some(pool);
        self
    }

    /// Switch to the hierarchy cost model: price each call's data
    /// movement ([`dataflow::trace_layer`]) into
    /// `EnergyBreakdown::movement_fj`.  `None` restores the compact
    /// model (the bit-compatible default).
    pub fn with_hierarchy(mut self, hier: Option<Arc<MemoryHierarchy>>) -> Self {
        self.hier = hier;
        self
    }

    /// The attached memory hierarchy (`None` = compact model).
    pub fn hierarchy(&self) -> Option<&Arc<MemoryHierarchy>> {
        self.hier.as_ref()
    }

    /// Attach an analog device model.  The default is
    /// `device::default_model(spec.sigma_code)` — the bit-preserved
    /// legacy convention.
    pub fn with_device(mut self, device: Arc<dyn DeviceModel>) -> Self {
        self.device = device;
        self
    }

    /// The engine's analog device model.
    pub fn device(&self) -> &Arc<dyn DeviceModel> {
        &self.device
    }

    /// Active cost-model name (`"compact"` or `"hierarchy"`).
    pub fn cost_model(&self) -> &'static str {
        if self.hier.is_some() {
            MODEL_HIERARCHY
        } else {
            MODEL_COMPACT
        }
    }

    /// Price one call's data movement into the merged account — a
    /// deterministic post-pass over the plan geometry, so the f64s are
    /// identical for any thread count or unit merge order.
    pub(crate) fn price_movement(
        &self,
        account: &mut EnergyAccount,
        m: usize,
        plan: &LayerPlan,
        placement: Option<&plan::LayerPlacement>,
    ) {
        if let Some(h) = &self.hier {
            let t = dataflow::trace_layer(m, plan, placement, h);
            for (acc, v) in account.breakdown.movement_fj.iter_mut().zip(t.movement_fj) {
                *acc += v;
            }
        }
    }

    /// The engine's tile-execution pool: the attached one, else the
    /// process-global default (created on first use).
    pub fn pool(&self) -> Arc<ExecPool> {
        self.pool.clone().unwrap_or_else(ExecPool::global)
    }

    /// Worker-thread count of the engine's pool.
    pub fn threads(&self) -> usize {
        self.pool().threads()
    }

    /// The shared plan cache handle.
    pub fn plan_cache(&self) -> &Arc<PlanCache> {
        &self.plans
    }

    /// The plan-cache scope this engine reads and writes.
    pub fn plan_scope(&self) -> PlanScope {
        self.plan_scope
    }

    /// Cache activity snapshot (hit rate, packed layer count).
    pub fn plan_stats(&self) -> PlanCacheStats {
        self.plans.stats()
    }

    pub(crate) fn n_slices(&self) -> usize {
        self.spec.a_bits.div_ceil(self.spec.analog_band as usize)
    }

    /// Dual-precision all-digital baselines (PG [13] / DRQ [14]) as a
    /// plan executor.
    ///
    /// Both split the activation into a high nibble (bits 4..8) and a low
    /// nibble; the low pass runs only for "important" outputs — PG gates
    /// on the high-pass output magnitude, DRQ on the input-region mean.
    /// Runs over the same packed plan tiles as the CIM modes (the padded
    /// columns contribute zero to either pass, so tiling is exact), and
    /// over the same `(row-chunk, N-tile)` work units on the pool — the
    /// math is noise-free, so determinism is trivial here.
    fn execute_dual(
        &self,
        plan: &Arc<LayerPlan>,
        a: &[i32],
        m: usize,
        k: usize,
    ) -> Result<GemmResult> {
        let sp = self.spec;
        let (kt, nt, n) = (plan.kt, plan.nt, plan.n);
        let a_p: Arc<Vec<i32>> = Arc::new(pad_cols(a, m, k, plan.k_pad));
        let chunks = m.div_ceil(UNIT_ROWS).max(1);
        let results = self.pool().run_indexed(chunks * nt, |u| {
            let (ci, ni) = (u / nt, u % nt);
            let (s0, s1) = (ci * UNIT_ROWS, ((ci + 1) * UNIT_ROWS).min(m));
            let plan = plan.clone();
            let a_p = a_p.clone();
            let mode = self.mode;
            let energy = self.energy;
            let (pg_delta, drq_thresh) = (self.pg_delta, self.drq_thresh);
            move || {
                dual_unit(
                    &plan,
                    &a_p,
                    mode,
                    energy,
                    pg_delta,
                    drq_thresh,
                    k,
                    s0,
                    s1,
                    ni,
                )
            }
        });

        let mut out = vec![0i32; m * n];
        let mut account = EnergyAccount::default();
        let mut b_hist = [0u64; 16];
        let mut bda = vec![0i32; m * nt];
        for (u, unit) in results.iter().enumerate() {
            let (ci, ni) = (u / nt, u % nt);
            let s0 = ci * UNIT_ROWS;
            let c_lo = ni * sp.hmus;
            let c_hi = ((ni + 1) * sp.hmus).min(n);
            for (r, &full) in unit.boundaries.iter().enumerate() {
                let s = s0 + r;
                for (h, c) in (c_lo..c_hi).enumerate() {
                    out[s * n + c] = unit.vals[r * sp.hmus + h];
                }
                bda[s * nt + ni] = full;
                b_hist[full as usize] += kt as u64;
            }
            account.merge(&unit.account);
        }
        Ok(GemmResult { out, m, n, account, b_hist, bda, n_tiles: nt })
    }

    /// CIM-mode plan executor (DCIM / HCIM / OSA / ACIM): shard the GEMM
    /// into `(row-chunk, N-tile)` work units on the pool.  Each unit
    /// fuses the SE pass (OSA boundary select) with the computing pass
    /// over every K-tile of its rows, writes a disjoint output slice,
    /// and keeps its own `EnergyAccount`; units are merged in index
    /// order, and noise streams are seeded per `(layer, row, N-tile)` —
    /// so results and accounting are bit-identical for any thread count.
    fn execute_cim(
        &self,
        plan: &Arc<LayerPlan>,
        a: &[i32],
        m: usize,
        k: usize,
        layer_idx: u64,
    ) -> Result<GemmResult> {
        let sp = self.spec;
        let (kt, nt, k_pad, n_pad, n) = (plan.kt, plan.nt, plan.k_pad, plan.n_pad, plan.n);
        let a_p: Arc<Vec<i32>> = Arc::new(pad_cols(a, m, k, k_pad));

        // Pre-pack activation bit planes once per (sample, K-tile): they
        // are reused by the SE pass, the compute pass and every N-tile.
        // DCIM runs the exact integer path on the raw tiles and never
        // touches bit planes, so skip the packing entirely there.
        let mut packed = Vec::new();
        if self.mode != CimMode::Dcim {
            packed.reserve(m * kt);
            for s in 0..m {
                for ki in 0..kt {
                    let tile = &a_p[s * k_pad + ki * sp.cols..s * k_pad + (ki + 1) * sp.cols];
                    packed.push(PackedBits::pack(tile, sp.a_bits, false));
                }
            }
        }
        let a_packed: Arc<Vec<PackedBits>> = Arc::new(packed);

        let n_slices = self.n_slices();
        let chunks = m.div_ceil(UNIT_ROWS).max(1);
        let results = self.pool().run_indexed(chunks * nt, |u| {
            let (ci, ni) = (u / nt, u % nt);
            let (s0, s1) = (ci * UNIT_ROWS, ((ci + 1) * UNIT_ROWS).min(m));
            let plan = plan.clone();
            let a_p = a_p.clone();
            let a_packed = a_packed.clone();
            let mode = self.mode;
            let ose = self.ose.clone();
            let energy = self.energy;
            let fixed_b = self.fixed_b;
            let noise_seed = self.noise_seed;
            let device = self.device.clone();
            move || {
                cim_unit(
                    &plan,
                    &a_p,
                    &a_packed,
                    mode,
                    &ose,
                    energy,
                    fixed_b,
                    noise_seed,
                    layer_idx,
                    k,
                    s0,
                    s1,
                    ni,
                    n_slices,
                    &device,
                )
            }
        });

        let mut out = vec![0i32; m * n_pad];
        let mut account = EnergyAccount::default();
        let mut b_hist = [0u64; 16];
        let mut bda = vec![0i32; m * nt];
        for (u, unit) in results.iter().enumerate() {
            let (ci, ni) = (u / nt, u % nt);
            let s0 = ci * UNIT_ROWS;
            for (r, &b) in unit.boundaries.iter().enumerate() {
                let s = s0 + r;
                bda[s * nt + ni] = b;
                if (0..16).contains(&b) {
                    b_hist[b as usize] += kt as u64;
                }
                out[s * n_pad + ni * sp.hmus..s * n_pad + (ni + 1) * sp.hmus]
                    .copy_from_slice(&unit.vals[r * sp.hmus..(r + 1) * sp.hmus]);
            }
            account.merge(&unit.account);
        }

        // strip N padding
        let mut final_out = vec![0i32; m * n];
        for s in 0..m {
            final_out[s * n..(s + 1) * n].copy_from_slice(&out[s * n_pad..s * n_pad + n]);
        }
        Ok(GemmResult { out: final_out, m, n, account, b_hist, bda, n_tiles: nt })
    }
}

/// One work unit's result: one N-tile's output for a chunk of rows,
/// already accumulated over every K-tile.
pub(crate) struct UnitOut {
    /// `[rows, hmus]` accumulators.
    pub(crate) vals: Vec<i32>,
    /// Per-row boundary (CIM modes) or full-precision flag (PG/DRQ).
    pub(crate) boundaries: Vec<i32>,
    pub(crate) account: EnergyAccount,
}

/// CIM-mode work unit: rows `s0..s1` of N-tile `ni`.  SE pass (OSA) and
/// computing pass fused per row; noise per `(layer, row, N-tile)` stream
/// advanced K-tile-major (DESIGN.md §6), with the per-conversion draws
/// delegated to the device model (the zero-sigma "zeros without
/// advancing" convention lives in `DeviceModel::conversion_noise` now).
/// A baseline device takes the legacy popcount compute path; any other
/// device routes through `compute_*_dev` with per-(layer, macro) static
/// column gains — the draw count per K-tile is fixed by (mode, device),
/// never by the resolved boundary, so streams stay aligned.
#[allow(clippy::too_many_arguments)]
pub(crate) fn cim_unit(
    plan: &LayerPlan,
    a_p: &[i32],
    a_packed: &[PackedBits],
    mode: CimMode,
    ose: &Ose,
    energy: EnergyParams,
    fixed_b: i32,
    noise_seed: u64,
    layer_idx: u64,
    k: usize,
    s0: usize,
    s1: usize,
    ni: usize,
    n_slices: usize,
    device: &Arc<dyn DeviceModel>,
) -> UnitOut {
    let sp = plan.spec;
    let (kt, k_pad) = (plan.kt, plan.k_pad);
    let rows = s1 - s0;
    let mut vals = vec![0i32; rows * sp.hmus];
    let mut boundaries = vec![0i32; rows];
    let mut account = EnergyAccount::default();
    let dev_p = device.params();
    let baseline = device.is_baseline();
    let n_sub = if baseline { 1 } else { dev_p.sub_conversions(sp.cols) };
    let per_tile = if mode == CimMode::Acim {
        sp.hmus * sp.w_bits * n_slices * n_sub
    } else {
        sp.hmus * sp.w_bits * n_sub
    };
    // Static column gains per K-tile of this N-tile, fixed per
    // (seed, layer, macro) — macro index = plan unit index ni*kt + ki.
    // Computed once per work unit; rows share the same silicon.
    let col_gains: Vec<Option<Vec<f32>>> = if baseline || mode == CimMode::Dcim {
        Vec::new()
    } else {
        (0..kt)
            .map(|ki| device.column_gains(noise_seed, layer_idx, (ni * kt + ki) as u64, sp.cols))
            .collect()
    };
    for (r, s) in (s0..s1).enumerate() {
        // ---- Saliency-Evaluation mode (OSA only): resolve B_D/A ------
        let b = match mode {
            CimMode::Pg | CimMode::Drq => unreachable!("dual precision runs execute_dual"),
            CimMode::Dcim => crate::spec::B_DCIM,
            CimMode::Hcim => fixed_b,
            CimMode::Acim => -1,
            CimMode::Osa => {
                let mut acc = SaliencyAccumulator::default();
                for ki in 0..kt {
                    acc.add(plan.unit(ni, ki).saliency(&a_packed[s * kt + ki]));
                }
                // N/Q normalization: rescale by the layer's true K so
                // thresholds are layer-independent
                let s_norm = crate::spec::normalize_saliency(acc.value() as i64, k, sp.cols);
                ose.select(s_norm)
            }
        };
        boundaries[r] = b;
        // ---- Computing mode over every K-tile ------------------------
        let mut stream =
            SplitMix64::new(unit_noise_seed(noise_seed, layer_idx, s as u64, ni as u64));
        for ki in 0..kt {
            let unit = plan.unit(ni, ki);
            let (tile_vals, counts, with_se) = match mode {
                CimMode::Pg | CimMode::Drq => unreachable!("dual precision runs execute_dual"),
                CimMode::Dcim => {
                    let tile = &a_p[s * k_pad + ki * sp.cols..s * k_pad + (ki + 1) * sp.cols];
                    (unit.exact(tile), plan.counts(0, false), false)
                }
                CimMode::Acim => {
                    let noise = device.conversion_noise(&mut stream, per_tile);
                    let vals = if baseline {
                        unit.compute_acim(&a_packed[s * kt + ki], &noise)
                    } else {
                        let ctx = DevCtx {
                            col_gains: col_gains[ki].as_deref(),
                            s_ou: dev_p.s_ou,
                            adc_offset: dev_p.adc_offset,
                            adc_gain: dev_p.adc_gain,
                        };
                        unit.compute_acim_dev(&a_packed[s * kt + ki], &noise, &ctx)
                    };
                    (vals, plan.acim_counts(), false)
                }
                CimMode::Osa | CimMode::Hcim => {
                    let noise = device.conversion_noise(&mut stream, per_tile);
                    let with_se = mode == CimMode::Osa;
                    let vals = if baseline {
                        unit.compute_hybrid(&a_packed[s * kt + ki], b, &noise)
                    } else {
                        let ctx = DevCtx {
                            col_gains: col_gains[ki].as_deref(),
                            s_ou: dev_p.s_ou,
                            adc_offset: dev_p.adc_offset,
                            adc_gain: dev_p.adc_gain,
                        };
                        unit.compute_hybrid_dev(&a_packed[s * kt + ki], b, &noise, &ctx)
                    };
                    (vals, plan.counts(b, with_se), with_se)
                }
            };
            for (acc, v) in vals[r * sp.hmus..(r + 1) * sp.hmus].iter_mut().zip(&tile_vals) {
                *acc += v;
            }
            account.record(&energy.op_energy(&counts, with_se, &sp), &counts);
        }
    }
    UnitOut { vals, boundaries, account }
}

/// Dual-precision (PG/DRQ) work unit: rows `s0..s1` of N-tile `ni`.
/// `boundaries` carries the per-row full-precision flag (0/1).
#[allow(clippy::too_many_arguments)]
fn dual_unit(
    plan: &LayerPlan,
    a_p: &[i32],
    mode: CimMode,
    energy: EnergyParams,
    pg_delta: i32,
    drq_thresh: i32,
    k: usize,
    s0: usize,
    s1: usize,
    ni: usize,
) -> UnitOut {
    let sp = plan.spec;
    let (kt, k_pad, n) = (plan.kt, plan.k_pad, plan.n);
    let rows = s1 - s0;
    let mut vals = vec![0i32; rows * sp.hmus];
    let mut boundaries = vec![0i32; rows];
    let mut account = EnergyAccount::default();
    let c_lo = ni * sp.hmus;
    let c_hi = ((ni + 1) * sp.hmus).min(n);
    for (r, s) in (s0..s1).enumerate() {
        // DRQ gates on the *unpadded* row mean: slice the true-k prefix
        // of the padded row (identical data, no extra copy of `a`)
        let row = &a_p[s * k_pad..s * k_pad + k];
        let mut full = mode == CimMode::Drq && {
            let mean: i64 = row.iter().map(|&x| x as i64).sum::<i64>() / k as i64;
            mean >= drq_thresh as i64
        };
        // high-nibble pass over the packed weight tiles
        let mut hi = vec![0i32; sp.hmus];
        for ki in 0..kt {
            let tile = &a_p[s * k_pad + ki * sp.cols..s * k_pad + (ki + 1) * sp.cols];
            for (acc, v) in hi.iter_mut().zip(plan.unit(ni, ki).exact_masked(tile, !0xF)) {
                *acc += v;
            }
        }
        if mode == CimMode::Pg {
            full = hi[..c_hi - c_lo].iter().any(|v| v.abs() >= pg_delta);
        }
        let out_row = if full {
            let mut ex = vec![0i32; sp.hmus];
            for ki in 0..kt {
                let tile = &a_p[s * k_pad + ki * sp.cols..s * k_pad + (ki + 1) * sp.cols];
                for (acc, v) in ex.iter_mut().zip(plan.unit(ni, ki).exact(tile)) {
                    *acc += v;
                }
            }
            ex
        } else {
            hi
        };
        vals[r * sp.hmus..(r + 1) * sp.hmus].copy_from_slice(&out_row);
        boundaries[r] = full as i32;
        // energy: hi pass always; low pass only when not gated
        let counts = plan.dual_counts(full);
        for _ in 0..kt {
            account.record(&energy.op_energy(&counts, false, &sp), &counts);
        }
    }
    UnitOut { vals, boundaries, account }
}

impl GemmEngine for MacroGemm {
    fn name(&self) -> &str {
        "native-macrosim"
    }

    fn prepare(&mut self, w: &[i32], n: usize, k: usize, layer_idx: u64) -> Result<()> {
        self.plans
            .get_or_build_scoped(self.plan_scope, layer_idx, w, n, k, self.spec)
            .map(|_| ())
    }

    fn gemm(
        &mut self,
        a: &[i32],
        m: usize,
        k: usize,
        w: &[i32],
        n: usize,
        layer_idx: u64,
    ) -> Result<GemmResult> {
        let plan = self.plans.get_or_build_scoped(self.plan_scope, layer_idx, w, n, k, self.spec)?;
        let mut r = if matches!(self.mode, CimMode::Pg | CimMode::Drq) {
            self.execute_dual(&plan, a, m, k)?
        } else {
            self.execute_cim(&plan, a, m, k, layer_idx)?
        };
        self.price_movement(&mut r.account, m, &plan, None);
        Ok(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest::check;

    fn rand_mat(g: &mut SplitMix64, rows: usize, cols: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..rows * cols).map(|_| g.next_range_i32(lo, hi)).collect()
    }

    fn exact_gemm(a: &[i32], m: usize, k: usize, w: &[i32], n: usize) -> Vec<i64> {
        let mut out = vec![0i64; m * n];
        for s in 0..m {
            for c in 0..n {
                let mut acc = 0i64;
                for x in 0..k {
                    acc += a[s * k + x] as i64 * w[c * k + x] as i64;
                }
                out[s * n + c] = acc;
            }
        }
        out
    }

    #[test]
    fn dcim_matches_exact_for_arbitrary_shapes() {
        check("dcim gemm exact", 10, |g| {
            let mut rng = SplitMix64::new(g.u64());
            let (m, k, n) =
                (rng.next_below(6) + 1, rng.next_below(300) + 1, rng.next_below(20) + 1);
            let a = rand_mat(&mut rng, m, k, 0, 256);
            let w = rand_mat(&mut rng, n, k, -128, 128);
            let mut gemm = MacroGemm::with_mode(CimMode::Dcim);
            let r = gemm.gemm(&a, m, k, &w, n, 0).unwrap();
            let expect = exact_gemm(&a, m, k, &w, n);
            let got: Vec<i64> = r.out.iter().map(|&x| x as i64).collect();
            assert_eq!(got, expect, "m={m} k={k} n={n}");
        });
    }

    #[test]
    fn hcim_b0_equals_dcim_outputs() {
        let mut rng = SplitMix64::new(3);
        let (m, k, n) = (4, 300, 10);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let mut hcim = MacroGemm::with_mode(CimMode::Hcim);
        hcim.fixed_b = 0;
        let mut dcim = MacroGemm::with_mode(CimMode::Dcim);
        assert_eq!(
            hcim.gemm(&a, m, k, &w, n, 0).unwrap().out,
            dcim.gemm(&a, m, k, &w, n, 0).unwrap().out
        );
    }

    #[test]
    fn hcim_error_grows_with_b() {
        let mut rng = SplitMix64::new(4);
        let (m, k, n) = (16, 288, 8);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let exact = exact_gemm(&a, m, k, &w, n);
        let mut prev = -1.0;
        for b in [5, 8, 10] {
            let mut gemm = MacroGemm::with_mode(CimMode::Hcim);
            gemm.fixed_b = b;
            let r = gemm.gemm(&a, m, k, &w, n, 0).unwrap();
            let mse: f64 = r
                .out
                .iter()
                .zip(&exact)
                .map(|(&o, &e)| (o as f64 - e as f64).powi(2))
                .sum::<f64>()
                / exact.len() as f64;
            assert!(mse > prev, "B={b}");
            prev = mse;
        }
    }

    #[test]
    fn osa_selects_varied_boundaries() {
        let mut rng = SplitMix64::new(5);
        let m = 32;
        let k = crate::spec::COLS;
        let n = crate::spec::HMUS;
        // half the samples high-magnitude, half low
        let mut a = Vec::new();
        for s in 0..m {
            let (lo, hi) = if s % 2 == 0 { (180, 256) } else { (0, 30) };
            a.extend(rand_mat(&mut rng, 1, k, lo, hi));
        }
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let mut gemm = MacroGemm::with_mode(CimMode::Osa);
        let r = gemm.gemm(&a, m, k, &w, n, 0).unwrap();
        let distinct: std::collections::HashSet<i32> = r.bda.iter().copied().collect();
        assert!(distinct.len() >= 2, "OSE chose a single boundary: {distinct:?}");
        // high-magnitude samples must get a more precise (lower) boundary
        let hi_b: f64 =
            (0..m).step_by(2).map(|s| r.bda[s] as f64).sum::<f64>() / (m / 2) as f64;
        let lo_b: f64 =
            (1..m).step_by(2).map(|s| r.bda[s] as f64).sum::<f64>() / (m / 2) as f64;
        assert!(hi_b < lo_b, "salient rows got coarser precision: {hi_b} vs {lo_b}");
        assert!(r.b_hist.iter().sum::<u64>() > 0);
    }

    #[test]
    fn osa_uses_less_energy_than_dcim() {
        let mut rng = SplitMix64::new(6);
        let (m, k, n) = (16, 288, 16);
        let a = rand_mat(&mut rng, m, k, 0, 120);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let e_d = MacroGemm::with_mode(CimMode::Dcim)
            .gemm(&a, m, k, &w, n, 0)
            .unwrap()
            .account
            .total_energy_j();
        let e_o = MacroGemm::with_mode(CimMode::Osa)
            .gemm(&a, m, k, &w, n, 0)
            .unwrap()
            .account
            .total_energy_j();
        assert!(e_o < e_d, "OSA {e_o} >= DCIM {e_d}");
    }

    #[test]
    fn acim_runs_with_energy() {
        let mut rng = SplitMix64::new(7);
        let (m, k, n) = (4, 144, 8);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let r = MacroGemm::with_mode(CimMode::Acim).gemm(&a, m, k, &w, n, 0).unwrap();
        assert!(r.account.breakdown.adc_fj > 0.0);
        assert_eq!(r.bda, vec![-1; 4]);
    }

    #[test]
    fn noise_stream_is_deterministic_per_seed() {
        let mut rng = SplitMix64::new(8);
        let (m, k, n) = (4, 144, 8);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let r1 = MacroGemm::with_mode(CimMode::Hcim).gemm(&a, m, k, &w, n, 3).unwrap();
        let r2 = MacroGemm::with_mode(CimMode::Hcim).gemm(&a, m, k, &w, n, 3).unwrap();
        assert_eq!(r1.out, r2.out);
        let r3 = MacroGemm::with_mode(CimMode::Hcim).gemm(&a, m, k, &w, n, 4).unwrap();
        assert_ne!(r1.out, r3.out, "different layer index must shift the noise stream");
    }

    #[test]
    fn device_models_stay_thread_deterministic() {
        use crate::device::{build, DeviceParams};
        let mut rng = SplitMix64::new(20);
        let (m, k, n) = (8, 300, 10);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let base = MacroGemm::with_mode(CimMode::Osa)
            .with_pool(ExecPool::new(1))
            .gemm(&a, m, k, &w, n, 5)
            .unwrap();
        for model in ["capacitor-mismatch", "lognormal-conductance"] {
            let dev = build(
                model,
                DeviceParams { sigma: 0.05, s_ou: 16, ..DeviceParams::default() },
            )
            .unwrap();
            let run = |threads: usize| {
                MacroGemm::with_mode(CimMode::Osa)
                    .with_device(dev.clone())
                    .with_pool(ExecPool::new(threads))
                    .gemm(&a, m, k, &w, n, 5)
                    .unwrap()
            };
            let (r1, r4) = (run(1), run(4));
            assert_eq!(r1.out, r4.out, "{model} logits must not depend on thread count");
            assert_eq!(r1.bda, r4.bda, "{model} boundaries");
            assert_eq!(
                r1.account.total_energy_j().to_bits(),
                r4.account.total_energy_j().to_bits(),
                "{model} energy f64s"
            );
            assert_ne!(r1.out, base.out, "{model} variation must move outputs");
            // boundary selection is pre-analog: the OSE never sees the
            // device, so degrade maps match the baseline exactly
            assert_eq!(r1.bda, base.bda, "{model} OSE boundaries are device-independent");
        }
    }

    #[test]
    fn cached_plan_calls_stay_deterministic() {
        // The noise stream is per-call: executing over a cached plan must
        // give the same result as the call that built it.
        let mut rng = SplitMix64::new(9);
        let (m, k, n) = (4, 300, 10);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let mut gemm = MacroGemm::with_mode(CimMode::Osa);
        let r1 = gemm.gemm(&a, m, k, &w, n, 2).unwrap();
        let r2 = gemm.gemm(&a, m, k, &w, n, 2).unwrap();
        assert_eq!(r1.out, r2.out);
        assert_eq!(r1.bda, r2.bda);
        let stats = gemm.plan_stats();
        assert_eq!((stats.hits, stats.misses), (1, 1), "second call must hit the cache");
    }

    #[test]
    fn dual_precision_modes_run_through_plan_tiles() {
        let mut rng = SplitMix64::new(10);
        let (m, k, n) = (6, 300, 10);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let exact = exact_gemm(&a, m, k, &w, n);
        for mode in [CimMode::Pg, CimMode::Drq] {
            let mut gemm = MacroGemm::with_mode(mode);
            let r = gemm.gemm(&a, m, k, &w, n, 0).unwrap();
            assert_eq!(r.out.len(), m * n);
            // gated outputs equal the high-nibble partial; full outputs
            // are exact — either way |err| is bounded by the low nibble.
            for (s, (&got, &want)) in r.out.iter().zip(&exact).enumerate() {
                let err = (got as i64 - want).unsigned_abs();
                let bound: u64 = 15 * 128 * k as u64;
                assert!(err <= bound, "row {s}: err {err} > {bound}");
            }
            assert_eq!(gemm.plan_stats().misses, 1);
        }
    }

    #[test]
    fn padding_helpers() {
        let a = vec![1, 2, 3, 4];
        let p = pad_cols(&a, 2, 2, 4);
        assert_eq!(p, vec![1, 2, 0, 0, 3, 4, 0, 0]);
        let w = pad_matrix(&a, 2, 2, 3, 3);
        assert_eq!(w, vec![1, 2, 0, 3, 4, 0, 0, 0, 0]);
    }
}
