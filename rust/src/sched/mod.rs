//! Layer scheduler: im2col lowering, K/N tiling onto 64x144 macros, and
//! the digital/analog workload allocation of paper Fig. 5a.
//!
//! [`MacroGemm`] is the native (bit-exact, cycle-accounted) execution
//! engine; `runtime::PjrtGemm` implements the same [`GemmEngine`]
//! interface on top of the AOT PJRT artifacts.  Both follow the *same
//! noise-stream convention* as `python/compile/model.py::MacroGemm`
//! (one SplitMix64 stream per layer, advanced N-tile-major then K-tile,
//! drawing `m*hmus*w_bits` normals per tile), so all three agree
//! bit-exactly for a given seed.

pub mod im2col;

use crate::config::CimMode;
use crate::energy::{EnergyAccount, EnergyParams};
use crate::macrosim::ose::{Ose, SaliencyAccumulator};
use crate::macrosim::{counts_for_boundary, MacroUnit};
use crate::spec::MacroSpec;
use crate::util::prng::{layer_noise_seed, SplitMix64};
use anyhow::Result;

/// Fixed sample-chunk size for deterministic intra-GEMM parallelism.
const PAR_CHUNK: usize = 32;

/// Pad a row-major `[m, k]` matrix to `[m, k_pad]` with zeros.
pub fn pad_cols(a: &[i32], m: usize, k: usize, k_pad: usize) -> Vec<i32> {
    assert_eq!(a.len(), m * k);
    if k == k_pad {
        return a.to_vec();
    }
    let mut out = vec![0i32; m * k_pad];
    for r in 0..m {
        out[r * k_pad..r * k_pad + k].copy_from_slice(&a[r * k..(r + 1) * k]);
    }
    out
}

/// Pad a row-major `[n, k]` matrix to `[n_pad, k_pad]` with zeros.
pub fn pad_matrix(w: &[i32], n: usize, k: usize, n_pad: usize, k_pad: usize) -> Vec<i32> {
    assert_eq!(w.len(), n * k);
    let mut out = vec![0i32; n_pad * k_pad];
    for r in 0..n {
        out[r * k_pad..r * k_pad + k].copy_from_slice(&w[r * k..(r + 1) * k]);
    }
    out
}

/// Result of one tiled GEMM through the macro datapath.
#[derive(Debug, Clone)]
pub struct GemmResult {
    /// `[m, n]` row-major i32 accumulators.
    pub out: Vec<i32>,
    pub m: usize,
    pub n: usize,
    /// Energy/cycle accounting over all macro ops.
    pub account: EnergyAccount,
    /// Histogram of chosen boundaries (index = B value, 0..16).
    pub b_hist: [u64; 16],
    /// Chosen boundary per (sample, N-tile), `[m, n_tiles]` row-major
    /// (0 for DCIM, fixed B for HCIM, OSE-selected for OSA; -1 for ACIM).
    pub bda: Vec<i32>,
    pub n_tiles: usize,
}

/// Abstract GEMM engine so `nn::Executor` can run on either the native
/// simulator or the PJRT artifacts.
pub trait GemmEngine {
    /// `a`: `[m, k]` uint8-as-i32 row-major; `w`: `[n, k]` int8-as-i32.
    fn gemm(&mut self, a: &[i32], m: usize, k: usize, w: &[i32], n: usize, layer_idx: u64)
        -> Result<GemmResult>;

    /// Engine label for logs/metrics.
    fn name(&self) -> &'static str;
}

/// Native tiled macro GEMM (the cycle-level path).
#[derive(Debug, Clone)]
pub struct MacroGemm {
    pub mode: CimMode,
    pub spec: MacroSpec,
    pub fixed_b: i32,
    pub ose: Ose,
    pub noise_seed: u64,
    pub energy: EnergyParams,
    /// PG baseline: low-order pass is skipped when the high-order
    /// partial's magnitude stays below this (accumulator units).
    pub pg_delta: i32,
    /// DRQ baseline: inputs whose tile mean is below this (uint8 units)
    /// run at 4-bit precision.
    pub drq_thresh: i32,
}

impl MacroGemm {
    pub fn new(
        mode: CimMode,
        spec: MacroSpec,
        fixed_b: i32,
        thresholds: Vec<i32>,
        noise_seed: u64,
    ) -> Result<Self> {
        Ok(Self {
            mode,
            spec,
            fixed_b,
            ose: Ose::with_default_candidates(thresholds)?,
            noise_seed,
            energy: EnergyParams::default(),
            pg_delta: 1 << 13,
            drq_thresh: 48,
        })
    }

    /// Convenience constructor for a mode with default knobs.
    pub fn with_mode(mode: CimMode) -> Self {
        Self {
            mode,
            spec: MacroSpec::default(),
            fixed_b: 8,
            ose: Ose::with_default_candidates(vec![0, 0, 32, 94, 1024]).unwrap(),
            noise_seed: 0xC1A0_2024,
            energy: EnergyParams::default(),
            pg_delta: 1 << 13,
            drq_thresh: 48,
        }
    }

    /// Dual-precision all-digital baselines (PG [13] / DRQ [14]).
    ///
    /// Both split the activation into a high nibble (bits 4..8) and a low
    /// nibble; the low pass runs only for "important" outputs — PG gates
    /// on the high-pass output magnitude, DRQ on the input-region mean.
    fn gemm_dual_precision(
        &self,
        a: &[i32],
        m: usize,
        k: usize,
        w: &[i32],
        n: usize,
    ) -> Result<GemmResult> {
        let sp = self.spec;
        let kt = k.div_ceil(sp.cols).max(1);
        let nt = n.div_ceil(sp.hmus).max(1);
        let half_pairs = (sp.w_bits * sp.a_bits / 2) as u32;
        let mut out = vec![0i32; m * n];
        let mut account = EnergyAccount::default();
        let mut b_hist = [0u64; 16];
        let mut bda = vec![0i32; m * nt];
        for s in 0..m {
            let row = &a[s * k..(s + 1) * k];
            let drq_full = if self.mode == CimMode::Drq {
                let mean: i64 = row.iter().map(|&x| x as i64).sum::<i64>() / k as i64;
                mean >= self.drq_thresh as i64
            } else {
                false
            };
            for ni in 0..nt {
                let mut full = self.mode == CimMode::Drq && drq_full;
                let c_lo = ni * sp.hmus;
                let c_hi = ((ni + 1) * sp.hmus).min(n);
                let mut hi_vals = vec![0i32; c_hi - c_lo];
                for (ci, c) in (c_lo..c_hi).enumerate() {
                    let wr = &w[c * k..(c + 1) * k];
                    hi_vals[ci] =
                        row.iter().zip(wr).map(|(&x, &y)| (x & !0xF) * y).sum::<i32>();
                }
                if self.mode == CimMode::Pg {
                    full = hi_vals.iter().any(|v| v.abs() >= self.pg_delta);
                }
                for (ci, c) in (c_lo..c_hi).enumerate() {
                    out[s * n + c] = if full {
                        let wr = &w[c * k..(c + 1) * k];
                        row.iter().zip(wr).map(|(&x, &y)| x * y).sum::<i32>()
                    } else {
                        hi_vals[ci]
                    };
                }
                // energy: hi pass always; low pass only when not gated
                let pairs = if full { 2 * half_pairs } else { half_pairs };
                let mut counts = crate::macrosim::OpCounts {
                    digital_pairs: pairs,
                    compute_cycles: pairs.div_ceil(2),
                    ..Default::default()
                };
                counts.discard_pairs = 2 * half_pairs - pairs;
                for _ in 0..kt {
                    account.record(&self.energy.op_energy(&counts, false, &sp), &counts);
                }
                bda[s * nt + ni] = full as i32;
                b_hist[full as usize] += kt as u64;
            }
        }
        Ok(GemmResult { out, m, n, account, b_hist, bda, n_tiles: nt })
    }

    fn n_slices(&self) -> usize {
        self.spec.a_bits.div_ceil(self.spec.analog_band as usize)
    }
}

impl GemmEngine for MacroGemm {
    fn name(&self) -> &'static str {
        "native-macrosim"
    }

    fn gemm(
        &mut self,
        a: &[i32],
        m: usize,
        k: usize,
        w: &[i32],
        n: usize,
        layer_idx: u64,
    ) -> Result<GemmResult> {
        if matches!(self.mode, CimMode::Pg | CimMode::Drq) {
            return self.gemm_dual_precision(a, m, k, w, n);
        }
        let sp = self.spec;
        let kt = k.div_ceil(sp.cols).max(1);
        let nt = n.div_ceil(sp.hmus).max(1);
        let k_pad = kt * sp.cols;
        let n_pad = nt * sp.hmus;
        let a_p = pad_cols(a, m, k, k_pad);
        let w_p = pad_matrix(w, n, k, n_pad, k_pad);
        let mut stream = SplitMix64::new(layer_noise_seed(self.noise_seed, layer_idx));

        // Pre-pack activation bit planes once per (sample, K-tile): they
        // are reused by the SE pass, the compute pass and every N-tile.
        let mut a_packed = Vec::with_capacity(m * kt);
        for s in 0..m {
            for ki in 0..kt {
                let tile = &a_p[s * k_pad + ki * sp.cols..s * k_pad + (ki + 1) * sp.cols];
                a_packed.push(crate::quant::PackedBits::pack(tile, sp.a_bits, false));
            }
        }

        let mut out = vec![0i32; m * n_pad];
        let mut account = EnergyAccount::default();
        let mut b_hist = [0u64; 16];
        let mut bda = vec![0i32; m * nt];

        for ni in 0..nt {
            // Build the macro for this group of 8 output channels, one
            // K-tile at a time (the hardware reloads weights per tile).
            let units: Vec<MacroUnit> = (0..kt)
                .map(|ki| {
                    let mut wt = Vec::with_capacity(sp.hmus * sp.cols);
                    for h in 0..sp.hmus {
                        let row = (ni * sp.hmus + h) * k_pad + ki * sp.cols;
                        wt.extend_from_slice(&w_p[row..row + sp.cols]);
                    }
                    MacroUnit::new(&wt, sp)
                })
                .collect::<Result<_>>()?;

            // ---- Saliency-Evaluation mode (OSA only) --------------------
            let boundaries: Vec<i32> = match self.mode {
                CimMode::Pg | CimMode::Drq => unreachable!("handled above"),
                CimMode::Dcim => vec![crate::spec::B_DCIM; m],
                CimMode::Hcim => vec![self.fixed_b; m],
                CimMode::Acim => vec![-1; m],
                CimMode::Osa => {
                    // SE mode is pure compute: parallelize over fixed
                    // sample chunks (deterministic regardless of core
                    // count — each chunk writes a disjoint slice)
                    let mut bs = vec![0i32; m];
                    let units_ref = &units;
                    let a_packed_ref = &a_packed;
                    let ose = &self.ose;
                    std::thread::scope(|scope| {
                        for (ci, chunk) in bs.chunks_mut(PAR_CHUNK).enumerate() {
                            scope.spawn(move || {
                                for (off, slot) in chunk.iter_mut().enumerate() {
                                    let s = ci * PAR_CHUNK + off;
                                    let mut acc = SaliencyAccumulator::default();
                                    for (ki, unit) in units_ref.iter().enumerate() {
                                        acc.add(unit.saliency(&a_packed_ref[s * kt + ki]));
                                    }
                                    // N/Q normalization: rescale by the
                                    // layer's true K so thresholds are
                                    // layer-independent
                                    let s_norm = crate::spec::normalize_saliency(
                                        acc.value() as i64,
                                        k,
                                        sp.cols,
                                    );
                                    *slot = ose.select(s_norm);
                                }
                            });
                        }
                    });
                    bs
                }
            };

            // ---- Computing mode ----------------------------------------
            // Parallelized over fixed sample chunks: each chunk writes a
            // disjoint slice of a per-tile output buffer and keeps its own
            // EnergyAccount; chunks are merged in index order, so results
            // and accounting are bit-identical regardless of core count.
            for (ki, unit) in units.iter().enumerate() {
                let per_sample = if self.mode == CimMode::Acim {
                    sp.hmus * sp.w_bits * self.n_slices()
                } else {
                    sp.hmus * sp.w_bits
                };
                // noise buffer for this (ni, ki) tile — matches python's
                // MacroGemm._noise call order exactly (DCIM draws none)
                let noise = if self.mode == CimMode::Dcim || sp.sigma_code == 0.0 {
                    vec![0.0f32; if self.mode == CimMode::Dcim { 0 } else { m * per_sample }]
                } else {
                    stream.normals_f32(m * per_sample, sp.sigma_code)
                };
                let mut tile_out = vec![0i32; m * sp.hmus];
                let n_chunks = m.div_ceil(PAR_CHUNK);
                let mut chunk_accounts = vec![EnergyAccount::default(); n_chunks];
                let mode = self.mode;
                let energy = &self.energy;
                let boundaries_ref = &boundaries;
                let a_p_ref = &a_p;
                let a_packed_ref = &a_packed;
                let noise_ref = &noise;
                let n_slices = self.n_slices();
                std::thread::scope(|scope| {
                    for ((ci, out_chunk), acct) in
                        tile_out.chunks_mut(PAR_CHUNK * sp.hmus).enumerate().zip(&mut chunk_accounts)
                    {
                        scope.spawn(move || {
                            let rows = out_chunk.len() / sp.hmus;
                            for off in 0..rows {
                                let s = ci * PAR_CHUNK + off;
                                let (vals, counts, with_se) = match mode {
                                    CimMode::Pg | CimMode::Drq => {
                                        unreachable!("handled above")
                                    }
                                    CimMode::Dcim => {
                                        let tile = &a_p_ref[s * k_pad + ki * sp.cols
                                            ..s * k_pad + (ki + 1) * sp.cols];
                                        let c = counts_for_boundary(0, false, &sp);
                                        (unit.exact(tile), c, false)
                                    }
                                    CimMode::Acim => {
                                        let packed = &a_packed_ref[s * kt + ki];
                                        let nslice = &noise_ref
                                            [s * per_sample..(s + 1) * per_sample];
                                        // ACIM: every plane analog
                                        let mut c = counts_for_boundary(0, false, &sp);
                                        c.digital_pairs = 0;
                                        c.analog_pairs = (sp.w_bits * sp.a_bits) as u32;
                                        c.discard_pairs = 0;
                                        c.adc_groups = (sp.w_bits * n_slices) as u32;
                                        c.compute_cycles = c.adc_groups + 2;
                                        (unit.compute_acim(packed, nslice), c, false)
                                    }
                                    CimMode::Osa => {
                                        let packed = &a_packed_ref[s * kt + ki];
                                        let nslice = &noise_ref
                                            [s * per_sample..(s + 1) * per_sample];
                                        let b = boundaries_ref[s];
                                        let c = counts_for_boundary(b, true, &sp);
                                        (unit.compute_hybrid(packed, b, nslice), c, true)
                                    }
                                    CimMode::Hcim => {
                                        let packed = &a_packed_ref[s * kt + ki];
                                        let nslice = &noise_ref
                                            [s * per_sample..(s + 1) * per_sample];
                                        let b = boundaries_ref[s];
                                        let c = counts_for_boundary(b, false, &sp);
                                        (unit.compute_hybrid(packed, b, nslice), c, false)
                                    }
                                };
                                out_chunk[off * sp.hmus..(off + 1) * sp.hmus]
                                    .copy_from_slice(&vals);
                                acct.record(&energy.op_energy(&counts, with_se, &sp), &counts);
                            }
                        });
                    }
                });
                for s in 0..m {
                    for h in 0..sp.hmus {
                        out[s * n_pad + ni * sp.hmus + h] += tile_out[s * sp.hmus + h];
                    }
                }
                for acct in &chunk_accounts {
                    account.merge(acct);
                }
            }

            for s in 0..m {
                bda[s * nt + ni] = boundaries[s];
                let b = boundaries[s];
                if (0..16).contains(&b) {
                    b_hist[b as usize] += kt as u64;
                }
            }
        }

        // strip N padding
        let mut final_out = vec![0i32; m * n];
        for s in 0..m {
            final_out[s * n..(s + 1) * n].copy_from_slice(&out[s * n_pad..s * n_pad + n]);
        }
        Ok(GemmResult { out: final_out, m, n, account, b_hist, bda, n_tiles: nt })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ptest::check;

    fn rand_mat(g: &mut SplitMix64, rows: usize, cols: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..rows * cols).map(|_| g.next_range_i32(lo, hi)).collect()
    }

    fn exact_gemm(a: &[i32], m: usize, k: usize, w: &[i32], n: usize) -> Vec<i64> {
        let mut out = vec![0i64; m * n];
        for s in 0..m {
            for c in 0..n {
                let mut acc = 0i64;
                for x in 0..k {
                    acc += a[s * k + x] as i64 * w[c * k + x] as i64;
                }
                out[s * n + c] = acc;
            }
        }
        out
    }

    #[test]
    fn dcim_matches_exact_for_arbitrary_shapes() {
        check("dcim gemm exact", 10, |g| {
            let mut rng = SplitMix64::new(g.u64());
            let (m, k, n) =
                (rng.next_below(6) + 1, rng.next_below(300) + 1, rng.next_below(20) + 1);
            let a = rand_mat(&mut rng, m, k, 0, 256);
            let w = rand_mat(&mut rng, n, k, -128, 128);
            let mut gemm = MacroGemm::with_mode(CimMode::Dcim);
            let r = gemm.gemm(&a, m, k, &w, n, 0).unwrap();
            let expect = exact_gemm(&a, m, k, &w, n);
            let got: Vec<i64> = r.out.iter().map(|&x| x as i64).collect();
            assert_eq!(got, expect, "m={m} k={k} n={n}");
        });
    }

    #[test]
    fn hcim_b0_equals_dcim_outputs() {
        let mut rng = SplitMix64::new(3);
        let (m, k, n) = (4, 300, 10);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let mut hcim = MacroGemm::with_mode(CimMode::Hcim);
        hcim.fixed_b = 0;
        let mut dcim = MacroGemm::with_mode(CimMode::Dcim);
        assert_eq!(
            hcim.gemm(&a, m, k, &w, n, 0).unwrap().out,
            dcim.gemm(&a, m, k, &w, n, 0).unwrap().out
        );
    }

    #[test]
    fn hcim_error_grows_with_b() {
        let mut rng = SplitMix64::new(4);
        let (m, k, n) = (16, 288, 8);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let exact = exact_gemm(&a, m, k, &w, n);
        let mut prev = -1.0;
        for b in [5, 8, 10] {
            let mut gemm = MacroGemm::with_mode(CimMode::Hcim);
            gemm.fixed_b = b;
            let r = gemm.gemm(&a, m, k, &w, n, 0).unwrap();
            let mse: f64 = r
                .out
                .iter()
                .zip(&exact)
                .map(|(&o, &e)| (o as f64 - e as f64).powi(2))
                .sum::<f64>()
                / exact.len() as f64;
            assert!(mse > prev, "B={b}");
            prev = mse;
        }
    }

    #[test]
    fn osa_selects_varied_boundaries() {
        let mut rng = SplitMix64::new(5);
        let m = 32;
        let k = crate::spec::COLS;
        let n = crate::spec::HMUS;
        // half the samples high-magnitude, half low
        let mut a = Vec::new();
        for s in 0..m {
            let (lo, hi) = if s % 2 == 0 { (180, 256) } else { (0, 30) };
            a.extend(rand_mat(&mut rng, 1, k, lo, hi));
        }
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let mut gemm = MacroGemm::with_mode(CimMode::Osa);
        let r = gemm.gemm(&a, m, k, &w, n, 0).unwrap();
        let distinct: std::collections::HashSet<i32> = r.bda.iter().copied().collect();
        assert!(distinct.len() >= 2, "OSE chose a single boundary: {distinct:?}");
        // high-magnitude samples must get a more precise (lower) boundary
        let hi_b: f64 =
            (0..m).step_by(2).map(|s| r.bda[s] as f64).sum::<f64>() / (m / 2) as f64;
        let lo_b: f64 =
            (1..m).step_by(2).map(|s| r.bda[s] as f64).sum::<f64>() / (m / 2) as f64;
        assert!(hi_b < lo_b, "salient rows got coarser precision: {hi_b} vs {lo_b}");
        assert!(r.b_hist.iter().sum::<u64>() > 0);
    }

    #[test]
    fn osa_uses_less_energy_than_dcim() {
        let mut rng = SplitMix64::new(6);
        let (m, k, n) = (16, 288, 16);
        let a = rand_mat(&mut rng, m, k, 0, 120);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let e_d = MacroGemm::with_mode(CimMode::Dcim)
            .gemm(&a, m, k, &w, n, 0)
            .unwrap()
            .account
            .total_energy_j();
        let e_o = MacroGemm::with_mode(CimMode::Osa)
            .gemm(&a, m, k, &w, n, 0)
            .unwrap()
            .account
            .total_energy_j();
        assert!(e_o < e_d, "OSA {e_o} >= DCIM {e_d}");
    }

    #[test]
    fn acim_runs_with_energy() {
        let mut rng = SplitMix64::new(7);
        let (m, k, n) = (4, 144, 8);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let r = MacroGemm::with_mode(CimMode::Acim).gemm(&a, m, k, &w, n, 0).unwrap();
        assert!(r.account.breakdown.adc_fj > 0.0);
        assert_eq!(r.bda, vec![-1; 4]);
    }

    #[test]
    fn noise_stream_is_deterministic_per_seed() {
        let mut rng = SplitMix64::new(8);
        let (m, k, n) = (4, 144, 8);
        let a = rand_mat(&mut rng, m, k, 0, 256);
        let w = rand_mat(&mut rng, n, k, -128, 128);
        let r1 = MacroGemm::with_mode(CimMode::Hcim).gemm(&a, m, k, &w, n, 3).unwrap();
        let r2 = MacroGemm::with_mode(CimMode::Hcim).gemm(&a, m, k, &w, n, 3).unwrap();
        assert_eq!(r1.out, r2.out);
        let r3 = MacroGemm::with_mode(CimMode::Hcim).gemm(&a, m, k, &w, n, 4).unwrap();
        assert_ne!(r1.out, r3.out, "different layer index must shift the noise stream");
    }

    #[test]
    fn padding_helpers() {
        let a = vec![1, 2, 3, 4];
        let p = pad_cols(&a, 2, 2, 4);
        assert_eq!(p, vec![1, 2, 0, 0, 3, 4, 0, 0]);
        let w = pad_matrix(&a, 2, 2, 3, 3);
        assert_eq!(w, vec![1, 2, 0, 3, 4, 0, 0, 0, 0]);
    }
}
