//! Plan/execute split for the macro GEMM — weight-stationary caching.
//!
//! The HCIMA array is weight-stationary hardware: weights are written
//! into the split-port 6T cells once per layer and reused by every input
//! tile.  The original engine re-packed every [`MacroUnit`] per call, per
//! batch, per request — the dominant avoidable cost on the serving hot
//! path.  This module builds an immutable [`LayerPlan`] exactly once per
//! layer (padded dims, packed weight tiles, per-mode op-count templates)
//! and caches it by `layer_idx` in a [`PlanCache`] shared via `Arc`
//! across engine clones — i.e. across all coordinator worker threads —
//! so `MacroUnit` packing for a given layer happens once per process
//! (DESIGN.md §5).
//!
//! The plan is mode-independent: it carries the packed weights plus
//! op-count templates for every boundary, so one cache serves DCIM /
//! HCIM / OSA / ACIM and the dual-precision PG / DRQ baselines alike,
//! and can be shared between the native and PJRT engines.

use crate::macrosim::{counts_for_boundary, MacroUnit, OpCounts};
use crate::spec::MacroSpec;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Boundaries with a precomputed op-count template (covers `B_DCIM` and
/// every Fig 5b candidate; out-of-range boundaries fall back to
/// [`counts_for_boundary`]).
const B_TEMPLATES: i32 = 16;

/// Cheap order-sensitive fingerprint of a weight matrix (SplitMix64-style
/// mixing).  Used to detect weight drift under a cached `layer_idx`; a
/// collision can only *miss* drift, never reject valid reuse.
pub fn weight_fingerprint(w: &[i32]) -> u64 {
    let mut h: u64 = 0x9E37_79B9_7F4A_7C15 ^ (w.len() as u64);
    for &x in w {
        h = h.wrapping_add(x as u32 as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h ^= h >> 31;
    }
    h
}

/// Immutable per-layer execution plan: everything about a GEMM that does
/// not depend on the activations.
#[derive(Debug)]
pub struct LayerPlan {
    pub layer_idx: u64,
    /// Unpadded K (contraction) dimension.
    pub k: usize,
    /// Unpadded N (output-channel) dimension.
    pub n: usize,
    /// K-tile count.
    pub kt: usize,
    /// N-tile count.
    pub nt: usize,
    pub k_pad: usize,
    pub n_pad: usize,
    pub spec: MacroSpec,
    /// Fingerprint of the source weight matrix (drift detection).
    pub w_fingerprint: u64,
    /// Packed macro units, `[nt, kt]` row-major — the weights as written
    /// into the array, bit-planes pre-packed for the popcount datapath.
    units: Vec<MacroUnit>,
    /// Op-count templates per boundary `b in 0..B_TEMPLATES`, indexed
    /// `[b][with_se]`.
    counts: Vec<[OpCounts; 2]>,
    /// Full-analog (ACIM) op-count template.
    acim: OpCounts,
    /// Dual-precision (PG/DRQ) templates, indexed by `full`.
    dual: [OpCounts; 2],
}

impl LayerPlan {
    /// Pack a layer's `[n, k]` weight matrix into macro tiles and
    /// precompute the op-count templates.  This is the expensive step the
    /// cache amortizes; everything it produces is immutable.
    pub fn build(w: &[i32], n: usize, k: usize, layer_idx: u64, sp: MacroSpec) -> Result<Self> {
        if w.len() != n * k {
            bail!("layer {layer_idx}: weight length {} != n*k = {}", w.len(), n * k);
        }
        let kt = k.div_ceil(sp.cols).max(1);
        let nt = n.div_ceil(sp.hmus).max(1);
        let k_pad = kt * sp.cols;
        let n_pad = nt * sp.hmus;
        let w_p = super::pad_matrix(w, n, k, n_pad, k_pad);
        let mut units = Vec::with_capacity(nt * kt);
        for ni in 0..nt {
            for ki in 0..kt {
                let mut wt = Vec::with_capacity(sp.hmus * sp.cols);
                for h in 0..sp.hmus {
                    let row = (ni * sp.hmus + h) * k_pad + ki * sp.cols;
                    wt.extend_from_slice(&w_p[row..row + sp.cols]);
                }
                units.push(MacroUnit::new(&wt, sp)?);
            }
        }

        let counts: Vec<[OpCounts; 2]> = (0..B_TEMPLATES)
            .map(|b| [counts_for_boundary(b, false, &sp), counts_for_boundary(b, true, &sp)])
            .collect();

        // ACIM: every plane analog, one ADC group per (weight plane,
        // activation slice).
        let n_slices = sp.a_bits.div_ceil(sp.analog_band as usize);
        let mut acim = counts_for_boundary(0, false, &sp);
        acim.digital_pairs = 0;
        acim.analog_pairs = (sp.w_bits * sp.a_bits) as u32;
        acim.discard_pairs = 0;
        acim.adc_groups = (sp.w_bits * n_slices) as u32;
        acim.compute_cycles = acim.adc_groups + 2;

        // PG/DRQ dual precision: the high-nibble pass always runs; the
        // low-nibble pass only for "important" outputs.
        let half_pairs = (sp.w_bits * sp.a_bits / 2) as u32;
        let dual = [false, true].map(|full| {
            let pairs = if full { 2 * half_pairs } else { half_pairs };
            OpCounts {
                digital_pairs: pairs,
                discard_pairs: 2 * half_pairs - pairs,
                compute_cycles: pairs.div_ceil(2),
                ..Default::default()
            }
        });

        Ok(Self {
            layer_idx,
            k,
            n,
            kt,
            nt,
            k_pad,
            n_pad,
            spec: sp,
            w_fingerprint: weight_fingerprint(w),
            units,
            counts,
            acim,
            dual,
        })
    }

    /// The packed macro for N-tile `ni`, K-tile `ki`.
    #[inline]
    pub fn unit(&self, ni: usize, ki: usize) -> &MacroUnit {
        &self.units[ni * self.kt + ki]
    }

    /// Computing-mode op-count template at boundary `b`.
    #[inline]
    pub fn counts(&self, b: i32, with_se: bool) -> OpCounts {
        if (0..B_TEMPLATES).contains(&b) {
            self.counts[b as usize][with_se as usize]
        } else {
            counts_for_boundary(b, with_se, &self.spec)
        }
    }

    /// Full-analog op-count template.
    #[inline]
    pub fn acim_counts(&self) -> OpCounts {
        self.acim
    }

    /// Dual-precision template (`full` = low pass not gated off).
    #[inline]
    pub fn dual_counts(&self, full: bool) -> OpCounts {
        self.dual[full as usize]
    }

    /// Number of packed weight tiles (`nt * kt`).
    pub fn packed_tiles(&self) -> usize {
        self.units.len()
    }
}

/// Snapshot of cache activity, for metrics / benches / tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Lookups answered from the cache (no packing).
    pub hits: u64,
    /// Lookups that built (packed) a new plan.
    pub misses: u64,
    /// Plans currently cached.
    pub layers: u64,
}

impl PlanCacheStats {
    /// hits / (hits + misses), 0.0 when the cache was never used.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Thread-safe layer-plan cache, keyed by `layer_idx`.
///
/// Contract (weight stationarity): for the lifetime of one cache, a given
/// `layer_idx` always refers to the same weight matrix — exactly the
/// guarantee `nn::Executor` provides by assigning stable indices in graph
/// order.  Dimension, spec, or weight-content changes under a cached
/// index are rejected loudly rather than silently recomputed (contents
/// via [`weight_fingerprint`], an O(n*k) check that is negligible next
/// to the O(m*n*k) GEMM it guards).
#[derive(Debug, Default)]
pub struct PlanCache {
    plans: Mutex<HashMap<u64, Arc<LayerPlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the plan for `layer_idx`, packing the weights on the first
    /// call only.  Concurrent callers serialize on the cache lock, so a
    /// plan is never built twice.
    pub fn get_or_build(
        &self,
        layer_idx: u64,
        w: &[i32],
        n: usize,
        k: usize,
        sp: MacroSpec,
    ) -> Result<Arc<LayerPlan>> {
        let mut plans = self.plans.lock().unwrap();
        if let Some(plan) = plans.get(&layer_idx) {
            if plan.n != n || plan.k != k || plan.spec != sp {
                bail!(
                    "plan cache: layer {layer_idx} was planned as [{}x{}] but called with \
                     [{n}x{k}] — layer indices must be stable per weight matrix",
                    plan.n,
                    plan.k
                );
            }
            if plan.w_fingerprint != weight_fingerprint(w) {
                bail!(
                    "plan cache: layer {layer_idx} called with different weight contents — \
                     layer indices must be stable per weight matrix (clear() the cache to \
                     reload weights)"
                );
            }
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(plan.clone());
        }
        let plan = Arc::new(LayerPlan::build(w, n, k, layer_idx, sp)?);
        plans.insert(layer_idx, plan.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok(plan)
    }

    pub fn stats(&self) -> PlanCacheStats {
        PlanCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            layers: self.plans.lock().unwrap().len() as u64,
        }
    }

    /// Drop every cached plan (weights will re-pack on next use).
    pub fn clear(&self) {
        self.plans.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::SplitMix64;

    fn rand_w(seed: u64, n: usize, k: usize) -> Vec<i32> {
        let mut g = SplitMix64::new(seed);
        (0..n * k).map(|_| g.next_range_i32(-128, 128)).collect()
    }

    #[test]
    fn plan_dims_and_tiles() {
        let sp = MacroSpec::default();
        let (n, k) = (20, 300);
        let plan = LayerPlan::build(&rand_w(1, n, k), n, k, 0, sp).unwrap();
        assert_eq!(plan.kt, 3);
        assert_eq!(plan.nt, 3);
        assert_eq!(plan.k_pad, 432);
        assert_eq!(plan.n_pad, 24);
        assert_eq!(plan.packed_tiles(), 9);
    }

    #[test]
    fn plan_units_match_direct_packing() {
        // The plan's packed tile must equal a MacroUnit built from the
        // same padded weight rows by hand.
        let sp = MacroSpec::default();
        let (n, k) = (10, 150);
        let w = rand_w(2, n, k);
        let plan = LayerPlan::build(&w, n, k, 0, sp).unwrap();
        let w_p = crate::sched::pad_matrix(&w, n, k, plan.n_pad, plan.k_pad);
        for ni in 0..plan.nt {
            for ki in 0..plan.kt {
                let mut wt = Vec::new();
                for h in 0..sp.hmus {
                    let row = (ni * sp.hmus + h) * plan.k_pad + ki * sp.cols;
                    wt.extend_from_slice(&w_p[row..row + sp.cols]);
                }
                assert_eq!(plan.unit(ni, ki).weights(), &wt[..], "tile ({ni},{ki})");
            }
        }
    }

    #[test]
    fn count_templates_match_direct_computation() {
        let sp = MacroSpec::default();
        let plan = LayerPlan::build(&rand_w(3, 8, 144), 8, 144, 0, sp).unwrap();
        for b in 0..16 {
            assert_eq!(plan.counts(b, false), counts_for_boundary(b, false, &sp), "B={b}");
            assert_eq!(plan.counts(b, true), counts_for_boundary(b, true, &sp), "B={b} se");
        }
        // out-of-template boundaries fall back
        assert_eq!(plan.counts(20, false), counts_for_boundary(20, false, &sp));
    }

    #[test]
    fn cache_hits_and_misses() {
        let sp = MacroSpec::default();
        let cache = PlanCache::new();
        let w = rand_w(4, 8, 144);
        cache.get_or_build(0, &w, 8, 144, sp).unwrap();
        cache.get_or_build(0, &w, 8, 144, sp).unwrap();
        cache.get_or_build(1, &w, 8, 144, sp).unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.layers), (1, 2, 2));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
        cache.clear();
        assert_eq!(cache.stats().layers, 0);
    }

    #[test]
    fn cache_rejects_dimension_drift() {
        let sp = MacroSpec::default();
        let cache = PlanCache::new();
        let w = rand_w(5, 8, 144);
        cache.get_or_build(0, &w, 8, 144, sp).unwrap();
        assert!(cache.get_or_build(0, &w[..8 * 72], 8, 72, sp).is_err());
    }

    #[test]
    fn cache_rejects_weight_content_drift() {
        let sp = MacroSpec::default();
        let cache = PlanCache::new();
        let w = rand_w(6, 8, 144);
        cache.get_or_build(0, &w, 8, 144, sp).unwrap();
        let mut w2 = w.clone();
        w2[10] = w2[10].wrapping_neg().clamp(-128, 127);
        if w2[10] == w[10] {
            w2[10] = if w[10] == 1 { 2 } else { 1 };
        }
        assert!(
            cache.get_or_build(0, &w2, 8, 144, sp).is_err(),
            "same-shape weight change must be rejected, not served stale tiles"
        );
        // unchanged weights still hit
        cache.get_or_build(0, &w, 8, 144, sp).unwrap();
        assert_eq!(cache.stats().hits, 1);
    }

    #[test]
    fn fingerprint_is_order_and_content_sensitive() {
        let a = vec![1, 2, 3, 4];
        let b = vec![2, 1, 3, 4];
        let c = vec![1, 2, 3, 5];
        assert_ne!(weight_fingerprint(&a), weight_fingerprint(&b));
        assert_ne!(weight_fingerprint(&a), weight_fingerprint(&c));
        assert_eq!(weight_fingerprint(&a), weight_fingerprint(&[1, 2, 3, 4]));
    }

    #[test]
    fn bad_weight_length_rejected() {
        let sp = MacroSpec::default();
        assert!(LayerPlan::build(&[0; 10], 8, 144, 0, sp).is_err());
    }
}
