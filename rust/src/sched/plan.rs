//! Plan/execute split for the macro GEMM — weight-stationary caching.
//!
//! The HCIMA array is weight-stationary hardware: weights are written
//! into the split-port 6T cells once per layer and reused by every input
//! tile.  The original engine re-packed every [`MacroUnit`] per call, per
//! batch, per request — the dominant avoidable cost on the serving hot
//! path.  This module builds an immutable [`LayerPlan`] exactly once per
//! layer (padded dims, packed weight tiles, per-mode op-count templates)
//! and caches it by `layer_idx` in a [`PlanCache`] shared via `Arc`
//! across engine clones — i.e. across all coordinator worker threads —
//! so `MacroUnit` packing for a given layer happens once per process
//! (DESIGN.md §5).
//!
//! The plan is mode-independent: it carries the packed weights plus
//! op-count templates for every boundary, so one cache serves DCIM /
//! HCIM / OSA / ACIM and the dual-precision PG / DRQ baselines alike,
//! and can be shared between the native and PJRT engines.

use crate::macrosim::{counts_for_boundary, MacroUnit, OpCounts};
use crate::spec::MacroSpec;
use anyhow::{bail, Result};
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Boundaries with a precomputed op-count template (covers `B_DCIM` and
/// every Fig 5b candidate; out-of-range boundaries fall back to
/// [`counts_for_boundary`]).
const B_TEMPLATES: i32 = 16;

/// Cheap order-sensitive fingerprint of a weight matrix (SplitMix64-style
/// mixing).  Used to detect weight drift under a cached `layer_idx`; a
/// collision can only *miss* drift, never reject valid reuse.
pub fn weight_fingerprint(w: &[i32]) -> u64 {
    let mut h: u64 = 0x9E37_79B9_7F4A_7C15 ^ (w.len() as u64);
    for &x in w {
        h = h.wrapping_add(x as u32 as u64).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h ^= h >> 31;
    }
    h
}

/// Immutable per-layer execution plan: everything about a GEMM that does
/// not depend on the activations.
#[derive(Debug)]
pub struct LayerPlan {
    pub layer_idx: u64,
    /// Unpadded K (contraction) dimension.
    pub k: usize,
    /// Unpadded N (output-channel) dimension.
    pub n: usize,
    /// K-tile count.
    pub kt: usize,
    /// N-tile count.
    pub nt: usize,
    pub k_pad: usize,
    pub n_pad: usize,
    pub spec: MacroSpec,
    /// Fingerprint of the source weight matrix (drift detection).
    pub w_fingerprint: u64,
    /// Packed macro units, `[nt, kt]` row-major — the weights as written
    /// into the array, bit-planes pre-packed for the popcount datapath.
    units: Vec<MacroUnit>,
    /// Op-count templates per boundary `b in 0..B_TEMPLATES`, indexed
    /// `[b][with_se]`.
    counts: Vec<[OpCounts; 2]>,
    /// Full-analog (ACIM) op-count template.
    acim: OpCounts,
    /// Dual-precision (PG/DRQ) templates, indexed by `full`.
    dual: [OpCounts; 2],
}

impl LayerPlan {
    /// Pack a layer's `[n, k]` weight matrix into macro tiles and
    /// precompute the op-count templates.  This is the expensive step the
    /// cache amortizes; everything it produces is immutable.
    pub fn build(w: &[i32], n: usize, k: usize, layer_idx: u64, sp: MacroSpec) -> Result<Self> {
        if w.len() != n * k {
            bail!("layer {layer_idx}: weight length {} != n*k = {}", w.len(), n * k);
        }
        let kt = k.div_ceil(sp.cols).max(1);
        let nt = n.div_ceil(sp.hmus).max(1);
        let k_pad = kt * sp.cols;
        let n_pad = nt * sp.hmus;
        let w_p = super::pad_matrix(w, n, k, n_pad, k_pad);
        let mut units = Vec::with_capacity(nt * kt);
        for ni in 0..nt {
            for ki in 0..kt {
                let mut wt = Vec::with_capacity(sp.hmus * sp.cols);
                for h in 0..sp.hmus {
                    let row = (ni * sp.hmus + h) * k_pad + ki * sp.cols;
                    wt.extend_from_slice(&w_p[row..row + sp.cols]);
                }
                units.push(MacroUnit::new(&wt, sp)?);
            }
        }

        let counts: Vec<[OpCounts; 2]> = (0..B_TEMPLATES)
            .map(|b| [counts_for_boundary(b, false, &sp), counts_for_boundary(b, true, &sp)])
            .collect();

        // ACIM: every plane analog, one ADC group per (weight plane,
        // activation slice).
        let n_slices = sp.a_bits.div_ceil(sp.analog_band as usize);
        let mut acim = counts_for_boundary(0, false, &sp);
        acim.digital_pairs = 0;
        acim.analog_pairs = (sp.w_bits * sp.a_bits) as u32;
        acim.discard_pairs = 0;
        acim.adc_groups = (sp.w_bits * n_slices) as u32;
        acim.compute_cycles = acim.adc_groups + 2;

        // PG/DRQ dual precision: the high-nibble pass always runs; the
        // low-nibble pass only for "important" outputs.
        let half_pairs = (sp.w_bits * sp.a_bits / 2) as u32;
        let dual = [false, true].map(|full| {
            let pairs = if full { 2 * half_pairs } else { half_pairs };
            OpCounts {
                digital_pairs: pairs,
                discard_pairs: 2 * half_pairs - pairs,
                compute_cycles: pairs.div_ceil(2),
                ..Default::default()
            }
        });

        Ok(Self {
            layer_idx,
            k,
            n,
            kt,
            nt,
            k_pad,
            n_pad,
            spec: sp,
            w_fingerprint: weight_fingerprint(w),
            units,
            counts,
            acim,
            dual,
        })
    }

    /// The packed macro for N-tile `ni`, K-tile `ki`.
    #[inline]
    pub fn unit(&self, ni: usize, ki: usize) -> &MacroUnit {
        &self.units[ni * self.kt + ki]
    }

    /// Computing-mode op-count template at boundary `b`.
    #[inline]
    pub fn counts(&self, b: i32, with_se: bool) -> OpCounts {
        if (0..B_TEMPLATES).contains(&b) {
            self.counts[b as usize][with_se as usize]
        } else {
            counts_for_boundary(b, with_se, &self.spec)
        }
    }

    /// Full-analog op-count template.
    #[inline]
    pub fn acim_counts(&self) -> OpCounts {
        self.acim
    }

    /// Dual-precision template (`full` = low pass not gated off).
    #[inline]
    pub fn dual_counts(&self, full: bool) -> OpCounts {
        self.dual[full as usize]
    }

    /// Number of packed weight tiles (`nt * kt`).
    pub fn packed_tiles(&self) -> usize {
        self.units.len()
    }
}

/// How the fleet placement planner trades replication against residency
/// (`[fleet] placement`, overridable per request via
/// `options.placement` on `POST /v2/infer`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PlacementMode {
    /// Replicate each layer to fill the fleet; fall back to CIMPool-style
    /// weight pooling (tile dedup) and finally wrap-around assignment
    /// when a layer alone exceeds the fleet's residency.
    #[default]
    Auto,
    /// Maximize replicas for throughput and never pool — duplicate tiles
    /// cost residency; oversized layers wrap around the fleet.
    Replicate,
    /// One replica, no pooling: every tile must be weight-stationary
    /// resident.  A model over aggregate capacity is rejected
    /// (`FleetCapacityExceeded`) instead of silently repacking.
    Resident,
}

impl PlacementMode {
    pub const ALL: [PlacementMode; 3] =
        [PlacementMode::Auto, PlacementMode::Replicate, PlacementMode::Resident];

    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "auto" => Some(Self::Auto),
            "replicate" => Some(Self::Replicate),
            "resident" => Some(Self::Resident),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::Auto => "auto",
            Self::Replicate => "replicate",
            Self::Resident => "resident",
        }
    }
}

/// Fleet geometry the placement planner needs (resolved from `[fleet]`
/// config by `sched::fleet`; decoupled from `SystemConfig` so the
/// planner is testable standalone).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetDims {
    /// Simulated macro count K.
    pub macros: usize,
    /// Per-macro weight-stationary residency budget, in packed tiles.
    pub residency_tiles: usize,
}

/// Where one layer's packed weight tiles live on the fleet.
///
/// `assign` maps tile `(ni, ki)` (index `ni*kt + ki`) to a macro id for
/// replica 0; replica `r` lives at `assign[t] + r*stride`.  Work units
/// pick their replica round-robin by row chunk, so replicas split the
/// activation stream deterministically.
#[derive(Debug, Clone)]
pub struct LayerPlacement {
    pub layer_idx: u64,
    pub nt: usize,
    pub kt: usize,
    pub fleet_k: usize,
    /// Tile -> macro id (replica 0), `[nt*kt]` row-major like the plan.
    pub assign: Vec<u16>,
    /// Whole-layer replicas packed onto the fleet (>= 1).
    pub replicas: usize,
    /// Macro-id offset between consecutive replicas.
    pub stride: usize,
    /// Distinct macros one replica occupies.
    pub macros_needed: usize,
    /// Assignment wrapped past the fleet: residency is overcommitted and
    /// tiles stream in on demand (reported, not fatal).
    pub wrapped: bool,
}

impl LayerPlacement {
    /// Plan one layer's tiles onto the fleet.  `unique_tiles` is the
    /// layer's deduplicated tile count (pooling input); pass `nt*kt`
    /// when pooling is off or unknown.
    ///
    /// Sharding prefers the N dimension (whole output columns per macro,
    /// no reduce cost) and splits K only when one column's K-tiles
    /// exceed a single macro's residency — split-K is what incurs the
    /// inter-macro partial-sum transfer charge.
    pub fn plan(
        layer_idx: u64,
        nt: usize,
        kt: usize,
        unique_tiles: usize,
        fleet: FleetDims,
        mode: PlacementMode,
    ) -> Self {
        let fleet_k = fleet.macros.max(1);
        let tiles = nt * kt;
        // CIMPool-style spill: in auto mode a layer past the whole
        // fleet's budget gets its residency demand scaled down by the
        // dedup ratio (shared tiles are resident once, indexed many
        // times).  Replicate never pools; resident rejects upstream.
        let mut residency = fleet.residency_tiles.max(1);
        if mode == PlacementMode::Auto
            && tiles > fleet_k * residency
            && unique_tiles > 0
            && unique_tiles < tiles
        {
            residency = residency * tiles / unique_tiles;
        }
        let col_macros = kt.div_ceil(residency).max(1);
        let mut assign = Vec::with_capacity(tiles);
        let mut macros_needed;
        if col_macros == 1 {
            let cols_per_macro = (residency / kt.max(1)).max(1);
            macros_needed = nt.div_ceil(cols_per_macro);
            for ni in 0..nt {
                for _ki in 0..kt {
                    assign.push((ni / cols_per_macro) as u16);
                }
            }
        } else {
            macros_needed = nt * col_macros;
            for ni in 0..nt {
                for ki in 0..kt {
                    assign.push((ni * col_macros + ki / residency) as u16);
                }
            }
        }
        let wrapped = macros_needed > fleet_k;
        if wrapped {
            for a in &mut assign {
                *a = (*a as usize % fleet_k) as u16;
            }
            macros_needed = fleet_k;
        }
        let replicas = match mode {
            PlacementMode::Resident => 1,
            PlacementMode::Auto | PlacementMode::Replicate => (fleet_k / macros_needed).max(1),
        };
        Self {
            layer_idx,
            nt,
            kt,
            fleet_k,
            assign,
            replicas,
            stride: macros_needed,
            macros_needed,
            wrapped,
        }
    }

    /// Macro executing tile `(ni, ki)` for replica `r`.
    #[inline]
    pub fn macro_of(&self, ni: usize, ki: usize, replica: usize) -> usize {
        self.assign[ni * self.kt + ki] as usize + (replica % self.replicas) * self.stride
    }

    /// Distinct macros across column `ni`'s K-tiles — a span > 1 means
    /// split-K: partial sums must hop between macros to reduce.
    pub fn k_span(&self, ni: usize) -> usize {
        let col = &self.assign[ni * self.kt..(ni + 1) * self.kt];
        let mut seen: Vec<u16> = col.to_vec();
        seen.sort_unstable();
        seen.dedup();
        seen.len()
    }

    /// Whether any column is split across macros.
    pub fn split_k(&self) -> bool {
        (0..self.nt).any(|ni| self.k_span(ni) > 1)
    }

    /// Resident tiles on macro `m`, counting every replica.
    pub fn tiles_on(&self, m: usize) -> usize {
        (0..self.replicas)
            .map(|r| {
                self.assign
                    .iter()
                    .filter(|&&a| a as usize + r * self.stride == m)
                    .count()
            })
            .sum()
    }
}

/// Whole-model placement: every layer's [`LayerPlacement`] plus the
/// aggregate residency picture — what `GET /v2/topology` reports and
/// what the coordinator's resident-mode capacity check reads.
#[derive(Debug, Clone)]
pub struct PlacementPlan {
    pub fleet: FleetDims,
    pub mode: PlacementMode,
    pub layers: Vec<LayerPlacement>,
    /// Total packed tiles across all layers (one replica each).
    pub total_tiles: usize,
    /// Deduplicated tiles (pooled residency demand).
    pub unique_tiles: usize,
}

impl PlacementPlan {
    /// Plan a whole model.  `layers` carries `(layer_idx, nt, kt,
    /// unique_tiles)` per GEMM layer in stable graph order.
    pub fn plan(
        layers: &[(u64, usize, usize, usize)],
        fleet: FleetDims,
        mode: PlacementMode,
    ) -> Self {
        let placed: Vec<LayerPlacement> = layers
            .iter()
            .map(|&(idx, nt, kt, uniq)| LayerPlacement::plan(idx, nt, kt, uniq, fleet, mode))
            .collect();
        let total_tiles = layers.iter().map(|&(_, nt, kt, _)| nt * kt).sum();
        let unique_tiles = layers.iter().map(|&(_, _, _, u)| u).sum();
        Self { fleet, mode, layers: placed, total_tiles, unique_tiles }
    }

    /// Aggregate fleet capacity in tiles.
    pub fn capacity_tiles(&self) -> usize {
        self.fleet.macros * self.fleet.residency_tiles
    }

    /// Resident tiles per macro (replicas included).
    pub fn macro_residency(&self) -> Vec<usize> {
        let mut per = vec![0usize; self.fleet.macros.max(1)];
        for lp in &self.layers {
            for (m, slot) in per.iter_mut().enumerate() {
                *slot += lp.tiles_on(m);
            }
        }
        per
    }
}

/// Cache scope: `(backend, fleet_k, placement)` folded into one key so
/// plans built for different fleet shapes can never shadow each other
/// (switching fleet sizes at runtime used to serve the stale
/// single-macro plan — the key ignored fleet geometry entirely).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub struct PlanScope(pub u64);

impl PlanScope {
    /// The legacy single-macro scope (every pre-fleet caller).
    pub const SINGLE: PlanScope = PlanScope(0);

    /// Fold a backend name + fleet geometry + placement mode into a
    /// scope key (FNV-style mixing; never collides with `SINGLE`).
    pub fn for_backend(backend: &str, fleet_k: usize, placement: PlacementMode) -> Self {
        let mut h: u64 = 0xCBF2_9CE4_8422_2325;
        for &b in backend.as_bytes() {
            h = (h ^ b as u64).wrapping_mul(0x0000_0100_0000_01B3);
        }
        h ^= (fleet_k as u64).wrapping_add(1).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        h ^= h >> 31;
        h = h.wrapping_mul(0x94D0_49BB_1331_11EB);
        h ^= (placement as u64).wrapping_add(1).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        h ^= h >> 29;
        PlanScope(h.max(1))
    }
}

/// Snapshot of cache activity, for metrics / benches / tests.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    /// Lookups answered from the cache (no packing).
    pub hits: u64,
    /// Lookups that built (packed) a new plan.
    pub misses: u64,
    /// Plans currently cached.
    pub layers: u64,
}

impl PlanCacheStats {
    /// hits / (hits + misses), 0.0 when the cache was never used.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Thread-safe layer-plan cache, keyed by `layer_idx`.
///
/// Contract (weight stationarity): for the lifetime of one cache, a given
/// `layer_idx` always refers to the same weight matrix — exactly the
/// guarantee `nn::Executor` provides by assigning stable indices in graph
/// order.  Dimension, spec, or weight-content changes under a cached
/// index are rejected loudly rather than silently recomputed (contents
/// via [`weight_fingerprint`], an O(n*k) check that is negligible next
/// to the O(m*n*k) GEMM it guards).
///
/// Plans are additionally keyed by a [`PlanScope`] — `(backend, fleet_k,
/// placement)` folded to a `u64` — so a fleet-sharded build can never
/// shadow (or be served) the single-macro plan for the same layer when
/// the fleet size changes at runtime.  Legacy callers use
/// [`PlanCache::get_or_build`], which pins [`PlanScope::SINGLE`].
#[derive(Debug, Default)]
pub struct PlanCache {
    plans: Mutex<HashMap<(u64, u64), Arc<LayerPlan>>>,
    placements: Mutex<HashMap<u64, Arc<PlacementPlan>>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl PlanCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fetch the plan for `layer_idx` in the legacy single-macro scope.
    pub fn get_or_build(
        &self,
        layer_idx: u64,
        w: &[i32],
        n: usize,
        k: usize,
        sp: MacroSpec,
    ) -> Result<Arc<LayerPlan>> {
        self.get_or_build_scoped(PlanScope::SINGLE, layer_idx, w, n, k, sp)
    }

    /// Fetch the plan for `(scope, layer_idx)`, packing the weights on
    /// the first call only.  Concurrent callers serialize on the cache
    /// lock, so a plan is never built twice.
    pub fn get_or_build_scoped(
        &self,
        scope: PlanScope,
        layer_idx: u64,
        w: &[i32],
        n: usize,
        k: usize,
        sp: MacroSpec,
    ) -> Result<Arc<LayerPlan>> {
        let mut plans = self.plans.lock().unwrap();
        if let Some(plan) = plans.get(&(scope.0, layer_idx)) {
            if plan.n != n || plan.k != k || plan.spec != sp {
                bail!(
                    "plan cache: layer {layer_idx} was planned as [{}x{}] but called with \
                     [{n}x{k}] — layer indices must be stable per weight matrix",
                    plan.n,
                    plan.k
                );
            }
            if plan.w_fingerprint != weight_fingerprint(w) {
                bail!(
                    "plan cache: layer {layer_idx} called with different weight contents — \
                     layer indices must be stable per weight matrix (clear() the cache to \
                     reload weights)"
                );
            }
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Ok(plan.clone());
        }
        let plan = Arc::new(LayerPlan::build(w, n, k, layer_idx, sp)?);
        plans.insert((scope.0, layer_idx), plan.clone());
        self.misses.fetch_add(1, Ordering::Relaxed);
        Ok(plan)
    }

    /// Fetch the cached [`PlacementPlan`] for `scope`, planning it with
    /// `build` on first use.  Placement is a pure function of the graph
    /// geometry + fleet shape, both folded into the scope key, so one
    /// entry per scope is exact.
    pub fn placement(
        &self,
        scope: PlanScope,
        build: impl FnOnce() -> PlacementPlan,
    ) -> Arc<PlacementPlan> {
        let mut placements = self.placements.lock().unwrap();
        placements.entry(scope.0).or_insert_with(|| Arc::new(build())).clone()
    }

    pub fn stats(&self) -> PlanCacheStats {
        PlanCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            layers: self.plans.lock().unwrap().len() as u64,
        }
    }

    /// Drop every cached plan (weights will re-pack on next use).
    pub fn clear(&self) {
        self.plans.lock().unwrap().clear();
        self.placements.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::SplitMix64;

    fn rand_w(seed: u64, n: usize, k: usize) -> Vec<i32> {
        let mut g = SplitMix64::new(seed);
        (0..n * k).map(|_| g.next_range_i32(-128, 128)).collect()
    }

    #[test]
    fn plan_dims_and_tiles() {
        let sp = MacroSpec::default();
        let (n, k) = (20, 300);
        let plan = LayerPlan::build(&rand_w(1, n, k), n, k, 0, sp).unwrap();
        assert_eq!(plan.kt, 3);
        assert_eq!(plan.nt, 3);
        assert_eq!(plan.k_pad, 432);
        assert_eq!(plan.n_pad, 24);
        assert_eq!(plan.packed_tiles(), 9);
    }

    #[test]
    fn plan_units_match_direct_packing() {
        // The plan's packed tile must equal a MacroUnit built from the
        // same padded weight rows by hand.
        let sp = MacroSpec::default();
        let (n, k) = (10, 150);
        let w = rand_w(2, n, k);
        let plan = LayerPlan::build(&w, n, k, 0, sp).unwrap();
        let w_p = crate::sched::pad_matrix(&w, n, k, plan.n_pad, plan.k_pad);
        for ni in 0..plan.nt {
            for ki in 0..plan.kt {
                let mut wt = Vec::new();
                for h in 0..sp.hmus {
                    let row = (ni * sp.hmus + h) * plan.k_pad + ki * sp.cols;
                    wt.extend_from_slice(&w_p[row..row + sp.cols]);
                }
                assert_eq!(plan.unit(ni, ki).weights(), &wt[..], "tile ({ni},{ki})");
            }
        }
    }

    #[test]
    fn count_templates_match_direct_computation() {
        let sp = MacroSpec::default();
        let plan = LayerPlan::build(&rand_w(3, 8, 144), 8, 144, 0, sp).unwrap();
        for b in 0..16 {
            assert_eq!(plan.counts(b, false), counts_for_boundary(b, false, &sp), "B={b}");
            assert_eq!(plan.counts(b, true), counts_for_boundary(b, true, &sp), "B={b} se");
        }
        // out-of-template boundaries fall back
        assert_eq!(plan.counts(20, false), counts_for_boundary(20, false, &sp));
    }

    #[test]
    fn cache_hits_and_misses() {
        let sp = MacroSpec::default();
        let cache = PlanCache::new();
        let w = rand_w(4, 8, 144);
        cache.get_or_build(0, &w, 8, 144, sp).unwrap();
        cache.get_or_build(0, &w, 8, 144, sp).unwrap();
        cache.get_or_build(1, &w, 8, 144, sp).unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.layers), (1, 2, 2));
        assert!((s.hit_rate() - 1.0 / 3.0).abs() < 1e-12);
        cache.clear();
        assert_eq!(cache.stats().layers, 0);
    }

    #[test]
    fn cache_rejects_dimension_drift() {
        let sp = MacroSpec::default();
        let cache = PlanCache::new();
        let w = rand_w(5, 8, 144);
        cache.get_or_build(0, &w, 8, 144, sp).unwrap();
        assert!(cache.get_or_build(0, &w[..8 * 72], 8, 72, sp).is_err());
    }

    #[test]
    fn cache_rejects_weight_content_drift() {
        let sp = MacroSpec::default();
        let cache = PlanCache::new();
        let w = rand_w(6, 8, 144);
        cache.get_or_build(0, &w, 8, 144, sp).unwrap();
        let mut w2 = w.clone();
        w2[10] = w2[10].wrapping_neg().clamp(-128, 127);
        if w2[10] == w[10] {
            w2[10] = if w[10] == 1 { 2 } else { 1 };
        }
        assert!(
            cache.get_or_build(0, &w2, 8, 144, sp).is_err(),
            "same-shape weight change must be rejected, not served stale tiles"
        );
        // unchanged weights still hit
        cache.get_or_build(0, &w, 8, 144, sp).unwrap();
        assert_eq!(cache.stats().hits, 1);
    }

    #[test]
    fn fingerprint_is_order_and_content_sensitive() {
        let a = vec![1, 2, 3, 4];
        let b = vec![2, 1, 3, 4];
        let c = vec![1, 2, 3, 5];
        assert_ne!(weight_fingerprint(&a), weight_fingerprint(&b));
        assert_ne!(weight_fingerprint(&a), weight_fingerprint(&c));
        assert_eq!(weight_fingerprint(&a), weight_fingerprint(&[1, 2, 3, 4]));
    }

    #[test]
    fn bad_weight_length_rejected() {
        let sp = MacroSpec::default();
        assert!(LayerPlan::build(&[0; 10], 8, 144, 0, sp).is_err());
    }

    #[test]
    fn scoped_plans_do_not_shadow_each_other() {
        // The PR-8 bugfix: the same layer_idx under two scopes (e.g.
        // single-macro vs fleet) must build two independent plans, not
        // serve one stale entry across fleet-size switches.
        let sp = MacroSpec::default();
        let cache = PlanCache::new();
        let w = rand_w(7, 8, 144);
        let fleet = PlanScope::for_backend("macro-fleet", 4, PlacementMode::Auto);
        assert_ne!(fleet, PlanScope::SINGLE);
        assert_ne!(fleet, PlanScope::for_backend("macro-fleet", 2, PlacementMode::Auto));
        assert_ne!(fleet, PlanScope::for_backend("macro-fleet", 4, PlacementMode::Resident));
        cache.get_or_build(0, &w, 8, 144, sp).unwrap();
        cache.get_or_build_scoped(fleet, 0, &w, 8, 144, sp).unwrap();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.layers), (0, 2, 2));
        // second lookup in each scope hits
        cache.get_or_build(0, &w, 8, 144, sp).unwrap();
        cache.get_or_build_scoped(fleet, 0, &w, 8, 144, sp).unwrap();
        assert_eq!(cache.stats().hits, 2);
    }

    #[test]
    fn placement_mode_parses_round_trip() {
        for m in PlacementMode::ALL {
            assert_eq!(PlacementMode::parse(m.name()), Some(m));
        }
        assert_eq!(PlacementMode::parse("banana"), None);
        assert_eq!(PlacementMode::default(), PlacementMode::Auto);
    }

    #[test]
    fn placement_packs_whole_columns_when_they_fit() {
        // kt=2 <= R=4: no K-split, two columns per macro, replicas fill
        // the fleet.
        let fleet = FleetDims { macros: 4, residency_tiles: 4 };
        let lp = LayerPlacement::plan(0, 4, 2, 8, fleet, PlacementMode::Auto);
        assert!(!lp.split_k());
        assert_eq!(lp.macros_needed, 2);
        assert_eq!(lp.replicas, 2);
        assert_eq!(lp.stride, 2);
        assert!(!lp.wrapped);
        for ni in 0..4 {
            assert_eq!(lp.k_span(ni), 1, "column {ni}");
            assert_eq!(lp.macro_of(ni, 0, 0), ni / 2);
            assert_eq!(lp.macro_of(ni, 0, 1), ni / 2 + 2);
        }
        // residency: each macro holds one replica's share = 4 tiles
        let per: Vec<usize> = (0..4).map(|m| lp.tiles_on(m)).collect();
        assert_eq!(per, vec![4, 4, 4, 4]);
    }

    #[test]
    fn placement_splits_k_when_column_exceeds_residency() {
        // kt=4 > R=2: each column spans 2 macros -> split-K reduce.
        let fleet = FleetDims { macros: 4, residency_tiles: 2 };
        let lp = LayerPlacement::plan(0, 2, 4, 8, fleet, PlacementMode::Auto);
        assert!(lp.split_k());
        assert_eq!(lp.macros_needed, 4);
        assert_eq!(lp.replicas, 1);
        assert!(!lp.wrapped);
        for ni in 0..2 {
            assert_eq!(lp.k_span(ni), 2, "column {ni}");
        }
        // ki-blocks are contiguous: first R tiles of a column on one
        // macro, the rest on the next.
        assert_eq!(lp.macro_of(0, 0, 0), lp.macro_of(0, 1, 0));
        assert_ne!(lp.macro_of(0, 1, 0), lp.macro_of(0, 2, 0));
    }

    #[test]
    fn placement_wraps_instead_of_failing_when_oversubscribed() {
        let fleet = FleetDims { macros: 2, residency_tiles: 1 };
        let lp = LayerPlacement::plan(0, 4, 2, 8, fleet, PlacementMode::Replicate);
        assert!(lp.wrapped);
        assert_eq!(lp.macros_needed, 2);
        assert_eq!(lp.replicas, 1);
        assert!(lp.assign.iter().all(|&a| (a as usize) < 2));
    }

    #[test]
    fn placement_k1_is_single_macro_identity() {
        // K=1 must put everything on macro 0 with one replica — the
        // fleet backend's bit-parity with the single-macro path depends
        // on this being the identity placement.
        let fleet = FleetDims { macros: 1, residency_tiles: 1 };
        for mode in PlacementMode::ALL {
            let lp = LayerPlacement::plan(3, 5, 7, 35, fleet, mode);
            assert!(lp.assign.iter().all(|&a| a == 0), "{mode:?}");
            assert_eq!(lp.replicas, 1);
            assert!(!lp.split_k());
        }
    }

    #[test]
    fn resident_mode_never_replicates() {
        let fleet = FleetDims { macros: 8, residency_tiles: 16 };
        let lp = LayerPlacement::plan(0, 2, 2, 4, fleet, PlacementMode::Resident);
        assert_eq!(lp.replicas, 1);
        let replicate = LayerPlacement::plan(0, 2, 2, 4, fleet, PlacementMode::Replicate);
        assert!(replicate.replicas > 1);
    }

    #[test]
    fn auto_mode_pools_to_avoid_wrap() {
        // 8 logical tiles, only 4 unique, fleet holds 4: replicate mode
        // wraps (8 > 4), auto mode pools (dedup ratio 2x doubles the
        // effective residency) and stays fully resident.
        let fleet = FleetDims { macros: 4, residency_tiles: 1 };
        let pooled = LayerPlacement::plan(0, 4, 2, 4, fleet, PlacementMode::Auto);
        assert!(!pooled.wrapped);
        let unpooled = LayerPlacement::plan(0, 4, 2, 4, fleet, PlacementMode::Replicate);
        assert!(unpooled.wrapped);
    }

    #[test]
    fn placement_plan_aggregates_and_caches() {
        let fleet = FleetDims { macros: 2, residency_tiles: 8 };
        let layers = [(0u64, 2usize, 2usize, 4usize), (1, 1, 3, 3)];
        let pp = PlacementPlan::plan(&layers, fleet, PlacementMode::Auto);
        assert_eq!(pp.total_tiles, 7);
        assert_eq!(pp.unique_tiles, 7);
        assert_eq!(pp.capacity_tiles(), 16);
        assert_eq!(pp.layers.len(), 2);
        let per = pp.macro_residency();
        assert_eq!(per.len(), 2);
        let placed: usize = pp.layers.iter().map(|l| l.replicas * l.nt * l.kt).sum();
        assert_eq!(per.iter().sum::<usize>(), placed);

        let cache = PlanCache::new();
        let scope = PlanScope::for_backend("macro-fleet", 2, PlacementMode::Auto);
        let build = || PlacementPlan::plan(&layers, fleet, PlacementMode::Auto);
        let a = cache.placement(scope, build);
        let b = cache.placement(scope, || panic!("must be cached"));
        assert!(Arc::ptr_eq(&a, &b));
        cache.clear();
        let c = cache.placement(scope, build);
        assert!(!Arc::ptr_eq(&a, &c));
    }
}
