//! Harnesses that regenerate every table and figure of the paper's
//! evaluation section (§VI) — one function per artifact, each printing
//! the same rows/series the paper reports (DESIGN.md §9).
//!
//! Invoked from the CLI: `osa-hcim fig 5a|5b|6|7|8a|8b|9` and
//! `osa-hcim table1`.

use crate::config::{CimMode, SystemConfig};
use crate::energy::{AreaParams, EnergyParams, CLK_ANALOG_HZ};
use crate::engine::{Backend, BackendKnobs, Engine};
use crate::macrosim::{counts_for_boundary, MacroUnit};
use crate::nn::data::{Dataset, Golden};
use crate::nn::{accuracy, cross_entropy, Executor, QGraph};
use crate::spec::{MacroSpec, B_CANDIDATES};
use crate::util::prng::SplitMix64;
use anyhow::{Context, Result};
use std::path::Path;
use std::sync::Arc;

/// Shared experiment context (artifacts loaded once).
///
/// All engine construction flows through [`FigCtx::backend`], i.e.
/// through the context's [`Engine`]: the weight-stationary plan cache
/// and the tile pool (sized from `[engine] threads` / `--threads`) are
/// shared by every backend the context hands out, so each layer is
/// packed once per context across all figure harnesses and every
/// calibration loss evaluation.
pub struct FigCtx {
    /// Mutable copy of the engine's config: ablation harnesses
    /// (`--fs-frac`, `--nq-shift`) intentionally override spec knobs
    /// after load, and [`FigCtx::backend`] reads *this* copy.
    pub cfg: SystemConfig,
    pub ds: Dataset,
    pub golden: Golden,
    pub engine: Engine,
}

impl FigCtx {
    pub fn load(cfg: SystemConfig) -> Result<Self> {
        let dir = cfg.artifacts_dir.clone();
        cfg.spec
            .validate_against_artifacts(&dir)
            .context("spec.json mismatch — run `make artifacts`")?;
        let graph = Arc::new(QGraph::load(&dir)?);
        let engine = Engine::builder().config(cfg.clone()).graph(graph).build()?;
        Ok(Self { ds: Dataset::load(&dir)?, golden: Golden::load(&dir)?, cfg, engine })
    }

    /// The loaded model graph.
    pub fn graph(&self) -> &QGraph {
        self.engine.graph().as_ref()
    }

    /// A backend pinned to `mode` under the context's (possibly
    /// ablation-overridden) config.
    pub fn backend(&self, mode: CimMode) -> Result<Box<dyn Backend>> {
        self.engine.backend_with(&self.cfg, mode)
    }

    /// Run `n` test images through a mode.
    pub fn eval_mode(
        &self,
        mode: CimMode,
        fixed_b: i32,
        thresholds: &[i32],
        n: usize,
    ) -> Result<ModeEval> {
        let mut gemm = self.backend(mode)?;
        gemm.apply(&BackendKnobs {
            fixed_b: Some(fixed_b),
            thresholds: (mode == CimMode::Osa && !thresholds.is_empty())
                .then(|| thresholds.to_vec()),
            ..Default::default()
        })?;
        let mut exec = Executor::new(self.graph(), gemm);
        let (images, labels) = self.ds.test_batch(0, n);
        let (logits, stats) = exec.forward(images, labels.len())?;
        Ok(ModeEval {
            acc: accuracy(&logits, labels, self.graph().num_classes),
            ce: cross_entropy(&logits, labels, self.graph().num_classes),
            tops_w: stats.account.tops_per_watt(&self.cfg.spec),
            b_hist: stats.b_hist,
            energy_nj_per_img: stats.account.total_energy_j() * 1e9 / labels.len() as f64,
            macro_ops: stats.account.macro_ops,
        })
    }
}

/// Result of one operating-point evaluation.
#[derive(Debug, Clone)]
pub struct ModeEval {
    pub acc: f64,
    pub ce: f64,
    pub tops_w: f64,
    pub b_hist: [u64; 16],
    pub energy_nj_per_img: f64,
    pub macro_ops: u64,
}

// ---------------------------------------------------------------- Fig 5a

/// Workload allocation for DCIM/ACIM per boundary (8b x 8b MAC).
pub fn fig5a() -> String {
    let sp = MacroSpec::default();
    let mut out = String::from(
        "Fig 5a — 1-bit MAC workload allocation vs B_D/A (8b x 8b MAC, 64 1-bit MACs)\n\
         B_D/A  digital  analog  discard  ADC-groups\n",
    );
    for b in [5, 6, 7, 8, 9, 10] {
        let c = counts_for_boundary(b, false, &sp);
        out.push_str(&format!(
            "{b:>5}  {:>7}  {:>6}  {:>7}  {:>10}\n",
            c.digital_pairs, c.analog_pairs, c.discard_pairs, c.adc_groups
        ));
    }
    out
}

// ---------------------------------------------------------------- Fig 5b

/// SNR / energy-efficiency / execution-speed tradeoff per boundary.
pub fn fig5b(samples: usize, seed: u64) -> Result<String> {
    let sp = MacroSpec::default();
    let ep = EnergyParams::default();
    let mut rng = SplitMix64::new(seed);
    let w: Vec<i32> = (0..sp.hmus * sp.cols).map(|_| rng.next_range_i32(-128, 128)).collect();
    let unit = MacroUnit::new(&w, sp)?;
    let acts: Vec<Vec<i32>> = (0..samples)
        .map(|_| (0..sp.cols).map(|_| rng.next_range_i32(0, 256)).collect())
        .collect();
    let exact: Vec<Vec<i32>> = acts.iter().map(|a| unit.exact(a)).collect();
    let mut out = String::from(
        "Fig 5b — SNR / energy efficiency / execution speed vs B_D/A (8b x 8b MAC)\n\
         B_D/A  SNR(dB)  TOPS/W  speedup(vs DCIM)  cycles\n",
    );
    let dcim_counts = counts_for_boundary(0, false, &sp);
    let dcim_cycles = dcim_counts.compute_cycles as f64;
    for b in [5, 6, 7, 8, 9, 10] {
        let mut sig = 0.0f64;
        let mut err = 0.0f64;
        let mut noise_g = SplitMix64::new(seed ^ 0xABCD);
        for (a, ex) in acts.iter().zip(&exact) {
            let p = unit.pack_acts(a);
            let noise = noise_g.normals_f32(sp.hmus * sp.w_bits, sp.sigma_code);
            let got = unit.compute_hybrid(&p, b, &noise);
            for (g, e) in got.iter().zip(ex) {
                sig += (*e as f64) * (*e as f64);
                err += ((g - e) as f64) * ((g - e) as f64);
            }
        }
        let snr = 10.0 * (sig / err.max(1e-12)).log10();
        let c = counts_for_boundary(b, true, &sp);
        let e = ep.op_energy(&c, true, &sp);
        let tw = ep.tops_per_watt(&e, &sp);
        let speedup = dcim_cycles / c.total_cycles() as f64;
        out.push_str(&format!(
            "{b:>5}  {snr:>7.1}  {tw:>6.2}  {speedup:>16.2}  {:>6}\n",
            c.total_cycles()
        ));
    }
    Ok(out)
}

// ---------------------------------------------------------------- Fig 6

/// Macro layout summary (the paper's Fig 6 table, with modeled area).
pub fn fig6() -> String {
    let sp = MacroSpec::default();
    let a = AreaParams::default();
    let mut out = String::from("Fig 6 — OSA-HCIM macro summary (modeled, 65 nm)\n");
    out.push_str("  Technology           65 nm CMOS (behavioral model)\n");
    out.push_str("  Supply               0.6 - 1.2 V (energy calibrated @0.6 V)\n");
    out.push_str(&format!(
        "  Array size           {} x {} (split-port 6T)\n",
        crate::spec::ROWS,
        sp.cols
    ));
    out.push_str(&format!(
        "  HMUs                 {} (144 HCIMA each, DAT + N/Q + 3b SAR ADC)\n",
        sp.hmus
    ));
    out.push_str(&format!(
        "  Input precision      4/8 b (DAC slices 1-{} b)\n",
        sp.analog_band
    ));
    out.push_str("  Weight precision     4/8 b (two's complement)\n");
    out.push_str(&format!("  B_D/A candidates     {B_CANDIDATES:?}\n"));
    out.push_str(&format!("  Analog clock         {} MHz (DAT at 2x)\n", CLK_ANALOG_HZ / 1e6));
    out.push_str(&format!("  Modeled area         {:.3} mm^2\n", a.total_um2() / 1e6));
    out
}

// ---------------------------------------------------------------- Fig 7

/// Power & area breakdowns at the OSA operating mix of a real workload.
pub fn fig7(ctx: &FigCtx, images: usize) -> Result<String> {
    // the context's backend is already programmed with the configured
    // thresholds (the engine factory reads `cfg.thresholds`)
    let mut exec = Executor::new(ctx.graph(), ctx.backend(CimMode::Osa)?);
    let (imgs, labels) = ctx.ds.test_batch(0, images);
    let (_, stats) = exec.forward(imgs, labels.len())?;
    let mut out = String::from("Fig 7 — power & area breakdown of OSA-HCIM\n");
    out.push_str(&format!(
        "(workload: {} SynthCIFAR images through ResNet-mini, OSA mode, {} macro ops)\n\n",
        labels.len(),
        stats.account.macro_ops
    ));
    out.push_str("  power:\n");
    for (name, frac) in stats.account.breakdown.fractions() {
        out.push_str(&format!("    {name:<24} {:>5.1}%\n", frac * 100.0));
    }
    out.push_str("  area:\n");
    for (name, frac) in AreaParams::default().fractions() {
        out.push_str(&format!("    {name:<24} {:>5.1}%\n", frac * 100.0));
    }
    out.push_str("\n  paper anchors: OSE ≈1% power/1% area, ADC ≈17% power/6% area\n");
    out.push_str(&format!(
        "  modeled OSA efficiency on this workload: {:.2} TOPS/W\n",
        stats.account.tops_per_watt(&ctx.cfg.spec)
    ));
    Ok(out)
}

// ---------------------------------------------------------------- Fig 8

/// Glyph for one boundary value (finer B -> darker glyph).
fn b_glyph(b: i32) -> char {
    match b {
        5 => '@',
        6 => '#',
        7 => '+',
        8 => '-',
        9 => '.',
        10 => ' ',
        _ => '?',
    }
}

/// Per-pixel B_D/A maps of selected hidden layers for one image.
pub fn fig8a(ctx: &FigCtx, image_idx: usize, layers: &[&str]) -> Result<String> {
    let mut exec = Executor::new(ctx.graph(), ctx.backend(CimMode::Osa)?);
    exec.collect_bda = true;
    let (imgs, labels) = ctx.ds.test_batch(image_idx, 1);
    let (_, stats) = exec.forward(imgs, 1)?;
    let class_names = [
        "circle", "square", "triangle", "cross", "ring", "hbar", "vbar", "diamond", "checker",
        "corner_l",
    ];
    let mut out = format!(
        "Fig 8a — per-pixel B_D/A maps (test image {image_idx}, label={})\n\
         glyphs: @=5 (most digital) #=6 +=7 -=8 .=9 ' '=10 (most analog)\n\n",
        class_names.get(labels[0] as usize).unwrap_or(&"?")
    );
    for (name, ho, wo, nt, bda) in &stats.bda_maps {
        if !layers.is_empty() && !layers.contains(&name.as_str()) {
            continue;
        }
        out.push_str(&format!("  layer {name} ({ho}x{wo}):\n"));
        for y in 0..*ho {
            out.push_str("    |");
            for x in 0..*wo {
                // most precise boundary across N-tiles at this pixel
                let row = (y * wo + x) * nt;
                let b = (0..*nt).map(|t| bda[row + t]).min().unwrap_or(10);
                out.push(b_glyph(b));
            }
            out.push_str("|\n");
        }
        out.push('\n');
    }
    Ok(out)
}

/// Proportion of each B_D/A across conv layers of the network.
pub fn fig8b(ctx: &FigCtx, images: usize) -> Result<String> {
    let mut exec = Executor::new(ctx.graph(), ctx.backend(CimMode::Osa)?);
    exec.collect_bda = true;
    let (imgs, labels) = ctx.ds.test_batch(0, images);
    let (_, stats) = exec.forward(imgs, labels.len())?;
    let mut out = format!(
        "Fig 8b — B_D/A usage per conv layer ({} images, OSA mode)\n  {:<18}",
        labels.len(),
        "layer"
    );
    for b in B_CANDIDATES {
        out.push_str(&format!("  B={b:<3}"));
    }
    out.push('\n');
    // aggregate maps across the batch per layer name, preserving order
    let mut seen: Vec<(String, [u64; 16])> = Vec::new();
    for (name, _, _, nt, bda) in &stats.bda_maps {
        let entry = match seen.iter_mut().find(|(n, _)| n == name) {
            Some((_, h)) => h,
            None => {
                seen.push((name.clone(), [0u64; 16]));
                &mut seen.last_mut().unwrap().1
            }
        };
        for chunk in bda.chunks(*nt) {
            for &b in chunk {
                if (0..16).contains(&b) {
                    entry[b as usize] += 1;
                }
            }
        }
    }
    for (name, hist) in &seen {
        let total: u64 = hist.iter().sum::<u64>().max(1);
        out.push_str(&format!("  {name:<18}"));
        for b in B_CANDIDATES {
            out.push_str(&format!(" {:>5.1}%", hist[b as usize] as f64 / total as f64 * 100.0));
        }
        out.push('\n');
    }
    Ok(out)
}

// ---------------------------------------------------------------- Fig 9

/// One Fig 9 operating point.
#[derive(Debug, Clone)]
pub struct Fig9Point {
    pub label: String,
    pub acc: f64,
    pub tops_w: f64,
    pub energy_ratio_vs_dcim: f64,
    pub thresholds: Vec<i32>,
}

/// Accuracy vs energy-efficiency Pareto: DCIM, HCIM (fixed), ACIM and
/// OSA-HCIM under the loss-constraint profiles.
pub fn fig9(ctx: &FigCtx, images: usize, calib_images: usize) -> Result<(String, Vec<Fig9Point>)> {
    let mut points = Vec::new();
    let dcim = ctx.eval_mode(CimMode::Dcim, 0, &[], images)?;
    points.push(Fig9Point {
        label: "DCIM".into(),
        acc: dcim.acc,
        tops_w: dcim.tops_w,
        energy_ratio_vs_dcim: 1.0,
        thresholds: vec![],
    });
    for b in [6, 8] {
        let h = ctx.eval_mode(CimMode::Hcim, b, &[], images)?;
        points.push(Fig9Point {
            label: format!("HCIM (B={b})"),
            acc: h.acc,
            tops_w: h.tops_w,
            energy_ratio_vs_dcim: dcim.energy_nj_per_img / h.energy_nj_per_img,
            thresholds: vec![],
        });
    }
    let acim = ctx.eval_mode(CimMode::Acim, 0, &[], images)?;
    points.push(Fig9Point {
        label: "ACIM".into(),
        acc: acim.acc,
        tops_w: acim.tops_w,
        energy_ratio_vs_dcim: dcim.energy_nj_per_img / acim.energy_nj_per_img,
        thresholds: vec![],
    });
    // prior-work dual-precision baselines (paper §II-A: PG [13], DRQ [14])
    for mode in [CimMode::Pg, CimMode::Drq] {
        let ev = ctx.eval_mode(mode, 0, &[], images)?;
        points.push(Fig9Point {
            label: mode.name().to_uppercase(),
            acc: ev.acc,
            tops_w: ev.tops_w,
            energy_ratio_vs_dcim: dcim.energy_nj_per_img / ev.energy_nj_per_img,
            thresholds: vec![],
        });
    }

    // OSA under each loss-constraint profile (thresholds from Fig 4b).
    for profile in crate::osa::PROFILES {
        let constraints = crate::osa::loss_profile(profile).unwrap();
        let cal = calibrate_osa(ctx, &constraints, calib_images)?;
        let ev = ctx.eval_mode(CimMode::Osa, ctx.cfg.fixed_b, &cal.thresholds, images)?;
        points.push(Fig9Point {
            label: format!("OSA-HCIM ({profile})"),
            acc: ev.acc,
            tops_w: ev.tops_w,
            energy_ratio_vs_dcim: dcim.energy_nj_per_img / ev.energy_nj_per_img,
            thresholds: cal.thresholds.clone(),
        });
    }

    let mut out = format!(
        "Fig 9 — accuracy vs energy efficiency ({images} test images; thresholds \
         calibrated on {calib_images} train images)\n\
         point                  acc(%)  TOPS/W  energy-ratio-vs-DCIM  thresholds\n"
    );
    for p in &points {
        out.push_str(&format!(
            "  {:<21} {:>6.2}  {:>6.2}  {:>20.2}  {:?}\n",
            p.label,
            p.acc * 100.0,
            p.tops_w,
            p.energy_ratio_vs_dcim,
            p.thresholds
        ));
    }
    Ok((out, points))
}

/// Calibrate OSA thresholds (Fig 4b) on the train split.
pub fn calibrate_osa(
    ctx: &FigCtx,
    constraints: &[f64],
    calib_images: usize,
) -> Result<crate::osa::CalibrationResult> {
    let (imgs, labels) = ctx.ds.train_batch(0, calib_images);
    let labels = labels.to_vec();
    let n = labels.len();
    // baseline loss: DCIM
    let mut dcim_exec = Executor::new(ctx.graph(), ctx.backend(CimMode::Dcim)?);
    let (logits, _) = dcim_exec.forward(imgs, n)?;
    let baseline = cross_entropy(&logits, &labels, ctx.graph().num_classes);
    // saliency upper bound after K-normalization: the small-K stem layer
    // can scale a full-range raw S up to ~nq_max*3*hmus * (cols/27) ≈ 900
    let s_max = 1024;
    let graph = ctx.graph();
    let mut loss_fn = |ts: &[i32]| -> f64 {
        // plans are threshold-independent: every evaluation of the
        // search reuses the engine's packed weight tiles (one backend
        // per evaluation, all on the shared plan cache + pool)
        let gemm = match ctx.backend(CimMode::Osa).and_then(|mut g| {
            g.apply(&BackendKnobs { thresholds: Some(ts.to_vec()), ..Default::default() })?;
            Ok(g)
        }) {
            Ok(g) => g,
            Err(e) => {
                log::error!("bad thresholds {ts:?}: {e:#}");
                return f64::INFINITY;
            }
        };
        let mut exec = Executor::new(graph, gemm);
        match exec.forward(imgs, n) {
            Ok((logits, _)) => cross_entropy(&logits, &labels, graph.num_classes),
            Err(e) => {
                log::error!("calibration eval failed: {e:#}");
                f64::INFINITY
            }
        }
    };
    crate::osa::calibrate_thresholds(&mut loss_fn, baseline, constraints, s_max, 6)
}

// ---------------------------------------------------------------- Table I

/// The comparison table's "This Work" column (plus context rows).
pub fn table1(ctx: &FigCtx, images: usize, calib_images: usize) -> Result<String> {
    let (_, points) = fig9(ctx, images, calib_images)?;
    let dcim = &points[0];
    let osa: Vec<&Fig9Point> = points.iter().filter(|p| p.label.starts_with("OSA-HCIM")).collect();
    let acc_lo = osa.iter().map(|p| p.acc).fold(f64::INFINITY, f64::min);
    let acc_hi = osa.iter().map(|p| p.acc).fold(0.0, f64::max);
    let tw_lo = osa.iter().map(|p| p.tops_w).fold(f64::INFINITY, f64::min);
    let tw_hi = osa.iter().map(|p| p.tops_w).fold(0.0, f64::max);
    let ratio_hi = osa.iter().map(|p| p.energy_ratio_vs_dcim).fold(0.0, f64::max);
    let mut out = String::from("Table I — \"This Work\" column (SynthCIFAR substitute workload)\n");
    out.push_str("  Tech               65 nm (behavioral model)\n");
    out.push_str("  CIM type           Dynamic Hybrid\n");
    out.push_str("  Input precision    4/8b   Weight precision 4/8b\n");
    out.push_str("  Array size         64x144\n");
    out.push_str(&format!(
        "  Accuracy           {:.1}~{:.1}% (drop {:.1}~{:.1}% vs DCIM {:.1}%)\n",
        acc_lo * 100.0,
        acc_hi * 100.0,
        (dcim.acc - acc_hi) * 100.0,
        (dcim.acc - acc_lo) * 100.0,
        dcim.acc * 100.0
    ));
    out.push_str(&format!(
        "  Energy eff.        {tw_lo:.2}~{tw_hi:.2} TOPS/W (DCIM {:.2})\n",
        dcim.tops_w
    ));
    out.push_str(&format!("  Max gain vs DCIM   {ratio_hi:.2}x (paper: 1.95x)\n"));
    out.push_str("  Saliency-aware     Yes (first CIM with dynamic D/A boundary)\n");
    Ok(out)
}

/// Write a figure's text to `results/<name>.txt` as well as stdout.
pub fn emit(name: &str, text: &str, results_dir: &Path) -> Result<()> {
    println!("{text}");
    std::fs::create_dir_all(results_dir)?;
    let path = results_dir.join(format!("{name}.txt"));
    std::fs::write(&path, text)?;
    log::info!("wrote {}", path.display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig5a_rows() {
        let t = fig5a();
        assert!(t.contains("B_D/A"));
        // B=8 anchor from the decomposition: 28 digital / 26 analog / 10
        assert!(t.contains("28"), "{t}");
        assert!(t.lines().count() == 8, "{t}");
    }

    #[test]
    fn fig5b_produces_rows() {
        let t = fig5b(32, 7).unwrap();
        assert!(t.lines().count() >= 8, "{t}");
        assert!(t.contains("TOPS/W"));
    }

    #[test]
    fn fig6_summary() {
        let t = fig6();
        assert!(t.contains("64 x 144"));
        assert!(t.contains("mm^2"));
    }

    #[test]
    fn glyphs_cover_candidates() {
        for b in B_CANDIDATES {
            assert_ne!(b_glyph(b), '?');
        }
        assert_eq!(b_glyph(3), '?');
    }
}
