//! Typed configuration for the whole stack + a TOML-subset parser
//! (serde/toml are not in the offline mirror).
//!
//! The accepted grammar covers what `configs/*.toml` uses: `[section]`
//! headers, `key = value` with string/int/float/bool/array-of-number
//! values, and `#` comments.

use crate::energy::hierarchy::{self, MemoryHierarchy};
use crate::serve::qos::Tier;
use crate::spec::MacroSpec;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Flat parsed TOML: `section.key -> raw value`.
#[derive(Debug, Default, Clone)]
pub struct Toml {
    values: BTreeMap<String, TomlValue>,
}

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<f64>),
}

impl Toml {
    pub fn parse(text: &str) -> Result<Self> {
        let mut section = String::new();
        let mut values = BTreeMap::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let full = if section.is_empty() {
                key.trim().to_string()
            } else {
                format!("{section}.{}", key.trim())
            };
            values.insert(full, parse_value(value.trim(), lineno + 1)?);
        }
        Ok(Self { values })
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.values.get(key) {
            None => Ok(default),
            Some(TomlValue::Float(x)) => Ok(*x),
            Some(TomlValue::Int(x)) => Ok(*x as f64),
            Some(other) => bail!("{key}: expected number, found {other:?}"),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.values.get(key) {
            None => Ok(default),
            Some(TomlValue::Int(x)) if *x >= 0 => Ok(*x as usize),
            Some(other) => bail!("{key}: expected non-negative int, found {other:?}"),
        }
    }

    pub fn get_bool(&self, key: &str, default: bool) -> Result<bool> {
        match self.values.get(key) {
            None => Ok(default),
            Some(TomlValue::Bool(b)) => Ok(*b),
            Some(other) => bail!("{key}: expected bool, found {other:?}"),
        }
    }

    pub fn get_str(&self, key: &str, default: &str) -> Result<String> {
        match self.values.get(key) {
            None => Ok(default.to_string()),
            Some(TomlValue::Str(s)) => Ok(s.clone()),
            Some(other) => bail!("{key}: expected string, found {other:?}"),
        }
    }

    pub fn get_array_i32(&self, key: &str) -> Result<Option<Vec<i32>>> {
        match self.values.get(key) {
            None => Ok(None),
            Some(TomlValue::Array(v)) => Ok(Some(v.iter().map(|x| *x as i32).collect())),
            Some(other) => bail!("{key}: expected array, found {other:?}"),
        }
    }

    pub fn get_array_f64(&self, key: &str) -> Result<Option<Vec<f64>>> {
        match self.values.get(key) {
            None => Ok(None),
            Some(TomlValue::Array(v)) => Ok(Some(v.clone())),
            Some(other) => bail!("{key}: expected array, found {other:?}"),
        }
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside quoted strings is respected
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str, lineno: usize) -> Result<TomlValue> {
    if let Some(body) = text.strip_prefix('"') {
        let Some(body) = body.strip_suffix('"') else {
            bail!("line {lineno}: unterminated string");
        };
        return Ok(TomlValue::Str(body.to_string()));
    }
    if text == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if text == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(body) = text.strip_prefix('[') {
        let Some(body) = body.strip_suffix(']') else {
            bail!("line {lineno}: unterminated array");
        };
        let mut out = Vec::new();
        for part in body.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            out.push(part.parse::<f64>().with_context(|| format!("line {lineno}: bad number {part}"))?);
        }
        return Ok(TomlValue::Array(out));
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("line {lineno}: cannot parse value {text:?}")
}

/// Operating mode of the CIM datapath.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CimMode {
    /// All orders digital — loss-free baseline.
    Dcim,
    /// Fixed hybrid boundary for every MAC (prior-work fixed HCIM).
    Hcim,
    /// On-the-fly saliency-aware boundary (this paper).
    Osa,
    /// Full analog baseline.
    Acim,
    /// Precision Gating (Zhang et al., paper ref [13]): dual-precision,
    /// all-digital — compute high-order activation bits first, add the
    /// low-order pass only when the partial output magnitude exceeds a
    /// learned delta.
    Pg,
    /// DRQ (Song et al., paper ref [14]): dual-precision by input-region
    /// mean — regions with low mean activation run at 4-bit precision.
    Drq,
}

impl CimMode {
    pub fn parse(text: &str) -> Result<Self> {
        Ok(match text {
            "dcim" => CimMode::Dcim,
            "hcim" => CimMode::Hcim,
            "osa" => CimMode::Osa,
            "acim" => CimMode::Acim,
            "pg" => CimMode::Pg,
            "drq" => CimMode::Drq,
            other => bail!("unknown mode {other:?} (dcim|hcim|osa|acim|pg|drq)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CimMode::Dcim => "dcim",
            CimMode::Hcim => "hcim",
            CimMode::Osa => "osa",
            CimMode::Acim => "acim",
            CimMode::Pg => "pg",
            CimMode::Drq => "drq",
        }
    }
}

/// Full-stack runtime configuration.
#[derive(Debug, Clone)]
pub struct SystemConfig {
    pub artifacts_dir: PathBuf,
    pub spec: MacroSpec,
    pub mode: CimMode,
    /// Fixed boundary for HCIM mode.
    pub fixed_b: i32,
    /// OSE thresholds (ascending); calibrated via `osa::calibrate`.
    pub thresholds: Vec<i32>,
    /// Base seed for per-layer ADC noise streams.
    pub noise_seed: u64,
    /// Batcher: max requests per batch.
    pub max_batch: usize,
    /// Batcher: max microseconds to wait filling a batch.
    pub batch_timeout_us: u64,
    /// Worker threads in the coordinator.
    pub workers: usize,
    /// Tile-execution pool size (`[engine] threads`, `--threads`);
    /// 0 = auto (the `OSA_ENGINE_THREADS` env override, else every
    /// available core).  An *explicit* `threads = 0` is rejected at
    /// load time — omit the key for auto.  One pool is shared by all
    /// coordinator workers, so this bounds total tile parallelism
    /// rather than multiplying it by the worker count (DESIGN.md §11).
    pub engine_threads: usize,
    /// Active execution backend, by `engine::BackendRegistry` name
    /// (`[engine] backend`, `--backend`, or per-request via
    /// `POST /v2/infer`).  Unknown names fail at engine build time with
    /// an error listing every registered backend.
    pub backend: String,
    /// Simulated macro count K for the `macro-fleet` backend
    /// (`[fleet] macros`, `--fleet`, `EngineBuilder::fleet`).
    pub fleet_macros: usize,
    /// Per-macro weight-stationary residency budget, in packed weight
    /// tiles (`[fleet] residency_tiles`).
    pub fleet_residency_tiles: usize,
    /// Energy per partial sum per inter-macro hop, femtojoules
    /// (`[fleet] hop_energy_fj`) — charged when a layer's K dimension
    /// is split across macros and partial sums must hop to reduce.
    pub fleet_hop_energy_fj: f64,
    /// Latency per inter-macro hop, analog-clock cycles
    /// (`[fleet] hop_latency_cycles`).
    pub fleet_hop_latency_cycles: u64,
    /// Fleet placement mode: `auto` (replicate, pool, then wrap),
    /// `replicate` (never pool) or `resident` (strict capacity)
    /// (`[fleet] placement`; per-request `options.placement` override).
    pub fleet_placement: String,
    /// QoS tier assumed when a request names none
    /// (`[serve] default_tier`); unknown tier strings are rejected at
    /// load time.
    pub default_tier: Tier,
    /// Bound of each QoS tier's admission queue; admission past it is a
    /// typed `Busy` error (HTTP 429 at the gateway).
    pub queue_cap: usize,
    /// Gateway: serve HTTP/1.1 persistent connections (keep-alive
    /// request loop).  `false` answers every request with
    /// `Connection: close` — the one-request-per-connection baseline.
    pub keep_alive: bool,
    /// Gateway: max concurrent HTTP connections (the connection cap).
    /// Event loop: that many connections are served concurrently and as
    /// many again may sit parked awaiting a slot.  Threaded pool: the
    /// worker count, with an accept backlog of the same depth.  Past
    /// both, admission answers 429 and closes.
    pub max_conns: usize,
    /// Gateway: serve through the readiness-driven event loop (epoll /
    /// poll; unix only — other platforms fall back to the threaded
    /// pool).  `false` forces the thread-per-connection pool
    /// (`--no-event-loop` escape hatch).
    pub event_loop: bool,
    /// Gateway: per-read socket timeout in milliseconds for the
    /// keep-alive loop (idle sessions are closed after it; a stalled
    /// mid-request read is answered 408).  The whole-request slowloris
    /// deadline is 4x this.  0 disables both.
    pub read_timeout_ms: u64,
    /// Enable the dynamic precision governor (`serve::governor`).
    pub governor: bool,
    /// Modeled macro power budget in watts for the governor; 0 disables
    /// the energy term of the feedback loop.
    pub energy_budget_w: f64,
    /// Governor: queue pressure (worst tier fill fraction) above which
    /// one tier degrades one precision level.
    pub gov_high_watermark: f64,
    /// Governor: pressure below which one tier recovers one level.
    pub gov_low_watermark: f64,
    /// Governor: max degrade levels per tier (each level doubles the
    /// tier's OSE thresholds).
    pub gov_max_level: u32,
    /// Governor: minimum milliseconds between level changes.
    pub gov_hold_ms: u64,
    /// Observability: collect per-request trace spans (`[obs] trace`,
    /// `--no-trace`).  Metrics/histograms are always on; this gates
    /// only the span ring + `/debug/trace`.
    pub obs_trace: bool,
    /// Observability: span ring capacity (`[obs] trace_capacity`);
    /// fixed memory, oldest spans overwritten.
    pub obs_trace_capacity: usize,
    /// Observability: log a structured warn line for any request slower
    /// than this many milliseconds end to end (`[obs] slow_ms`,
    /// `--slow-ms`); 0 disables the slow-request log.
    pub obs_slow_ms: u64,
    /// Analog device-variation model (`[device] model`, `--device`), by
    /// `device::build` name: `gaussian-thermal` (the baseline, bit-
    /// preserving path), `ideal`, `capacitor-mismatch` or
    /// `lognormal-conductance` (DESIGN.md §16).
    pub device_model: String,
    /// Device noise sigma override in ADC code units (`[device] sigma`,
    /// `--device-sigma`); `None` inherits `cim.sigma_code`.
    pub device_sigma: Option<f64>,
    /// Operation-unit group size: columns per sub-conversion
    /// (`[device] s_ou`); 0 = whole-row charge share (the baseline).
    pub device_s_ou: usize,
    /// Static ADC offset error in code units (`[device] adc_offset`).
    pub device_adc_offset: f64,
    /// Static ADC gain error, multiplicative (`[device] adc_gain`).
    pub device_adc_gain: f64,
    /// Path to a `SWEEP_*.json` report whose per-level corner
    /// accuracies feed the governor's degrade-ladder floors
    /// (`[device] sweep_report`); empty disables the feedback.
    pub device_sweep_report: String,
    /// Device corner sigma for the sweep's governor-ladder evaluation
    /// (`[device] corner_sigma`, `sweep --corner-sigma`).
    pub device_corner_sigma: f64,
    /// Per-tier accuracy floors (fraction in [0, 1]) under the device
    /// corner; a governor degrade level whose swept corner accuracy
    /// falls below the tier's floor is refused (`[device] sla_gold`
    /// etc.; 0 disables the floor for that tier).
    pub device_sla_gold: f64,
    pub device_sla_silver: f64,
    pub device_sla_batch: f64,
    /// Energy cost model (`[hardware] model`): `"compact"` keeps the
    /// calibrated per-op constants (bit-identical to pre-hierarchy
    /// numbers); `"hierarchy"` additionally prices per-level data
    /// movement from the declarative [`MemoryHierarchy`] stack.
    pub hardware_model: String,
    /// Declarative memory stack (`[hardware]` level arrays); only
    /// priced when `hardware_model = "hierarchy"`.
    pub hardware: MemoryHierarchy,
}

impl Default for SystemConfig {
    fn default() -> Self {
        Self {
            artifacts_dir: crate::spec::default_artifacts_dir(),
            spec: MacroSpec::default(),
            mode: CimMode::Osa,
            fixed_b: 8,
            thresholds: vec![0, 0, 32, 94, 1024],
            noise_seed: 0xC1A0_2024,
            max_batch: 64,
            batch_timeout_us: 2_000,
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            engine_threads: 0,
            backend: "macro-hybrid".to_string(),
            fleet_macros: 1,
            fleet_residency_tiles: 64,
            fleet_hop_energy_fj: 120.0,
            fleet_hop_latency_cycles: 2,
            fleet_placement: "auto".to_string(),
            default_tier: Tier::Silver,
            queue_cap: 256,
            keep_alive: true,
            max_conns: 64,
            event_loop: true,
            read_timeout_ms: 5_000,
            governor: true,
            energy_budget_w: 0.0,
            gov_high_watermark: 0.75,
            gov_low_watermark: 0.25,
            gov_max_level: 3,
            gov_hold_ms: 100,
            obs_trace: true,
            obs_trace_capacity: 4096,
            obs_slow_ms: 250,
            device_model: "gaussian-thermal".to_string(),
            device_sigma: None,
            device_s_ou: 0,
            device_adc_offset: 0.0,
            device_adc_gain: 1.0,
            device_sweep_report: String::new(),
            device_corner_sigma: 1.5 * crate::spec::SIGMA_CODE,
            device_sla_gold: 0.0,
            device_sla_silver: 0.0,
            device_sla_batch: 0.0,
            hardware_model: hierarchy::MODEL_COMPACT.to_string(),
            hardware: MemoryHierarchy::default(),
        }
    }
}

impl SystemConfig {
    /// Resolved tile-pool size: explicit `[engine] threads` when set,
    /// else [`crate::sched::exec::auto_threads`] (env override / cores).
    pub fn resolved_engine_threads(&self) -> usize {
        if self.engine_threads > 0 {
            self.engine_threads
        } else {
            crate::sched::exec::auto_threads()
        }
    }

    /// Load from a TOML file, falling back to defaults for missing keys.
    pub fn from_toml_file(path: &Path) -> Result<Self> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {}", path.display()))?;
        Self::from_toml(&Toml::parse(&text)?)
    }

    pub fn from_toml(t: &Toml) -> Result<Self> {
        let mut cfg = Self::default();
        cfg.artifacts_dir = PathBuf::from(
            t.get_str("system.artifacts_dir", &cfg.artifacts_dir.to_string_lossy())?,
        );
        cfg.mode = CimMode::parse(&t.get_str("cim.mode", cfg.mode.name())?)?;
        cfg.fixed_b = t.get_f64("cim.fixed_b", cfg.fixed_b as f64)? as i32;
        if let Some(th) = t.get_array_i32("cim.thresholds")? {
            cfg.thresholds = th;
        }
        cfg.spec.sigma_code = t.get_f64("cim.sigma_code", cfg.spec.sigma_code)?;
        cfg.spec.adc_fs_frac = t.get_f64("cim.adc_fs_frac", cfg.spec.adc_fs_frac as f64)? as f32;
        cfg.noise_seed = t.get_f64("cim.noise_seed", cfg.noise_seed as f64)? as u64;
        cfg.max_batch = t.get_usize("coordinator.max_batch", cfg.max_batch)?;
        cfg.batch_timeout_us =
            t.get_usize("coordinator.batch_timeout_us", cfg.batch_timeout_us as usize)? as u64;
        cfg.workers = t.get_usize("coordinator.workers", cfg.workers)?;
        // NOTE: `coordinator.use_pjrt` (a bool nothing ever read) is
        // superseded by `engine.backend = "pjrt"` and intentionally no
        // longer parsed; unknown keys are ignored, so old files load.
        cfg.engine_threads = t.get_usize("engine.threads", cfg.engine_threads)?;
        // 0 means "auto" internally, but an *explicit* zero in the file
        // is a misconfiguration, not a request for auto
        if t.get("engine.threads").is_some() && cfg.engine_threads == 0 {
            bail!("engine.threads must be >= 1 (omit the key for auto-sizing)");
        }
        cfg.backend = t.get_str("engine.backend", &cfg.backend)?;
        cfg.fleet_macros = t.get_usize("fleet.macros", cfg.fleet_macros)?;
        cfg.fleet_residency_tiles =
            t.get_usize("fleet.residency_tiles", cfg.fleet_residency_tiles)?;
        cfg.fleet_hop_energy_fj = t.get_f64("fleet.hop_energy_fj", cfg.fleet_hop_energy_fj)?;
        cfg.fleet_hop_latency_cycles =
            t.get_usize("fleet.hop_latency_cycles", cfg.fleet_hop_latency_cycles as usize)? as u64;
        cfg.fleet_placement = t.get_str("fleet.placement", &cfg.fleet_placement)?;
        let tier_name = t.get_str("serve.default_tier", cfg.default_tier.name())?;
        cfg.default_tier = Tier::parse(&tier_name).ok_or_else(|| {
            anyhow::anyhow!("serve.default_tier: unknown tier {tier_name:?} (gold|silver|batch)")
        })?;
        cfg.queue_cap = t.get_usize("serve.queue_cap", cfg.queue_cap)?;
        cfg.keep_alive = t.get_bool("serve.keep_alive", cfg.keep_alive)?;
        cfg.max_conns = t.get_usize("serve.max_conns", cfg.max_conns)?;
        cfg.event_loop = t.get_bool("serve.event_loop", cfg.event_loop)?;
        cfg.read_timeout_ms =
            t.get_usize("serve.read_timeout_ms", cfg.read_timeout_ms as usize)? as u64;
        cfg.governor = t.get_bool("serve.governor", cfg.governor)?;
        cfg.energy_budget_w = t.get_f64("serve.energy_budget_w", cfg.energy_budget_w)?;
        cfg.gov_high_watermark = t.get_f64("serve.gov_high_watermark", cfg.gov_high_watermark)?;
        cfg.gov_low_watermark = t.get_f64("serve.gov_low_watermark", cfg.gov_low_watermark)?;
        cfg.gov_max_level = t.get_usize("serve.gov_max_level", cfg.gov_max_level as usize)? as u32;
        cfg.gov_hold_ms = t.get_usize("serve.gov_hold_ms", cfg.gov_hold_ms as usize)? as u64;
        cfg.obs_trace = t.get_bool("obs.trace", cfg.obs_trace)?;
        cfg.obs_trace_capacity = t.get_usize("obs.trace_capacity", cfg.obs_trace_capacity)?;
        cfg.obs_slow_ms = t.get_usize("obs.slow_ms", cfg.obs_slow_ms as usize)? as u64;
        cfg.device_model = t.get_str("device.model", &cfg.device_model)?;
        if t.get("device.sigma").is_some() {
            cfg.device_sigma = Some(t.get_f64("device.sigma", 0.0)?);
        }
        cfg.device_s_ou = t.get_usize("device.s_ou", cfg.device_s_ou)?;
        cfg.device_adc_offset = t.get_f64("device.adc_offset", cfg.device_adc_offset)?;
        cfg.device_adc_gain = t.get_f64("device.adc_gain", cfg.device_adc_gain)?;
        cfg.device_sweep_report = t.get_str("device.sweep_report", &cfg.device_sweep_report)?;
        cfg.device_corner_sigma = t.get_f64("device.corner_sigma", cfg.device_corner_sigma)?;
        cfg.device_sla_gold = t.get_f64("device.sla_gold", cfg.device_sla_gold)?;
        cfg.device_sla_silver = t.get_f64("device.sla_silver", cfg.device_sla_silver)?;
        cfg.device_sla_batch = t.get_f64("device.sla_batch", cfg.device_sla_batch)?;
        cfg.hardware_model = t.get_str("hardware.model", &cfg.hardware_model)?;
        for (i, name) in hierarchy::LEVEL_NAMES.iter().enumerate() {
            let key = format!("hardware.{name}");
            if let Some(vals) = t.get_array_f64(&key)? {
                cfg.hardware.levels[i] = hierarchy::MemoryLevel::from_array(&key, &vals)?;
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Cross-field validation with field-named errors.  Runs at config
    /// load AND at `engine::EngineBuilder::build` (CLI overrides land
    /// between the two).
    pub fn validate(&self) -> Result<()> {
        if self.backend.trim().is_empty() {
            bail!("engine.backend must not be empty (e.g. \"macro-hybrid\")");
        }
        if self.gov_low_watermark > self.gov_high_watermark {
            bail!(
                "serve.gov_low_watermark ({}) must not exceed serve.gov_high_watermark ({})",
                self.gov_low_watermark,
                self.gov_high_watermark
            );
        }
        if self.obs_trace && self.obs_trace_capacity == 0 {
            bail!("obs.trace_capacity must be >= 1 while obs.trace is enabled");
        }
        if self.fleet_macros == 0 {
            bail!("fleet.macros must be >= 1");
        }
        if self.fleet_residency_tiles == 0 {
            bail!("fleet.residency_tiles must be >= 1");
        }
        if self.fleet_hop_energy_fj < 0.0 {
            bail!("fleet.hop_energy_fj must be >= 0, got {}", self.fleet_hop_energy_fj);
        }
        if crate::sched::plan::PlacementMode::parse(&self.fleet_placement).is_none() {
            bail!(
                "fleet.placement: unknown mode {:?} (auto|replicate|resident)",
                self.fleet_placement
            );
        }
        if self.thresholds.len() + 1 != crate::spec::B_CANDIDATES.len() {
            bail!(
                "cim.thresholds: need {} thresholds for {} candidates, got {}",
                crate::spec::B_CANDIDATES.len() - 1,
                crate::spec::B_CANDIDATES.len(),
                self.thresholds.len()
            );
        }
        if !crate::device::MODEL_NAMES.contains(&self.device_model.as_str()) {
            bail!(
                "device.model: unknown model {:?} (one of: {})",
                self.device_model,
                crate::device::MODEL_NAMES.join(", ")
            );
        }
        if let Some(s) = self.device_sigma {
            if s.is_nan() || s < 0.0 {
                bail!("device.sigma must be >= 0, got {s}");
            }
        }
        if self.device_adc_gain.is_nan() || self.device_adc_gain <= 0.0 {
            bail!("device.adc_gain must be > 0, got {}", self.device_adc_gain);
        }
        if !self.device_adc_offset.is_finite() {
            bail!("device.adc_offset must be finite, got {}", self.device_adc_offset);
        }
        if self.device_corner_sigma.is_nan() || self.device_corner_sigma < 0.0 {
            bail!("device.corner_sigma must be >= 0, got {}", self.device_corner_sigma);
        }
        for (key, sla) in [
            ("device.sla_gold", self.device_sla_gold),
            ("device.sla_silver", self.device_sla_silver),
            ("device.sla_batch", self.device_sla_batch),
        ] {
            if !(0.0..=1.0).contains(&sla) {
                bail!("{key} must be an accuracy fraction in [0, 1], got {sla}");
            }
        }
        hierarchy::validate_model(&self.hardware_model)?;
        self.hardware.validate(crate::sched::fleet::tile_bytes(&self.spec))?;
        Ok(())
    }

    /// `true` when the hierarchy-and-dataflow cost model is selected.
    pub fn hierarchy_model(&self) -> bool {
        self.hardware_model == hierarchy::MODEL_HIERARCHY
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# sample config
[system]
artifacts_dir = "artifacts"   # comment after value

[cim]
mode = "hcim"
fixed_b = 7
thresholds = [10, 20, 30, 40, 50]
sigma_code = 0.0

[coordinator]
max_batch = 32
use_pjrt = true   # retired knob: ignored (backend selection replaced it)
"#;

    #[test]
    fn parse_sample() {
        let cfg = SystemConfig::from_toml(&Toml::parse(SAMPLE).unwrap()).unwrap();
        assert_eq!(cfg.mode, CimMode::Hcim);
        assert_eq!(cfg.fixed_b, 7);
        assert_eq!(cfg.thresholds, vec![10, 20, 30, 40, 50]);
        assert_eq!(cfg.spec.sigma_code, 0.0);
        assert_eq!(cfg.max_batch, 32);
    }

    #[test]
    fn defaults_when_empty() {
        let cfg = SystemConfig::from_toml(&Toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg.mode, CimMode::Osa);
        assert_eq!(cfg.thresholds.len(), 5);
    }

    #[test]
    fn value_types() {
        let t = Toml::parse("x = 3\ny = 2.5\nz = \"s\"\nw = true\nv = [1, 2]").unwrap();
        assert_eq!(t.get("x"), Some(&TomlValue::Int(3)));
        assert_eq!(t.get("y"), Some(&TomlValue::Float(2.5)));
        assert_eq!(t.get("z"), Some(&TomlValue::Str("s".into())));
        assert_eq!(t.get("w"), Some(&TomlValue::Bool(true)));
        assert_eq!(t.get("v"), Some(&TomlValue::Array(vec![1.0, 2.0])));
    }

    #[test]
    fn serve_section_parsed() {
        let t = Toml::parse(
            "[serve]\nqueue_cap = 64\ngovernor = false\nenergy_budget_w = 2.5\n\
             gov_high_watermark = 0.9\ngov_low_watermark = 0.1\ngov_max_level = 5\n\
             gov_hold_ms = 20\nkeep_alive = false\nmax_conns = 8\nread_timeout_ms = 250\n\
             event_loop = false",
        )
        .unwrap();
        let cfg = SystemConfig::from_toml(&t).unwrap();
        assert_eq!(cfg.queue_cap, 64);
        assert!(!cfg.governor);
        assert_eq!(cfg.energy_budget_w, 2.5);
        assert_eq!(cfg.gov_max_level, 5);
        assert_eq!(cfg.gov_hold_ms, 20);
        assert!(!cfg.keep_alive);
        assert_eq!(cfg.max_conns, 8);
        assert_eq!(cfg.read_timeout_ms, 250);
        assert!(!cfg.event_loop);
        // defaults when the section is absent
        let cfg = SystemConfig::from_toml(&Toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg.queue_cap, 256);
        assert!(cfg.governor);
        assert_eq!(cfg.energy_budget_w, 0.0);
        assert!(cfg.keep_alive);
        assert_eq!(cfg.max_conns, 64);
        assert_eq!(cfg.read_timeout_ms, 5_000);
        assert!(cfg.event_loop);
    }

    #[test]
    fn engine_section_parsed() {
        let t = Toml::parse("[engine]\nthreads = 3\nbackend = \"macro-dcim\"").unwrap();
        let cfg = SystemConfig::from_toml(&t).unwrap();
        assert_eq!(cfg.engine_threads, 3);
        assert_eq!(cfg.resolved_engine_threads(), 3);
        assert_eq!(cfg.backend, "macro-dcim");
        // absent section -> auto (always at least one thread), default backend
        let cfg = SystemConfig::from_toml(&Toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg.engine_threads, 0);
        assert!(cfg.resolved_engine_threads() >= 1);
        assert_eq!(cfg.backend, "macro-hybrid");
        assert_eq!(cfg.default_tier, Tier::Silver);
    }

    #[test]
    fn explicit_zero_engine_threads_rejected() {
        let t = Toml::parse("[engine]\nthreads = 0").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("engine.threads"), "{err}");
        // negative is rejected by the typed getter, also field-named
        let t = Toml::parse("[engine]\nthreads = -2").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("engine.threads"), "{err}");
    }

    #[test]
    fn empty_backend_name_rejected() {
        let t = Toml::parse("[engine]\nbackend = \"\"").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("engine.backend"), "{err}");
        // whitespace-only is just as empty
        let t = Toml::parse("[engine]\nbackend = \"  \"").unwrap();
        assert!(SystemConfig::from_toml(&t).is_err());
    }

    #[test]
    fn fleet_section_parsed_and_validated() {
        let t = Toml::parse(
            "[fleet]\nmacros = 4\nresidency_tiles = 8\nhop_energy_fj = 95.5\n\
             hop_latency_cycles = 3\nplacement = \"resident\"",
        )
        .unwrap();
        let cfg = SystemConfig::from_toml(&t).unwrap();
        assert_eq!(cfg.fleet_macros, 4);
        assert_eq!(cfg.fleet_residency_tiles, 8);
        assert_eq!(cfg.fleet_hop_energy_fj, 95.5);
        assert_eq!(cfg.fleet_hop_latency_cycles, 3);
        assert_eq!(cfg.fleet_placement, "resident");
        // defaults when the section is absent: single macro, auto
        let cfg = SystemConfig::from_toml(&Toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg.fleet_macros, 1);
        assert_eq!(cfg.fleet_residency_tiles, 64);
        assert_eq!(cfg.fleet_placement, "auto");
        // zero macros / residency and unknown placement are rejected
        let t = Toml::parse("[fleet]\nmacros = 0").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("fleet.macros"), "{err}");
        let t = Toml::parse("[fleet]\nresidency_tiles = 0").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("fleet.residency_tiles"), "{err}");
        let t = Toml::parse("[fleet]\nplacement = \"everywhere\"").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("fleet.placement"), "{err}");
        let t = Toml::parse("[fleet]\nhop_energy_fj = -1.0").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("fleet.hop_energy_fj"), "{err}");
    }

    #[test]
    fn serve_default_tier_parsed_and_validated() {
        let t = Toml::parse("[serve]\ndefault_tier = \"gold\"").unwrap();
        let cfg = SystemConfig::from_toml(&t).unwrap();
        assert_eq!(cfg.default_tier, Tier::Gold);
        let t = Toml::parse("[serve]\ndefault_tier = \"bronze\"").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("serve.default_tier"), "{err}");
        assert!(err.to_string().contains("bronze"), "{err}");
    }

    #[test]
    fn validate_is_rerunnable_on_mutated_configs() {
        // the builder re-validates after CLI overrides; make sure a
        // config mutated into a bad state is caught with a field name
        let mut cfg = SystemConfig::default();
        cfg.backend = String::new();
        assert!(cfg.validate().unwrap_err().to_string().contains("engine.backend"));
        let mut cfg = SystemConfig::default();
        cfg.thresholds = vec![1, 2];
        assert!(cfg.validate().unwrap_err().to_string().contains("cim.thresholds"));
    }

    #[test]
    fn obs_section_parsed() {
        let t = Toml::parse("[obs]\ntrace = false\ntrace_capacity = 128\nslow_ms = 50").unwrap();
        let cfg = SystemConfig::from_toml(&t).unwrap();
        assert!(!cfg.obs_trace);
        assert_eq!(cfg.obs_trace_capacity, 128);
        assert_eq!(cfg.obs_slow_ms, 50);
        // defaults when the section is absent
        let cfg = SystemConfig::from_toml(&Toml::parse("").unwrap()).unwrap();
        assert!(cfg.obs_trace);
        assert_eq!(cfg.obs_trace_capacity, 4096);
        assert_eq!(cfg.obs_slow_ms, 250);
        // a zero-capacity ring with tracing on is a misconfiguration
        let t = Toml::parse("[obs]\ntrace_capacity = 0").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("obs.trace_capacity"), "{err}");
        // ... but fine when tracing is off
        let t = Toml::parse("[obs]\ntrace = false\ntrace_capacity = 0").unwrap();
        assert!(SystemConfig::from_toml(&t).is_ok());
    }

    #[test]
    fn inverted_watermarks_rejected() {
        let t = Toml::parse("[serve]\ngov_high_watermark = 0.2\ngov_low_watermark = 0.8").unwrap();
        assert!(SystemConfig::from_toml(&t).is_err());
    }

    #[test]
    fn bad_threshold_count_rejected() {
        let t = Toml::parse("[cim]\nthresholds = [1, 2]").unwrap();
        assert!(SystemConfig::from_toml(&t).is_err());
    }

    #[test]
    fn hash_inside_string_kept() {
        let t = Toml::parse("s = \"a#b\" # real comment").unwrap();
        assert_eq!(t.get("s"), Some(&TomlValue::Str("a#b".into())));
    }

    #[test]
    fn hardware_section_parsed() {
        let t = Toml::parse(
            "[hardware]\nmodel = \"hierarchy\"\nweight_sram = [8192, 4.5, 6.0, 32, 2]\n\
             dram = [1200, 500, 500, 8, 1]",
        )
        .unwrap();
        let cfg = SystemConfig::from_toml(&t).unwrap();
        assert!(cfg.hierarchy_model());
        let lv = cfg.hardware.level(hierarchy::WEIGHT_SRAM);
        assert_eq!(lv.size_bytes, 8192);
        assert_eq!(lv.read_fj, 4.5);
        assert_eq!(lv.bandwidth_words, 32.0);
        assert_eq!(lv.ports, 2);
        assert_eq!(cfg.hardware.level(hierarchy::DRAM).size_bytes, 1200);
        // untouched levels keep the anchor defaults
        assert_eq!(cfg.hardware.level(hierarchy::CELL_GROUP).size_bytes, 1152);
        // defaults when the section is absent: compact + anchor stack
        let cfg = SystemConfig::from_toml(&Toml::parse("").unwrap()).unwrap();
        assert!(!cfg.hierarchy_model());
        assert_eq!(cfg.hardware, MemoryHierarchy::default());
    }

    #[test]
    fn hardware_validation_rejects_bad_levels() {
        // unknown model string
        let t = Toml::parse("[hardware]\nmodel = \"zigzag\"").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("hardware.model"), "{err}");
        // non-positive size
        let t = Toml::parse("[hardware]\nact_sram = [0, 5.2, 6.4, 16, 1]").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("hardware.act_sram"), "{err}");
        // negative per-access energy
        let t = Toml::parse("[hardware]\nacc_rf = [256, -1.0, 1.3, 16, 2]").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("hardware.acc_rf"), "{err}");
        // zero bandwidth
        let t = Toml::parse("[hardware]\ndram = [67108864, 620, 640, 0, 1]").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("hardware.dram"), "{err}");
        // a weight-holding level too small for one packed tile (1152 B)
        let t = Toml::parse("[hardware]\nweight_sram = [1024, 5.8, 7.2, 16, 1]").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("hardware.weight_sram"), "{err}");
        assert!(err.to_string().contains("packed weight tile"), "{err}");
        // wrong arity
        let t = Toml::parse("[hardware]\ncell_group = [1152, 0.0]").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("hardware.cell_group"), "{err}");
        // validate() is re-runnable on mutated configs (builder path)
        let mut cfg = SystemConfig::default();
        cfg.hardware_model = "bogus".into();
        assert!(cfg.validate().unwrap_err().to_string().contains("hardware.model"));
    }

    #[test]
    fn device_section_parsed_and_validated() {
        let t = Toml::parse(
            "[device]\nmodel = \"capacitor-mismatch\"\nsigma = 0.1\ns_ou = 16\n\
             adc_offset = 0.05\nadc_gain = 1.02\nsweep_report = \"SWEEP_corner.json\"\n\
             corner_sigma = 0.6\nsla_gold = 0.85\nsla_silver = 0.8\nsla_batch = 0.7",
        )
        .unwrap();
        let cfg = SystemConfig::from_toml(&t).unwrap();
        assert_eq!(cfg.device_model, "capacitor-mismatch");
        assert_eq!(cfg.device_sigma, Some(0.1));
        assert_eq!(cfg.device_s_ou, 16);
        assert_eq!(cfg.device_adc_offset, 0.05);
        assert_eq!(cfg.device_adc_gain, 1.02);
        assert_eq!(cfg.device_sweep_report, "SWEEP_corner.json");
        assert_eq!(cfg.device_corner_sigma, 0.6);
        assert_eq!(cfg.device_sla_gold, 0.85);
        // defaults when the section is absent: the bit-preserving baseline
        let cfg = SystemConfig::from_toml(&Toml::parse("").unwrap()).unwrap();
        assert_eq!(cfg.device_model, "gaussian-thermal");
        assert_eq!(cfg.device_sigma, None);
        assert_eq!(cfg.device_s_ou, 0);
        assert_eq!(cfg.device_adc_offset, 0.0);
        assert_eq!(cfg.device_adc_gain, 1.0);
        assert!(cfg.device_sweep_report.is_empty());
        // unknown model names fail with the registry listed
        let t = Toml::parse("[device]\nmodel = \"quantum-foam\"").unwrap();
        let err = SystemConfig::from_toml(&t).unwrap_err();
        assert!(err.to_string().contains("device.model"), "{err}");
        assert!(err.to_string().contains("lognormal-conductance"), "{err}");
        // out-of-range knobs are field-named errors
        let t = Toml::parse("[device]\nsigma = -0.1").unwrap();
        assert!(SystemConfig::from_toml(&t).unwrap_err().to_string().contains("device.sigma"));
        let t = Toml::parse("[device]\nadc_gain = 0.0").unwrap();
        assert!(SystemConfig::from_toml(&t).unwrap_err().to_string().contains("device.adc_gain"));
        let t = Toml::parse("[device]\nsla_gold = 1.5").unwrap();
        assert!(SystemConfig::from_toml(&t).unwrap_err().to_string().contains("device.sla_gold"));
    }

    #[test]
    fn mode_roundtrip() {
        for m in [CimMode::Dcim, CimMode::Hcim, CimMode::Osa, CimMode::Acim] {
            assert_eq!(CimMode::parse(m.name()).unwrap(), m);
        }
        assert!(CimMode::parse("bogus").is_err());
    }
}
