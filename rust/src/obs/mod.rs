//! Observability substrate (DESIGN.md §13): request tracing, bounded
//! atomic histograms and Prometheus text exposition — std-only, like
//! the rest of the crate.
//!
//! Three layers, each independently testable:
//!
//! * **Histograms** — [`Histogram`] is a log-bucketed `AtomicU64` array
//!   (4 sub-buckets per power of two, ≤25% relative bucket width).
//!   Recording is one `fetch_add` per bucket — wait-free, no `Mutex`,
//!   fixed memory forever.  [`HistSnapshot`] is the plain-data copy a
//!   reporter walks for percentiles; snapshots merge across tiers and
//!   stages.  This replaces the coordinator's `Vec<f64>` sample rings.
//! * **Spans** — every request gets a [`RequestId`] minted at accept
//!   (or adopted from an inbound `X-Request-Id`).  Stage spans
//!   (`parse → admit → queue → coalesce → exec → write`, plus
//!   per-layer `layer` sub-spans from the executor) land in a
//!   fixed-capacity seqlock ring ([`SpanRing`]): writers never block —
//!   a slot mid-write is simply skipped and counted as dropped.  The
//!   tail exports as Chrome `trace_event` JSON (`GET /debug/trace`).
//! * **Exposition** — [`PromWriter`] renders counters/gauges/histograms
//!   in the Prometheus text format (families grouped, labels escaped,
//!   non-finite values scrubbed to 0), and [`parse_exposition`] is the
//!   promtool-free validator CI round-trips the output through.
//!
//! [`ServerObs`] is the registry instance the coordinator and gateway
//! share: one `Arc`, all interior atomics, cloned freely onto the hot
//! path.

use crate::energy::hierarchy::NUM_LEVELS;
use crate::io::json::{arr, num, obj, s, JsonValue};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

// ---------------------------------------------------------------------------
// Monotonic process clock
// ---------------------------------------------------------------------------

static EPOCH: OnceLock<Instant> = OnceLock::new();

/// Microseconds since the first call in this process — the common time
/// base every span uses, so trace events from different threads align.
pub fn now_us() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_micros() as u64
}

/// Map non-finite floats to 0.0 — the single scrub every emitted gauge
/// goes through (JSON `/metrics` and Prometheus alike), so a NaN from a
/// zero-cycle energy account can never poison a scrape.
pub fn scrub(x: f64) -> f64 {
    if x.is_finite() {
        x
    } else {
        0.0
    }
}

// ---------------------------------------------------------------------------
// Request ids
// ---------------------------------------------------------------------------

/// Format a request id the way it appears in `X-Request-Id` and logs.
pub fn format_rid(rid: u64) -> String {
    format!("req-{rid:016x}")
}

/// Parse an id previously produced by [`format_rid`] (inbound
/// correlation); anything else is treated as foreign and re-minted.
pub fn parse_rid(text: &str) -> Option<u64> {
    let hex = text.strip_prefix("req-")?;
    if hex.len() != 16 || !hex.bytes().all(|b| b.is_ascii_hexdigit()) {
        return None;
    }
    u64::from_str_radix(hex, 16).ok()
}

// ---------------------------------------------------------------------------
// Log-bucketed atomic histogram
// ---------------------------------------------------------------------------

/// Sub-buckets per power of two: 4 → worst-case relative bucket width
/// of 25%, and 252 buckets cover the full `u64` range.
const SUBS: usize = 4;
/// Values below this are their own exact bucket.
const LINEAR: u64 = 8;
/// Total bucket count: 8 linear + 4 per octave for exponents 3..=63.
pub const HIST_BUCKETS: usize = LINEAR as usize + (64 - 3) * SUBS; // 252

/// Bucket index for a value (monotone in `v`).
pub fn bucket_index(v: u64) -> usize {
    if v < LINEAR {
        return v as usize;
    }
    let exp = 63 - v.leading_zeros() as usize; // >= 3
    let sub = ((v >> (exp - 2)) & (SUBS as u64 - 1)) as usize;
    LINEAR as usize + (exp - 3) * SUBS + sub
}

/// Smallest value that lands in bucket `i` (saturates past `u64::MAX`).
pub fn bucket_lower(i: usize) -> u64 {
    if i < LINEAR as usize {
        return i as u64;
    }
    let k = i - LINEAR as usize;
    let exp = 3 + k / SUBS;
    let sub = (k % SUBS) as u64;
    if exp >= 64 {
        return u64::MAX;
    }
    (1u64 << exp) + (sub << (exp - 2))
}

/// Largest value that lands in bucket `i` (inclusive upper bound — the
/// Prometheus `le` of the bucket).
pub fn bucket_upper(i: usize) -> u64 {
    if i + 1 >= HIST_BUCKETS {
        return u64::MAX;
    }
    bucket_lower(i + 1).saturating_sub(1)
}

/// Fixed-memory log-bucketed histogram; `record` is one relaxed
/// `fetch_add` per field — wait-free and lock-free on every path.
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)] // array-init idiom
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            buckets: [ZERO; HIST_BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one value (wait-free; safe from any thread).
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Plain-data copy for reporting (and merging).
    pub fn snapshot(&self) -> HistSnapshot {
        let mut counts = vec![0u64; HIST_BUCKETS];
        for (i, b) in self.buckets.iter().enumerate() {
            counts[i] = b.load(Ordering::Relaxed);
        }
        HistSnapshot {
            counts,
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of a [`Histogram`]; mergeable across tiers and
/// stages (bucket-wise add), walkable for percentiles.
#[derive(Debug, Clone, Default)]
pub struct HistSnapshot {
    pub counts: Vec<u64>,
    pub count: u64,
    pub sum: u64,
}

impl HistSnapshot {
    /// Bucket-wise merge (`self += other`) — tiers into an aggregate,
    /// stages into a total.
    pub fn merge(&mut self, other: &HistSnapshot) {
        if self.counts.len() < other.counts.len() {
            self.counts.resize(other.counts.len(), 0);
        }
        for (i, c) in other.counts.iter().enumerate() {
            self.counts[i] += c;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// Percentile estimate (bucket midpoint), `q` in [0, 1].  Empty
    /// snapshots report 0.0 — never NaN.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                let lo = bucket_lower(i);
                let hi = bucket_upper(i);
                return lo as f64 + (hi.saturating_sub(lo)) as f64 / 2.0;
            }
        }
        bucket_upper(HIST_BUCKETS - 1) as f64
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

// ---------------------------------------------------------------------------
// Trace spans
// ---------------------------------------------------------------------------

/// Request lifecycle stage a span measures.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage {
    /// Socket read + HTTP parse (includes read wait in threaded mode).
    Parse = 0,
    /// Validation + tier-queue admission in `submit_with_sink`.
    Admit = 1,
    /// Enqueue → dispatch wait in the tier queue.
    Queue = 2,
    /// First-enqueue → batch dispatch (the coalescing window actually
    /// used; overlaps the member requests' queue spans by design).
    Coalesce = 3,
    /// Whole-batch forward pass through the engine.
    Exec = 4,
    /// One layer's GEMM inside an exec span (label = layer name).
    Layer = 5,
    /// Response serialization + socket write.
    Write = 6,
}

impl Stage {
    pub const ALL: [Stage; 7] = [
        Stage::Parse,
        Stage::Admit,
        Stage::Queue,
        Stage::Coalesce,
        Stage::Exec,
        Stage::Layer,
        Stage::Write,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            Stage::Parse => "parse",
            Stage::Admit => "admit",
            Stage::Queue => "queue",
            Stage::Coalesce => "coalesce",
            Stage::Exec => "exec",
            Stage::Layer => "layer",
            Stage::Write => "write",
        }
    }

    fn from_u8(x: u8) -> Option<Stage> {
        Stage::ALL.into_iter().find(|s| *s as u8 == x)
    }
}

/// One exported span (a decoded ring slot).
#[derive(Debug, Clone)]
pub struct SpanRecord {
    pub rid: u64,
    pub stage: Stage,
    /// Tier index (`Tier::index()`), 255 when not applicable.
    pub tier: u8,
    /// Digital↔analog boundary for exec spans, 255 when not applicable.
    pub boundary: u8,
    pub start_us: u64,
    pub dur_us: u64,
    /// Backend name for exec spans, layer name for layer spans.
    pub label: String,
}

const LABEL_BYTES: usize = 16;

struct Slot {
    /// Seqlock: even = stable, odd = mid-write.
    seq: AtomicU64,
    rid: AtomicU64,
    /// stage (8) | tier (8) | boundary (8).
    meta: AtomicU64,
    start_us: AtomicU64,
    dur_us: AtomicU64,
    label: [AtomicU64; 2],
}

/// Fixed-capacity lock-free span ring.  Writers claim a slot with one
/// CAS; if another writer holds it (a full wrap-around race) the span
/// is dropped and counted instead of blocking.  Readers validate the
/// per-slot sequence and skip torn slots.
pub struct SpanRing {
    slots: Vec<Slot>,
    cursor: AtomicU64,
    dropped: AtomicU64,
}

impl SpanRing {
    pub fn new(capacity: usize) -> Self {
        let cap = capacity.max(16);
        let slots = (0..cap)
            .map(|_| Slot {
                seq: AtomicU64::new(0),
                rid: AtomicU64::new(0),
                meta: AtomicU64::new(0),
                start_us: AtomicU64::new(0),
                dur_us: AtomicU64::new(0),
                label: [AtomicU64::new(0), AtomicU64::new(0)],
            })
            .collect();
        SpanRing { slots, cursor: AtomicU64::new(0), dropped: AtomicU64::new(0) }
    }

    pub fn capacity(&self) -> usize {
        self.slots.len()
    }

    /// Spans recorded since start (monotone; `min(recorded, capacity)`
    /// slots are retained).
    pub fn recorded(&self) -> u64 {
        self.cursor.load(Ordering::Acquire)
    }

    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Exact bytes this ring occupies — constant for its lifetime (the
    /// flat-memory regression test asserts on this).
    pub fn heap_bytes(&self) -> usize {
        self.slots.len() * std::mem::size_of::<Slot>()
    }

    #[allow(clippy::too_many_arguments)] // mirrors the packed slot layout
    pub fn record(
        &self,
        rid: u64,
        stage: Stage,
        tier: u8,
        boundary: u8,
        start_us: u64,
        dur_us: u64,
        label: &str,
    ) {
        let idx = (self.cursor.fetch_add(1, Ordering::AcqRel) as usize) % self.slots.len();
        let slot = &self.slots[idx];
        let seq = slot.seq.load(Ordering::Relaxed);
        if seq & 1 == 1
            || slot
                .seq
                .compare_exchange(seq, seq + 1, Ordering::Acquire, Ordering::Relaxed)
                .is_err()
        {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        slot.rid.store(rid, Ordering::Relaxed);
        let meta = stage as u64 | (tier as u64) << 8 | (boundary as u64) << 16;
        slot.meta.store(meta, Ordering::Relaxed);
        slot.start_us.store(start_us, Ordering::Relaxed);
        slot.dur_us.store(dur_us, Ordering::Relaxed);
        let mut bytes = [0u8; LABEL_BYTES];
        let lb = label.as_bytes();
        let n = lb.len().min(LABEL_BYTES);
        bytes[..n].copy_from_slice(&lb[..n]);
        slot.label[0].store(u64::from_le_bytes(bytes[..8].try_into().unwrap()), Ordering::Relaxed);
        slot.label[1].store(u64::from_le_bytes(bytes[8..].try_into().unwrap()), Ordering::Relaxed);
        slot.seq.store(seq + 2, Ordering::Release);
    }

    fn read_slot(&self, idx: usize) -> Option<SpanRecord> {
        let slot = &self.slots[idx];
        for _ in 0..4 {
            let s0 = slot.seq.load(Ordering::Acquire);
            if s0 & 1 == 1 {
                continue;
            }
            let rid = slot.rid.load(Ordering::Relaxed);
            let meta = slot.meta.load(Ordering::Relaxed);
            let start_us = slot.start_us.load(Ordering::Relaxed);
            let dur_us = slot.dur_us.load(Ordering::Relaxed);
            let l0 = slot.label[0].load(Ordering::Relaxed);
            let l1 = slot.label[1].load(Ordering::Relaxed);
            if slot.seq.load(Ordering::Acquire) != s0 {
                continue;
            }
            let stage = Stage::from_u8((meta & 0xff) as u8)?;
            let mut bytes = [0u8; LABEL_BYTES];
            bytes[..8].copy_from_slice(&l0.to_le_bytes());
            bytes[8..].copy_from_slice(&l1.to_le_bytes());
            let end = bytes.iter().position(|&b| b == 0).unwrap_or(LABEL_BYTES);
            let label = String::from_utf8_lossy(&bytes[..end]).into_owned();
            return Some(SpanRecord {
                rid,
                stage,
                tier: ((meta >> 8) & 0xff) as u8,
                boundary: ((meta >> 16) & 0xff) as u8,
                start_us,
                dur_us,
                label,
            });
        }
        None
    }

    /// The most recent `n` spans in insertion order (oldest first).
    pub fn tail(&self, n: usize) -> Vec<SpanRecord> {
        let cur = self.cursor.load(Ordering::Acquire);
        let have = cur.min(self.slots.len() as u64);
        let take = (n as u64).min(have);
        let mut out = Vec::with_capacity(take as usize);
        for i in (cur - take)..cur {
            if let Some(rec) = self.read_slot((i % self.slots.len() as u64) as usize) {
                out.push(rec);
            }
        }
        out
    }
}

/// One layer's contribution to a forward pass, reported by the
/// executor: GEMM wall time (offset-relative so the coordinator can
/// anchor it inside the exec span) plus energy attribution.
#[derive(Debug, Clone)]
pub struct LayerSample {
    pub name: String,
    /// Start offset from the beginning of the forward pass.
    pub offset_us: u64,
    pub dur_us: u64,
    pub energy_fj: f64,
    /// Data-movement share of `energy_fj` per memory level
    /// (`energy::hierarchy::LEVEL_NAMES` order); all-zero under the
    /// `compact` cost model.
    pub movement_fj: [f64; NUM_LEVELS],
    pub macro_ops: u64,
}

/// Accumulated per-layer attribution (all atomic; updated once per
/// batch, read by both exposition formats).
#[derive(Default)]
pub struct LayerStat {
    pub calls: AtomicU64,
    pub exec_us: AtomicU64,
    pub energy_fj: AtomicU64,
    pub movement_fj: [AtomicU64; NUM_LEVELS],
    pub macro_ops: AtomicU64,
}

/// Plain-data copy of a [`LayerStat`].
#[derive(Debug, Clone, Default)]
pub struct LayerStatSnap {
    pub calls: u64,
    pub exec_us: u64,
    pub energy_j: f64,
    /// Cumulative modeled data movement per memory level, joules.
    pub movement_j: [f64; NUM_LEVELS],
    pub macro_ops: u64,
}

// ---------------------------------------------------------------------------
// The shared registry
// ---------------------------------------------------------------------------

/// Everything the serving stack records into, one `Arc` shared by the
/// gateway, the coordinator workers and the executor: request-id mint,
/// latency/stage histograms, the span ring and per-layer attribution.
/// Every hot-path method is lock-free (the per-layer map takes a
/// `Mutex` once per *batch*, never per request).
pub struct ServerObs {
    next_rid: AtomicU64,
    trace_on: AtomicBool,
    slow_us: AtomicU64,
    /// Aggregate request latency (submit → response sent).
    pub latency_us: Histogram,
    pub tier_latency_us: [Histogram; 3],
    pub tier_queue_us: [Histogram; 3],
    pub tier_exec_us: [Histogram; 3],
    pub tier_write_us: [Histogram; 3],
    /// Socket read + parse time per HTTP request (all routes).
    pub parse_us: Histogram,
    ring: SpanRing,
    layers: Mutex<BTreeMap<String, Arc<LayerStat>>>,
}

impl Default for ServerObs {
    fn default() -> Self {
        Self::new(4096, 250, true)
    }
}

impl std::fmt::Debug for ServerObs {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ServerObs")
            .field("trace_on", &self.trace_enabled())
            .field("trace_capacity", &self.ring.capacity())
            .field("spans_recorded", &self.ring.recorded())
            .field("latency_count", &self.latency_us.count())
            .finish_non_exhaustive()
    }
}

impl ServerObs {
    pub fn new(trace_capacity: usize, slow_ms: u64, trace_on: bool) -> Self {
        // Seed the id mint from wall time so ids from distinct processes
        // do not collide in merged logs; low bits count sequentially.
        let seed = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(1);
        ServerObs {
            next_rid: AtomicU64::new((seed | 1) << 20),
            trace_on: AtomicBool::new(trace_on),
            slow_us: AtomicU64::new(slow_ms.saturating_mul(1000)),
            latency_us: Histogram::new(),
            tier_latency_us: std::array::from_fn(|_| Histogram::new()),
            tier_queue_us: std::array::from_fn(|_| Histogram::new()),
            tier_exec_us: std::array::from_fn(|_| Histogram::new()),
            tier_write_us: std::array::from_fn(|_| Histogram::new()),
            parse_us: Histogram::new(),
            ring: SpanRing::new(trace_capacity),
            layers: Mutex::new(BTreeMap::new()),
        }
    }

    /// Mint a fresh request id.
    pub fn mint_rid(&self) -> u64 {
        self.next_rid.fetch_add(1, Ordering::Relaxed)
    }

    pub fn trace_enabled(&self) -> bool {
        self.trace_on.load(Ordering::Relaxed)
    }

    /// Toggle span collection at runtime (the overhead bench flips it).
    pub fn set_trace_enabled(&self, on: bool) {
        self.trace_on.store(on, Ordering::Relaxed);
    }

    /// Slow-request threshold in µs (0 disables the slow log line).
    pub fn slow_us(&self) -> u64 {
        self.slow_us.load(Ordering::Relaxed)
    }

    /// Record one span (no-op unless tracing is enabled).
    #[allow(clippy::too_many_arguments)] // mirrors the packed slot layout
    pub fn span(
        &self,
        rid: u64,
        stage: Stage,
        tier: u8,
        boundary: u8,
        start_us: u64,
        dur_us: u64,
        label: &str,
    ) {
        if self.trace_enabled() {
            self.ring.record(rid, stage, tier, boundary, start_us, dur_us, label);
        }
    }

    pub fn spans_tail(&self, n: usize) -> Vec<SpanRecord> {
        self.ring.tail(n)
    }

    pub fn trace_capacity(&self) -> usize {
        self.ring.capacity()
    }

    pub fn spans_recorded(&self) -> u64 {
        self.ring.recorded()
    }

    pub fn spans_dropped(&self) -> u64 {
        self.ring.dropped()
    }

    /// Total heap footprint of the telemetry stores — constant for the
    /// registry's lifetime (histograms are inline arrays, the ring is
    /// sized once); the flat-memory regression test pins this.
    pub fn heap_bytes(&self) -> usize {
        self.ring.heap_bytes()
            + self
                .layers
                .lock()
                .unwrap()
                .iter()
                .map(|(k, _)| k.len() + std::mem::size_of::<LayerStat>())
                .sum::<usize>()
    }

    /// Fold a forward pass's per-layer samples into the attribution
    /// table (one short `Mutex` hold per batch; the per-request record
    /// path never sees it).
    pub fn record_layers(&self, samples: &[LayerSample]) {
        if samples.is_empty() {
            return;
        }
        let stats: Vec<Arc<LayerStat>> = {
            let mut map = self.layers.lock().unwrap();
            samples
                .iter()
                .map(|smp| map.entry(smp.name.clone()).or_default().clone())
                .collect()
        };
        for (smp, stat) in samples.iter().zip(stats) {
            stat.calls.fetch_add(1, Ordering::Relaxed);
            stat.exec_us.fetch_add(smp.dur_us, Ordering::Relaxed);
            stat.energy_fj.fetch_add(smp.energy_fj.max(0.0) as u64, Ordering::Relaxed);
            for (acc, &fj) in stat.movement_fj.iter().zip(&smp.movement_fj) {
                acc.fetch_add(fj.max(0.0) as u64, Ordering::Relaxed);
            }
            stat.macro_ops.fetch_add(smp.macro_ops, Ordering::Relaxed);
        }
    }

    /// Per-layer attribution snapshot, layer-name order.
    pub fn layer_snapshot(&self) -> Vec<(String, LayerStatSnap)> {
        self.layers
            .lock()
            .unwrap()
            .iter()
            .map(|(name, st)| {
                (
                    name.clone(),
                    LayerStatSnap {
                        calls: st.calls.load(Ordering::Relaxed),
                        exec_us: st.exec_us.load(Ordering::Relaxed),
                        energy_j: st.energy_fj.load(Ordering::Relaxed) as f64 * 1e-15,
                        movement_j: std::array::from_fn(|i| {
                            st.movement_fj[i].load(Ordering::Relaxed) as f64 * 1e-15
                        }),
                        macro_ops: st.macro_ops.load(Ordering::Relaxed),
                    },
                )
            })
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Chrome trace-event export
// ---------------------------------------------------------------------------

/// Render spans as a Chrome `trace_event` document (load it in
/// `chrome://tracing` or Perfetto).  One timeline row per request id
/// (`tid`), events sorted by start time.
pub fn chrome_trace_doc(spans: &[SpanRecord]) -> JsonValue {
    let mut sorted: Vec<&SpanRecord> = spans.iter().collect();
    sorted.sort_by_key(|r| (r.start_us, r.stage as u8));
    let events = sorted.into_iter().map(|r| {
        let name = if r.label.is_empty() {
            r.stage.name().to_string()
        } else {
            format!("{}:{}", r.stage.name(), r.label)
        };
        let mut args: Vec<(&str, JsonValue)> = vec![("request_id", s(&format_rid(r.rid)))];
        if r.tier != u8::MAX {
            args.push(("tier", num(r.tier as f64)));
        }
        if r.boundary != u8::MAX {
            args.push(("boundary", num(r.boundary as f64)));
        }
        if !r.label.is_empty() {
            args.push(("label", s(&r.label)));
        }
        obj(vec![
            ("name", s(&name)),
            ("cat", s(r.stage.name())),
            ("ph", s("X")),
            ("ts", num(r.start_us as f64)),
            ("dur", num(r.dur_us as f64)),
            ("pid", num(1.0)),
            ("tid", num((r.rid & 0xffff_ffff) as f64)),
            ("args", obj(args)),
        ])
    });
    obj(vec![("traceEvents", arr(events)), ("displayTimeUnit", s("ms"))])
}

// ---------------------------------------------------------------------------
// Prometheus text exposition — writer
// ---------------------------------------------------------------------------

/// The exposition content type (`text/plain; version=0.0.4`).
pub const PROM_CONTENT_TYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FamilyType {
    Counter,
    Gauge,
    Histogram,
}

impl FamilyType {
    fn name(&self) -> &'static str {
        match self {
            FamilyType::Counter => "counter",
            FamilyType::Gauge => "gauge",
            FamilyType::Histogram => "histogram",
        }
    }
}

struct Family {
    name: String,
    help: String,
    ty: FamilyType,
    lines: Vec<String>,
}

/// Prometheus text-format writer.  Samples may be appended in any
/// order; `finish()` groups each family under one `# HELP`/`# TYPE`
/// header (the format requires family lines to be contiguous).  All
/// values pass through [`scrub`].
#[derive(Default)]
pub struct PromWriter {
    families: Vec<Family>,
    index: BTreeMap<String, usize>,
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn format_value(x: f64) -> String {
    let x = scrub(x);
    if x.fract() == 0.0 && x.abs() < 9e15 {
        format!("{}", x as i64)
    } else {
        format!("{x}")
    }
}

fn label_block(labels: &[(&str, String)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let mut inner: Vec<String> = Vec::with_capacity(labels.len());
    for (k, v) in labels {
        inner.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    format!("{{{}}}", inner.join(","))
}

impl PromWriter {
    pub fn new() -> Self {
        Self::default()
    }

    fn family(&mut self, name: &str, help: &str, ty: FamilyType) -> &mut Family {
        let idx = *self.index.entry(name.to_string()).or_insert_with(|| {
            self.families.push(Family {
                name: name.to_string(),
                help: help.to_string(),
                ty,
                lines: Vec::new(),
            });
            self.families.len() - 1
        });
        &mut self.families[idx]
    }

    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, String)], value: f64) {
        let line = format!("{name}{} {}", label_block(labels), format_value(value));
        self.family(name, help, FamilyType::Counter).lines.push(line);
    }

    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, String)], value: f64) {
        let line = format!("{name}{} {}", label_block(labels), format_value(value));
        self.family(name, help, FamilyType::Gauge).lines.push(line);
    }

    /// Emit a histogram family member: cumulative `_bucket{le=}` lines
    /// over the non-empty buckets, then `+Inf`, `_sum` and `_count`.
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, String)],
        h: &HistSnapshot,
    ) {
        let mut lines = Vec::new();
        let mut cum = 0u64;
        for (i, c) in h.counts.iter().enumerate() {
            if *c == 0 {
                continue;
            }
            cum += c;
            let le = bucket_upper(i);
            let le_text =
                if le == u64::MAX { "+Inf".to_string() } else { format!("{le}") };
            let mut ls: Vec<(&str, String)> = labels.to_vec();
            ls.push(("le", le_text.clone()));
            if le_text != "+Inf" {
                lines.push(format!("{name}_bucket{} {cum}", label_block(&ls)));
            }
        }
        let mut inf: Vec<(&str, String)> = labels.to_vec();
        inf.push(("le", "+Inf".to_string()));
        lines.push(format!("{name}_bucket{} {}", label_block(&inf), h.count));
        lines.push(format!("{name}_sum{} {}", label_block(labels), format_value(h.sum as f64)));
        lines.push(format!("{name}_count{} {}", label_block(labels), h.count));
        self.family(name, help, FamilyType::Histogram).lines.extend(lines);
    }

    pub fn finish(self) -> String {
        let mut out = String::new();
        for f in &self.families {
            out.push_str(&format!("# HELP {} {}\n", f.name, f.help));
            out.push_str(&format!("# TYPE {} {}\n", f.name, f.ty.name()));
            for line in &f.lines {
                out.push_str(line);
                out.push('\n');
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Prometheus text exposition — parser (the promtool-free lint)
// ---------------------------------------------------------------------------

/// One parsed sample line.
#[derive(Debug, Clone, PartialEq)]
pub struct PromSample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: f64,
}

/// A parsed exposition document.
#[derive(Debug, Default)]
pub struct Exposition {
    pub samples: Vec<PromSample>,
    /// `# TYPE` per family.
    pub types: BTreeMap<String, String>,
}

impl Exposition {
    /// All samples of one exact metric name.
    pub fn metric(&self, name: &str) -> Vec<&PromSample> {
        self.samples.iter().filter(|s| s.name == name).collect()
    }

    /// The value of the single sample matching `name` and all given
    /// labels, if exactly one matches.
    pub fn value(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        let hits: Vec<&PromSample> = self
            .samples
            .iter()
            .filter(|s| {
                s.name == name
                    && labels.iter().all(|(k, v)| {
                        s.labels.iter().any(|(lk, lv)| lk == k && lv == v)
                    })
            })
            .collect();
        if hits.len() == 1 {
            Some(hits[0].value)
        } else {
            None
        }
    }
}

fn valid_metric_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
}

/// Base family name of a sample (strips histogram suffixes).
fn family_of(name: &str) -> &str {
    for suf in ["_bucket", "_sum", "_count"] {
        if let Some(base) = name.strip_suffix(suf) {
            return base;
        }
    }
    name
}

fn parse_label_pairs(text: &str, line_no: usize) -> Result<Vec<(String, String)>, String> {
    let mut labels = Vec::new();
    let bytes = text.as_bytes();
    let mut i = 0usize;
    while i < bytes.len() {
        let rest = &text[i..];
        let eq = rest.find('=').ok_or(format!("line {line_no}: label without '='"))?;
        let name = rest[..eq].trim();
        if !valid_label_name(name) {
            return Err(format!("line {line_no}: bad label name {name:?}"));
        }
        i += eq + 1;
        if bytes.get(i) != Some(&b'"') {
            return Err(format!("line {line_no}: label value must be quoted"));
        }
        i += 1;
        let mut value = String::new();
        loop {
            match bytes.get(i) {
                None => return Err(format!("line {line_no}: unterminated label value")),
                Some(b'"') => {
                    i += 1;
                    break;
                }
                Some(b'\\') => {
                    match bytes.get(i + 1) {
                        Some(b'\\') => value.push('\\'),
                        Some(b'"') => value.push('"'),
                        Some(b'n') => value.push('\n'),
                        other => {
                            return Err(format!("line {line_no}: bad escape {other:?}"));
                        }
                    }
                    i += 2;
                }
                Some(_) => {
                    let c_start = i;
                    while i < bytes.len() && bytes[i] != b'"' && bytes[i] != b'\\' {
                        i += 1;
                    }
                    value.push_str(&text[c_start..i]);
                }
            }
        }
        labels.push((name.to_string(), value));
        match bytes.get(i) {
            Some(b',') => i += 1,
            None => break,
            Some(c) => {
                return Err(format!("line {line_no}: unexpected {:?} after label", *c as char))
            }
        }
    }
    Ok(labels)
}

fn parse_prom_value(text: &str, line_no: usize) -> Result<f64, String> {
    match text {
        "+Inf" => Ok(f64::INFINITY),
        "-Inf" => Ok(f64::NEG_INFINITY),
        "NaN" => Ok(f64::NAN),
        other => other
            .parse::<f64>()
            .map_err(|_| format!("line {line_no}: bad sample value {other:?}")),
    }
}

/// Parse + validate a Prometheus text exposition.  Checks: name and
/// label syntax, numeric values, `# TYPE` known and unique, family
/// lines contiguous, histogram `le` bucket counts cumulative and
/// `_count` consistent with the `+Inf` bucket.  This is the CI lint —
/// the gateway's output must round-trip through it.
pub fn parse_exposition(text: &str) -> Result<Exposition, String> {
    let mut out = Exposition::default();
    let mut closed: Vec<String> = Vec::new(); // families whose block ended
    let mut current: Option<String> = None;
    for (idx, raw) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = raw.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            let mut parts = rest.splitn(3, ' ');
            match parts.next() {
                Some("TYPE") => {
                    let name = parts.next().ok_or(format!("line {line_no}: TYPE without name"))?;
                    let ty = parts.next().ok_or(format!("line {line_no}: TYPE without kind"))?;
                    if !valid_metric_name(name) {
                        return Err(format!("line {line_no}: bad metric name {name:?}"));
                    }
                    if !["counter", "gauge", "histogram", "summary", "untyped"].contains(&ty) {
                        return Err(format!("line {line_no}: unknown TYPE {ty:?}"));
                    }
                    if out.types.insert(name.to_string(), ty.to_string()).is_some() {
                        return Err(format!("line {line_no}: duplicate TYPE for {name}"));
                    }
                    if let Some(cur) = current.take() {
                        closed.push(cur);
                    }
                    if closed.iter().any(|c| c == name) {
                        return Err(format!("line {line_no}: family {name} not contiguous"));
                    }
                    current = Some(name.to_string());
                }
                Some("HELP") => {
                    let name = parts.next().ok_or(format!("line {line_no}: HELP without name"))?;
                    if !valid_metric_name(name) {
                        return Err(format!("line {line_no}: bad metric name {name:?}"));
                    }
                }
                _ => {} // free-form comment
            }
            continue;
        }
        if line.starts_with('#') {
            continue; // comment without space — tolerated
        }
        // sample: name[{labels}] value [timestamp]
        let (name_part, rest) = match line.find(['{', ' ']) {
            Some(pos) => (&line[..pos], &line[pos..]),
            None => return Err(format!("line {line_no}: sample without value")),
        };
        if !valid_metric_name(name_part) {
            return Err(format!("line {line_no}: bad metric name {name_part:?}"));
        }
        let (labels, value_text) = if let Some(inner) = rest.strip_prefix('{') {
            let close = inner.rfind('}').ok_or(format!("line {line_no}: unterminated labels"))?;
            (parse_label_pairs(&inner[..close], line_no)?, inner[close + 1..].trim())
        } else {
            (Vec::new(), rest.trim())
        };
        let mut fields = value_text.split_whitespace();
        let value_field =
            fields.next().ok_or(format!("line {line_no}: sample without value"))?;
        let value = parse_prom_value(value_field, line_no)?;
        if let Some(ts) = fields.next() {
            ts.parse::<i64>().map_err(|_| format!("line {line_no}: bad timestamp {ts:?}"))?;
        }
        if fields.next().is_some() {
            return Err(format!("line {line_no}: trailing garbage"));
        }
        let fam = family_of(name_part).to_string();
        match &current {
            Some(cur) if *cur == fam => {}
            _ => {
                let seen = closed.iter().any(|c| *c == fam)
                    || out.types.contains_key(&fam) && current.as_deref() != Some(fam.as_str());
                if seen {
                    return Err(format!("line {line_no}: family {fam} not contiguous"));
                }
                if let Some(cur) = current.take() {
                    closed.push(cur);
                }
                current = Some(fam.clone());
            }
        }
        out.samples.push(PromSample {
            name: name_part.to_string(),
            labels,
            value,
        });
    }
    validate_histograms(&out)?;
    Ok(out)
}

/// Histogram-specific checks: per-labelset `le` buckets must be
/// strictly increasing with cumulative counts, and `_count` must equal
/// the `+Inf` bucket.
fn validate_histograms(doc: &Exposition) -> Result<(), String> {
    let mut hist_families: Vec<&String> = Vec::new();
    for (name, ty) in &doc.types {
        if ty == "histogram" {
            hist_families.push(name);
        }
    }
    for fam in hist_families {
        let bucket_name = format!("{fam}_bucket");
        // group buckets by labels-minus-le
        let mut groups: BTreeMap<String, Vec<(f64, f64)>> = BTreeMap::new();
        for smp in doc.metric(&bucket_name) {
            let mut le = None;
            let mut key_labels: Vec<String> = Vec::new();
            for (k, v) in &smp.labels {
                if k == "le" {
                    le = Some(parse_prom_value(v, 0).map_err(|_| format!("{fam}: bad le {v:?}"))?);
                } else {
                    key_labels.push(format!("{k}={v}"));
                }
            }
            let le = le.ok_or(format!("{fam}: bucket without le"))?;
            groups.entry(key_labels.join(",")).or_default().push((le, smp.value));
        }
        for (key, buckets) in &groups {
            let mut prev_le = f64::NEG_INFINITY;
            let mut prev_cum = -1.0f64;
            for (le, cum) in buckets {
                if *le <= prev_le {
                    return Err(format!("{fam}{{{key}}}: le not increasing"));
                }
                if *cum < prev_cum {
                    return Err(format!("{fam}{{{key}}}: bucket counts not cumulative"));
                }
                prev_le = *le;
                prev_cum = *cum;
            }
            let last = buckets.last().unwrap();
            if !last.0.is_infinite() {
                return Err(format!("{fam}{{{key}}}: missing +Inf bucket"));
            }
            // _count for the same labelset must match the +Inf bucket
            let count_name = format!("{fam}_count");
            for smp in doc.metric(&count_name) {
                let smp_key: Vec<String> =
                    smp.labels.iter().map(|(k, v)| format!("{k}={v}")).collect();
                if smp_key.join(",") == *key && smp.value != last.1 {
                    return Err(format!(
                        "{fam}{{{key}}}: _count {} != +Inf bucket {}",
                        smp.value, last.1
                    ));
                }
            }
        }
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Sweep progress
// ---------------------------------------------------------------------------

/// Progress counters for the `osa-hcim sweep` design-space explorer
/// (DESIGN.md §16).  Same shape as the rest of the registry: interior
/// atomics, wait-free updates, snapshot reads — the sweep driver bumps
/// these per grid cell and emits one structured log line each, so a
/// long Monte-Carlo run streams its position without any extra wiring.
#[derive(Debug, Default)]
pub struct SweepProgress {
    cells_total: AtomicU64,
    cells_done: AtomicU64,
    images_done: AtomicU64,
}

impl SweepProgress {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare the grid size before the first cell runs.
    pub fn begin(&self, cells: u64) {
        self.cells_total.store(cells, Ordering::Relaxed);
        self.cells_done.store(0, Ordering::Relaxed);
        self.images_done.store(0, Ordering::Relaxed);
    }

    /// Record one finished grid cell (`images` forwards evaluated).
    pub fn cell_done(&self, label: &str, images: u64) {
        let done = self.cells_done.fetch_add(1, Ordering::Relaxed) + 1;
        self.images_done.fetch_add(images, Ordering::Relaxed);
        let total = self.cells_total.load(Ordering::Relaxed);
        log::info!("sweep cell {done}/{total} done: {label}");
    }

    /// `(cells_done, cells_total, images_done)` at this instant.
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.cells_done.load(Ordering::Relaxed),
            self.cells_total.load(Ordering::Relaxed),
            self.images_done.load(Ordering::Relaxed),
        )
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_progress_counts_cells_and_images() {
        let p = SweepProgress::new();
        p.begin(4);
        assert_eq!(p.snapshot(), (0, 4, 0));
        p.cell_done("b=8 sigma=0.3 seed=0", 16);
        p.cell_done("b=8 sigma=0.3 seed=1", 16);
        assert_eq!(p.snapshot(), (2, 4, 32));
        // begin() resets for the next grid
        p.begin(2);
        assert_eq!(p.snapshot(), (0, 2, 0));
    }

    #[test]
    fn bucket_index_monotone_and_invertible() {
        let mut prev = 0usize;
        for v in [0u64, 1, 7, 8, 9, 15, 16, 100, 1_000, 65_535, 1 << 20, 1 << 40, u64::MAX] {
            let i = bucket_index(v);
            assert!(i >= prev, "bucket index not monotone at {v}");
            prev = i;
            assert!(bucket_lower(i) <= v, "lower({i}) > {v}");
            assert!(v <= bucket_upper(i), "upper({i}) < {v}");
            assert!(i < HIST_BUCKETS);
        }
        // every bucket boundary maps back to its own bucket
        for i in 0..HIST_BUCKETS {
            let lo = bucket_lower(i);
            if lo == u64::MAX {
                continue;
            }
            assert_eq!(bucket_index(lo), i, "lower({i}) not in bucket {i}");
        }
    }

    #[test]
    fn histogram_percentile_within_one_bucket_of_exact() {
        use crate::util::prng::SplitMix64;
        let h = Histogram::new();
        let mut g = SplitMix64::new(42);
        let mut exact: Vec<u64> = Vec::new();
        for _ in 0..10_000 {
            // log-uniform-ish latencies, 1us .. ~1s
            let v = 1u64 << g.next_below(21);
            let v = v + g.next_below(v.max(1) as usize) as u64;
            h.record(v);
            exact.push(v);
        }
        exact.sort_unstable();
        let snap = h.snapshot();
        assert_eq!(snap.count, 10_000);
        for q in [0.5, 0.95, 0.99] {
            let rank = ((q * exact.len() as f64).ceil() as usize).clamp(1, exact.len());
            let exact_v = exact[rank - 1];
            let est = snap.percentile(q) as u64;
            let delta = bucket_index(exact_v).abs_diff(bucket_index(est));
            assert!(delta <= 1, "p{q}: exact {exact_v} vs est {est} off by {delta} buckets");
        }
    }

    #[test]
    fn histogram_merge_equals_combined_stream() {
        let a = Histogram::new();
        let b = Histogram::new();
        let c = Histogram::new();
        for v in 0..1000u64 {
            if v % 2 == 0 {
                a.record(v * 3);
            } else {
                b.record(v * 3);
            }
            c.record(v * 3);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        let combined = c.snapshot();
        assert_eq!(merged.count, combined.count);
        assert_eq!(merged.sum, combined.sum);
        assert_eq!(merged.counts, combined.counts);
        assert_eq!(merged.percentile(0.5), combined.percentile(0.5));
    }

    #[test]
    fn empty_snapshot_is_zero_not_nan() {
        let snap = Histogram::new().snapshot();
        assert_eq!(snap.percentile(0.5), 0.0);
        assert_eq!(snap.mean(), 0.0);
    }

    #[test]
    fn span_ring_tail_and_wraparound() {
        let ring = SpanRing::new(16);
        for i in 0..40u64 {
            ring.record(i, Stage::Exec, 1, 8, i * 10, 5, "osa");
        }
        let tail = ring.tail(8);
        assert_eq!(tail.len(), 8);
        // insertion order, newest last
        let rids: Vec<u64> = tail.iter().map(|r| r.rid).collect();
        assert_eq!(rids, (32..40).collect::<Vec<u64>>());
        assert_eq!(tail[0].stage, Stage::Exec);
        assert_eq!(tail[0].tier, 1);
        assert_eq!(tail[0].boundary, 8);
        assert_eq!(tail[0].label, "osa");
        assert_eq!(ring.recorded(), 40);
        // asking for more than capacity returns at most capacity
        assert_eq!(ring.tail(1000).len(), 16);
    }

    #[test]
    fn span_ring_concurrent_writers_never_block() {
        let ring = Arc::new(SpanRing::new(64));
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let ring = ring.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..5_000u64 {
                    ring.record(t << 32 | i, Stage::Queue, 0, u8::MAX, i, 1, "");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(ring.recorded(), 20_000);
        // every retained slot decodes (drops are counted, not corrupted)
        let tail = ring.tail(64);
        assert!(tail.len() + ring.dropped() as usize >= 1);
        for r in &tail {
            assert_eq!(r.stage, Stage::Queue);
        }
    }

    #[test]
    fn rid_format_round_trips() {
        for rid in [0u64, 1, 0xdead_beef, u64::MAX] {
            assert_eq!(parse_rid(&format_rid(rid)), Some(rid));
        }
        assert_eq!(parse_rid("not-a-rid"), None);
        assert_eq!(parse_rid("req-123"), None); // short hex
        assert_eq!(parse_rid("req-zzzzzzzzzzzzzzzz"), None);
    }

    #[test]
    fn server_obs_mints_distinct_rids() {
        let obs = ServerObs::new(64, 250, true);
        let a = obs.mint_rid();
        let b = obs.mint_rid();
        assert_ne!(a, b);
    }

    #[test]
    fn chrome_trace_doc_shape() {
        let spans = vec![
            SpanRecord {
                rid: 7,
                stage: Stage::Exec,
                tier: 0,
                boundary: 8,
                start_us: 100,
                dur_us: 50,
                label: "osa".into(),
            },
            SpanRecord {
                rid: 7,
                stage: Stage::Parse,
                tier: u8::MAX,
                boundary: u8::MAX,
                start_us: 10,
                dur_us: 5,
                label: String::new(),
            },
        ];
        let doc = chrome_trace_doc(&spans);
        let events = doc.get("traceEvents").and_then(JsonValue::as_array).unwrap();
        assert_eq!(events.len(), 2);
        // sorted by start time: parse first
        assert_eq!(events[0].get("name").and_then(JsonValue::as_str), Some("parse"));
        assert_eq!(events[1].get("name").and_then(JsonValue::as_str), Some("exec:osa"));
        assert_eq!(events[0].get("ph").and_then(JsonValue::as_str), Some("X"));
        let args = events[1].get("args").unwrap();
        assert_eq!(
            args.get("request_id").and_then(JsonValue::as_str),
            Some("req-0000000000000007")
        );
        assert_eq!(args.get("boundary").and_then(JsonValue::as_f64), Some(8.0));
    }

    #[test]
    fn prom_writer_round_trips_through_parser() {
        let mut w = PromWriter::new();
        w.counter("osa_requests_total", "Requests served.", &[], 42.0);
        let gold = [("tier", "gold".to_string())];
        w.counter("osa_tier_requests_total", "Per-tier requests.", &gold, 10.0);
        let silver = [("tier", "silver".to_string())];
        w.counter("osa_tier_requests_total", "Per-tier requests.", &silver, 30.0);
        w.gauge("osa_queue_depth", "Queue depth.", &[("tier", "gold".into())], 3.0);
        w.gauge("osa_watts", "Mean power.", &[], f64::NAN); // scrubbed to 0
        let h = Histogram::new();
        for v in [10u64, 20, 30, 5000] {
            h.record(v);
        }
        w.histogram("osa_request_latency_microseconds", "Latency.", &gold, &h.snapshot());
        let text = w.finish();
        let doc = parse_exposition(&text).expect("writer output must parse");
        assert_eq!(doc.value("osa_requests_total", &[]), Some(42.0));
        assert_eq!(doc.value("osa_tier_requests_total", &[("tier", "silver")]), Some(30.0));
        assert_eq!(doc.value("osa_watts", &[]), Some(0.0));
        assert_eq!(
            doc.types.get("osa_request_latency_microseconds").map(String::as_str),
            Some("histogram")
        );
        assert_eq!(
            doc.value("osa_request_latency_microseconds_count", &[("tier", "gold")]),
            Some(4.0)
        );
        // label escaping survives the round trip
        let mut w2 = PromWriter::new();
        w2.gauge("osa_g", "g", &[("k", "a\"b\\c\nd".into())], 1.0);
        let doc2 = parse_exposition(&w2.finish()).unwrap();
        assert_eq!(doc2.samples[0].labels[0].1, "a\"b\\c\nd");
    }

    #[test]
    fn parser_rejects_malformed_expositions() {
        // bad metric name
        assert!(parse_exposition("9bad_name 1\n").is_err());
        // unquoted label value
        assert!(parse_exposition("m{tier=gold} 1\n").is_err());
        // non-numeric value
        assert!(parse_exposition("m abc\n").is_err());
        // unknown TYPE
        assert!(parse_exposition("# TYPE m doughnut\nm 1\n").is_err());
        // duplicate TYPE
        assert!(parse_exposition("# TYPE m counter\nm 1\n# TYPE m counter\n").is_err());
        // non-contiguous family
        assert!(parse_exposition("# TYPE a counter\na 1\n# TYPE b counter\nb 1\na 2\n").is_err());
        // histogram with non-cumulative buckets
        let bad_hist = "# TYPE h histogram\n\
                        h_bucket{le=\"1\"} 5\nh_bucket{le=\"2\"} 3\nh_bucket{le=\"+Inf\"} 5\n\
                        h_sum 9\nh_count 5\n";
        assert!(parse_exposition(bad_hist).is_err());
        // histogram _count disagreeing with +Inf
        let bad_count = "# TYPE h histogram\n\
                         h_bucket{le=\"+Inf\"} 5\nh_sum 9\nh_count 7\n";
        assert!(parse_exposition(bad_count).is_err());
        // and a well-formed one passes
        let ok = "# HELP h help\n# TYPE h histogram\n\
                  h_bucket{le=\"1\"} 2\nh_bucket{le=\"+Inf\"} 4\nh_sum 10\nh_count 4\n";
        assert!(parse_exposition(ok).is_ok());
    }

    #[test]
    fn layer_attribution_accumulates() {
        let obs = ServerObs::new(64, 0, true);
        let mut movement = [0.0; NUM_LEVELS];
        movement[0] = 5.0e5;
        movement[4] = 1.0e5;
        let samples = vec![
            LayerSample {
                name: "conv1".into(),
                offset_us: 0,
                dur_us: 100,
                energy_fj: 2.0e6,
                movement_fj: movement,
                macro_ops: 50,
            },
            LayerSample {
                name: "fc".into(),
                offset_us: 100,
                dur_us: 20,
                energy_fj: 1.0e6,
                movement_fj: [0.0; NUM_LEVELS],
                macro_ops: 10,
            },
        ];
        obs.record_layers(&samples);
        obs.record_layers(&samples);
        let snap = obs.layer_snapshot();
        assert_eq!(snap.len(), 2);
        let conv = &snap.iter().find(|(n, _)| n == "conv1").unwrap().1;
        assert_eq!(conv.calls, 2);
        assert_eq!(conv.exec_us, 200);
        assert_eq!(conv.macro_ops, 100);
        assert!((conv.energy_j - 4.0e-9).abs() < 1e-15);
        assert!((conv.movement_j[0] - 1.0e-9).abs() < 1e-15);
        assert!((conv.movement_j[4] - 2.0e-10).abs() < 1e-15);
        assert_eq!(conv.movement_j[1], 0.0);
    }

    #[test]
    fn scrub_maps_non_finite_to_zero() {
        assert_eq!(scrub(f64::NAN), 0.0);
        assert_eq!(scrub(f64::INFINITY), 0.0);
        assert_eq!(scrub(f64::NEG_INFINITY), 0.0);
        assert_eq!(scrub(1.5), 1.5);
    }
}
